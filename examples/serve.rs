//! The full serving lifecycle: **train → export → persist → reload →
//! batched link prediction**.
//!
//! Training is the write path (engine, rank pool, MU iterations); this
//! example then crosses to the read path: the factors are exported as a
//! [`drescal::serve::FactorModel`] artifact, written to disk, reloaded
//! as a serving process would, and queried through a
//! [`drescal::serve::QueryEngine`] — batched top-k completion, pointwise
//! scores, and the LRU answer cache.
//!
//! ```text
//! cargo run --release --example serve
//! ```

use drescal::coordinator::JobData;
use drescal::engine::{Engine, EngineConfig, Report};
use drescal::rescal::RescalOptions;
use drescal::serve::{Answer, FactorModel, Query, QueryEngine};

fn main() {
    // ---- train (the write path) -------------------------------------
    // 48 entities in 3 planted communities, 2 relation slices
    let planted = drescal::data::synthetic::block_tensor(48, 2, 3, 0.01, 11);
    let mut engine = Engine::new(EngineConfig::default()).expect("engine");
    let data = engine
        .load_dataset(JobData::dense(planted.x.clone()))
        .expect("load dataset");
    let report = engine
        .factorize(data, &RescalOptions::new(3, 300), 42)
        .expect("factorize");
    println!(
        "trained: rel_error = {:.4} after {} iterations",
        report.rel_error, report.iters_run
    );

    // ---- export + persist -------------------------------------------
    let model = engine
        .export_model(&Report::Factorize(report))
        .expect("export model");
    let path = std::env::temp_dir().join("drescal_serve_example_model.json");
    model.save(&path).expect("save model");
    println!(
        "exported {}x{}x{} model (k={}) to {}",
        model.n(),
        model.n(),
        model.m(),
        model.k(),
        path.display()
    );
    drop(model);
    drop(engine); // the serving side needs no engine at all

    // ---- reload + serve (the read path) -----------------------------
    let model = FactorModel::load(&path).expect("load model");
    let mut qe = QueryEngine::new(model);

    // a micro-batch of concurrent (s, r, ?) completions: one GEMM
    let queries: Vec<Query> =
        (0..6).map(|s| Query::TopObjects { s, r: 0, top: 3 }).collect();
    let answers = qe.submit_batch(&queries).expect("batched query");
    for (q, a) in queries.iter().zip(&answers) {
        if let (Query::TopObjects { s, .. }, Answer::TopK(hits)) = (q, a) {
            let ranked: Vec<String> = hits
                .iter()
                .map(|h| format!("{} ({:.3})", h.entity, h.score))
                .collect();
            println!("(s={s}, r=0, ?) -> {}", ranked.join(", "));
        }
    }
    let stats = qe.stats();
    println!(
        "batch of {} served by {} GEMM batch(es), {} candidates scored",
        queries.len(),
        stats.batches,
        stats.scored_candidates
    );
    assert_eq!(stats.batches, 1, "one relation+direction group = one GEMM");

    // entities share a planted community in blocks of 16: the top
    // completion for subject 0 should come from its own block
    if let Answer::TopK(hits) = &answers[0] {
        assert!(hits[0].entity < 16, "top object {} not in subject 0's community", hits[0].entity);
    }

    // a pointwise score
    let score = qe.query(Query::Score { s: 0, r: 0, o: 1 }).expect("score");
    if let Answer::Score(v) = score {
        println!("score(0, 0, 1) = {v:.4}");
    }

    // ---- the cache: a repeat is free --------------------------------
    let before = qe.stats();
    let again = qe.query(queries[0]).expect("cached query");
    let after = qe.stats();
    assert_eq!(again, answers[0], "cached answer is identical");
    assert_eq!(after.cache_hits, before.cache_hits + 1);
    assert_eq!(
        after.scored_candidates, before.scored_candidates,
        "a cache hit scores zero additional candidates"
    );
    println!("repeat of the first query: cache hit, zero candidates scored");

    std::fs::remove_file(&path).ok();
}
