//! The storage plane end to end: synthesize a toy knowledge graph as a
//! triple list, ingest it into binary tile shards, train from the
//! manifest (each rank reading only its own shards), export a model
//! that carries the interned names, and answer link-prediction queries
//! by name.
//!
//! Run with: `cargo run --release --example ingest_serve`

use drescal::engine::{DatasetSpec, Engine, EngineConfig, Report};
use drescal::rescal::RescalOptions;
use drescal::serve::{Answer, Query, QueryEngine};
use drescal::store::{self, IngestOptions};

fn main() -> drescal::error::Result<()> {
    let dir = std::env::temp_dir().join(format!("drescal_ingest_demo_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    // 1. a toy knowledge graph: three communities of people who mostly
    //    "know" their own community and "admire" the next one
    let people: Vec<String> = (0..24).map(|i| format!("person{i:02}")).collect();
    let mut triples = String::new();
    for i in 0..24usize {
        for j in 0..24usize {
            if i == j {
                continue;
            }
            if i / 8 == j / 8 && (i + j) % 2 == 0 {
                triples.push_str(&format!("{}\tknows\t{}\n", people[i], people[j]));
            }
            if (i / 8 + 1) % 3 == j / 8 && (i * j) % 5 == 0 {
                triples.push_str(&format!("{}\tadmires\t{}\n", people[i], people[j]));
            }
        }
    }
    let input = dir.join("people.tsv");
    std::fs::write(&input, triples)?;

    // 2. ingest: stream the triples into 2×2 checksummed binary shards
    let corpus = dir.join("corpus");
    let report = store::ingest_triples_file(
        &input,
        &corpus,
        &IngestOptions { grid: 2, dense: false, source: "people.tsv".into() },
    )?;
    println!(
        "ingested {} triples -> {} entities, {} relations, {} shards",
        report.triples,
        report.n,
        report.m,
        report.grid * report.grid
    );

    // 3. train from the manifest: the 2×2 engine matches the ingest
    //    grid, so each rank reads exactly its own shard
    let mut engine = Engine::new(EngineConfig::new(4))?;
    let data = engine.load_dataset(DatasetSpec::from_manifest_path(&corpus)?)?;
    let trained = engine.factorize(data, &RescalOptions::new(3, 200), 42)?;
    println!(
        "trained k=3 factors: rel_error {:.4} in {} iterations",
        trained.rel_error, trained.iters_run
    );

    // 4. export with the interned names riding along, persist, reload
    let model = engine.export_model_for(&Report::Factorize(trained), data)?;
    let model_path = dir.join("people_model.json");
    model.save(&model_path)?;
    let model = drescal::serve::FactorModel::load(&model_path)?;
    println!(
        "exported + reloaded model: {} named entities, {} named relations",
        model.entity_names().map_or(0, |n| n.len()),
        model.relation_names().map_or(0, |n| n.len()),
    );

    // 5. serve by name: who does person03 know?
    let s = model.resolve_entity("person03")?;
    let r = model.resolve_relation("knows")?;
    let mut qe = QueryEngine::new(model);
    match qe.query(Query::TopObjects { s, r, top: 5 })? {
        Answer::TopK(hits) => {
            println!("top-5 'person03 knows ?' completions:");
            for hit in hits {
                let name = qe
                    .model()
                    .entity_names()
                    .and_then(|names| names.get(hit.entity).cloned())
                    .unwrap_or_else(|| hit.entity.to_string());
                println!("  {name}  (score {:.4})", hit.score);
            }
        }
        Answer::Score(_) => unreachable!("top-k query"),
    }

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
