//! Fig 13 reproduction: model determination at the paper's extreme scales,
//! replayed through the calibrated machine model (DESIGN.md §3) as
//! `Simulate` jobs, plus *real* scaled-down runs of the same code path —
//! all four jobs submitted to engines through the unified job API.
//!
//! * Fig 13a — 11.5 TB dense tensor (396800×396800×20) on 4096 cores:
//!   modeled sweep runtime; the real anchor run performs the same RESCALk
//!   sweep at 1/1550 scale and recovers k = 10.
//! * Fig 13b — 9.5 EB sparse tensor (373555200²×20) on 22801 cores across
//!   densities 1e-5..1e-9: modeled compute/communication breakdown (the
//!   paper's ">90% communication" claim), anchored by a real sparse run.
//!
//! Run: `cargo run --release --example exascale_sim`

use drescal::bench_util::{calibrate_dense_flops, fmt_secs, print_table};
use drescal::coordinator::metrics::RunMetrics;
use drescal::coordinator::JobData;
use drescal::data::synthetic;
use drescal::engine::{Engine, EngineConfig, SimScenario, SimSpec};
use drescal::model_selection::{InitStrategy, RescalkConfig, SelectionRule};
use drescal::rescal::RescalOptions;
use drescal::simulate::Machine;

fn main() {
    // ---- model anchor: measure this host's dense rate -------------------
    let flops = calibrate_dense_flops();
    println!("host dense GEMM rate: {:.1} GFLOP/s (model calibration input)", flops / 1e9);

    // one 2×2 engine serves the modeled replays AND the real anchor sweep
    let mut engine = Engine::new(EngineConfig::new(4)).expect("engine");
    let machine = Machine::cpu_cluster();

    // ---- Fig 13a: 11.5 TB dense, modeled --------------------------------
    let dense_report = engine
        .simulate(SimSpec { machine, scenario: SimScenario::Dense11Tb })
        .expect("simulate job");
    let dense = &dense_report.rows[0];
    println!(
        "\nFig 13a (modeled): {}\n  {:.1} TB logical on {} ranks -> compute {} + comm {} = {} total",
        dense.label,
        dense.logical_bytes() / 1e12,
        dense.p,
        fmt_secs(dense.compute_seconds),
        fmt_secs(dense.comm_seconds),
        fmt_secs(dense.total()),
    );
    println!("  paper: ≈3 h wall for the full sweep — modeled {}", fmt_secs(dense.total()));

    // ---- Fig 13a anchor: same pipeline, real, scaled down ---------------
    println!("\nFig 13a (real anchor): k sweep on a 256×256×4 tensor, k_true = 10");
    let planted = synthetic::block_tensor(256, 4, 10, 0.01, 131);
    let cfg = RescalkConfig {
        k_min: 8,
        k_max: 11,
        perturbations: 5,
        delta: 0.02,
        rescal_iters: 500,
        tol: 0.05,
        err_every: 25,
        regress_iters: 30,
        seed: 131,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        ..Default::default()
    };
    let report = engine
        .model_select(&JobData::dense(planted.x.clone()), &cfg)
        .expect("model-select job");
    for s in &report.scores {
        println!(
            "   k={:>2}  min-sil {:+.3}  rel-err {:.4}{}",
            s.k,
            s.sil_min,
            s.rel_error,
            if s.k == report.k_opt { "  <- k_opt" } else { "" }
        );
    }
    println!("  recovered k = {} (paper: k = 10, err 6%, min-sil 0.9)", report.k_opt);
    assert_eq!(report.k_opt, 10, "anchor run must recover k=10");

    // ---- Fig 13b: 9.5 EB sparse, modeled ---------------------------------
    let sparse_report = engine
        .simulate(SimSpec { machine, scenario: SimScenario::SparseExabyte })
        .expect("simulate job");
    let rows: Vec<Vec<String>> = sparse_report
        .rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0e}", r.density),
                fmt_secs(r.compute_seconds),
                fmt_secs(r.comm_seconds),
                fmt_secs(r.total()),
                format!("{:.1}%", 100.0 * r.comm_fraction()),
            ]
        })
        .collect();
    print_table(
        "Fig 13b (modeled): 9.5EB sparse, 22801 ranks, 100 MU iterations",
        &["density", "compute", "comm", "total", "comm%"],
        &rows,
    );
    println!("paper: >90% of execution in MPI communication, total flat across densities");

    // ---- Fig 13b anchor: real sparse run breakdown ----------------------
    // the 4×4 grid needs its own engine (grid size is fixed per engine);
    // the tensor is generated rank-locally — exactly the paper's layout,
    // where the global X never exists on any single node
    println!("\nFig 13b (real anchor): sparse 512×512×4 @ 1e-2 density, p=16");
    let mut wide = Engine::new(EngineConfig::new(16).with_trace(true)).expect("engine");
    let xs = wide
        .load_dataset(synthetic::SyntheticSpec::sparse(512, 4, 10, 1e-2, 132))
        .expect("load dataset");
    let report = wide
        .factorize(xs, &RescalOptions::new(10, 30), 132)
        .expect("factorize");
    let metrics = RunMetrics::from_traces(&report.traces);
    print!("{}", metrics.format_breakdown());
    println!(
        "  (in-process ranks share memory, so absolute comm% is far below a real\n   cluster's — the modeled rows above carry the cluster-scale claim)"
    );
    println!("\nexascale_sim OK");
}
