//! End-to-end driver (the DESIGN.md §6 flagship): the **full pyDRESCALk
//! pipeline on the full three-layer stack**, through the engine job API —
//! virtual-MPI grid (L3 Rust) executing AOT JAX+Pallas artifacts (L1/L2)
//! through PJRT, on a real workload:
//!
//! 1. generate a 256×256×4 block-community relational tensor (k_true = 5)
//! 2. build one [`Engine`] (rank pool + per-rank backends, spawned once)
//! 3. submit a `ModelSelect` job: perturbation resampling (Alg 4),
//!    distributed non-negative RESCAL per perturbation (Alg 3) — with
//!    `--features pjrt` every GEMM in the hot loop is a compiled HLO
//!    artifact — LSA clustering (Alg 5) + silhouettes (Alg 6) + core
//!    regression, automatic k selection
//! 4. read the unified report: scores, factors, per-op runtime breakdown
//!
//! Run: `make artifacts && cargo run --release --example end_to_end`
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use drescal::backend::BackendSpec;
use drescal::coordinator::metrics::RunMetrics;
use drescal::coordinator::JobData;
use drescal::data::synthetic;
use drescal::engine::{Engine, EngineConfig};
use drescal::linalg::pearson::best_match_correlation;
use drescal::model_selection::{InitStrategy, RescalkConfig, SelectionRule};

fn main() {
    let artifact_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let backend = if artifact_dir.join("manifest.json").exists() {
        println!("backend: XLA/PJRT artifacts from {}", artifact_dir.display());
        BackendSpec::Xla { artifact_dir: artifact_dir.to_string_lossy().into_owned() }
    } else {
        println!("backend: native (run `make artifacts` for the PJRT path)");
        BackendSpec::Native
    };

    // -- workload ---------------------------------------------------------
    let n = 256;
    let m = 4;
    let k_true = 5;
    let planted = synthetic::block_tensor(n, m, k_true, 0.01, 2024);
    println!("workload: {n}×{n}×{m} block-community tensor, k_true = {k_true}");

    // -- configure once ----------------------------------------------------
    let mut engine = Engine::new(
        EngineConfig::new(4).with_backend(backend).with_trace(true),
    )
    .expect("engine");

    // -- full model-selection pipeline ------------------------------------
    let cfg = RescalkConfig {
        k_min: 3,
        k_max: 7,
        perturbations: 5,
        delta: 0.02,
        rescal_iters: 600,
        tol: 0.02,
        err_every: 25,
        regress_iters: 30,
        seed: 7,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        ..Default::default()
    };
    println!(
        "sweep: k ∈ [{}, {}], r = {} perturbations, {} MU iters each\n",
        cfg.k_min, cfg.k_max, cfg.perturbations, cfg.rescal_iters
    );
    // register once: the 256×256×4 tensor is tiled to the ranks a single
    // time, however many perturbation runs the sweep performs
    let data = engine
        .load_dataset(JobData::dense(planted.x.clone()))
        .expect("load dataset");
    let report = engine.model_select(data, &cfg).expect("model-select job");

    // -- results -----------------------------------------------------------
    println!("   k   min-sil   avg-sil   rel-err");
    for s in &report.scores {
        let mark = if s.k == report.k_opt { "  <- k_opt" } else { "" };
        println!(
            "  {:>2}   {:>7.3}   {:>7.3}   {:>7.4}{mark}",
            s.k, s.sil_min, s.sil_avg, s.rel_error
        );
    }
    println!("\nselected k_opt = {} (truth {k_true})", report.k_opt);

    let corr = if report.k_opt == k_true {
        best_match_correlation(&planted.a_true, &report.a)
    } else {
        0.0
    };
    println!("feature recovery (best-match |Pearson r|): {corr:.3}");

    let metrics = RunMetrics::from_traces(&report.traces);
    println!("\nruntime breakdown (mean over {} ranks):", report.traces.len());
    print!("{}", metrics.format_breakdown());
    println!("wall time: {:.1}s", report.wall_seconds);

    assert_eq!(report.k_opt, k_true, "model selection must recover k_true");
    assert!(corr > 0.8, "feature recovery too weak: {corr}");
    println!("\nend_to_end OK");
}
