//! Quickstart for the engine API: build one [`Engine`], register a small
//! relational tensor once (each rank caches its tile), factorize it on
//! the 2×2 persistent rank grid, and recover the latent communities —
//! then reuse the same pool *and the same resident tiles* for a
//! refinement job.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use drescal::coordinator::JobData;
use drescal::engine::{Engine, EngineConfig};
use drescal::rescal::RescalOptions;

fn main() {
    // a 64-entity, 3-relation knowledge graph with 4 planted communities
    let planted = drescal::data::synthetic::block_tensor(64, 3, 4, 0.01, 7);
    println!(
        "tensor: {}×{}×{}  (k_true = {})",
        planted.x.n1(),
        planted.x.n2(),
        planted.x.m(),
        planted.k_true
    );

    // configure once: p = 4 ranks, native backend, tracing off
    let mut engine = Engine::new(EngineConfig::default()).expect("engine");
    // load once: the tensor is tiled to the ranks a single time; every
    // job below references the resident tiles through the handle
    let data = engine
        .load_dataset(JobData::dense(planted.x.clone()))
        .expect("load dataset");
    let opts = RescalOptions::new(4, 300).with_tol(0.02, 20);
    let report = engine.factorize(data, &opts, 42).expect("factorize");

    println!(
        "factorized in {:.2}s: rel_error = {:.4} after {} iterations",
        report.wall_seconds, report.rel_error, report.iters_run
    );

    // community of each entity = argmax over the columns of A
    let recovered: Vec<usize> = (0..64)
        .map(|i| {
            (0..4)
                .max_by(|&a, &b| report.a[(i, a)].partial_cmp(&report.a[(i, b)]).unwrap())
                .unwrap()
        })
        .collect();
    // entities 0..16 share a community, 16..32 another, ...
    let mut consistent = 0;
    for block in 0..4 {
        let slice = &recovered[block * 16..(block + 1) * 16];
        let first = slice[0];
        consistent += slice.iter().filter(|&&c| c == first).count();
    }
    println!("community assignment consistency: {consistent}/64 entities");
    assert!(report.rel_error < 0.1, "expected a good fit");

    // the pool and the resident tiles persist: a second, deeper job on
    // the same engine reuses every rank thread, backend, and tile
    let refined = engine
        .factorize(data, &RescalOptions::new(4, 600).with_tol(0.01, 20), 42)
        .expect("refine");
    println!(
        "refined on the same pool: rel_error = {:.4} ({} backend builds, {} tile builds total)",
        refined.rel_error,
        engine.stats().backend_builds,
        engine.stats().tile_builds
    );
    assert_eq!(engine.stats().backend_builds, 4, "pool must not rebuild backends");
    assert_eq!(engine.stats().tile_builds, 4, "jobs must not re-tile the dataset");
    println!("quickstart OK");
}
