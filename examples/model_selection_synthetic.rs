//! Fig 5 reproduction: latent feature identification on synthetic tensors,
//! driven through the engine job API.
//!
//! The paper's demonstration pair, scaled to laptop size (the generative
//! process — Gaussian latent features, Exp(1) core, ±1% uniform noise — is
//! identical to §6.2.1):
//!
//! * data 1: planted k = 7 (paper: 1024×1024×10) — Fig 5a + 5c
//! * data 2: planted k = 17 (paper: 2160×2160×20) — Fig 5b + 5d
//!
//! Both sweeps run as `ModelSelect` jobs on one persistent [`Engine`]
//! (rank pool spawned once). Prints the silhouette/error series the paper
//! plots, the selected k, and the feature-recovery Pearson correlations.
//!
//! Run: `cargo run --release --example model_selection_synthetic`

use drescal::coordinator::JobData;
use drescal::data::synthetic;
use drescal::engine::{Engine, EngineConfig};
use drescal::linalg::pearson::{best_match_correlation, pearson_matrix};
use drescal::model_selection::{InitStrategy, RescalkConfig, SelectionRule};
use drescal::tensor::Mat;

fn run_dataset(
    engine: &mut Engine,
    name: &str,
    n: usize,
    m: usize,
    k_true: usize,
    k_lo: usize,
    k_hi: usize,
    seed: u64,
) {
    println!("\n=== {name}: {n}×{n}×{m}, planted k = {k_true} ===");
    let planted = synthetic::block_tensor(n, m, k_true, 0.01, seed);
    let cfg = RescalkConfig {
        k_min: k_lo,
        k_max: k_hi,
        perturbations: 6,
        delta: 0.02,
        rescal_iters: 500,
        tol: 0.02,
        err_every: 25,
        regress_iters: 30,
        seed,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        ..Default::default()
    };
    let report = engine
        .model_select(&JobData::dense(planted.x.clone()), &cfg)
        .expect("model-select job");

    // Fig 5a/5b: silhouette + relative error vs k
    println!("   k   min-sil   avg-sil   rel-err");
    for s in &report.scores {
        let mark = if s.k == report.k_opt { "  <- k_opt" } else { "" };
        println!(
            "  {:>2}   {:>7.3}   {:>7.3}   {:>7.4}{mark}",
            s.k, s.sil_min, s.sil_avg, s.rel_error
        );
    }
    let hit = report.k_opt == k_true;
    println!(
        "selected k_opt = {} — {}",
        report.k_opt,
        if hit { "matches ground truth ✓" } else { "MISS" }
    );

    // Fig 5c/5d: feature recovery
    if hit {
        let score = best_match_correlation(&planted.a_true, &report.a);
        println!("best-match feature correlation: {score:.3}");
        print_correlation_matrix(&planted.a_true, &report.a);
    }
    assert!(hit, "{name}: model selection missed the planted k");
}

fn print_correlation_matrix(truth: &Mat, found: &Mat) {
    let corr = pearson_matrix(truth, found);
    println!("Pearson correlation matrix (rows: true features, cols: recovered):");
    for i in 0..corr.rows() {
        let row: Vec<String> =
            (0..corr.cols()).map(|j| format!("{:+.2}", corr[(i, j)])).collect();
        println!("  [{}]", row.join(" "));
    }
}

fn main() {
    // one engine, two sweep jobs: the rank pool and backends are reused
    let mut engine = Engine::new(EngineConfig::new(4)).expect("engine");
    // data 1 (paper Fig 5a/5c): k = 7
    run_dataset(&mut engine, "data 1", 140, 6, 7, 5, 9, 51);
    // data 2 (paper Fig 5b/5d): k = 17
    run_dataset(&mut engine, "data 2", 340, 6, 17, 15, 19, 52);
    let stats = engine.stats();
    println!(
        "\n{} jobs on one pool, {} backend builds total",
        stats.jobs_completed, stats.backend_builds
    );
    println!("\nmodel_selection_synthetic OK");
}
