//! Fig 6 reproduction: latent feature identification in the *Nations* and
//! *Trade* relational datasets (§6.2.2), as two `ModelSelect` jobs on one
//! persistent [`Engine`].
//!
//! * Nations (14×14×56 binary): k sweep 1..7 on a 2×2 grid → k_opt = 4,
//!   with the four geopolitical communities and the R-slice interaction
//!   graphs for selected relations (Fig 6a/6c/6e).
//! * Trade (23×23×420, zero-padded to 24): k sweep 1..7 → k_opt = 5, the
//!   five economic blocs, and the temporal R-slice evolution across months
//!   1/151/301/420 (Fig 6b/6d/6f).
//!
//! Run: `cargo run --release --example nations_trade`

use drescal::coordinator::{JobData, RescalkReport};
use drescal::data::{nations, trade};
use drescal::engine::{Engine, EngineConfig};
use drescal::model_selection::{InitStrategy, RescalkConfig, SelectionRule};
use drescal::tensor::Mat;

fn sweep(
    engine: &mut Engine,
    data: JobData,
    seed: u64,
    r: usize,
    iters: usize,
    init: InitStrategy,
    rule: SelectionRule,
) -> RescalkReport {
    let cfg = RescalkConfig {
        k_min: 1,
        k_max: 7,
        perturbations: r,
        delta: 0.02,
        rescal_iters: iters,
        tol: 0.015,
        err_every: 100,
        regress_iters: 40,
        seed,
        rule,
        init,
        ..Default::default()
    };
    engine.model_select(&data, &cfg).expect("model-select job")
}

fn print_scores(report: &RescalkReport) {
    println!("   k   min-sil   avg-sil   rel-err");
    for s in &report.scores {
        let mark = if s.k == report.k_opt { "  <- k_opt" } else { "" };
        println!(
            "  {:>2}   {:>7.3}   {:>7.3}   {:>7.4}{mark}",
            s.k, s.sil_min, s.sil_avg, s.rel_error
        );
    }
}

/// Report each entity's dominant latent community (argmax of its A row).
fn print_communities(a: &Mat, names: &[&str], k: usize) {
    let mut groups: Vec<Vec<&str>> = vec![Vec::new(); k];
    for (i, name) in names.iter().enumerate() {
        let c = (0..k).max_by(|&x, &y| a[(i, x)].partial_cmp(&a[(i, y)]).unwrap()).unwrap();
        groups[c].push(name);
    }
    for (c, members) in groups.iter().enumerate() {
        println!("  community-{}: {}", c + 1, members.join(", "));
    }
}

/// Print an R slice as weighted directed community-interaction edges
/// (the graphs of Fig 6e/6f).
fn print_interactions(r_slice: &Mat, label: &str) {
    let k = r_slice.rows();
    let max = r_slice.max_abs().max(1e-12);
    println!("  {label}:");
    let mut edges: Vec<(f32, usize, usize)> = Vec::new();
    for i in 0..k {
        for j in 0..k {
            let w = r_slice[(i, j)] / max;
            if w > 0.3 {
                edges.push((w, i, j));
            }
        }
    }
    edges.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    for (w, i, j) in edges.iter().take(6) {
        println!("    community-{} -> community-{}  weight {:.2}", i + 1, j + 1, w);
    }
}

fn main() {
    // one 2×2 engine carries both dataset sweeps
    let mut engine = Engine::new(EngineConfig::new(4)).expect("engine");

    // ---- Nations --------------------------------------------------------
    println!("=== Nations: 14×14×56 binary relational tensor ===");
    let nations_x = nations::nations_tensor(11);
    let report = sweep(
        &mut engine,
        JobData::dense(nations_x),
        11,
        8,
        400,
        InitStrategy::Random,
        SelectionRule::default(),
    );
    print_scores(&report);
    println!("\nlatent communities (k = {}):", report.k_opt);
    print_communities(&report.a, &nations::NATIONS, report.k_opt);
    println!("\ncommunity interactions for sample relations:");
    for (t, label) in [(5usize, "relation 5"), (20, "relation 20"), (40, "relation 40")] {
        print_interactions(report.r.slice(t), label);
    }
    let nations_k = report.k_opt;

    // ---- Trade ----------------------------------------------------------
    // The paper runs 10,000 MU iterations over all 420 months; we keep the
    // budget laptop-sized by sweeping on a 60-month temporal subsample
    // (every 7th month) with deep iteration, which preserves the bloc
    // structure and the growth trend.
    println!("\n=== Trade: 23×23×420 (padded to 24, 60-month subsample) ===");
    let trade_full = trade::trade_tensor_padded(13, 24);
    let sub: Vec<_> = (0..trade_full.m())
        .step_by(7)
        .map(|t| trade_full.slice(t).clone())
        .collect();
    let trade_x = drescal::tensor::Tensor3::from_slices(sub);
    // NNDSVD init (paper §3.4): random init stalls in a merged-community
    // local minimum on this dataset; the SVD-seeded start converges to the
    // five-bloc solution (see DESIGN.md §3)
    let factors = drescal::model_selection::nndsvd_factors(&trade_x, 1, 7);
    let report = sweep(
        &mut engine,
        JobData::dense(trade_x),
        13,
        6,
        2500,
        InitStrategy::Nndsvd { factors, jitter: 0.1 },
        // every k is stable under the SVD-seeded ensemble, so the error
        // elbow decides (paper: "good accuracy of the reconstruction")
        SelectionRule::StableElbow { threshold: 0.8, min_gain: 0.10 },
    );
    print_scores(&report);
    println!("\nlatent communities (k = {}):", report.k_opt);
    // drop the zero-padding row from the report
    let mut names: Vec<&str> = trade::COUNTRIES.to_vec();
    names.push("(padding)");
    print_communities(&report.a, &names, report.k_opt);
    println!("\ntemporal evolution of bloc interactions (Fig 6f months):");
    for (t, month) in [(0usize, 1usize), (21, 148), (43, 302), (59, 414)] {
        print_interactions(report.r.slice(t), &format!("month {month}"));
    }
    // total interaction strength must grow over time (paper: minimal at
    // month 1, maximum at month 420)
    let strength = |t: usize| report.r.slice(t).sum();
    println!(
        "\ntotal bloc-interaction strength: month1 {:.2} -> month414 {:.2}",
        strength(0),
        strength(59)
    );
    assert!(strength(59) > strength(0), "trade growth not captured");

    println!(
        "\nnations k_opt = {nations_k} (paper: 4), trade k_opt = {} (paper: 5)",
        report.k_opt
    );
    println!("nations_trade OK");
}
