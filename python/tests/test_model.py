"""L2 correctness: the JAX segments compose into a full sequential RESCAL
MU iteration whose error decreases on planted data — the strongest
end-to-end check possible without the Rust coordinator."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SMALL = st.integers(min_value=2, max_value=8)


def planted(rng, n, m, k):
    a = rng.uniform(0.1, 1.0, (n, k)).astype(np.float32)
    r = rng.exponential(1.0, (m, k, k)).astype(np.float32)
    x = np.stack([a @ r[t] @ a.T for t in range(m)])
    return jnp.asarray(x), jnp.asarray(a), jnp.asarray(r)


def full_iteration(x, a, r):
    """One sequential MU iteration composed *only* from L2 segments
    (single-rank grid: the partials are the full quantities)."""
    n, k = a.shape
    m = x.shape[0]
    ata = model.gram_partial(a)
    num_a = jnp.zeros_like(a)
    deno_a = jnp.zeros_like(a)
    new_r = []
    for t in range(m):
        xa = model.xa_partial(x[t], a)
        atxa = model.atxa_partial(a, xa)
        r_t = model.r_slice_update(r[t], ata, atxa)
        new_r.append(r_t)
        xart = model.xart_local(xa, r_t)
        ar = model.ar_local(a, r_t)
        xtar = model.xtar_partial(x[t], ar)
        num_a = num_a + xart + xtar
        deno_a = deno_a + model.deno_terms(a, ar, ata, r_t)
    a_new = a * num_a / (deno_a + ref.MU_EPS)
    return a_new, jnp.stack(new_r)


def rel_error(x, a, r):
    rec = jnp.stack([a @ r[t] @ a.T for t in range(x.shape[0])])
    return float(jnp.linalg.norm(x - rec) / jnp.linalg.norm(x))


class TestSegments:
    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(4, 24), k=SMALL, seed=st.integers(0, 2**16))
    def test_gram_and_partials_shapes(self, n, k, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.uniform(0.1, 1, (n, k)).astype(np.float32))
        xt = jnp.asarray(rng.uniform(0.1, 1, (n, n)).astype(np.float32))
        assert model.gram_partial(a).shape == (k, k)
        xa = model.xa_partial(xt, a)
        assert xa.shape == (n, k)
        assert model.atxa_partial(a, xa).shape == (k, k)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(4, 16), k=SMALL, seed=st.integers(0, 2**16))
    def test_deno_terms_match_reference(self, n, k, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.uniform(0.1, 1, (n, k)).astype(np.float32))
        r_t = jnp.asarray(rng.uniform(0.1, 1, (k, k)).astype(np.float32))
        ata = ref.gram(a)
        ar = ref.matmul(a, r_t)
        got = model.deno_terms(a, ar, ata, r_t)
        want = a @ (r_t.T @ ata @ r_t + r_t @ ata @ r_t.T)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


class TestFullIteration:
    def test_error_decreases_over_iterations(self):
        rng = np.random.default_rng(42)
        x, _a_true, _r_true = planted(rng, 16, 2, 3)
        a = jnp.asarray(rng.uniform(0.05, 1.0, (16, 3)).astype(np.float32))
        r = jnp.asarray(rng.uniform(0.05, 1.0, (2, 3, 3)).astype(np.float32))
        errs = [rel_error(x, a, r)]
        for _ in range(30):
            a, r = full_iteration(x, a, r)
            errs.append(rel_error(x, a, r))
        assert errs[-1] < 0.2, f"did not converge: {errs[-1]}"
        # monotone within tolerance (MU is monotone in exact arithmetic)
        for e0, e1 in zip(errs, errs[1:]):
            assert e1 <= e0 + 1e-3, f"error rose {e0} -> {e1}"

    def test_factors_stay_nonnegative(self):
        rng = np.random.default_rng(7)
        x, _, _ = planted(rng, 12, 2, 2)
        a = jnp.asarray(rng.uniform(0.05, 1.0, (12, 2)).astype(np.float32))
        r = jnp.asarray(rng.uniform(0.05, 1.0, (2, 2, 2)).astype(np.float32))
        for _ in range(10):
            a, r = full_iteration(x, a, r)
        assert (np.asarray(a) >= 0).all()
        assert (np.asarray(r) >= 0).all()

    def test_matches_pure_jnp_iteration(self):
        """The kernel-composed iteration equals the same math in plain jnp."""
        rng = np.random.default_rng(9)
        x, _, _ = planted(rng, 10, 2, 3)
        a0 = jnp.asarray(rng.uniform(0.05, 1.0, (10, 3)).astype(np.float32))
        r0 = jnp.asarray(rng.uniform(0.05, 1.0, (2, 3, 3)).astype(np.float32))
        a1, r1 = full_iteration(x, a0, r0)

        # plain jnp
        ata = a0.T @ a0
        num_a = jnp.zeros_like(a0)
        deno_a = jnp.zeros_like(a0)
        r_new = []
        for t in range(2):
            xa = x[t] @ a0
            atxa = a0.T @ xa
            deno_r = ata @ (r0[t] @ ata)
            r_t = r0[t] * atxa / (deno_r + ref.MU_EPS)
            r_new.append(r_t)
            num_a = num_a + xa @ r_t.T + x[t].T @ (a0 @ r_t)
            deno_a = deno_a + a0 @ (r_t.T @ ata @ r_t + r_t @ ata @ r_t.T)
        a_want = a0 * num_a / (deno_a + ref.MU_EPS)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a_want), rtol=1e-3, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(r1), np.asarray(jnp.stack(r_new)), rtol=1e-3, atol=1e-5
        )
