"""L1 correctness: every Pallas kernel vs the pure-jnp oracle, swept over
shapes with hypothesis. This is the core correctness signal for the compute
layer the Rust runtime executes."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, matmul, matmul_t, mu_update, r_update, t_matmul, ref

DIM = st.integers(min_value=1, max_value=40)
SMALL = st.integers(min_value=1, max_value=12)


def rand(rng, *shape):
    return jnp.asarray(rng.uniform(0.1, 1.0, shape).astype(np.float32))


def assert_close(got, want, rtol=1e-4, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = rand(rng, m, k), rand(rng, k, n)
        assert_close(matmul(x, y), ref.matmul(x, y))

    def test_block_boundary_shapes(self):
        rng = np.random.default_rng(0)
        # shapes straddling the 128 MXU tile
        for m in (127, 128, 129, 256):
            x, y = rand(rng, m, 7), rand(rng, 7, 5)
            assert_close(matmul(x, y), ref.matmul(x, y))

    def test_identity(self):
        eye = jnp.eye(6, dtype=jnp.float32)
        x = rand(np.random.default_rng(1), 6, 6)
        assert_close(matmul(x, eye), x)


class TestTMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, k=SMALL, n=SMALL, seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = rand(rng, m, k), rand(rng, m, n)
        assert_close(t_matmul(x, y), ref.t_matmul(x, y))

    def test_accumulation_across_row_blocks(self):
        # m > MXU tile forces the accumulating grid path
        rng = np.random.default_rng(2)
        x, y = rand(rng, 384, 4), rand(rng, 384, 6)
        assert_close(t_matmul(x, y), ref.t_matmul(x, y), rtol=1e-3)


class TestMatmulT:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, k=SMALL, n=SMALL, seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = rand(rng, m, k), rand(rng, n, k)
        assert_close(matmul_t(x, y), ref.matmul_t(x, y))


class TestGram:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, k=SMALL, seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, k, seed):
        rng = np.random.default_rng(seed)
        x = rand(rng, m, k)
        assert_close(gram(x), ref.gram(x))

    @settings(max_examples=10, deadline=None)
    @given(m=DIM, k=SMALL, seed=st.integers(0, 2**16))
    def test_symmetric_psd_diag(self, m, k, seed):
        rng = np.random.default_rng(seed)
        g = np.asarray(gram(rand(rng, m, k)))
        np.testing.assert_allclose(g, g.T, rtol=1e-5)
        assert (np.diag(g) >= 0).all()


class TestMuUpdate:
    @settings(max_examples=25, deadline=None)
    @given(m=DIM, n=SMALL, seed=st.integers(0, 2**16))
    def test_matches_ref(self, m, n, seed):
        rng = np.random.default_rng(seed)
        t, num, deno = rand(rng, m, n), rand(rng, m, n), rand(rng, m, n)
        assert_close(mu_update(t, num, deno), ref.mu_update(t, num, deno))

    def test_zero_denominator_guarded(self):
        t = jnp.ones((3, 3), jnp.float32)
        num = jnp.ones((3, 3), jnp.float32)
        deno = jnp.zeros((3, 3), jnp.float32)
        out = np.asarray(mu_update(t, num, deno))
        assert np.isfinite(out).all()

    @settings(max_examples=10, deadline=None)
    @given(m=SMALL, n=SMALL, seed=st.integers(0, 2**16))
    def test_preserves_nonnegativity(self, m, n, seed):
        rng = np.random.default_rng(seed)
        out = np.asarray(mu_update(rand(rng, m, n), rand(rng, m, n), rand(rng, m, n)))
        assert (out >= 0).all()


class TestRUpdate:
    @settings(max_examples=25, deadline=None)
    @given(k=st.integers(1, 16), seed=st.integers(0, 2**16))
    def test_matches_ref(self, k, seed):
        rng = np.random.default_rng(seed)
        r, ata, atxa = rand(rng, k, k), rand(rng, k, k), rand(rng, k, k)
        assert_close(r_update(r, ata, atxa), ref.r_update(r, ata, atxa), rtol=1e-3)

    def test_fixed_point_when_num_equals_deno(self):
        # if AᵀXA == AᵀA·R·AᵀA the update must be (numerically) a no-op
        rng = np.random.default_rng(3)
        k = 4
        r, ata = rand(rng, k, k), rand(rng, k, k)
        atxa = ref.matmul(ata, ref.matmul(r, ata))
        out = r_update(r, ata, atxa)
        assert_close(out, r, rtol=1e-4)


class TestDtype:
    @pytest.mark.parametrize("fn,nargs", [(matmul, 2), (gram, 1)])
    def test_outputs_f32(self, fn, nargs):
        rng = np.random.default_rng(4)
        args = [rand(rng, 8, 8) for _ in range(nargs)]
        assert fn(*args).dtype == jnp.float32
