"""AOT pipeline: artifacts are valid HLO text, the manifest is consistent,
and re-export is idempotent."""

import json
import os

from compile import aot


def test_export_and_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    written = aot.export(out, tiles=[16], ks=[2], verbose=False)
    assert written > 0
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["dtype"] == "f32"
    kinds = {op["kind"] for op in manifest["ops"]}
    assert {"matmul", "t_matmul", "matmul_t", "gram", "r_update"} <= kinds
    for op in manifest["ops"]:
        path = os.path.join(out, op["file"])
        assert os.path.exists(path), op["file"]
        text = open(path).read()
        assert text.startswith("HloModule"), f"{op['file']} is not HLO text"
        # rank-2 f32 inputs as promised to the Rust loader
        for shape in op["shapes"]:
            assert len(shape) == 2


def test_reexport_is_noop(tmp_path):
    out = str(tmp_path / "artifacts")
    first = aot.export(out, tiles=[16], ks=[2], verbose=False)
    assert first > 0
    second = aot.export(out, tiles=[16], ks=[2], verbose=False)
    assert second == 0, "unchanged inputs must not rewrite artifacts"


def test_force_rewrites(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.export(out, tiles=[16], ks=[2], verbose=False)
    assert aot.export(out, tiles=[16], ks=[2], force=True, verbose=False) > 0


def test_shape_change_invalidates(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.export(out, tiles=[16], ks=[2], verbose=False)
    assert aot.export(out, tiles=[16], ks=[2, 3], verbose=False) > 0


def test_parse_int_list():
    assert aot.parse_int_list("2..5") == [2, 3, 4, 5]
    assert aot.parse_int_list("32,128") == [32, 128]
    assert aot.parse_int_list("1,3..5") == [1, 3, 4, 5]


def test_dedup_across_tiles():
    # k×k ops are shared between tile configurations
    ops = aot.collect_ops([16, 32], [2])
    keys = [(k, tuple(map(tuple, s))) for k, _, s in ops]
    assert len(keys) == len(set(keys)), "duplicate artifacts"
    small = [op for op in ops if op[2] == [(2, 2), (2, 2)]]
    assert len(small) <= 2  # matmul + matmul_t once, not per tile
