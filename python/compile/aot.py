"""AOT export: lower the L2 segments to HLO text + manifest.

HLO **text** is the interchange format (not ``.serialize()``): jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out-dir ../artifacts [--tiles 32,128] [--ks 2..8]

Re-running is a no-op when inputs are unchanged (content hash check), so
``make artifacts`` stays cheap.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

#: default per-rank tile edges — matched to the examples:
#: quickstart (n=64, 2×2 grid → 32) and end_to_end (n=256, 2×2 grid → 128).
DEFAULT_TILES = (32, 128)
#: default latent ranks: the end_to_end sweep explores k ∈ 2..8.
DEFAULT_KS = tuple(range(2, 9))


def to_hlo_text(fn, shapes):
    """Lower ``fn`` at the given input shapes to XLA HLO text."""
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_tag(shapes):
    return "_".join("x".join(str(d) for d in s) for s in shapes)


def collect_ops(tiles, ks):
    """Deduplicated (kind, fn, shapes) set over all configurations."""
    seen = {}
    for tile in tiles:
        for k in ks:
            for kind, fn, shapes in model.backend_ops(tile, k):
                key = (kind, tuple(map(tuple, shapes)))
                seen.setdefault(key, (kind, fn, shapes))
    return list(seen.values())

def export(out_dir, tiles, ks, force=False, verbose=True):
    """Write one HLO artifact per (kind, shapes) plus manifest.json.
    Returns the number of artifacts written (0 if everything was fresh)."""
    os.makedirs(out_dir, exist_ok=True)
    ops = collect_ops(tiles, ks)
    # freshness: hash of the op list + source of model/kernels
    src_dir = os.path.dirname(os.path.abspath(__file__))
    hasher = hashlib.sha256()
    for name in ("model.py", os.path.join("kernels", "mu_kernels.py"),
                 os.path.join("kernels", "ref.py")):
        with open(os.path.join(src_dir, name), "rb") as f:
            hasher.update(f.read())
    hasher.update(repr(sorted((k, tuple(map(tuple, s))) for k, _, s in ops)).encode())
    stamp = hasher.hexdigest()
    stamp_path = os.path.join(out_dir, ".stamp")
    manifest_path = os.path.join(out_dir, "manifest.json")
    if not force and os.path.exists(stamp_path) and os.path.exists(manifest_path):
        with open(stamp_path) as f:
            if f.read().strip() == stamp:
                if verbose:
                    print(f"artifacts up to date ({len(ops)} ops) in {out_dir}")
                return 0

    entries = []
    written = 0
    for kind, fn, shapes in ops:
        fname = f"{kind}_{shape_tag(shapes)}.hlo.txt"
        text = to_hlo_text(fn, shapes)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({"kind": kind, "file": fname, "shapes": [list(s) for s in shapes]})
        written += 1
        if verbose:
            print(f"  wrote {fname} ({len(text)} chars)")
    manifest = {"dtype": "f32", "ops": entries}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    with open(stamp_path, "w") as f:
        f.write(stamp)
    if verbose:
        print(f"wrote {written} artifacts + manifest to {out_dir}")
    return written


def parse_int_list(text):
    out = []
    for part in text.split(","):
        part = part.strip()
        if ".." in part:
            lo, hi = part.split("..")
            out.extend(range(int(lo), int(hi) + 1))
        elif part:
            out.append(int(part))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--tiles", default=",".join(map(str, DEFAULT_TILES)))
    ap.add_argument("--ks", default="2..8")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    tiles = parse_int_list(args.tiles)
    ks = parse_int_list(args.ks)
    export(args.out_dir, tiles, ks, force=args.force)


if __name__ == "__main__":
    sys.exit(main())
