"""Pure-jnp reference oracles for the Pallas kernels.

Every L1 kernel in this package is checked against these definitions by
``python/tests`` (pytest + hypothesis). They are also the semantic
specification of the HLO artifacts the Rust runtime executes.
"""

import jax.numpy as jnp

#: ε used in multiplicative-update denominators (paper §2.2).
MU_EPS = 1e-16


def matmul(x, y):
    """``X · Y``."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def t_matmul(x, y):
    """``Xᵀ · Y`` (no transposed materialization in the kernel)."""
    return jnp.dot(x.T, y, preferred_element_type=jnp.float32)


def matmul_t(x, y):
    """``X · Yᵀ``."""
    return jnp.dot(x, y.T, preferred_element_type=jnp.float32)


def gram(x):
    """``XᵀX``."""
    return jnp.dot(x.T, x, preferred_element_type=jnp.float32)


def mu_update(target, num, deno, eps=MU_EPS):
    """Fused multiplicative update ``target ∘ num / (deno + eps)``."""
    return target * num / (deno + eps)


def r_update(r_t, ata, atxa, eps=MU_EPS):
    """One R-slice multiplicative update (paper Eq 2, first rule):
    ``R_t ∘ AᵀX_tA / (AᵀA · R_t · AᵀA + ε)``."""
    rata = matmul(r_t, ata)
    deno = matmul(ata, rata)
    return mu_update(r_t, atxa, deno, eps)
