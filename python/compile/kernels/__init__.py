"""L1: Pallas kernels for the RESCAL hot path + pure-jnp oracles."""

from . import mu_kernels, ref
from .mu_kernels import gram, matmul, matmul_t, mu_update, r_update, t_matmul

__all__ = [
    "gram",
    "matmul",
    "matmul_t",
    "mu_kernels",
    "mu_update",
    "r_update",
    "ref",
    "t_matmul",
]
