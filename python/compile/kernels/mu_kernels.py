"""L1 Pallas kernels for the RESCAL multiplicative-update hot path.

TPU-oriented design (DESIGN.md §Hardware-Adaptation): the paper's CuPy/
cuBLAS GEMMs become Pallas kernels tiled for the MXU — row-blocked GEMMs
with VMEM-resident accumulators, the K dimension kept whole per block (the
RESCAL inner dimensions are either the tile width or the small rank k, both
VMEM-friendly). ``interpret=True`` everywhere: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the kernels lower to plain HLO which both
pytest and the Rust runtime execute; on a real TPU the same BlockSpecs
drive the HBM↔VMEM schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

#: MXU-native tile edge; row blocks are capped at this.
MXU_TILE = 128


def _row_block(m: int) -> int:
    """Largest divisor of ``m`` not exceeding the MXU tile edge."""
    bm = min(MXU_TILE, m)
    while m % bm:
        bm -= 1
    return bm


# ---------------------------------------------------------------------------
# matmul: O = X · Y, grid over row blocks of X
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...], preferred_element_type=jnp.float32)


def matmul(x, y):
    """``X·Y`` with X row-blocked through VMEM, Y held resident."""
    m, kk = x.shape
    k2, n = y.shape
    assert kk == k2, f"inner dim mismatch {kk} vs {k2}"
    bm = _row_block(m)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, kk), lambda i: (i, 0)),
            pl.BlockSpec((kk, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


# ---------------------------------------------------------------------------
# t_matmul: O = Xᵀ · Y, accumulating over row blocks
# ---------------------------------------------------------------------------


def _t_matmul_kernel(x_ref, y_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...].T, y_ref[...], preferred_element_type=jnp.float32)


def t_matmul(x, y):
    """``Xᵀ·Y`` without materializing the transpose: each row block
    contributes a rank-``bm`` update into the VMEM-resident output."""
    m, kk = x.shape
    m2, n = y.shape
    assert m == m2, f"row dim mismatch {m} vs {m2}"
    bm = _row_block(m)
    return pl.pallas_call(
        _t_matmul_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, kk), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((kk, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((kk, n), jnp.float32),
        interpret=True,
    )(x, y)


# ---------------------------------------------------------------------------
# matmul_t: O = X · Yᵀ, grid over row blocks of X
# ---------------------------------------------------------------------------


def _matmul_t_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...].T, preferred_element_type=jnp.float32)


def matmul_t(x, y):
    """``X·Yᵀ`` (Y is small — a core slice — and stays VMEM-resident)."""
    m, kk = x.shape
    n, k2 = y.shape
    assert kk == k2, f"inner dim mismatch {kk} vs {k2}"
    bm = _row_block(m)
    return pl.pallas_call(
        _matmul_t_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, kk), lambda i: (i, 0)),
            pl.BlockSpec((n, kk), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


# ---------------------------------------------------------------------------
# gram: O = XᵀX, accumulating over row blocks
# ---------------------------------------------------------------------------


def _gram_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = x_ref[...]
    o_ref[...] += jnp.dot(blk.T, blk, preferred_element_type=jnp.float32)


def gram(x):
    """``XᵀX`` — the paper's ``gram_mul`` breakdown category."""
    m, kk = x.shape
    bm = _row_block(m)
    return pl.pallas_call(
        _gram_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, kk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((kk, kk), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((kk, kk), jnp.float32),
        interpret=True,
    )(x)


# ---------------------------------------------------------------------------
# mu_update: fused elementwise target * num / (deno + eps)
# ---------------------------------------------------------------------------


def _mu_kernel(eps, t_ref, n_ref, d_ref, o_ref):
    o_ref[...] = t_ref[...] * n_ref[...] / (d_ref[...] + eps)


def mu_update(target, num, deno, eps=ref.MU_EPS):
    """Fused MU elementwise step, row-blocked."""
    m, n = target.shape
    bm = _row_block(m)
    spec = pl.BlockSpec((bm, n), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_mu_kernel, float(eps)),
        grid=(m // bm,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(target, num, deno)


# ---------------------------------------------------------------------------
# r_update: fully fused R-slice MU step (k×k operands stay in VMEM)
# ---------------------------------------------------------------------------


def _r_update_kernel(eps, r_ref, ata_ref, atxa_ref, o_ref):
    r = r_ref[...]
    ata = ata_ref[...]
    rata = jnp.dot(r, ata, preferred_element_type=jnp.float32)
    deno = jnp.dot(ata, rata, preferred_element_type=jnp.float32)
    o_ref[...] = r * atxa_ref[...] / (deno + eps)


def r_update(r_t, ata, atxa, eps=ref.MU_EPS):
    """``R_t ∘ AᵀX_tA / (AᵀA·R_t·AᵀA + ε)`` in one kernel — two k×k GEMMs
    plus the elementwise update without leaving VMEM."""
    k = r_t.shape[0]
    assert r_t.shape == (k, k) and ata.shape == (k, k) and atxa.shape == (k, k)
    spec = pl.BlockSpec((k, k), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_r_update_kernel, float(eps)),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        interpret=True,
    )(r_t, ata, atxa)
