"""L2: the per-rank JAX compute segments of distributed RESCAL.

Algorithm 3 interleaves local GEMMs with MPI collectives, so the AOT
boundary is the maximal communication-free segment. Each function below is
one such segment, built from the L1 Pallas kernels, and is lowered by
``aot.py`` into one HLO artifact per static shape. The Rust coordinator
(`rust/src/rescal/distributed.rs`) executes them between its collectives.

Python never runs at serving time: these functions exist only to be traced.
"""

from .kernels import gram, matmul, matmul_t, r_update, t_matmul

# ---------------------------------------------------------------------------
# Segments of one MU iteration (in Algorithm 3 order)
# ---------------------------------------------------------------------------


def gram_partial(a_col):
    """Line 3 local part: ``A^(j)ᵀ A^(j)`` (row all_reduce follows)."""
    return gram(a_col)


def xa_partial(x_t, a_col):
    """Line 5 local part: ``X^(i,j)_t · A^(j)`` (row all_reduce follows)."""
    return matmul(x_t, a_col)


def atxa_partial(a_row, xa):
    """Line 6 local part: ``A^(i)ᵀ · (X_tA)`` (column all_reduce follows)."""
    return t_matmul(a_row, xa)


def r_slice_update(r_t, ata, atxa):
    """Lines 7-9, fully local (all inputs replicated): the fused R-slice
    multiplicative update from the L1 kernel."""
    return r_update(r_t, ata, atxa)


def xart_local(xa, r_t):
    """Line 10: ``(X_tA) · R_tᵀ``."""
    return matmul_t(xa, r_t)


def ar_local(a_row, r_t):
    """Line 11: ``A^(i) · R_t``."""
    return matmul(a_row, r_t)


def xtar_partial(x_t, ar):
    """Line 12 local part: ``X^(i,j)_tᵀ · (AR)`` (column all_reduce +
    diagonal row-broadcast follow)."""
    return t_matmul(x_t, ar)


def deno_terms(a_row, ar, ata, r_t):
    """Lines 15-19: the two denominator terms
    ``A R_tᵀ (AᵀA R_t)`` and ``(A R_t)(AᵀA R_tᵀ)``, summed."""
    atar = matmul(ata, r_t)
    art = matmul_t(a_row, r_t)
    artatar = matmul(art, atar)
    atart = matmul_t(ata, r_t)
    aratart = matmul(ar, atart)
    return artatar + aratart


def slice_segment(r_t, ata, atxa, xa, a_row):
    """The **fused local segment** of one slice update (lines 7-11 +
    15-19): everything between the AᵀXA column-reduce and the XᵀAR tile
    product, in one artifact — the §Perf optimization that collapses ~9
    PJRT calls per slice into one.

    Returns ``(r_new, xart, ar, deno)``.
    """
    r_new = r_slice_update(r_t, ata, atxa)
    xart = xart_local(xa, r_new)
    ar = ar_local(a_row, r_new)
    deno = deno_terms(a_row, ar, ata, r_new)
    return r_new, xart, ar, deno


# ---------------------------------------------------------------------------
# Ops exported to the Rust backend (kind -> (fn, shape builder))
# ---------------------------------------------------------------------------


def backend_ops(tile: int, k: int):
    """The (kind, fn, input_shapes) triples the Rust ``Backend`` trait
    dispatches on, for one (tile, k) static-shape configuration.

    ``tile`` is the per-rank square tile edge n/√p; ``k`` the latent rank.
    """
    t, kk = tile, k
    return [
        # gram of a factor block (gram_mul in the paper's breakdown)
        ("gram", gram, [(t, kk)]),
        # X_t·A and X_tᵀ·(AR): the tile-sized GEMMs
        ("matmul", matmul, [(t, t), (t, kk)]),
        ("t_matmul", t_matmul, [(t, t), (t, kk)]),
        # AᵀXA partial
        ("t_matmul", t_matmul, [(t, kk), (t, kk)]),
        # AR, XART and friends
        ("matmul", matmul, [(t, kk), (kk, kk)]),
        ("matmul_t", matmul_t, [(t, kk), (kk, kk)]),
        # small k×k algebra
        ("matmul", matmul, [(kk, kk), (kk, kk)]),
        ("matmul_t", matmul_t, [(kk, kk), (kk, kk)]),
        # fused R-slice update
        ("r_update", r_slice_update, [(kk, kk), (kk, kk), (kk, kk)]),
        # fused per-slice local segment (§Perf): r_t, ata, atxa, xa, a_row
        (
            "slice_segment",
            slice_segment,
            [(kk, kk), (kk, kk), (kk, kk), (t, kk), (t, kk)],
        ),
    ]
