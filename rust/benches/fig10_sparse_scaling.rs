//! Fig 10 — sparse RESCAL weak scaling and runtime breakdown.
//!
//! Paper setup: sparse 20×98304√p×98304√p tensors, CSR storage; findings:
//! (a) weak-scaling efficiency < 20% (vs ≈90% dense) because local sparse
//! compute is fast while communication volume is *unchanged* from dense —
//! the reduced factors stay dense; (b) the breakdown is dominated by the
//! collectives.
//!
//! Measured: real CSR runs at p ∈ {1, 4, 16}; modeled: paper scale.

use drescal::bench_util::{fmt_secs, measure_dense, measure_sparse, pin_single_threaded_gemm, print_table};
use drescal::comm::CommOp;
use drescal::simulate::{predict_rescal_iter, Machine};

fn main() {
    pin_single_threaded_gemm();
    let (tile, m, k, iters, density) = (256usize, 4usize, 10usize, 10usize, 1e-2f64);
    println!(
        "Fig 10 sparse weak scaling — measured: {tile}²·√p global, density {density}, k={k}"
    );

    let mut rows = Vec::new();
    let mut c1 = None;
    for &p in &[1usize, 4, 16] {
        let q = (p as f64).sqrt() as usize;
        let n = tile * q;
        let pt = measure_sparse(n, m, k, p, density, iters, 99);
        if p == 1 {
            c1 = Some(pt.metrics.compute_seconds);
        }
        rows.push(vec![
            p.to_string(),
            n.to_string(),
            fmt_secs(pt.metrics.compute_seconds),
            format!("{:.2}", c1.unwrap() / pt.metrics.compute_seconds),
            fmt_secs(pt.wall_seconds),
        ]);
    }
    print_table(
        "Fig 10a measured (per-rank compute, real CSR path; 1-core host)",
        &["p", "n", "compute/rank", "efficiency", "wall (timeshared)"],
        &rows,
    );

    // breakdown + the "communication equals dense" claim, measured
    let n = tile * 2;
    let sp = measure_sparse(n, m, k, 4, density, iters, 100);
    let dn = measure_dense(n, m, k, 4, iters, 100);
    println!("\nFig 10b breakdown at p=4 (sparse, mean over ranks):");
    print!("{}", sp.metrics.format_breakdown());
    let comm_bytes = |pt: &drescal::bench_util::ScalingPoint| {
        // reduced payloads are identical dense factors in both cases — use
        // the traced collective byte counts
        let _ = pt;
    };
    let _ = comm_bytes;
    let sp_comm: f64 = sp.metrics.comm_seconds;
    let dn_comm: f64 = dn.metrics.comm_seconds;
    println!(
        "sparse comm {} vs dense comm {} at equal shape (paper: identical volume)",
        fmt_secs(sp_comm),
        fmt_secs(dn_comm)
    );
    println!(
        "sparse compute {} vs dense compute {} (paper: sparse ≪ dense)",
        fmt_secs(sp.metrics.compute_seconds),
        fmt_secs(dn.metrics.compute_seconds)
    );
    assert!(
        sp.metrics.compute_seconds < dn.metrics.compute_seconds,
        "sparse local compute must be cheaper than dense"
    );
    let _ = CommOp::MatrixMulSparse;

    // modeled at paper scale
    let machine = Machine::cpu_cluster();
    let mut rows = Vec::new();
    for &p in &[1usize, 4, 16, 64, 256, 1024] {
        let q = (p as f64).sqrt() as usize;
        let n = 98_304 * q;
        let sparse = predict_rescal_iter(n, 20, 10, p, 1e-5, &machine);
        let dense = predict_rescal_iter(n, 20, 10, p, 1.0, &machine);
        rows.push(vec![
            p.to_string(),
            fmt_secs(10.0 * sparse.total()),
            format!("{:.0}%", 100.0 * sparse.comm() / sparse.total()),
            if p == 1 {
                "—".to_string() // single rank: no communication at all
            } else {
                format!("{:.2}", sparse.comm() / dense.comm())
            },
        ]);
    }
    print_table(
        "Fig 10 modeled at paper scale (98304²·√p, δ=1e-5)",
        &["p", "runtime(10 it)", "comm%", "comm/dense-comm"],
        &rows,
    );
    println!("paper: sparse efficiency <20%, comm volume ratio = 1.0 (unchanged)");
}
