//! Fig 7 — strong scaling of dense distributed RESCAL.
//!
//! Paper setup: 20×2¹⁴×2¹⁴ dense tensor, k = 10, 10 MU iterations, p ∈
//! {1 … 1024}; Fig 7a shows the per-op runtime breakdown, Fig 7b speedup
//! and GFLOPS (speedup peaks ≈590 at ~1000 cores).
//!
//! Here: the *measured* half runs the real system (native backend, one
//! GEMM thread per rank) on a scaled tensor at p ∈ {1, 4, 16, 64}. This
//! host has a single core, so rank threads timeshare and wall-clock
//! speedup is not observable; the measured claims are the **per-rank
//! compute time** (must fall ≈1/p — the paper's strong-scaling essence)
//! and the traced collective volumes. The *modeled* half replays the
//! paper's exact configuration through the α-β machine model
//! (DESIGN.md §3) and carries the wall-clock shape.

use drescal::bench_util::{fmt_secs, measure_dense, pin_single_threaded_gemm, print_table};
use drescal::coordinator::metrics::{gflops, rescal_flops_per_iter};
use drescal::simulate::{predict_rescal_iter, Machine};

fn main() {
    pin_single_threaded_gemm();
    let (n, m, k, iters) = (512usize, 4usize, 10usize, 10usize);
    println!("Fig 7 strong scaling — measured: {n}×{n}×{m}, k={k}, {iters} iters");

    let ps = [1usize, 4, 16, 64];
    let mut rows = Vec::new();
    let mut c1 = None;
    for &p in &ps {
        let pt = measure_dense(n, m, k, p, iters, 77);
        if p == 1 {
            c1 = Some(pt.metrics.compute_seconds);
        }
        // strong-scaling signal measurable on a 1-core host: per-rank
        // compute falls like 1/p
        let compute_speedup = c1.unwrap() / pt.metrics.compute_seconds;
        let flops = iters as f64 * rescal_flops_per_iter(n, m, k) / p as f64;
        rows.push(vec![
            p.to_string(),
            fmt_secs(pt.metrics.compute_seconds),
            format!("{:.1}", compute_speedup),
            format!("{:.2}", gflops(flops, pt.metrics.compute_seconds)),
            fmt_secs(pt.wall_seconds),
        ]);
    }
    print_table(
        "Fig 7a/7b measured (per-rank compute; 1-core host timeshares ranks)",
        &["p", "compute/rank", "compute speedup", "GFLOPS/rank", "wall (timeshared)"],
        &rows,
    );

    // per-op breakdown at p = 16 (Fig 7a's bars)
    let pt = measure_dense(n, m, k, 16, iters, 78);
    println!("\nper-op breakdown at p = 16 (mean over ranks):");
    print!("{}", pt.metrics.format_breakdown());

    // modeled at paper scale
    let machine = Machine::cpu_cluster();
    let (pn, pm, pk) = (1usize << 14, 20usize, 10usize);
    let mut rows = Vec::new();
    let t1 = predict_rescal_iter(pn, pm, pk, 1, 1.0, &machine).total();
    for &p in &[1usize, 4, 16, 64, 256, 1024] {
        let it = predict_rescal_iter(pn, pm, pk, p, 1.0, &machine);
        let speedup = t1 / it.total();
        rows.push(vec![
            p.to_string(),
            fmt_secs(iters as f64 * it.total()),
            format!("{:.0}", speedup),
            format!("{:.0}%", 100.0 * it.comm() / it.total()),
        ]);
    }
    print_table(
        "Fig 7b modeled at paper scale (20×16384×16384, k=10)",
        &["p", "runtime(10 it)", "speedup", "comm%"],
        &rows,
    );
    println!("paper: near-linear, speedup ≈590 at ~1000 cores");
}
