//! Fig 8 — weak scaling of dense distributed RESCAL on CPU.
//!
//! Paper setup: local tile fixed at 20×8192×8192 per rank, global size
//! 20×2¹³√p×2¹³√p, k = 10, 10 iterations; runtime ≈ flat O(log²p), speedup
//! ≈ linear (≈90% efficiency at 1024 cores).
//!
//! Measured here with a 192² local tile at p ∈ {1, 4, 16}, plus the
//! modeled paper-scale series and the §5.4 isoefficiency check.

use drescal::bench_util::{fmt_secs, measure_dense, pin_single_threaded_gemm, print_table};
use drescal::coordinator::metrics::{gflops, rescal_flops_per_iter};
use drescal::simulate::{predict_rescal_iter, Machine};

fn main() {
    pin_single_threaded_gemm();
    let (tile, m, k, iters) = (192usize, 4usize, 10usize, 10usize);
    println!("Fig 8 weak scaling — measured: {tile}²·√p global, m={m}, k={k}, {iters} iters");

    let mut rows = Vec::new();
    let mut c1 = None;
    for &p in &[1usize, 4, 16] {
        let q = (p as f64).sqrt() as usize;
        let n = tile * q;
        let pt = measure_dense(n, m, k, p, iters, 88);
        if p == 1 {
            c1 = Some(pt.metrics.compute_seconds);
        }
        // weak-scaling signal measurable on a 1-core host: per-rank
        // compute stays flat (efficiency = c1/cp ≈ 1)
        let eff = c1.unwrap() / pt.metrics.compute_seconds;
        let flops = iters as f64 * rescal_flops_per_iter(n, m, k) / p as f64;
        rows.push(vec![
            p.to_string(),
            n.to_string(),
            fmt_secs(pt.metrics.compute_seconds),
            format!("{:.2}", eff),
            format!("{:.2}", gflops(flops, pt.metrics.compute_seconds)),
        ]);
    }
    print_table(
        "Fig 8a/8b measured (per-rank compute; flat = perfect weak scaling)",
        &["p", "n", "compute/rank", "efficiency", "GFLOPS/rank"],
        &rows,
    );

    // modeled at paper scale
    let machine = Machine::cpu_cluster();
    let mut rows = Vec::new();
    let t1 = predict_rescal_iter(1 << 13, 20, 10, 1, 1.0, &machine).total();
    for &p in &[1usize, 4, 16, 64, 256, 1024] {
        let q = (p as f64).sqrt() as usize;
        let n = (1usize << 13) * q;
        let it = predict_rescal_iter(n, 20, 10, p, 1.0, &machine);
        rows.push(vec![
            p.to_string(),
            n.to_string(),
            fmt_secs(iters as f64 * it.total()),
            format!("{:.2}", t1 / it.total()),
            format!("{:.0}%", 100.0 * it.comm() / it.total()),
        ]);
    }
    print_table(
        "Fig 8 modeled at paper scale (8192² local tile, m=20, k=10)",
        &["p", "n", "runtime(10 it)", "efficiency", "comm%"],
        &rows,
    );
    println!("paper: runtime ≈ flat (O(log²p)), ≈90% efficiency at 1024 cores");

    // §5.4 isoefficiency: n = Θ(√p·log p) keeps efficiency constant
    let mut rows = Vec::new();
    for &p in &[4usize, 16, 64, 256, 1024] {
        let q = (p as f64).sqrt();
        let n = ((1 << 13) as f64 * q * (p as f64).log2() / 2.0) as usize;
        let it = predict_rescal_iter(n, 20, 10, p, 1.0, &machine);
        let eff = it.compute() / it.total();
        rows.push(vec![p.to_string(), n.to_string(), format!("{:.3}", eff)]);
    }
    print_table(
        "§5.4 isoefficiency check: n = Θ(√p·log p) ⇒ compute fraction ≈ constant",
        &["p", "n", "compute fraction"],
        &rows,
    );
}
