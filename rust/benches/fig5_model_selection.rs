//! Fig 5 — latent feature identification on synthetic data (bench form;
//! `examples/model_selection_synthetic.rs` is the full-size version).
//!
//! Prints the silhouette/error series for two planted tensors and checks
//! the paper's signature: silhouette ≈ 1 up to k_true, collapse beyond;
//! error floor reached at k_true; feature recovery by Pearson correlation.

use drescal::bench_util::{fmt_secs, pin_single_threaded_gemm, print_table};
use drescal::coordinator::JobData;
use drescal::data::synthetic;
use drescal::engine::Engine;
use drescal::linalg::pearson::best_match_correlation;
use drescal::model_selection::{InitStrategy, RescalkConfig, SelectionRule};

fn run_case(engine: &mut Engine, n: usize, m: usize, k_true: usize, seed: u64) {
    let planted = synthetic::block_tensor(n, m, k_true, 0.01, seed);
    let cfg = RescalkConfig {
        k_min: k_true - 2,
        k_max: k_true + 2,
        perturbations: 5,
        delta: 0.02,
        rescal_iters: 400,
        tol: 0.02,
        err_every: 25,
        regress_iters: 25,
        seed,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        ..Default::default()
    };
    let report = engine
        .model_select(&JobData::dense(planted.x.clone()), &cfg)
        .expect("model-select job");
    let rows: Vec<Vec<String>> = report
        .scores
        .iter()
        .map(|s| {
            vec![
                s.k.to_string(),
                format!("{:.3}", s.sil_min),
                format!("{:.3}", s.sil_avg),
                format!("{:.4}", s.rel_error),
                if s.k == report.k_opt { "<- k_opt".into() } else { String::new() },
            ]
        })
        .collect();
    print_table(
        &format!("Fig 5: {n}×{n}×{m}, planted k={k_true} (wall {})", fmt_secs(report.wall_seconds)),
        &["k", "min-sil", "avg-sil", "rel-err", ""],
        &rows,
    );
    assert_eq!(report.k_opt, k_true, "missed planted k");
    let corr = best_match_correlation(&planted.a_true, &report.a);
    println!("feature recovery |r| = {corr:.3} (paper: up to 0.98)");
    assert!(corr > 0.9);
}

fn main() {
    pin_single_threaded_gemm();
    // both sweeps share one persistent 2×2 engine (tracing off)
    let mut engine =
        Engine::new(drescal::engine::EngineConfig::new(4)).expect("engine");
    run_case(&mut engine, 96, 4, 7, 5001); // Fig 5a/5c analogue
    run_case(&mut engine, 128, 4, 9, 5002); // Fig 5b/5d analogue (scaled)
}
