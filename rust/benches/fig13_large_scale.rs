//! Fig 13 — model determination in exascale data (bench form of
//! `examples/exascale_sim.rs`; see that example for the full narrative).
//!
//! * Fig 13a: 11.5 TB dense RESCALk sweep on 4096 cores — modeled wall
//!   time vs the paper's ≈3 h, plus the real scaled-down anchor sweep.
//! * Fig 13b: 9.5 EB sparse runs across densities — modeled breakdown
//!   (paper: >90% communication, flat total).

use drescal::bench_util::{fmt_secs, pin_single_threaded_gemm, print_table};
use drescal::coordinator::JobData;
use drescal::data::synthetic;
use drescal::engine::{Engine, EngineConfig, SimScenario, SimSpec};
use drescal::model_selection::{InitStrategy, RescalkConfig, SelectionRule};
use drescal::simulate::Machine;

fn main() {
    pin_single_threaded_gemm();
    let machine = Machine::cpu_cluster();
    // one persistent engine runs the modeled replays and the real anchor
    let mut engine = Engine::new(EngineConfig::new(4)).expect("engine");

    // ---- Fig 13a modeled ----
    let dense_report = engine
        .simulate(SimSpec { machine, scenario: SimScenario::Dense11Tb })
        .expect("simulate");
    let dense = &dense_report.rows[0];
    println!(
        "Fig 13a modeled: {:.1} TB on {} ranks -> {} total ({:.0}% comm); paper ≈3 h",
        dense.logical_bytes() / 1e12,
        dense.p,
        fmt_secs(dense.total()),
        100.0 * dense.comm_fraction()
    );

    // ---- Fig 13a real anchor (trimmed): k recovery at 1/3100 scale ----
    let planted = synthetic::block_tensor(128, 4, 10, 0.01, 13);
    let cfg = RescalkConfig {
        k_min: 9,
        k_max: 11,
        perturbations: 4,
        delta: 0.02,
        rescal_iters: 400,
        tol: 0.05,
        err_every: 25,
        regress_iters: 25,
        seed: 13,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        ..Default::default()
    };
    let report = engine
        .model_select(&JobData::dense(planted.x), &cfg)
        .expect("model-select");
    println!(
        "Fig 13a anchor: recovered k = {} (truth 10) in {}",
        report.k_opt,
        fmt_secs(report.wall_seconds)
    );
    assert_eq!(report.k_opt, 10);

    // ---- Fig 13b modeled ----
    let sparse_report = engine
        .simulate(SimSpec { machine, scenario: SimScenario::SparseExabyte })
        .expect("simulate");
    let rows: Vec<Vec<String>> = sparse_report
        .rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0e}", r.density),
                fmt_secs(r.compute_seconds),
                fmt_secs(r.comm_seconds),
                fmt_secs(r.total()),
                format!("{:.1}%", 100.0 * r.comm_fraction()),
            ]
        })
        .collect();
    print_table(
        "Fig 13b modeled: 9.5EB sparse, 22801 ranks, 100 iters",
        &["density", "compute", "comm", "total", "comm%"],
        &rows,
    );
    println!("paper: >90% communication, total flat across densities");
}
