//! Fig 12 — strong and weak scaling of the distributed clustering and
//! silhouette algorithms (Algorithms 5 & 6).
//!
//! Paper findings: speedup tracks p only until communication overtakes the
//! (much smaller) compute — the factors A are tiny next to X and the 1D
//! layout needs global collectives — so the curves flatten much earlier
//! than RESCAL's (§6.4).
//!
//! Measured: real clustering + silhouette on planted factor stacks at
//! p ∈ {1, 4, 16}; modeled: paper-scale series from the §5.2 complexity.

use std::time::Instant;

use drescal::bench_util::{fmt_secs, pin_single_threaded_gemm, print_table};
use drescal::comm::grid::run_on_grid;
use drescal::comm::Trace;
use drescal::model_selection::{custom_cluster_rank, silhouette_rank};
use drescal::rng::Rng;
use drescal::simulate::{predict_clustering, Machine};
use drescal::tensor::Mat;

/// Build r noisy, column-permuted copies of a planted A (the input
/// Algorithm 5 sees), full height n.
fn planted_stack(n: usize, k: usize, r: usize, seed: u64) -> Vec<Mat> {
    let mut rng = Rng::new(seed);
    let truth = Mat::random_uniform(n, k, 0.1, 1.0, &mut rng);
    (0..r)
        .map(|_| {
            let perm = rng.permutation(k);
            let mut m = Mat::zeros(n, k);
            for c in 0..k {
                let mut col = truth.col(c);
                col.iter_mut().for_each(|v| *v *= 1.0 + 0.02 * (rng.uniform_f32() - 0.5));
                m.set_col(perm[c], &col);
            }
            m
        })
        .collect()
}

fn measure(n: usize, k: usize, r: usize, p: usize) -> (f64, f64) {
    let stack_full = planted_stack(n, k, r, 1234);
    let results = run_on_grid(p, |ctx| {
        let (s, e) = ctx.grid.chunk(n, ctx.row);
        let stack: Vec<Mat> = stack_full
            .iter()
            .map(|m| Mat::from_fn(e - s, k, |i, j| m[(s + i, j)]))
            .collect();
        let mut trace = Trace::new();
        let t0 = Instant::now();
        let out = custom_cluster_rank(&ctx.col_comm, &stack, 100, &mut trace);
        let cluster_secs = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let sil = silhouette_rank(&ctx.col_comm, &out.aligned, &mut trace);
        let sil_secs = t1.elapsed().as_secs_f64();
        assert!(sil.min > 0.9, "planted stack must cluster stably");
        (cluster_secs, sil_secs)
    });
    let p_f = results.len() as f64;
    let c: f64 = results.iter().map(|(c, _)| c).sum::<f64>() / p_f;
    let s: f64 = results.iter().map(|(_, s)| s).sum::<f64>() / p_f;
    (c, s)
}

fn main() {
    pin_single_threaded_gemm();
    let (k, r) = (10usize, 10usize);

    // ---- strong scaling: fixed factors, growing grid ----
    let n = 4096;
    println!("Fig 12a strong scaling — measured: A is {n}×{k}, r={r}");
    let mut rows = Vec::new();
    let mut t1 = None;
    for &p in &[1usize, 4, 16] {
        let (c, s) = measure(n, k, r, p);
        let total = c + s;
        if p == 1 {
            t1 = Some(total);
        }
        rows.push(vec![
            p.to_string(),
            fmt_secs(c),
            fmt_secs(s),
            format!("{:.2}", t1.unwrap() / total),
        ]);
    }
    print_table(
        "Fig 12a measured",
        &["p", "clustering", "silhouette", "speedup"],
        &rows,
    );

    // ---- weak scaling: factor height grows with √p ----
    println!("\nFig 12b weak scaling — measured: A is 2048·√p × {k}");
    let mut rows = Vec::new();
    let mut t1 = None;
    for &p in &[1usize, 4, 16] {
        let q = (p as f64).sqrt() as usize;
        let (c, s) = measure(2048 * q, k, r, p);
        let total = c + s;
        if p == 1 {
            t1 = Some(total);
        }
        rows.push(vec![
            p.to_string(),
            (2048 * q).to_string(),
            fmt_secs(total),
            format!("{:.2}", t1.unwrap() / total),
        ]);
    }
    print_table("Fig 12b measured", &["p", "n", "runtime", "efficiency"], &rows);

    // ---- modeled at paper scale ----
    let machine = Machine::cpu_cluster();
    let mut rows = Vec::new();
    let (c1, m1) = predict_clustering(1 << 13, 10, 10, 1, &machine, 20);
    for &p in &[1usize, 4, 16, 64, 256, 1024] {
        let (c, m) = predict_clustering(1 << 13, 10, 10, p, &machine, 20);
        let speedup = (c1 + m1) / (c + m);
        rows.push(vec![
            p.to_string(),
            fmt_secs(c + m),
            format!("{:.1}", speedup),
            format!("{:.0}%", 100.0 * m / (c + m)),
        ]);
    }
    print_table(
        "Fig 12a modeled at paper scale (A = 8192×10 per √p block)",
        &["p", "runtime", "speedup", "comm%"],
        &rows,
    );
    println!("paper: speedup flattens early — comm overtakes the small compute");
}
