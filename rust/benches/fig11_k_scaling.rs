//! Fig 11 — scaling with the latent dimension k.
//!
//! Paper setup: fixed 20×2¹⁸×2¹⁸ tensor on 1024 cores, k ∈ {2 … 256};
//! runtime follows the O(k²) complexity trend; the GPU version is faster
//! but increasingly communication-bound at large k.
//!
//! Measured: real runs on a fixed tensor at p = 4 sweeping k; modeled:
//! the paper-scale CPU and GPU series.

use drescal::bench_util::{fmt_secs, measure_dense, pin_single_threaded_gemm, print_table};
use drescal::simulate::{predict_rescal_iter, Machine};

fn main() {
    pin_single_threaded_gemm();
    let (n, m, iters, p) = (384usize, 4usize, 10usize, 4usize);
    println!("Fig 11 k-scaling — measured: {n}×{n}×{m} fixed, p={p}, {iters} iters");

    let mut rows = Vec::new();
    let mut base: Option<f64> = None;
    for &k in &[2usize, 4, 8, 16, 32] {
        let pt = measure_dense(n, m, k, p, iters, 111);
        if base.is_none() {
            base = Some(pt.wall_seconds);
        }
        rows.push(vec![
            k.to_string(),
            fmt_secs(pt.wall_seconds),
            format!("{:.1}×", pt.wall_seconds / base.unwrap()),
            format!("{:.0}%", 100.0 * pt.metrics.comm_fraction()),
        ]);
    }
    print_table(
        "Fig 11a measured (real system)",
        &["k", "runtime", "vs k=2", "comm%"],
        &rows,
    );

    // modeled at paper scale, CPU and GPU
    let cpu = Machine::cpu_cluster();
    let gpu = Machine::gpu_cluster();
    let n_paper = 1usize << 18;
    let mut rows = Vec::new();
    for &k in &[2usize, 4, 8, 16, 32, 64, 128, 256] {
        let c = predict_rescal_iter(n_paper, 20, k, 1024, 1.0, &cpu);
        let g = predict_rescal_iter(n_paper, 20, k, 1024, 1.0, &gpu);
        rows.push(vec![
            k.to_string(),
            fmt_secs(10.0 * c.total()),
            fmt_secs(10.0 * g.total()),
            format!("{:.0}%", 100.0 * g.comm() / g.total()),
        ]);
    }
    print_table(
        "Fig 11 modeled at paper scale (2¹⁸ entities, 1024 ranks)",
        &["k", "cpu runtime", "gpu runtime", "gpu comm%"],
        &rows,
    );
    println!("paper: ≈O(k²) trend on CPU; GPU faster but comm-bound at large k");

    // sanity: O(k²)-ish growth in the modeled CPU series
    let t8 = predict_rescal_iter(n_paper, 20, 8, 1024, 1.0, &cpu).total();
    let t32 = predict_rescal_iter(n_paper, 20, 32, 1024, 1.0, &cpu).total();
    let growth = t32 / t8;
    assert!(growth > 3.0, "k-scaling too flat: {growth}");
}
