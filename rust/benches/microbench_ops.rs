//! Microbenchmarks of the primitive operations: the data behind the §Perf
//! iteration log (EXPERIMENTS.md) and the calibration inputs of the
//! cluster replay model.
//!
//! Covers: native GEMM variants, CSR SpMM, the collectives, the PJRT
//! artifact path (per-call overhead + fused-segment gain), and LSA.

use drescal::backend::{native::NativeBackend, xla::XlaBackend, Backend};
use drescal::bench_util::{fmt_secs, print_table, time_fn};
use drescal::comm::grid::run_on_grid;
use drescal::linalg::lsa::lsa_max;
use drescal::rng::Rng;
use drescal::tensor::{Csr, Mat};

fn main() {
    let mut rng = Rng::new(1);

    // ---- dense GEMM family ----
    let mut rows = Vec::new();
    for &(m, k, n) in &[(128usize, 128usize, 8usize), (512, 512, 10), (1024, 1024, 16)] {
        let a = Mat::random_uniform(m, k, 0.0, 1.0, &mut rng);
        let b = Mat::random_uniform(k, n, 0.0, 1.0, &mut rng);
        let st = time_fn(2, 7, || {
            std::hint::black_box(a.matmul(&b));
        });
        let gf = 2.0 * (m * k * n) as f64 / st.median / 1e9;
        rows.push(vec![
            format!("{m}×{k}·{k}×{n}"),
            fmt_secs(st.median),
            format!("{gf:.2}"),
        ]);
    }
    let a = Mat::random_uniform(1024, 16, 0.0, 1.0, &mut rng);
    let st = time_fn(2, 7, || {
        std::hint::black_box(a.gram());
    });
    rows.push(vec!["gram 1024×16".into(), fmt_secs(st.median), String::new()]);
    print_table("native GEMM", &["shape", "median", "GFLOP/s"], &rows);

    // ---- sparse SpMM ----
    let mut rows = Vec::new();
    for &density in &[1e-1f64, 1e-2, 1e-3] {
        let s = Csr::random(2048, 2048, density, &mut rng);
        let b = Mat::random_uniform(2048, 10, 0.0, 1.0, &mut rng);
        let st = time_fn(1, 5, || {
            std::hint::black_box(s.matmul_dense(&b));
        });
        let gf = 2.0 * (s.nnz() * 10) as f64 / st.median / 1e9;
        rows.push(vec![format!("{density:.0e}"), s.nnz().to_string(), fmt_secs(st.median), format!("{gf:.2}")]);
    }
    print_table("CSR SpMM 2048²·(2048×10)", &["density", "nnz", "median", "GFLOP/s"], &rows);

    // ---- collectives (measured α/β of the virtual MPI) ----
    let mut rows = Vec::new();
    for &(p, len) in &[(4usize, 1024usize), (4, 1 << 18), (16, 1024), (16, 1 << 18)] {
        let st = time_fn(1, 5, || {
            let results = run_on_grid(p, |ctx| {
                let mut v = vec![ctx.rank as f32; len];
                for _ in 0..10 {
                    ctx.world.all_reduce_sum(&mut v).unwrap();
                }
                v[0]
            });
            std::hint::black_box(results);
        });
        rows.push(vec![
            p.to_string(),
            format!("{} KiB", len * 4 / 1024),
            fmt_secs(st.median / 10.0),
        ]);
    }
    print_table("virtual-MPI all_reduce (10 rounds amortized)", &["p", "payload", "per call"], &rows);

    // ---- PJRT path: per-call overhead and fused-segment gain ----
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let dir = dir.to_string_lossy().into_owned();
        let mut xla = XlaBackend::new(&dir).expect("backend");
        let mut native = NativeBackend::new();
        let t = 128usize;
        let k = 8usize;
        let x = Mat::random_uniform(t, t, 0.0, 1.0, &mut rng);
        let a = Mat::random_uniform(t, k, 0.0, 1.0, &mut rng);
        let rt = Mat::random_uniform(k, k, 0.1, 1.0, &mut rng);
        let ata = Mat::random_uniform(k, k, 0.1, 1.0, &mut rng);
        let atxa = Mat::random_uniform(k, k, 0.1, 1.0, &mut rng);
        let mut rows = Vec::new();
        let st = time_fn(3, 15, || {
            std::hint::black_box(xla.matmul(&x, &a));
        });
        rows.push(vec!["pjrt matmul 128²·128×8".into(), fmt_secs(st.median)]);
        let st = time_fn(3, 15, || {
            std::hint::black_box(native.matmul(&x, &a));
        });
        rows.push(vec!["native matmul (same)".into(), fmt_secs(st.median)]);
        let st = time_fn(3, 15, || {
            std::hint::black_box(xla.slice_segment(&rt, &ata, &atxa, &a, &a)).unwrap();
        });
        rows.push(vec!["pjrt fused slice_segment".into(), fmt_secs(st.median)]);
        // the same 9 ops through individual artifact calls
        let st = time_fn(3, 15, || {
            let r2 = xla.r_update_fused(&rt, &ata, &atxa).unwrap();
            let _ = std::hint::black_box(xla.matmul_t(&a, &r2));
            let ar = xla.matmul(&a, &r2);
            let atar = xla.matmul(&ata, &r2);
            let art = xla.matmul_t(&a, &r2);
            let _ = std::hint::black_box(xla.matmul(&art, &atar));
            let atart = xla.matmul_t(&ata, &r2);
            let _ = std::hint::black_box(xla.matmul(&ar, &atart));
        });
        rows.push(vec!["pjrt unfused (7 calls)".into(), fmt_secs(st.median)]);
        print_table("PJRT artifact path (§Perf)", &["op", "median"], &rows);
        println!("fused/unfused hits: {} calls served by artifacts", xla.hits);
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT microbench)");
    }

    // ---- LSA ----
    let mut rows = Vec::new();
    for &k in &[8usize, 32, 64] {
        let sim = Mat::random_uniform(k, k, 0.0, 1.0, &mut rng);
        let st = time_fn(2, 9, || {
            std::hint::black_box(lsa_max(&sim));
        });
        rows.push(vec![k.to_string(), fmt_secs(st.median)]);
    }
    print_table("linear sum assignment (O(k³))", &["k", "median"], &rows);
}
