//! Fig 9 — weak scaling of dense RESCAL on the GPU cluster.
//!
//! Paper setup (Kodiak, P100s, CUDA-aware MPI): same weak-scaling sweep as
//! Fig 8 but on GPUs, p ∈ {1, 4, 9, 16, 25, 64, 81}; findings: ≥10×
//! faster than CPU at equal rank counts, communication becomes the
//! bottleneck, and 81 GPUs match the GFLOPS of ~1000 CPU cores.
//!
//! The GPU is modeled (DESIGN.md §3): `Machine::gpu_cluster()` carries the
//! measured-class P100 rate and the CUDA-aware-MPI staging penalty. The
//! bench prints the CPU and GPU series side by side so every paper claim
//! is checkable.

use drescal::bench_util::{fmt_secs, print_table};
use drescal::simulate::{predict_rescal_iter, Machine};

fn main() {
    let cpu = Machine::cpu_cluster();
    let gpu = Machine::gpu_cluster();
    let (tile, m, k, iters) = (1usize << 13, 20usize, 10usize, 10usize);
    println!("Fig 9 weak scaling GPU vs CPU — {tile}² local tile, m={m}, k={k}");

    let mut rows = Vec::new();
    for &p in &[1usize, 4, 9, 16, 25, 64, 81] {
        let q = (p as f64).sqrt().ceil() as usize;
        let n = tile * q;
        let c = predict_rescal_iter(n, m, k, p, 1.0, &cpu);
        let g = predict_rescal_iter(n, m, k, p, 1.0, &gpu);
        rows.push(vec![
            p.to_string(),
            fmt_secs(iters as f64 * c.total()),
            format!("{:.0}%", 100.0 * c.comm() / c.total()),
            fmt_secs(iters as f64 * g.total()),
            format!("{:.0}%", 100.0 * g.comm() / g.total()),
            format!("{:.1}×", c.total() / g.total()),
        ]);
    }
    print_table(
        "Fig 9a modeled: CPU vs GPU weak scaling",
        &["p", "cpu runtime", "cpu comm%", "gpu runtime", "gpu comm%", "gpu advantage"],
        &rows,
    );

    // paper claim: 81 GPUs reach the GFLOPS of ~1000 CPU cores
    let flop = |n: usize, p: usize, mach: &Machine| {
        let it = predict_rescal_iter(n, m, k, p, 1.0, mach);
        let f = flops(n, m, k, p);
        f / it.total() / 1e9
    };
    let gpu81 = flop(tile * 9, 81, &gpu);
    let cpu1024 = flop(tile * 32, 1024, &cpu);
    println!(
        "\nFig 9b: aggregate GFLOPS — 81 GPUs {gpu81:.0} vs 1024 CPU cores {cpu1024:.0} \
         (paper: comparable)"
    );
    let ratio = gpu81 / cpu1024;
    assert!(
        (0.2..5.0).contains(&ratio),
        "GPU/CPU aggregate throughput ratio out of band: {ratio}"
    );
}

/// Total FLOPs of one full (all-ranks) MU iteration.
fn flops(n: usize, m: usize, k: usize, _p: usize) -> f64 {
    drescal::coordinator::metrics::rescal_flops_per_iter(n, m, k)
}
