//! Fig 6 — latent feature identification on the Nations and Trade
//! datasets (bench form; `examples/nations_trade.rs` prints the full
//! community and interaction analysis).
//!
//! Checks: Nations → k_opt = 4; Trade (subsampled) → k_opt = 5 under the
//! NNDSVD-seeded ensemble with the stable-elbow rule.

use drescal::bench_util::{fmt_secs, print_table};
use drescal::coordinator::JobData;
use drescal::data::{nations, trade};
use drescal::engine::{Engine, EngineConfig};
use drescal::model_selection::{nndsvd_factors, InitStrategy, RescalkConfig, SelectionRule};
use drescal::tensor::Tensor3;

fn print_scores(title: &str, report: &drescal::coordinator::RescalkReport) {
    let rows: Vec<Vec<String>> = report
        .scores
        .iter()
        .map(|s| {
            vec![
                s.k.to_string(),
                format!("{:.3}", s.sil_min),
                format!("{:.4}", s.rel_error),
                if s.k == report.k_opt { "<- k_opt".into() } else { String::new() },
            ]
        })
        .collect();
    print_table(title, &["k", "min-sil", "rel-err", ""], &rows);
}

fn main() {
    drescal::bench_util::pin_single_threaded_gemm();
    // one persistent 2×2 engine carries both dataset sweeps
    let mut engine = Engine::new(EngineConfig::new(4)).expect("engine");

    // ---- Nations ----
    let x = nations::nations_tensor(11);
    let cfg = RescalkConfig {
        k_min: 1,
        k_max: 6,
        perturbations: 6,
        delta: 0.02,
        rescal_iters: 400,
        tol: 0.0,
        err_every: 0,
        regress_iters: 30,
        seed: 11,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        ..Default::default()
    };
    let report = engine.model_select(&JobData::dense(x), &cfg).expect("model-select");
    print_scores(
        &format!("Fig 6a Nations 14×14×56 (wall {})", fmt_secs(report.wall_seconds)),
        &report,
    );
    assert_eq!(report.k_opt, 4, "Nations must recover k=4");

    // ---- Trade (temporal subsample, NNDSVD ensemble, elbow rule) ----
    let full = trade::trade_tensor_padded(13, 24);
    let sub: Vec<_> = (0..full.m()).step_by(14).map(|t| full.slice(t).clone()).collect();
    let x = Tensor3::from_slices(sub);
    let factors = nndsvd_factors(&x, 1, 6);
    let cfg = RescalkConfig {
        k_min: 1,
        k_max: 6,
        perturbations: 5,
        delta: 0.02,
        rescal_iters: 2000,
        tol: 0.015,
        err_every: 100,
        regress_iters: 30,
        seed: 13,
        rule: SelectionRule::StableElbow { threshold: 0.8, min_gain: 0.10 },
        init: InitStrategy::Nndsvd { factors, jitter: 0.1 },
        ..Default::default()
    };
    let report = engine.model_select(&JobData::dense(x), &cfg).expect("model-select");
    print_scores(
        &format!("Fig 6b Trade 24×24×30 subsample (wall {})", fmt_secs(report.wall_seconds)),
        &report,
    );
    assert_eq!(report.k_opt, 5, "Trade must recover k=5");
}
