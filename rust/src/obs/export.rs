//! Post-mortem telemetry artifacts: Chrome trace-event export and the
//! §6.3-style per-op summary table.
//!
//! [`chrome_trace_json`] turns gathered [`RankTimeline`]s into Chrome
//! trace-event JSON loadable in Perfetto or `chrome://tracing` — one
//! process row per OS pid, one thread row per rank, with every track
//! shifted onto a common wall-clock axis via the timelines' epoch
//! anchors so multi-process (and multi-host) runs line up instead of
//! all starting at t=0. [`summarize_chrome_trace`] parses such a file
//! back into the per-op table that `drescal trace-summary` prints via
//! [`format_summary`].

use std::collections::BTreeMap;

use super::{RankTimeline, NO_ITER};
use crate::error::{Error, Result};
use crate::json::Json;

fn jnum(n: f64) -> Json {
    Json::Num(n)
}

fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Export timelines as Chrome trace-event JSON (`ph:"X"` complete
/// events), loadable in Perfetto or `chrome://tracing`. Track layout:
/// one process row per OS pid, one thread row per rank. Each track's
/// timestamps are shifted by its wall-clock epoch anchor relative to the
/// earliest anchored track, so cross-process tracks align; tracks
/// without an anchor (`epoch_ms == 0`, pre-anchor artifacts) keep their
/// raw recorder timestamps. Ring-overflow drop counts ride the
/// `thread_name` metadata so [`chrome_trace_dropped`] can recover them.
pub fn chrome_trace_json(timelines: &[RankTimeline]) -> Json {
    let base_ms = timelines
        .iter()
        .filter(|t| t.epoch_ms > 0)
        .map(|t| t.epoch_ms)
        .min()
        .unwrap_or(0);
    let mut events = Vec::new();
    let mut pids_seen = std::collections::BTreeSet::new();
    for t in timelines {
        // wall-clock skew of this track vs the earliest one, in µs
        let shift_us = if t.epoch_ms > 0 { (t.epoch_ms - base_ms) as f64 * 1000.0 } else { 0.0 };
        if pids_seen.insert(t.pid) {
            events.push(obj(vec![
                ("ph", jstr("M")),
                ("name", jstr("process_name")),
                ("pid", jnum(t.pid as f64)),
                ("tid", jnum(0.0)),
                ("args", obj(vec![("name", jstr(&format!("drescal pid {}", t.pid)))])),
            ]));
        }
        events.push(obj(vec![
            ("ph", jstr("M")),
            ("name", jstr("thread_name")),
            ("pid", jnum(t.pid as f64)),
            ("tid", jnum(t.rank as f64)),
            (
                "args",
                obj(vec![
                    ("name", jstr(&format!("rank {}", t.rank))),
                    ("dropped", jnum(t.dropped as f64)),
                ]),
            ),
        ]));
        for s in &t.spans {
            let mut args = vec![("bytes", jnum(s.bytes as f64))];
            if s.iter != NO_ITER {
                args.push(("iter", jnum(s.iter as f64)));
            }
            events.push(obj(vec![
                ("ph", jstr("X")),
                ("pid", jnum(t.pid as f64)),
                ("tid", jnum(t.rank as f64)),
                ("ts", jnum(s.start_ns as f64 / 1000.0 + shift_us)),
                ("dur", jnum(s.dur_ns as f64 / 1000.0)),
                ("cat", jstr(&s.cat)),
                ("name", jstr(&s.label)),
                ("args", obj(args)),
            ]));
        }
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", jstr("ms")),
    ])
}

/// Total ring-overflow span drops recorded in a Chrome trace file (as
/// written by [`chrome_trace_json`]): summed over the `thread_name`
/// metadata rows. Pre-anchor traces without the field report 0.
pub fn chrome_trace_dropped(v: &Json) -> u64 {
    v.get("traceEvents")
        .and_then(Json::as_arr)
        .map(|events| {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
                .filter(|e| e.get("name").and_then(Json::as_str) == Some("thread_name"))
                .filter_map(|e| e.get("args").and_then(|a| a.get("dropped")).and_then(Json::as_f64))
                .sum::<f64>() as u64
        })
        .unwrap_or(0)
}

/// One row of the per-op summary table.
#[derive(Clone, Debug, PartialEq)]
pub struct SummaryRow {
    pub cat: String,
    pub name: String,
    pub count: u64,
    pub seconds: f64,
    pub bytes: u64,
}

/// Aggregate timelines into per-(cat, op) totals, ordered comm-last
/// within category name order (mirrors the paper's §6.3 rows).
pub fn summarize_timelines(timelines: &[RankTimeline]) -> Vec<SummaryRow> {
    let mut rows: BTreeMap<(String, String), (u64, u64, u64)> = BTreeMap::new();
    for t in timelines {
        for s in &t.spans {
            let e = rows.entry((s.cat.clone(), s.label.clone())).or_insert((0, 0, 0));
            e.0 += 1;
            e.1 += s.dur_ns;
            e.2 += s.bytes;
        }
    }
    rows.into_iter()
        .map(|((cat, name), (count, ns, bytes))| SummaryRow {
            cat,
            name,
            count,
            seconds: ns as f64 / 1e9,
            bytes,
        })
        .collect()
}

/// Parse a Chrome trace-event file (as written by [`chrome_trace_json`])
/// back into summary rows — the `drescal trace-summary` path.
pub fn summarize_chrome_trace(v: &Json) -> Result<Vec<SummaryRow>> {
    let events = v
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::msg("not a Chrome trace: missing traceEvents array"))?;
    let mut rows: BTreeMap<(String, String), (u64, u64, u64)> = BTreeMap::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let cat = e.get("cat").and_then(Json::as_str).unwrap_or("").to_string();
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::msg("trace event without a name"))?
            .to_string();
        let dur_us = e.get("dur").and_then(Json::as_f64).unwrap_or(0.0);
        let bytes = e
            .get("args")
            .and_then(|a| a.get("bytes"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
        let entry = rows.entry((cat, name)).or_insert((0, 0, 0));
        entry.0 += 1;
        entry.1 += (dur_us * 1000.0).round() as u64;
        entry.2 += bytes;
    }
    Ok(rows
        .into_iter()
        .map(|((cat, name), (count, ns, bytes))| SummaryRow {
            cat,
            name,
            count,
            seconds: ns as f64 / 1e9,
            bytes,
        })
        .collect())
}

/// Format summary rows as the §6.3-style breakdown table. `dropped` is
/// the number of spans lost to ring overflow across the summarized
/// timelines; the footer states it next to the sample total so a
/// truncated summary never silently passes as complete.
pub fn format_summary(rows: &[SummaryRow], dropped: u64) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "{:<10} {:<20} {:>8} {:>12} {:>14}", "cat", "op", "count", "seconds", "bytes");
    let mut total_s = 0.0;
    let mut total_b: u64 = 0;
    let mut total_n: u64 = 0;
    for r in rows {
        total_s += r.seconds;
        total_b += r.bytes;
        total_n += r.count;
        let _ = writeln!(
            out,
            "{:<10} {:<20} {:>8} {:>12.4} {:>14}",
            r.cat, r.name, r.count, r.seconds, r.bytes
        );
    }
    let _ = writeln!(out, "{:<10} {:<20} {:>8} {:>12.4} {:>14}", "total", "", "", total_s, total_b);
    let _ = writeln!(
        out,
        "recorded {total_n} sample(s) in {} row(s); {dropped} span(s) dropped to ring overflow{}",
        rows.len(),
        if dropped > 0 { " — rows above undercount" } else { "" }
    );
    out
}

#[cfg(test)]
mod tests {
    use super::super::TimelineSpan;
    use super::*;

    fn spans_for(rank: usize, pid: u64, epoch_ms: u64, spans: Vec<TimelineSpan>) -> RankTimeline {
        RankTimeline { rank, pid, epoch_ms, spans, dropped: 0 }
    }

    #[test]
    fn chrome_export_and_summary_agree() {
        let timelines = vec![
            spans_for(
                0,
                100,
                0,
                vec![
                    TimelineSpan {
                        cat: "comm".into(),
                        label: "row_reduce".into(),
                        start_ns: 0,
                        dur_ns: 2_000_000,
                        bytes: 512,
                        iter: 0,
                    },
                    TimelineSpan {
                        cat: "compute".into(),
                        label: "gram_mul".into(),
                        start_ns: 10,
                        dur_ns: 1_000_000,
                        bytes: 0,
                        iter: 0,
                    },
                ],
            ),
            spans_for(
                1,
                200,
                0,
                vec![TimelineSpan {
                    cat: "comm".into(),
                    label: "row_reduce".into(),
                    start_ns: 0,
                    dur_ns: 3_000_000,
                    bytes: 256,
                    iter: 0,
                }],
            ),
        ];
        let trace = chrome_trace_json(&timelines);
        // must parse back from its own serialization
        let parsed = Json::parse(&trace.to_string()).unwrap();
        let from_file = summarize_chrome_trace(&parsed).unwrap();
        let direct = summarize_timelines(&timelines);
        assert_eq!(from_file.len(), direct.len());
        for (a, b) in from_file.iter().zip(&direct) {
            assert_eq!((a.cat.as_str(), a.name.as_str(), a.count, a.bytes), (
                b.cat.as_str(),
                b.name.as_str(),
                b.count,
                b.bytes
            ));
            assert!((a.seconds - b.seconds).abs() < 1e-6);
        }
        let row = from_file.iter().find(|r| r.name == "row_reduce").unwrap();
        assert_eq!(row.count, 2);
        assert_eq!(row.bytes, 768);
        assert!((row.seconds - 0.005).abs() < 1e-6);
        // metadata rows: one process_name per pid, one thread_name per rank
        let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        let metas = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .count();
        assert_eq!(metas, 4);
        let table = format_summary(&from_file, 0);
        assert!(table.contains("row_reduce"));
        assert!(table.contains("total"));
        assert!(table.contains("recorded 3 sample(s)"));
    }

    #[test]
    fn epoch_anchors_shift_tracks_onto_a_common_axis() {
        let span = TimelineSpan {
            cat: "phase".into(),
            label: "pack".into(),
            start_ns: 1_000_000, // 1ms after its recorder epoch
            dur_ns: 500_000,
            bytes: 0,
            iter: 0,
        };
        let timelines = vec![
            spans_for(0, 100, 10_000, vec![span.clone()]),
            // this process started 250ms later on the wall clock
            spans_for(1, 200, 10_250, vec![span.clone()]),
        ];
        let parsed = Json::parse(&chrome_trace_json(&timelines).to_string()).unwrap();
        let ts_of = |pid: f64| {
            parsed
                .get("traceEvents")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .find(|e| {
                    e.get("ph").and_then(Json::as_str) == Some("X")
                        && e.get("pid").and_then(Json::as_f64) == Some(pid)
                })
                .and_then(|e| e.get("ts").and_then(Json::as_f64))
                .unwrap()
        };
        assert!((ts_of(100.0) - 1000.0).abs() < 1e-9, "earliest track keeps its timestamps");
        assert!(
            (ts_of(200.0) - 251_000.0).abs() < 1e-9,
            "later track shifts by the wall-clock skew"
        );
        // durations (and therefore summaries) are unaffected by the shift
        let rows = summarize_chrome_trace(&parsed).unwrap();
        assert_eq!(rows.len(), 1);
        assert!((rows[0].seconds - 0.001).abs() < 1e-9);
    }

    #[test]
    fn dropped_counts_survive_the_chrome_roundtrip() {
        let mut t = spans_for(0, 100, 0, vec![]);
        t.dropped = 42;
        let u = spans_for(1, 100, 0, vec![]);
        let parsed = Json::parse(&chrome_trace_json(&[t, u]).to_string()).unwrap();
        assert_eq!(chrome_trace_dropped(&parsed), 42);
        // and the summary footer names them
        let table = format_summary(&[], chrome_trace_dropped(&parsed));
        assert!(table.contains("42 span(s) dropped"));
        assert!(table.contains("undercount"));
    }
}
