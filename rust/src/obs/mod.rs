//! Unified telemetry plane: structured spans, metrics, live status, export.
//!
//! The paper's efficacy argument is observational — §6.3 breaks runtime
//! into named per-operation rows averaged over MPI ranks. This module is
//! the shared substrate behind that breakdown and behind every
//! performance PR that follows it. It is split into four layers:
//!
//! * **this module** — the recording core: [`Recorder`] (a per-rank span
//!   recorder on a monotonic clock, anchored to a wall-clock epoch so
//!   cross-host tracks align), the gathered [`RankTimeline`] form with
//!   its binary/JSON codecs, and [`MetricsRegistry`] with log-bucketed
//!   [`Histogram`]s. A disabled recorder performs **zero** heap
//!   allocations, which [`alloc_count`] counter-proves.
//! * [`export`] — post-mortem artifacts: Chrome trace-event JSON for
//!   Perfetto ([`chrome_trace_json`]) and the §6.3-style per-op summary
//!   table ([`summarize_timelines`] / [`format_summary`]).
//! * [`live`] — the in-flight plane: [`live::LiveHub`] accumulates
//!   per-iteration progress events and incrementally flushed spans from
//!   every rank *while the job runs*, and [`live::StatusServer`] serves
//!   them over a dependency-free HTTP/1.1 endpoint (`/healthz`,
//!   `/metrics` in Prometheus text exposition, `/progress`, `/trace`).
//! * [`watchdog`] — typed warnings derived from the progress stream:
//!   convergence stall, NaN/divergence, per-iteration deadline overrun,
//!   and transport degradation.
//!
//! Remote workers serialize their timelines with [`timeline_to_bytes`]
//! and ship them to rank 0 over the mesh
//! ([`crate::comm::Group::gather_bytes_to_root`]) — incrementally at
//! every iteration boundary (so a killed worker's pre-crash spans
//! survive into the final artifact) and in full at job end.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::json::Json;

pub mod export;
pub mod live;
pub mod watchdog;

pub use export::{
    chrome_trace_dropped, chrome_trace_json, format_summary, summarize_chrome_trace,
    summarize_timelines, SummaryRow,
};
pub use live::{http_get, LiveHub, ProgressEvent, StatusServer};
pub use watchdog::{Watchdog, WatchdogConfig, WatchdogEvent, WatchdogKind};

// ---------------------------------------------------------------------------
// Allocation accounting
// ---------------------------------------------------------------------------

/// Every heap allocation the telemetry plane performs bumps this counter
/// (the ring buffer's one-time reservation, timeline snapshots, …). A
/// telemetry-disabled run must leave it untouched — the zero-overhead
/// guarantee is counter-asserted, not assumed.
static OBS_ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Cumulative count of obs-plane heap allocations in this process.
pub fn alloc_count() -> u64 {
    OBS_ALLOCS.load(Ordering::Relaxed)
}

fn note_alloc() {
    OBS_ALLOCS.fetch_add(1, Ordering::Relaxed);
}

/// Milliseconds since the Unix epoch — the wall-clock anchor stamped on
/// every enabled recorder so multi-process traces align in Perfetto.
fn unix_epoch_ms_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

// ---------------------------------------------------------------------------
// Span recorder
// ---------------------------------------------------------------------------

/// Ring capacity: spans per rank per job. At ~48 bytes per span this is
/// ~1.5 MiB; long model-selection sweeps overwrite the oldest spans and
/// count the overflow in [`RankTimeline::dropped`].
const RING_CAP: usize = 32_768;

/// One recorded span. `Copy` with `&'static` strings: pushing a span
/// never allocates.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub cat: &'static str,
    pub label: &'static str,
    /// Start, nanoseconds since the recorder's epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    pub bytes: u64,
    /// MU iteration the span belongs to; [`NO_ITER`] outside the loop.
    pub iter: u32,
}

/// Sentinel iteration for spans outside the MU loop.
pub const NO_ITER: u32 = u32::MAX;

/// Per-rank span recorder. Not thread-safe by design: one per rank,
/// embedded in the rank's [`crate::comm::Trace`].
#[derive(Clone, Debug)]
pub struct Recorder {
    enabled: bool,
    epoch: Instant,
    /// Wall clock at `epoch`, for cross-host track alignment.
    epoch_ms: u64,
    ring: Vec<Span>,
    /// Next write position once the ring is full.
    next: usize,
    dropped: u64,
    iter: u32,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::disabled()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Recorder {
            enabled: true,
            epoch: Instant::now(),
            epoch_ms: unix_epoch_ms_now(),
            ring: Vec::new(),
            next: 0,
            dropped: 0,
            iter: NO_ITER,
        }
    }

    /// A recorder that drops everything. Performs no allocation, ever.
    pub fn disabled() -> Self {
        Recorder { enabled: false, epoch_ms: 0, ..Recorder::new() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Set the MU iteration charged to subsequent spans.
    #[inline]
    pub fn set_iter(&mut self, iter: u32) {
        self.iter = iter;
    }

    /// Current time on this recorder's clock, or `None` when disabled —
    /// the begin half of a begin/end span pair.
    #[inline]
    pub fn begin(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a span opened with [`Recorder::begin`].
    #[inline]
    pub fn end(&mut self, cat: &'static str, label: &'static str, t0: Option<Instant>, bytes: u64) {
        if let Some(t0) = t0 {
            let start_ns = t0.checked_duration_since(self.epoch).unwrap_or_default().as_nanos() as u64;
            let dur_ns = t0.elapsed().as_nanos() as u64;
            self.push(Span { cat, label, start_ns, dur_ns, bytes, iter: self.iter });
        }
    }

    /// Record a span whose duration the caller already measured (the op
    /// trace times collectives itself).
    #[inline]
    pub fn end_at(
        &mut self,
        cat: &'static str,
        label: &'static str,
        t0: Instant,
        dur: std::time::Duration,
        bytes: u64,
    ) {
        if !self.enabled {
            return;
        }
        let start_ns = t0.checked_duration_since(self.epoch).unwrap_or_default().as_nanos() as u64;
        self.push(Span { cat, label, start_ns, dur_ns: dur.as_nanos() as u64, bytes, iter: self.iter });
    }

    /// Append a span; overwrite-oldest once the ring is full.
    #[inline]
    pub fn push(&mut self, span: Span) {
        if !self.enabled {
            return;
        }
        if self.ring.capacity() == 0 {
            // the one allocation an instrumented rank pays
            self.ring.reserve_exact(RING_CAP);
            note_alloc();
        }
        if self.ring.len() < RING_CAP {
            self.ring.push(span);
        } else {
            self.ring[self.next] = span;
            self.next = (self.next + 1) % RING_CAP;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Total spans ever pushed (surviving + overwritten) — the cursor
    /// space for [`Recorder::snapshot_since`] incremental flushes.
    pub fn total_pushed(&self) -> u64 {
        self.ring.len() as u64 + self.dropped
    }

    /// Snapshot the ring in chronological order as this rank's timeline.
    pub fn snapshot(&self, rank: usize) -> RankTimeline {
        let mut spans = Vec::with_capacity(self.ring.len());
        note_alloc();
        for i in 0..self.ring.len() {
            let s = &self.ring[(self.next + i) % self.ring.len().max(1)];
            spans.push(TimelineSpan {
                cat: s.cat.to_string(),
                label: s.label.to_string(),
                start_ns: s.start_ns,
                dur_ns: s.dur_ns,
                bytes: s.bytes,
                iter: s.iter,
            });
        }
        RankTimeline {
            rank,
            pid: std::process::id() as u64,
            epoch_ms: self.epoch_ms,
            spans,
            dropped: self.dropped,
        }
    }

    /// Incremental snapshot: only spans pushed at or after `cursor`
    /// (a prior [`Recorder::total_pushed`] value). The returned
    /// timeline's `dropped` counts spans that were overwritten before
    /// this flush could ship them.
    pub fn snapshot_since(&self, rank: usize, cursor: u64) -> RankTimeline {
        let total = self.total_pushed();
        let first = cursor.min(total).max(self.dropped);
        let mut spans = Vec::with_capacity((total - first) as usize);
        if self.enabled {
            note_alloc();
        }
        for j in first..total {
            let slot = (self.next + (j - self.dropped) as usize) % self.ring.len().max(1);
            let s = &self.ring[slot];
            spans.push(TimelineSpan {
                cat: s.cat.to_string(),
                label: s.label.to_string(),
                start_ns: s.start_ns,
                dur_ns: s.dur_ns,
                bytes: s.bytes,
                iter: s.iter,
            });
        }
        RankTimeline {
            rank,
            pid: std::process::id() as u64,
            epoch_ms: self.epoch_ms,
            spans,
            dropped: first.saturating_sub(cursor.min(total)),
        }
    }
}

// ---------------------------------------------------------------------------
// Timelines (the gathered, cross-process form of a recorder's ring)
// ---------------------------------------------------------------------------

/// One span as it travels between processes and into exports.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineSpan {
    pub cat: String,
    pub label: String,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub bytes: u64,
    pub iter: u32,
}

/// All spans one rank recorded for a job, stamped with the OS process
/// that produced them (leader and remote workers differ).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankTimeline {
    pub rank: usize,
    pub pid: u64,
    /// Wall clock (ms since Unix epoch) at this rank's recorder epoch —
    /// the anchor that aligns multi-process tracks; 0 when unknown
    /// (pre-anchor artifacts).
    pub epoch_ms: u64,
    pub spans: Vec<TimelineSpan>,
    /// Spans lost to ring overflow.
    pub dropped: u64,
}

const TIMELINE_MAGIC: u32 = 0x4F42_5332; // "OBS2" (v2 added the epoch anchor)

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

struct ByteReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(Error::msg(format!(
                "telemetry buffer truncated at byte {} (wanted {n} more of {})",
                self.i,
                self.b.len()
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| Error::msg("telemetry buffer holds non-utf8 label"))
    }
}

/// Serialize a timeline to the compact binary form shipped over the mesh.
pub fn timeline_to_bytes(t: &RankTimeline) -> Vec<u8> {
    let mut out = Vec::with_capacity(40 + t.spans.len() * 48);
    note_alloc();
    put_u32(&mut out, TIMELINE_MAGIC);
    put_u64(&mut out, t.pid);
    put_u64(&mut out, t.epoch_ms);
    put_u64(&mut out, t.dropped);
    put_u32(&mut out, t.spans.len() as u32);
    for s in &t.spans {
        put_str(&mut out, &s.cat);
        put_str(&mut out, &s.label);
        put_u64(&mut out, s.start_ns);
        put_u64(&mut out, s.dur_ns);
        put_u64(&mut out, s.bytes);
        put_u32(&mut out, s.iter);
    }
    out
}

/// Inverse of [`timeline_to_bytes`]; `rank` is assigned by the gather
/// (member order in the world group).
pub fn timeline_from_bytes(rank: usize, bytes: &[u8]) -> Result<RankTimeline> {
    let mut r = ByteReader { b: bytes, i: 0 };
    let magic = r.u32()?;
    if magic != TIMELINE_MAGIC {
        return Err(Error::msg(format!("bad telemetry magic {magic:#x}")));
    }
    let pid = r.u64()?;
    let epoch_ms = r.u64()?;
    let dropped = r.u64()?;
    let count = r.u32()? as usize;
    let mut spans = Vec::with_capacity(count);
    note_alloc();
    for _ in 0..count {
        let cat = r.str()?;
        let label = r.str()?;
        let start_ns = r.u64()?;
        let dur_ns = r.u64()?;
        let bytes = r.u64()?;
        let iter = r.u32()?;
        spans.push(TimelineSpan { cat, label, start_ns, dur_ns, bytes, iter });
    }
    Ok(RankTimeline { rank, pid, epoch_ms, spans, dropped })
}

/// Timeline → JSON (the report's `telemetry.timeline` section). Spans
/// are flat arrays `[cat, label, start_ns, dur_ns, bytes, iter]` to keep
/// archived reports compact.
pub fn timeline_to_json(t: &RankTimeline) -> Json {
    let spans: Vec<Json> = t
        .spans
        .iter()
        .map(|s| {
            Json::Arr(vec![
                Json::Str(s.cat.clone()),
                Json::Str(s.label.clone()),
                Json::Num(s.start_ns as f64),
                Json::Num(s.dur_ns as f64),
                Json::Num(s.bytes as f64),
                Json::Num(s.iter as f64),
            ])
        })
        .collect();
    let mut o = BTreeMap::new();
    o.insert("rank".to_string(), Json::Num(t.rank as f64));
    o.insert("pid".to_string(), Json::Num(t.pid as f64));
    o.insert("epoch_ms".to_string(), Json::Num(t.epoch_ms as f64));
    o.insert("dropped".to_string(), Json::Num(t.dropped as f64));
    o.insert("spans".to_string(), Json::Arr(spans));
    Json::Obj(o)
}

/// Inverse of [`timeline_to_json`]. Reports written before the epoch
/// anchor existed load with `epoch_ms = 0`.
pub fn timeline_from_json(v: &Json) -> Result<RankTimeline> {
    let rank = v.get("rank").and_then(Json::as_usize).unwrap_or(0);
    let pid = v.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let epoch_ms = v.get("epoch_ms").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let dropped = v.get("dropped").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let mut spans = Vec::new();
    for s in v.get("spans").and_then(Json::as_arr).unwrap_or(&[]) {
        let a = s.as_arr().ok_or_else(|| Error::msg("timeline span is not an array"))?;
        if a.len() != 6 {
            return Err(Error::msg(format!("timeline span has {} fields, wanted 6", a.len())));
        }
        spans.push(TimelineSpan {
            cat: a[0].as_str().ok_or_else(|| Error::msg("span cat not a string"))?.to_string(),
            label: a[1].as_str().ok_or_else(|| Error::msg("span label not a string"))?.to_string(),
            start_ns: a[2].as_f64().unwrap_or(0.0) as u64,
            dur_ns: a[3].as_f64().unwrap_or(0.0) as u64,
            bytes: a[4].as_f64().unwrap_or(0.0) as u64,
            iter: a[5].as_f64().unwrap_or(NO_ITER as f64) as u32,
        });
    }
    Ok(RankTimeline { rank, pid, epoch_ms, spans, dropped })
}

// ---------------------------------------------------------------------------
// Histograms + metrics registry
// ---------------------------------------------------------------------------

/// Log-bucketed latency histogram over nanoseconds: bucket `i` holds
/// values in `[2^(i-1), 2^i)` (bucket 0 holds zero). Quantiles are exact
/// within bucket resolution (~2x), constant memory, merge is addition.
#[derive(Clone, Copy, Debug)]
pub struct Histogram {
    buckets: [u64; 64],
    count: u64,
    sum_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; 64], count: 0, sum_ns: 0 }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    fn bucket_of(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            (64 - ns.leading_zeros() as usize).min(63)
        }
    }

    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_ns / self.count
        }
    }

    /// Value at quantile `q` in [0, 1]: the upper bound of the first
    /// bucket whose cumulative count reaches `ceil(q * count)`.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return if i == 0 { 0 } else { (1u64 << i) - 1 };
            }
        }
        u64::MAX
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
    }
}

/// Named counters, gauges, and histograms. Plain `BTreeMap`s — the
/// registry lives on one thread next to whatever it instruments (the
/// live hub wraps one in a mutex for the status endpoint).
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    histograms: BTreeMap<&'static str, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    pub fn counter_add(&mut self, name: &'static str, delta: u64) {
        *self.counters.entry(name).or_insert(0) += delta;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_set(&mut self, name: &'static str, value: f64) {
        self.gauges.insert(name, value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram_record_ns(&mut self, name: &'static str, ns: u64) {
        self.histograms.entry(name).or_default().record_ns(ns);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.counters.iter().map(|(k, v)| (*k, *v))
    }

    pub fn gauges(&self) -> impl Iterator<Item = (&'static str, f64)> + '_ {
        self.gauges.iter().map(|(k, v)| (*k, *v))
    }

    pub fn histograms(&self) -> impl Iterator<Item = (&'static str, &Histogram)> + '_ {
        self.histograms.iter().map(|(k, v)| (*k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_never_allocates() {
        let before = alloc_count();
        let mut r = Recorder::disabled();
        for _ in 0..1000 {
            let t0 = r.begin();
            r.end("compute", "gram_mul", t0, 64);
        }
        assert!(r.is_empty());
        assert_eq!(alloc_count() - before, 0);
    }

    #[test]
    fn recorder_rings_and_counts_drops() {
        let mut r = Recorder::new();
        for i in 0..(RING_CAP + 10) {
            r.push(Span {
                cat: "compute",
                label: "gram_mul",
                start_ns: i as u64,
                dur_ns: 1,
                bytes: 0,
                iter: 0,
            });
        }
        assert_eq!(r.len(), RING_CAP);
        let snap = r.snapshot(0);
        assert_eq!(snap.dropped, 10);
        // chronological order: oldest surviving span first
        assert_eq!(snap.spans.first().unwrap().start_ns, 10);
        assert_eq!(snap.spans.last().unwrap().start_ns, (RING_CAP + 9) as u64);
    }

    #[test]
    fn enabled_recorder_is_wall_clock_anchored() {
        let r = Recorder::new();
        assert!(r.snapshot(0).epoch_ms > 0, "enabled recorders must carry an epoch anchor");
        assert_eq!(Recorder::disabled().epoch_ms, 0);
    }

    #[test]
    fn incremental_snapshots_partition_the_ring() {
        let mut r = Recorder::new();
        let span = |i: u64| Span {
            cat: "phase",
            label: "pack",
            start_ns: i,
            dur_ns: 1,
            bytes: 0,
            iter: 0,
        };
        for i in 0..5u64 {
            r.push(span(i));
        }
        let cursor = r.total_pushed();
        let first = r.snapshot_since(0, 0);
        assert_eq!(first.spans.len(), 5);
        assert_eq!(first.dropped, 0);
        // nothing new: empty delta
        assert!(r.snapshot_since(0, cursor).spans.is_empty());
        for i in 5..8u64 {
            r.push(span(i));
        }
        let delta = r.snapshot_since(0, cursor);
        assert_eq!(delta.spans.len(), 3);
        assert_eq!(delta.spans[0].start_ns, 5);
        assert_eq!(delta.dropped, 0);
        assert_eq!(delta.epoch_ms, first.epoch_ms);
    }

    #[test]
    fn incremental_snapshot_counts_overwritten_spans_as_dropped() {
        let mut r = Recorder::new();
        for i in 0..(RING_CAP as u64 + 10) {
            r.push(Span { cat: "c", label: "l", start_ns: i, dur_ns: 1, bytes: 0, iter: 0 });
        }
        // a cursor taken before the overwrite began: the 10 oldest spans
        // were lost before this flush, and the delta reports them
        let delta = r.snapshot_since(0, 0);
        assert_eq!(delta.dropped, 10);
        assert_eq!(delta.spans.len(), RING_CAP);
        assert_eq!(delta.spans.first().unwrap().start_ns, 10);
    }

    #[test]
    fn timeline_bytes_roundtrip() {
        let t = RankTimeline {
            rank: 3,
            pid: 4242,
            epoch_ms: 1_700_000_000_123,
            dropped: 7,
            spans: vec![
                TimelineSpan {
                    cat: "comm".into(),
                    label: "row_reduce".into(),
                    start_ns: 10,
                    dur_ns: 20,
                    bytes: 1024,
                    iter: 2,
                },
                TimelineSpan {
                    cat: "phase".into(),
                    label: "normalize".into(),
                    start_ns: 99,
                    dur_ns: 1,
                    bytes: 0,
                    iter: NO_ITER,
                },
            ],
        };
        let bytes = timeline_to_bytes(&t);
        let back = timeline_from_bytes(3, &bytes).unwrap();
        assert_eq!(back, t);
        assert!(timeline_from_bytes(0, &bytes[..bytes.len() - 2]).is_err());
        assert!(timeline_from_bytes(0, b"garbage!").is_err());
    }

    #[test]
    fn timeline_json_roundtrip() {
        let t = RankTimeline {
            rank: 1,
            pid: 77,
            epoch_ms: 123_456,
            dropped: 0,
            spans: vec![TimelineSpan {
                cat: "compute".into(),
                label: "gram_mul".into(),
                start_ns: 5,
                dur_ns: 6,
                bytes: 7,
                iter: 0,
            }],
        };
        let v = timeline_to_json(&t);
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(timeline_from_json(&parsed).unwrap(), t);
        // pre-anchor reports (no epoch_ms key) still load
        let mut legacy = v.clone();
        if let Json::Obj(o) = &mut legacy {
            o.remove("epoch_ms");
        }
        let parsed = Json::parse(&legacy.to_string()).unwrap();
        assert_eq!(timeline_from_json(&parsed).unwrap().epoch_ms, 0);
    }

    #[test]
    fn histogram_quantiles_within_bucket_resolution() {
        let mut h = Histogram::new();
        for ns in 1..=1000u64 {
            h.record_ns(ns * 1000); // 1µs .. 1ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_ns(0.50);
        let p99 = h.quantile_ns(0.99);
        // log2 buckets: answer within 2x of the exact quantile
        assert!((250_000..=1_048_575).contains(&p50), "p50={p50}");
        assert!((500_000..=2_097_151).contains(&p99), "p99={p99}");
        assert!(p50 <= p99);
        assert!(h.mean_ns() > 0);
        let mut other = Histogram::new();
        other.record_ns(1);
        other.merge(&h);
        assert_eq!(other.count(), 1001);
    }

    #[test]
    fn registry_counts_and_gauges() {
        let mut m = MetricsRegistry::new();
        m.counter_add("queries", 2);
        m.counter_add("queries", 3);
        m.gauge_set("cache_fill", 0.5);
        m.histogram_record_ns("latency", 1000);
        assert_eq!(m.counter("queries"), 5);
        assert_eq!(m.gauge("cache_fill"), Some(0.5));
        assert_eq!(m.histogram("latency").unwrap().count(), 1);
        assert_eq!(m.counters().count(), 1);
        assert_eq!(m.gauges().collect::<Vec<_>>(), vec![("cache_fill", 0.5)]);
        let hists: Vec<_> = m.histograms().map(|(k, h)| (k, h.count())).collect();
        assert_eq!(hists, vec![("latency", 1)]);
    }
}
