//! Unified telemetry plane: structured spans, metrics, and trace export.
//!
//! The paper's efficacy argument is observational — §6.3 breaks runtime
//! into named per-operation rows averaged over MPI ranks. This module is
//! the shared substrate behind that breakdown and behind every
//! performance PR that follows it:
//!
//! * [`Recorder`] — a per-rank span recorder on a monotonic clock.
//!   Spans carry a category (`"compute"`, `"comm"`, `"phase"`, …), a
//!   static label, a byte count, and the MU iteration they belong to.
//!   Storage is a preallocated ring (one allocation on first use,
//!   overwrite-oldest thereafter); a disabled recorder performs **zero**
//!   heap allocations, which [`alloc_count`] counter-proves.
//! * [`MetricsRegistry`] — named counters, gauges, and log-bucketed
//!   latency [`Histogram`]s (exact p50/p95/p99 within bucket
//!   resolution). The serve plane records per-query latency here.
//! * [`chrome_trace_json`] — exports a set of [`RankTimeline`]s as
//!   Chrome trace-event JSON loadable in Perfetto or `chrome://tracing`,
//!   one track per rank × process; [`summarize_chrome_trace`] parses
//!   such a file back into the §6.3-style per-op table that
//!   `drescal trace-summary` prints.
//!
//! Remote workers serialize their timelines with [`timeline_to_bytes`]
//! and ship them to rank 0 over the mesh
//! ([`crate::comm::Group::gather_bytes_to_root`]) at job end, so one
//! exported file covers the whole cluster.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::json::Json;

// ---------------------------------------------------------------------------
// Allocation accounting
// ---------------------------------------------------------------------------

/// Every heap allocation the telemetry plane performs bumps this counter
/// (the ring buffer's one-time reservation, timeline snapshots, …). A
/// telemetry-disabled run must leave it untouched — the zero-overhead
/// guarantee is counter-asserted, not assumed.
static OBS_ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Cumulative count of obs-plane heap allocations in this process.
pub fn alloc_count() -> u64 {
    OBS_ALLOCS.load(Ordering::Relaxed)
}

fn note_alloc() {
    OBS_ALLOCS.fetch_add(1, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Span recorder
// ---------------------------------------------------------------------------

/// Ring capacity: spans per rank per job. At ~48 bytes per span this is
/// ~1.5 MiB; long model-selection sweeps overwrite the oldest spans and
/// count the overflow in [`RankTimeline::dropped`].
const RING_CAP: usize = 32_768;

/// One recorded span. `Copy` with `&'static` strings: pushing a span
/// never allocates.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub cat: &'static str,
    pub label: &'static str,
    /// Start, nanoseconds since the recorder's epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    pub bytes: u64,
    /// MU iteration the span belongs to; [`NO_ITER`] outside the loop.
    pub iter: u32,
}

/// Sentinel iteration for spans outside the MU loop.
pub const NO_ITER: u32 = u32::MAX;

/// Per-rank span recorder. Not thread-safe by design: one per rank,
/// embedded in the rank's [`crate::comm::Trace`].
#[derive(Clone, Debug)]
pub struct Recorder {
    enabled: bool,
    epoch: Instant,
    ring: Vec<Span>,
    /// Next write position once the ring is full.
    next: usize,
    dropped: u64,
    iter: u32,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::disabled()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Recorder {
            enabled: true,
            epoch: Instant::now(),
            ring: Vec::new(),
            next: 0,
            dropped: 0,
            iter: NO_ITER,
        }
    }

    /// A recorder that drops everything. Performs no allocation, ever.
    pub fn disabled() -> Self {
        Recorder { enabled: false, ..Recorder::new() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Set the MU iteration charged to subsequent spans.
    #[inline]
    pub fn set_iter(&mut self, iter: u32) {
        self.iter = iter;
    }

    /// Current time on this recorder's clock, or `None` when disabled —
    /// the begin half of a begin/end span pair.
    #[inline]
    pub fn begin(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a span opened with [`Recorder::begin`].
    #[inline]
    pub fn end(&mut self, cat: &'static str, label: &'static str, t0: Option<Instant>, bytes: u64) {
        if let Some(t0) = t0 {
            let start_ns = t0.checked_duration_since(self.epoch).unwrap_or_default().as_nanos() as u64;
            let dur_ns = t0.elapsed().as_nanos() as u64;
            self.push(Span { cat, label, start_ns, dur_ns, bytes, iter: self.iter });
        }
    }

    /// Record a span whose duration the caller already measured (the op
    /// trace times collectives itself).
    #[inline]
    pub fn end_at(
        &mut self,
        cat: &'static str,
        label: &'static str,
        t0: Instant,
        dur: std::time::Duration,
        bytes: u64,
    ) {
        if !self.enabled {
            return;
        }
        let start_ns = t0.checked_duration_since(self.epoch).unwrap_or_default().as_nanos() as u64;
        self.push(Span { cat, label, start_ns, dur_ns: dur.as_nanos() as u64, bytes, iter: self.iter });
    }

    /// Append a span; overwrite-oldest once the ring is full.
    #[inline]
    pub fn push(&mut self, span: Span) {
        if !self.enabled {
            return;
        }
        if self.ring.capacity() == 0 {
            // the one allocation an instrumented rank pays
            self.ring.reserve_exact(RING_CAP);
            note_alloc();
        }
        if self.ring.len() < RING_CAP {
            self.ring.push(span);
        } else {
            self.ring[self.next] = span;
            self.next = (self.next + 1) % RING_CAP;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Snapshot the ring in chronological order as this rank's timeline.
    pub fn snapshot(&self, rank: usize) -> RankTimeline {
        let mut spans = Vec::with_capacity(self.ring.len());
        note_alloc();
        for i in 0..self.ring.len() {
            let s = &self.ring[(self.next + i) % self.ring.len().max(1)];
            spans.push(TimelineSpan {
                cat: s.cat.to_string(),
                label: s.label.to_string(),
                start_ns: s.start_ns,
                dur_ns: s.dur_ns,
                bytes: s.bytes,
                iter: s.iter,
            });
        }
        RankTimeline { rank, pid: std::process::id() as u64, spans, dropped: self.dropped }
    }
}

// ---------------------------------------------------------------------------
// Timelines (the gathered, cross-process form of a recorder's ring)
// ---------------------------------------------------------------------------

/// One span as it travels between processes and into exports.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineSpan {
    pub cat: String,
    pub label: String,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub bytes: u64,
    pub iter: u32,
}

/// All spans one rank recorded for a job, stamped with the OS process
/// that produced them (leader and remote workers differ).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RankTimeline {
    pub rank: usize,
    pub pid: u64,
    pub spans: Vec<TimelineSpan>,
    /// Spans lost to ring overflow.
    pub dropped: u64,
}

const TIMELINE_MAGIC: u32 = 0x4F42_5331; // "OBS1"

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

struct ByteReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(Error::msg(format!(
                "telemetry buffer truncated at byte {} (wanted {n} more of {})",
                self.i,
                self.b.len()
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| Error::msg("telemetry buffer holds non-utf8 label"))
    }
}

/// Serialize a timeline to the compact binary form shipped over the mesh.
pub fn timeline_to_bytes(t: &RankTimeline) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + t.spans.len() * 48);
    note_alloc();
    put_u32(&mut out, TIMELINE_MAGIC);
    put_u64(&mut out, t.pid);
    put_u64(&mut out, t.dropped);
    put_u32(&mut out, t.spans.len() as u32);
    for s in &t.spans {
        put_str(&mut out, &s.cat);
        put_str(&mut out, &s.label);
        put_u64(&mut out, s.start_ns);
        put_u64(&mut out, s.dur_ns);
        put_u64(&mut out, s.bytes);
        put_u32(&mut out, s.iter);
    }
    out
}

/// Inverse of [`timeline_to_bytes`]; `rank` is assigned by the gather
/// (member order in the world group).
pub fn timeline_from_bytes(rank: usize, bytes: &[u8]) -> Result<RankTimeline> {
    let mut r = ByteReader { b: bytes, i: 0 };
    let magic = r.u32()?;
    if magic != TIMELINE_MAGIC {
        return Err(Error::msg(format!("bad telemetry magic {magic:#x}")));
    }
    let pid = r.u64()?;
    let dropped = r.u64()?;
    let count = r.u32()? as usize;
    let mut spans = Vec::with_capacity(count);
    note_alloc();
    for _ in 0..count {
        let cat = r.str()?;
        let label = r.str()?;
        let start_ns = r.u64()?;
        let dur_ns = r.u64()?;
        let bytes = r.u64()?;
        let iter = r.u32()?;
        spans.push(TimelineSpan { cat, label, start_ns, dur_ns, bytes, iter });
    }
    Ok(RankTimeline { rank, pid, spans, dropped })
}

/// Timeline → JSON (the report's `telemetry.timeline` section). Spans
/// are flat arrays `[cat, label, start_ns, dur_ns, bytes, iter]` to keep
/// archived reports compact.
pub fn timeline_to_json(t: &RankTimeline) -> Json {
    let spans: Vec<Json> = t
        .spans
        .iter()
        .map(|s| {
            Json::Arr(vec![
                Json::Str(s.cat.clone()),
                Json::Str(s.label.clone()),
                Json::Num(s.start_ns as f64),
                Json::Num(s.dur_ns as f64),
                Json::Num(s.bytes as f64),
                Json::Num(s.iter as f64),
            ])
        })
        .collect();
    let mut o = BTreeMap::new();
    o.insert("rank".to_string(), Json::Num(t.rank as f64));
    o.insert("pid".to_string(), Json::Num(t.pid as f64));
    o.insert("dropped".to_string(), Json::Num(t.dropped as f64));
    o.insert("spans".to_string(), Json::Arr(spans));
    Json::Obj(o)
}

/// Inverse of [`timeline_to_json`].
pub fn timeline_from_json(v: &Json) -> Result<RankTimeline> {
    let rank = v.get("rank").and_then(Json::as_usize).unwrap_or(0);
    let pid = v.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let dropped = v.get("dropped").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let mut spans = Vec::new();
    for s in v.get("spans").and_then(Json::as_arr).unwrap_or(&[]) {
        let a = s.as_arr().ok_or_else(|| Error::msg("timeline span is not an array"))?;
        if a.len() != 6 {
            return Err(Error::msg(format!("timeline span has {} fields, wanted 6", a.len())));
        }
        spans.push(TimelineSpan {
            cat: a[0].as_str().ok_or_else(|| Error::msg("span cat not a string"))?.to_string(),
            label: a[1].as_str().ok_or_else(|| Error::msg("span label not a string"))?.to_string(),
            start_ns: a[2].as_f64().unwrap_or(0.0) as u64,
            dur_ns: a[3].as_f64().unwrap_or(0.0) as u64,
            bytes: a[4].as_f64().unwrap_or(0.0) as u64,
            iter: a[5].as_f64().unwrap_or(NO_ITER as f64) as u32,
        });
    }
    Ok(RankTimeline { rank, pid, spans, dropped })
}

// ---------------------------------------------------------------------------
// Histograms + metrics registry
// ---------------------------------------------------------------------------

/// Log-bucketed latency histogram over nanoseconds: bucket `i` holds
/// values in `[2^(i-1), 2^i)` (bucket 0 holds zero). Quantiles are exact
/// within bucket resolution (~2x), constant memory, merge is addition.
#[derive(Clone, Copy, Debug)]
pub struct Histogram {
    buckets: [u64; 64],
    count: u64,
    sum_ns: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; 64], count: 0, sum_ns: 0 }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    fn bucket_of(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            (64 - ns.leading_zeros() as usize).min(63)
        }
    }

    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_ns / self.count
        }
    }

    /// Value at quantile `q` in [0, 1]: the upper bound of the first
    /// bucket whose cumulative count reaches `ceil(q * count)`.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return if i == 0 { 0 } else { (1u64 << i) - 1 };
            }
        }
        u64::MAX
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
    }
}

/// Named counters, gauges, and histograms. Plain `BTreeMap`s — the
/// registry lives on one thread next to whatever it instruments.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    histograms: BTreeMap<&'static str, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry::default()
    }

    pub fn counter_add(&mut self, name: &'static str, delta: u64) {
        *self.counters.entry(name).or_insert(0) += delta;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_set(&mut self, name: &'static str, value: f64) {
        self.gauges.insert(name, value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram_record_ns(&mut self, name: &'static str, ns: u64) {
        self.histograms.entry(name).or_default().record_ns(ns);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.counters.iter().map(|(k, v)| (*k, *v))
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event export + §6.3 summary
// ---------------------------------------------------------------------------

fn jnum(n: f64) -> Json {
    Json::Num(n)
}

fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Export timelines as Chrome trace-event JSON (`ph:"X"` complete
/// events), loadable in Perfetto or `chrome://tracing`. Track layout:
/// one process row per OS pid, one thread row per rank. Timestamps are
/// per-rank recorder epochs, so cross-track skew is bounded by job
/// start-up, not wall-clock drift.
pub fn chrome_trace_json(timelines: &[RankTimeline]) -> Json {
    let mut events = Vec::new();
    let mut pids_seen = std::collections::BTreeSet::new();
    for t in timelines {
        if pids_seen.insert(t.pid) {
            events.push(obj(vec![
                ("ph", jstr("M")),
                ("name", jstr("process_name")),
                ("pid", jnum(t.pid as f64)),
                ("tid", jnum(0.0)),
                ("args", obj(vec![("name", jstr(&format!("drescal pid {}", t.pid)))])),
            ]));
        }
        events.push(obj(vec![
            ("ph", jstr("M")),
            ("name", jstr("thread_name")),
            ("pid", jnum(t.pid as f64)),
            ("tid", jnum(t.rank as f64)),
            ("args", obj(vec![("name", jstr(&format!("rank {}", t.rank)))])),
        ]));
        for s in &t.spans {
            let mut args = vec![("bytes", jnum(s.bytes as f64))];
            if s.iter != NO_ITER {
                args.push(("iter", jnum(s.iter as f64)));
            }
            events.push(obj(vec![
                ("ph", jstr("X")),
                ("pid", jnum(t.pid as f64)),
                ("tid", jnum(t.rank as f64)),
                ("ts", jnum(s.start_ns as f64 / 1000.0)),
                ("dur", jnum(s.dur_ns as f64 / 1000.0)),
                ("cat", jstr(&s.cat)),
                ("name", jstr(&s.label)),
                ("args", obj(args)),
            ]));
        }
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", jstr("ms")),
    ])
}

/// One row of the per-op summary table.
#[derive(Clone, Debug, PartialEq)]
pub struct SummaryRow {
    pub cat: String,
    pub name: String,
    pub count: u64,
    pub seconds: f64,
    pub bytes: u64,
}

/// Aggregate timelines into per-(cat, op) totals, ordered comm-last
/// within category name order (mirrors the paper's §6.3 rows).
pub fn summarize_timelines(timelines: &[RankTimeline]) -> Vec<SummaryRow> {
    let mut rows: BTreeMap<(String, String), (u64, u64, u64)> = BTreeMap::new();
    for t in timelines {
        for s in &t.spans {
            let e = rows.entry((s.cat.clone(), s.label.clone())).or_insert((0, 0, 0));
            e.0 += 1;
            e.1 += s.dur_ns;
            e.2 += s.bytes;
        }
    }
    rows.into_iter()
        .map(|((cat, name), (count, ns, bytes))| SummaryRow {
            cat,
            name,
            count,
            seconds: ns as f64 / 1e9,
            bytes,
        })
        .collect()
}

/// Parse a Chrome trace-event file (as written by [`chrome_trace_json`])
/// back into summary rows — the `drescal trace-summary` path.
pub fn summarize_chrome_trace(v: &Json) -> Result<Vec<SummaryRow>> {
    let events = v
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::msg("not a Chrome trace: missing traceEvents array"))?;
    let mut rows: BTreeMap<(String, String), (u64, u64, u64)> = BTreeMap::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let cat = e.get("cat").and_then(Json::as_str).unwrap_or("").to_string();
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::msg("trace event without a name"))?
            .to_string();
        let dur_us = e.get("dur").and_then(Json::as_f64).unwrap_or(0.0);
        let bytes = e
            .get("args")
            .and_then(|a| a.get("bytes"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64;
        let entry = rows.entry((cat, name)).or_insert((0, 0, 0));
        entry.0 += 1;
        entry.1 += (dur_us * 1000.0).round() as u64;
        entry.2 += bytes;
    }
    Ok(rows
        .into_iter()
        .map(|((cat, name), (count, ns, bytes))| SummaryRow {
            cat,
            name,
            count,
            seconds: ns as f64 / 1e9,
            bytes,
        })
        .collect())
}

/// Format summary rows as the §6.3-style breakdown table.
pub fn format_summary(rows: &[SummaryRow]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "{:<10} {:<20} {:>8} {:>12} {:>14}", "cat", "op", "count", "seconds", "bytes");
    let mut total_s = 0.0;
    let mut total_b: u64 = 0;
    for r in rows {
        total_s += r.seconds;
        total_b += r.bytes;
        let _ = writeln!(
            out,
            "{:<10} {:<20} {:>8} {:>12.4} {:>14}",
            r.cat, r.name, r.count, r.seconds, r.bytes
        );
    }
    let _ = writeln!(out, "{:<10} {:<20} {:>8} {:>12.4} {:>14}", "total", "", "", total_s, total_b);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_never_allocates() {
        let before = alloc_count();
        let mut r = Recorder::disabled();
        for _ in 0..1000 {
            let t0 = r.begin();
            r.end("compute", "gram_mul", t0, 64);
        }
        assert!(r.is_empty());
        assert_eq!(alloc_count() - before, 0);
    }

    #[test]
    fn recorder_rings_and_counts_drops() {
        let mut r = Recorder::new();
        for i in 0..(RING_CAP + 10) {
            r.push(Span {
                cat: "compute",
                label: "gram_mul",
                start_ns: i as u64,
                dur_ns: 1,
                bytes: 0,
                iter: 0,
            });
        }
        assert_eq!(r.len(), RING_CAP);
        let snap = r.snapshot(0);
        assert_eq!(snap.dropped, 10);
        // chronological order: oldest surviving span first
        assert_eq!(snap.spans.first().unwrap().start_ns, 10);
        assert_eq!(snap.spans.last().unwrap().start_ns, (RING_CAP + 9) as u64);
    }

    #[test]
    fn timeline_bytes_roundtrip() {
        let t = RankTimeline {
            rank: 3,
            pid: 4242,
            dropped: 7,
            spans: vec![
                TimelineSpan {
                    cat: "comm".into(),
                    label: "row_reduce".into(),
                    start_ns: 10,
                    dur_ns: 20,
                    bytes: 1024,
                    iter: 2,
                },
                TimelineSpan {
                    cat: "phase".into(),
                    label: "normalize".into(),
                    start_ns: 99,
                    dur_ns: 1,
                    bytes: 0,
                    iter: NO_ITER,
                },
            ],
        };
        let bytes = timeline_to_bytes(&t);
        let back = timeline_from_bytes(3, &bytes).unwrap();
        assert_eq!(back, t);
        assert!(timeline_from_bytes(0, &bytes[..bytes.len() - 2]).is_err());
        assert!(timeline_from_bytes(0, b"garbage!").is_err());
    }

    #[test]
    fn timeline_json_roundtrip() {
        let t = RankTimeline {
            rank: 1,
            pid: 77,
            dropped: 0,
            spans: vec![TimelineSpan {
                cat: "compute".into(),
                label: "gram_mul".into(),
                start_ns: 5,
                dur_ns: 6,
                bytes: 7,
                iter: 0,
            }],
        };
        let v = timeline_to_json(&t);
        let parsed = Json::parse(&v.to_string()).unwrap();
        assert_eq!(timeline_from_json(&parsed).unwrap(), t);
    }

    #[test]
    fn histogram_quantiles_within_bucket_resolution() {
        let mut h = Histogram::new();
        for ns in 1..=1000u64 {
            h.record_ns(ns * 1000); // 1µs .. 1ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_ns(0.50);
        let p99 = h.quantile_ns(0.99);
        // log2 buckets: answer within 2x of the exact quantile
        assert!((250_000..=1_048_575).contains(&p50), "p50={p50}");
        assert!((500_000..=2_097_151).contains(&p99), "p99={p99}");
        assert!(p50 <= p99);
        assert!(h.mean_ns() > 0);
        let mut other = Histogram::new();
        other.record_ns(1);
        other.merge(&h);
        assert_eq!(other.count(), 1001);
    }

    #[test]
    fn registry_counts_and_gauges() {
        let mut m = MetricsRegistry::new();
        m.counter_add("queries", 2);
        m.counter_add("queries", 3);
        m.gauge_set("cache_fill", 0.5);
        m.histogram_record_ns("latency", 1000);
        assert_eq!(m.counter("queries"), 5);
        assert_eq!(m.gauge("cache_fill"), Some(0.5));
        assert_eq!(m.histogram("latency").unwrap().count(), 1);
        assert_eq!(m.counters().count(), 1);
    }

    #[test]
    fn chrome_export_and_summary_agree() {
        let timelines = vec![
            RankTimeline {
                rank: 0,
                pid: 100,
                dropped: 0,
                spans: vec![
                    TimelineSpan {
                        cat: "comm".into(),
                        label: "row_reduce".into(),
                        start_ns: 0,
                        dur_ns: 2_000_000,
                        bytes: 512,
                        iter: 0,
                    },
                    TimelineSpan {
                        cat: "compute".into(),
                        label: "gram_mul".into(),
                        start_ns: 10,
                        dur_ns: 1_000_000,
                        bytes: 0,
                        iter: 0,
                    },
                ],
            },
            RankTimeline {
                rank: 1,
                pid: 200,
                dropped: 0,
                spans: vec![TimelineSpan {
                    cat: "comm".into(),
                    label: "row_reduce".into(),
                    start_ns: 0,
                    dur_ns: 3_000_000,
                    bytes: 256,
                    iter: 0,
                }],
            },
        ];
        let trace = chrome_trace_json(&timelines);
        // must parse back from its own serialization
        let parsed = Json::parse(&trace.to_string()).unwrap();
        let from_file = summarize_chrome_trace(&parsed).unwrap();
        let direct = summarize_timelines(&timelines);
        assert_eq!(from_file.len(), direct.len());
        for (a, b) in from_file.iter().zip(&direct) {
            assert_eq!((a.cat.as_str(), a.name.as_str(), a.count, a.bytes), (
                b.cat.as_str(),
                b.name.as_str(),
                b.count,
                b.bytes
            ));
            assert!((a.seconds - b.seconds).abs() < 1e-6);
        }
        let row = from_file.iter().find(|r| r.name == "row_reduce").unwrap();
        assert_eq!(row.count, 2);
        assert_eq!(row.bytes, 768);
        assert!((row.seconds - 0.005).abs() < 1e-6);
        // metadata rows: one process_name per pid, one thread_name per rank
        let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        let metas = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .count();
        assert_eq!(metas, 4);
        let table = format_summary(&from_file);
        assert!(table.contains("row_reduce"));
        assert!(table.contains("total"));
    }
}
