//! Convergence watchdog: typed warnings raised from the live progress
//! stream.
//!
//! The watchdog consumes one [`ProgressEvent`](super::ProgressEvent) per
//! MU iteration and raises [`WatchdogEvent`]s on convergence stall (no
//! relative-error improvement over a window), NaN / divergence,
//! per-iteration deadline overrun, and transport degradation
//! (reconnects, replacement epochs). Warnings surface both on the
//! leader's `/progress` route and in `Report.telemetry.watchdog`.

use super::live::ProgressEvent;
use crate::json::Json;

/// Thresholds for the watchdog. Defaults are deliberately loose: they
/// flag jobs that are badly wrong, not ones that are merely slow.
#[derive(Clone, Copy, Debug)]
pub struct WatchdogConfig {
    /// Fire a `Stall` after this many fresh error readings without
    /// improvement over the best seen so far.
    pub stall_iters: u32,
    /// Fire a `DeadlineOverrun` when a single iteration exceeds this.
    pub iter_deadline_ms: u64,
    /// Fire a `NonFinite` divergence warning when the error grows past
    /// `best * divergence_factor`.
    pub divergence_factor: f32,
}

impl Default for WatchdogConfig {
    fn default() -> Self {
        WatchdogConfig { stall_iters: 50, iter_deadline_ms: 30_000, divergence_factor: 10.0 }
    }
}

/// What went wrong.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WatchdogKind {
    /// No rel_error improvement over the configured window.
    Stall,
    /// rel_error went NaN/inf, or grew past the divergence factor.
    NonFinite,
    /// One iteration blew the per-iteration deadline.
    DeadlineOverrun,
    /// The transport lost a worker: reconnect, replacement epoch.
    TransportDegraded,
}

impl WatchdogKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            WatchdogKind::Stall => "stall",
            WatchdogKind::NonFinite => "non_finite",
            WatchdogKind::DeadlineOverrun => "deadline_overrun",
            WatchdogKind::TransportDegraded => "transport_degraded",
        }
    }

    pub fn parse(s: &str) -> Option<WatchdogKind> {
        match s {
            "stall" => Some(WatchdogKind::Stall),
            "non_finite" => Some(WatchdogKind::NonFinite),
            "deadline_overrun" => Some(WatchdogKind::DeadlineOverrun),
            "transport_degraded" => Some(WatchdogKind::TransportDegraded),
            _ => None,
        }
    }
}

/// One typed warning, stamped with the iteration that triggered it.
#[derive(Clone, Debug, PartialEq)]
pub struct WatchdogEvent {
    pub kind: WatchdogKind,
    pub iter: u32,
    pub detail: String,
}

impl WatchdogEvent {
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("kind".to_string(), Json::Str(self.kind.as_str().to_string()));
        o.insert("iter".to_string(), Json::Num(self.iter as f64));
        o.insert("detail".to_string(), Json::Str(self.detail.clone()));
        Json::Obj(o)
    }

    pub fn from_json(v: &Json) -> Option<WatchdogEvent> {
        Some(WatchdogEvent {
            kind: WatchdogKind::parse(v.get("kind")?.as_str()?)?,
            iter: v.get("iter")?.as_f64()? as u32,
            detail: v.get("detail")?.as_str()?.to_string(),
        })
    }
}

/// Streaming watchdog state. Feed it one event per iteration via
/// [`observe`](Watchdog::observe); it returns the warnings (if any)
/// raised by that event. Stall and non-finite warnings fire once per
/// episode, not once per iteration, so a stalled job produces one
/// warning rather than thousands.
#[derive(Clone, Debug)]
pub struct Watchdog {
    cfg: WatchdogConfig,
    best: f32,
    since_improve: u32,
    stall_fired: bool,
    nonfinite_fired: bool,
}

impl Watchdog {
    pub fn new(cfg: WatchdogConfig) -> Self {
        Watchdog {
            cfg,
            best: f32::INFINITY,
            since_improve: 0,
            stall_fired: false,
            nonfinite_fired: false,
        }
    }

    pub fn observe(&mut self, ev: &ProgressEvent) -> Vec<WatchdogEvent> {
        let mut out = Vec::new();
        if ev.iter_ns > self.cfg.iter_deadline_ms.saturating_mul(1_000_000) {
            out.push(WatchdogEvent {
                kind: WatchdogKind::DeadlineOverrun,
                iter: ev.iter,
                detail: format!(
                    "iteration took {:.1}ms (deadline {}ms)",
                    ev.iter_ns as f64 / 1e6,
                    self.cfg.iter_deadline_ms
                ),
            });
        }
        // stall/divergence only make sense on iterations where the
        // distributed error was actually recomputed
        if !ev.err_fresh {
            return out;
        }
        if !ev.rel_error.is_finite() {
            if !self.nonfinite_fired {
                self.nonfinite_fired = true;
                out.push(WatchdogEvent {
                    kind: WatchdogKind::NonFinite,
                    iter: ev.iter,
                    detail: format!("rel_error went non-finite ({})", ev.rel_error),
                });
            }
            return out;
        }
        self.nonfinite_fired = false;
        if self.best.is_finite() && ev.rel_error > self.best * self.cfg.divergence_factor {
            out.push(WatchdogEvent {
                kind: WatchdogKind::NonFinite,
                iter: ev.iter,
                detail: format!(
                    "diverging: rel_error {} is {:.0}x the best seen ({})",
                    ev.rel_error,
                    ev.rel_error / self.best,
                    self.best
                ),
            });
        }
        if ev.rel_error < self.best {
            self.best = ev.rel_error;
            self.since_improve = 0;
            self.stall_fired = false;
        } else {
            self.since_improve += 1;
            if self.since_improve >= self.cfg.stall_iters && !self.stall_fired {
                self.stall_fired = true;
                out.push(WatchdogEvent {
                    kind: WatchdogKind::Stall,
                    iter: ev.iter,
                    detail: format!(
                        "no rel_error improvement in {} error checks (best {})",
                        self.since_improve, self.best
                    ),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(iter: u32, rel_error: f32, err_fresh: bool, iter_ns: u64) -> ProgressEvent {
        ProgressEvent { iter, rel_error, err_fresh, iter_ns, ..ProgressEvent::default() }
    }

    fn cfg() -> WatchdogConfig {
        WatchdogConfig { stall_iters: 3, iter_deadline_ms: 10, divergence_factor: 10.0 }
    }

    #[test]
    fn stall_fires_once_and_resets_on_improvement() {
        let mut w = Watchdog::new(cfg());
        assert!(w.observe(&ev(0, 0.5, true, 0)).is_empty());
        for i in 1..=2 {
            assert!(w.observe(&ev(i, 0.5, true, 0)).is_empty());
        }
        let fired = w.observe(&ev(3, 0.5, true, 0));
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, WatchdogKind::Stall);
        assert_eq!(fired[0].iter, 3);
        // already fired: stays quiet while still stalled
        assert!(w.observe(&ev(4, 0.5, true, 0)).is_empty());
        // improvement re-arms the stall detector
        assert!(w.observe(&ev(5, 0.4, true, 0)).is_empty());
        for i in 6..=8 {
            assert!(w.observe(&ev(i, 0.4, true, 0)).is_empty());
        }
        assert_eq!(w.observe(&ev(9, 0.4, true, 0)).len(), 1);
    }

    #[test]
    fn stale_error_readings_do_not_advance_the_stall_clock() {
        let mut w = Watchdog::new(cfg());
        w.observe(&ev(0, 0.5, true, 0));
        for i in 1..100 {
            assert!(w.observe(&ev(i, 0.5, false, 0)).is_empty());
        }
    }

    #[test]
    fn nan_fires_once_per_episode() {
        let mut w = Watchdog::new(cfg());
        w.observe(&ev(0, 0.5, true, 0));
        let fired = w.observe(&ev(1, f32::NAN, true, 0));
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, WatchdogKind::NonFinite);
        assert!(w.observe(&ev(2, f32::NAN, true, 0)).is_empty());
        // recovery then a second NaN episode fires again
        assert!(w.observe(&ev(3, 0.4, true, 0)).is_empty());
        assert_eq!(w.observe(&ev(4, f32::INFINITY, true, 0)).len(), 1);
    }

    #[test]
    fn divergence_past_the_factor_is_flagged() {
        let mut w = Watchdog::new(cfg());
        w.observe(&ev(0, 0.1, true, 0));
        let fired = w.observe(&ev(1, 5.0, true, 0));
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, WatchdogKind::NonFinite);
        assert!(fired[0].detail.contains("diverging"));
    }

    #[test]
    fn deadline_overrun_checks_every_iteration() {
        let mut w = Watchdog::new(cfg());
        let fired = w.observe(&ev(0, 0.5, false, 11_000_000));
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].kind, WatchdogKind::DeadlineOverrun);
        // fires per offending iteration, fresh error or not
        assert_eq!(w.observe(&ev(1, 0.5, true, 12_000_000)).len(), 1);
        assert!(w.observe(&ev(2, 0.4, true, 1_000_000)).is_empty());
    }

    #[test]
    fn events_roundtrip_through_json() {
        let e = WatchdogEvent {
            kind: WatchdogKind::TransportDegraded,
            iter: 7,
            detail: "worker 2 replaced at epoch 1".to_string(),
        };
        let v = Json::parse(&e.to_json().to_string()).unwrap();
        assert_eq!(WatchdogEvent::from_json(&v), Some(e));
    }
}
