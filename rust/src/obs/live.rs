//! Live observability: the leader-side progress hub and the hand-rolled
//! HTTP/1.1 status endpoint.
//!
//! [`LiveHub`] is the in-flight mirror of a running job. Rank 0's
//! [`Trace`](crate::comm::Trace) feeds it one [`ProgressEvent`] per MU
//! iteration plus the incremental span deltas every rank ships at
//! iteration boundaries, so the hub's trace ring is current mid-job —
//! and a killed worker's pre-crash spans survive into the final
//! `--trace-out` artifact even though that worker never reaches the
//! end-of-run gather.
//!
//! [`StatusServer`] serves the hub over plain HTTP (no dependencies —
//! the offline crate set has no hyper, so the protocol is hand-rolled
//! over `std::net::TcpListener`):
//!
//! * `GET /healthz` — liveness, `ok\n`
//! * `GET /metrics` — Prometheus text exposition
//! * `GET /progress` — JSON job progress (iter, rel_error, per-phase ns,
//!   watchdog warnings, recent iteration history)
//! * `GET /trace` — Chrome trace JSON of the run so far
//!
//! `drescal monitor <addr>` and the tests poll these routes via
//! [`http_get`], a minimal client over `std::net::TcpStream`.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::export::chrome_trace_json;
use super::watchdog::{Watchdog, WatchdogConfig, WatchdogEvent, WatchdogKind};
use super::{MetricsRegistry, RankTimeline};
use crate::error::{Error, Result};
use crate::json::Json;

/// Most recent iterations kept for `/progress` history and the monitor.
const HISTORY_CAP: usize = 1024;
/// Per-(rank, pid) span cap in the hub's live mirror; overflow drains
/// the oldest spans into that entry's `dropped` count.
const HUB_SPAN_CAP: usize = 262_144;

/// One structured event per MU iteration, emitted from rank 0.
#[derive(Clone, Debug, Default)]
pub struct ProgressEvent {
    pub iter: u32,
    /// Most recent relative error (carried forward between checks).
    pub rel_error: f32,
    /// Improvement over the previous fresh reading (0 when stale).
    pub delta: f32,
    /// Whether `rel_error` was recomputed on this iteration.
    pub err_fresh: bool,
    /// Sum of rank 0's per-phase span time this iteration.
    pub iter_ns: u64,
    /// Cumulative wire bytes moved by rank 0's collectives so far.
    pub wire_bytes: u64,
    /// Wall-clock ms since the job started.
    pub elapsed_ms: u64,
    /// Rank 0's per-phase ns for this iteration, by phase label.
    pub phase_ns: BTreeMap<String, u64>,
}

struct HubState {
    job: String,
    iters_total: u64,
    started: Instant,
    timelines: BTreeMap<(usize, u64), RankTimeline>,
    history: VecDeque<ProgressEvent>,
    latest: Option<ProgressEvent>,
    last_fresh_err: Option<f32>,
    phase_totals: BTreeMap<String, u64>,
    watchdog: Watchdog,
    warnings: Vec<WatchdogEvent>,
    metrics: MetricsRegistry,
    done: bool,
    restarts: u64,
}

impl HubState {
    fn new() -> Self {
        HubState {
            job: String::new(),
            iters_total: 0,
            started: Instant::now(),
            timelines: BTreeMap::new(),
            history: VecDeque::new(),
            latest: None,
            last_fresh_err: None,
            phase_totals: BTreeMap::new(),
            watchdog: Watchdog::new(WatchdogConfig::default()),
            warnings: Vec::new(),
            metrics: MetricsRegistry::new(),
            done: false,
            restarts: 0,
        }
    }
}

/// The leader's shared, thread-safe view of the running job. The engine
/// owns one behind an [`Arc`]; rank 0's trace writes into it at
/// iteration boundaries and the [`StatusServer`] reads from it on every
/// request.
pub struct LiveHub {
    inner: Mutex<HubState>,
}

impl std::fmt::Debug for LiveHub {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LiveHub").finish_non_exhaustive()
    }
}

impl Default for LiveHub {
    fn default() -> Self {
        Self::new()
    }
}

impl LiveHub {
    pub fn new() -> Self {
        LiveHub { inner: Mutex::new(HubState::new()) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, HubState> {
        // a poisoned hub just means a panicking reader; the data is
        // plain-old-data and still safe to serve
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Reset job-scoped state at job start. Crash recovery reruns
    /// happen *within* one job, so pre-crash spans absorbed before a
    /// recovery survive until the next `job_started`.
    pub fn job_started(&self, label: &str, iters_total: u64) {
        let mut s = self.lock();
        *s = HubState::new();
        s.job = label.to_string();
        s.iters_total = iters_total;
    }

    /// Merge an incremental span delta from one rank into the live
    /// mirror. Entries are keyed by (rank, pid) so a replacement worker
    /// on the same rank accumulates separately from the process it
    /// replaced — that is what keeps a dead worker's spans alive.
    pub fn absorb(&self, t: RankTimeline) {
        let mut s = self.lock();
        s.metrics.counter_add("spans", t.spans.len() as u64);
        s.metrics.counter_add("spans_dropped", t.dropped);
        let e = s.timelines.entry((t.rank, t.pid)).or_insert_with(|| RankTimeline {
            rank: t.rank,
            pid: t.pid,
            epoch_ms: t.epoch_ms,
            spans: Vec::new(),
            dropped: 0,
        });
        if e.epoch_ms == 0 {
            e.epoch_ms = t.epoch_ms;
        }
        e.dropped += t.dropped;
        e.spans.extend(t.spans);
        if e.spans.len() > HUB_SPAN_CAP {
            let excess = e.spans.len() - HUB_SPAN_CAP;
            e.spans.drain(..excess);
            e.dropped += excess as u64;
        }
    }

    /// Record one MU iteration. `rank0_delta` is rank 0's span delta for
    /// the boundary (its `phase` spans for `iter` yield the per-phase
    /// breakdown); `wire_bytes` is rank 0's cumulative collective
    /// traffic. Runs the watchdog and updates `/metrics` series.
    pub fn on_iteration(
        &self,
        iter: u32,
        rel_error: f32,
        err_fresh: bool,
        wire_bytes: u64,
        rank0_delta: &RankTimeline,
    ) {
        let mut s = self.lock();
        let mut phase_ns: BTreeMap<String, u64> = BTreeMap::new();
        for span in &rank0_delta.spans {
            if span.cat == "phase" && span.iter == iter {
                *phase_ns.entry(span.label.clone()).or_insert(0) += span.dur_ns;
            }
        }
        let iter_ns: u64 = phase_ns.values().sum();
        for (label, ns) in &phase_ns {
            *s.phase_totals.entry(label.clone()).or_insert(0) += ns;
        }
        let delta = if err_fresh {
            let d = s.last_fresh_err.map(|prev| prev - rel_error).unwrap_or(0.0);
            s.last_fresh_err = Some(rel_error);
            d
        } else {
            0.0
        };
        let event = ProgressEvent {
            iter,
            rel_error,
            delta,
            err_fresh,
            iter_ns,
            wire_bytes,
            elapsed_ms: s.started.elapsed().as_millis() as u64,
            phase_ns,
        };
        let fired = s.watchdog.observe(&event);
        s.warnings.extend(fired);
        s.metrics.counter_add("iterations", 1);
        s.metrics.histogram_record_ns("iteration", iter_ns);
        if rel_error.is_finite() {
            s.metrics.gauge_set("rel_error", rel_error as f64);
        }
        if s.history.len() >= HISTORY_CAP {
            s.history.pop_front();
        }
        s.history.push_back(event.clone());
        s.latest = Some(event);
    }

    /// A worker died and the transport recovered (reconnect, replacement
    /// epoch). Counted on `/metrics` and raised as a typed warning.
    pub fn note_transport_degraded(&self, epoch: u64, detail: &str) {
        let mut s = self.lock();
        s.restarts += 1;
        let iter = s.latest.as_ref().map(|e| e.iter).unwrap_or(0);
        s.warnings.push(WatchdogEvent {
            kind: WatchdogKind::TransportDegraded,
            iter,
            detail: format!("epoch {epoch}: {detail}"),
        });
    }

    /// Mark the job finished and return the accumulated warnings for
    /// `Report.telemetry`.
    pub fn finish(&self, rel_error: f32) -> Vec<WatchdogEvent> {
        let mut s = self.lock();
        s.done = true;
        if rel_error.is_finite() {
            s.metrics.gauge_set("rel_error", rel_error as f64);
        }
        s.warnings.clone()
    }

    /// Warnings raised so far (without marking the job done).
    pub fn warnings_snapshot(&self) -> Vec<WatchdogEvent> {
        self.lock().warnings.clone()
    }

    /// Timelines in the live mirror whose pid is absent from
    /// `live_pids` — the pre-crash spans of workers that died before the
    /// end-of-run gather. The engine appends these to `--trace-out`.
    pub fn orphan_timelines(&self, live_pids: &BTreeSet<u64>) -> Vec<RankTimeline> {
        self.lock()
            .timelines
            .values()
            .filter(|t| !live_pids.contains(&t.pid))
            .cloned()
            .collect()
    }

    /// Engine-level gauge passthrough (workspace bytes, resident tiles,
    /// transport backend facts) onto `/metrics`.
    pub fn gauge_set(&self, name: &'static str, value: f64) {
        self.lock().metrics.gauge_set(name, value);
    }

    /// Engine-level counter passthrough onto `/metrics`.
    pub fn counter_add(&self, name: &'static str, delta: u64) {
        self.lock().metrics.counter_add(name, delta);
    }

    /// The `/progress` document.
    pub fn progress_json(&self) -> Json {
        let s = self.lock();
        let mut o = BTreeMap::new();
        o.insert("job".to_string(), Json::Str(s.job.clone()));
        o.insert("iters_total".to_string(), Json::Num(s.iters_total as f64));
        o.insert("done".to_string(), Json::Bool(s.done));
        o.insert("restarts".to_string(), Json::Num(s.restarts as f64));
        o.insert("elapsed_ms".to_string(), Json::Num(s.started.elapsed().as_millis() as f64));
        if let Some(e) = &s.latest {
            o.insert("iter".to_string(), Json::Num(e.iter as f64));
            o.insert("rel_error".to_string(), fin(e.rel_error as f64));
            o.insert("delta".to_string(), fin(e.delta as f64));
            o.insert("iter_ms".to_string(), Json::Num(e.iter_ns as f64 / 1e6));
            o.insert("wire_bytes".to_string(), Json::Num(e.wire_bytes as f64));
        }
        let phases: BTreeMap<String, Json> = s
            .phase_totals
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
            .collect();
        o.insert("phase_ns".to_string(), Json::Obj(phases));
        o.insert(
            "warnings".to_string(),
            Json::Arr(s.warnings.iter().map(|w| w.to_json()).collect()),
        );
        o.insert("history".to_string(), Json::Arr(s.history.iter().map(event_json).collect()));
        Json::Obj(o)
    }

    /// The `/trace` document: Chrome trace JSON of everything absorbed
    /// so far.
    pub fn trace_json(&self) -> Json {
        let s = self.lock();
        let timelines: Vec<RankTimeline> = s.timelines.values().cloned().collect();
        chrome_trace_json(&timelines)
    }

    /// The `/metrics` document: Prometheus text exposition.
    pub fn metrics_text(&self) -> String {
        use std::fmt::Write as _;
        let s = self.lock();
        let mut out = String::new();
        let _ = writeln!(out, "# TYPE drescal_job_done gauge");
        let _ = writeln!(out, "drescal_job_done {}", if s.done { 1 } else { 0 });
        let _ = writeln!(out, "# TYPE drescal_transport_restarts_total counter");
        let _ = writeln!(out, "drescal_transport_restarts_total {}", s.restarts);
        if let Some(e) = &s.latest {
            let _ = writeln!(out, "# TYPE drescal_wire_bytes_total counter");
            let _ = writeln!(out, "drescal_wire_bytes_total {}", e.wire_bytes);
        }
        let mut kinds: BTreeMap<&'static str, u64> = BTreeMap::new();
        for w in &s.warnings {
            *kinds.entry(w.kind.as_str()).or_insert(0) += 1;
        }
        let _ = writeln!(out, "# TYPE drescal_watchdog_events_total counter");
        if kinds.is_empty() {
            let _ = writeln!(out, "drescal_watchdog_events_total 0");
        }
        for (kind, n) in &kinds {
            let _ = writeln!(out, "drescal_watchdog_events_total{{kind=\"{kind}\"}} {n}");
        }
        let _ = writeln!(out, "# TYPE drescal_phase_seconds_total counter");
        for (phase, ns) in &s.phase_totals {
            let _ = writeln!(
                out,
                "drescal_phase_seconds_total{{phase=\"{}\"}} {}",
                sanitize(phase),
                *ns as f64 / 1e9
            );
        }
        if s.phase_totals.is_empty() {
            let _ = writeln!(out, "drescal_phase_seconds_total{{phase=\"none\"}} 0");
        }
        let kernel = crate::tensor::kernel::dispatch::active();
        let _ = writeln!(out, "# TYPE drescal_kernel_info gauge");
        let _ = writeln!(
            out,
            "drescal_kernel_info{{variant=\"{}\",isa=\"{}\"}} 1",
            kernel.name, kernel.isa
        );
        for (name, v) in s.metrics.counters() {
            let name = sanitize(name);
            let _ = writeln!(out, "# TYPE drescal_{name}_total counter");
            let _ = writeln!(out, "drescal_{name}_total {v}");
        }
        for (name, v) in s.metrics.gauges() {
            let name = sanitize(name);
            let _ = writeln!(out, "# TYPE drescal_{name} gauge");
            let _ = writeln!(out, "drescal_{name} {v}");
        }
        for (name, h) in s.metrics.histograms() {
            let name = sanitize(name);
            let _ = writeln!(out, "# TYPE drescal_{name}_seconds summary");
            for q in [0.5, 0.95, 0.99] {
                let _ = writeln!(
                    out,
                    "drescal_{name}_seconds{{quantile=\"{q}\"}} {}",
                    h.quantile_ns(q) as f64 / 1e9
                );
            }
            let _ = writeln!(out, "drescal_{name}_seconds_sum {}", h.sum_ns() as f64 / 1e9);
            let _ = writeln!(out, "drescal_{name}_seconds_count {}", h.count());
        }
        out
    }
}

fn fin(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

fn event_json(e: &ProgressEvent) -> Json {
    let mut o = BTreeMap::new();
    o.insert("iter".to_string(), Json::Num(e.iter as f64));
    o.insert("rel_error".to_string(), fin(e.rel_error as f64));
    o.insert("delta".to_string(), fin(e.delta as f64));
    o.insert("err_fresh".to_string(), Json::Bool(e.err_fresh));
    o.insert("iter_ms".to_string(), Json::Num(e.iter_ns as f64 / 1e6));
    o.insert("wire_bytes".to_string(), Json::Num(e.wire_bytes as f64));
    o.insert("elapsed_ms".to_string(), Json::Num(e.elapsed_ms as f64));
    Json::Obj(o)
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '_' }).collect()
}

/// The leader's status endpoint: a minimal HTTP/1.1 server over
/// `std::net::TcpListener`. Binds `127.0.0.1:<port>` (port 0 picks an
/// ephemeral port — the bound address is in [`addr`](Self::addr)),
/// serves connections serially on one named thread, and shuts down on
/// [`Drop`].
pub struct StatusServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl std::fmt::Debug for StatusServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StatusServer").field("addr", &self.addr).finish_non_exhaustive()
    }
}

impl StatusServer {
    pub fn start(port: u16, hub: Arc<LiveHub>) -> Result<StatusServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .map_err(|e| Error::msg(e).context(format!("binding status endpoint on port {port}")))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread_stop = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("drescal-status".to_string())
            .spawn(move || serve_loop(listener, hub, thread_stop))
            .map_err(|e| Error::msg(e).context("spawning status endpoint thread"))?;
        Ok(StatusServer { addr, stop, handle: Some(handle) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_loop(listener: TcpListener, hub: Arc<LiveHub>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                // status traffic is light and the handlers are cheap:
                // serial handling keeps the server to one thread
                let _ = handle_conn(stream, &hub);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

fn handle_conn(mut stream: TcpStream, hub: &LiveHub) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(2)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    while !buf.windows(4).any(|w| w == b"\r\n\r\n") && buf.len() < 8192 {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
    }
    let request = String::from_utf8_lossy(&buf);
    let mut parts = request.lines().next().unwrap_or("").split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, content_type, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "only GET is supported\n".to_string())
    } else {
        match path {
            "/healthz" => ("200 OK", "text/plain", "ok\n".to_string()),
            "/metrics" => ("200 OK", "text/plain; version=0.0.4", hub.metrics_text()),
            "/progress" => ("200 OK", "application/json", hub.progress_json().to_string()),
            "/trace" => ("200 OK", "application/json", hub.trace_json().to_string()),
            _ => ("404 Not Found", "text/plain", format!("no route {path}\n")),
        }
    };
    let header = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Minimal HTTP GET against a status endpoint; returns the body of a
/// 200 response. Used by `drescal monitor` and the tests.
pub fn http_get(addr: &str, path: &str, timeout: Duration) -> Result<String> {
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| Error::msg(e).context(format!("resolving {addr}")))?
        .next()
        .ok_or_else(|| Error::msg(format!("{addr} resolved to no address")))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)
        .map_err(|e| Error::msg(e).context(format!("connecting to {addr}")))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.write_all(
        format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut response = Vec::new();
    let mut chunk = [0u8; 16 * 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                response.extend_from_slice(&chunk[..n]);
                if response.len() > 64 * 1024 * 1024 {
                    return Err(Error::msg("status response exceeds 64MB"));
                }
            }
            Err(e) => return Err(Error::msg(e).context(format!("reading {addr}{path}"))),
        }
    }
    let text = String::from_utf8_lossy(&response);
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| Error::msg(format!("malformed HTTP response from {addr}{path}")))?;
    let status_line = head.lines().next().unwrap_or("");
    if !status_line.contains(" 200 ") {
        return Err(Error::msg(format!("{addr}{path} returned {status_line}")));
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::super::TimelineSpan;
    use super::*;

    fn delta_for(iter: u32, phases: &[(&str, u64)]) -> RankTimeline {
        RankTimeline {
            rank: 0,
            pid: 100,
            epoch_ms: 1_000,
            spans: phases
                .iter()
                .map(|(label, ns)| TimelineSpan {
                    cat: "phase".to_string(),
                    label: label.to_string(),
                    start_ns: 0,
                    dur_ns: *ns,
                    bytes: 0,
                    iter,
                })
                .collect(),
            dropped: 0,
        }
    }

    #[test]
    fn hub_tracks_iterations_and_phase_totals() {
        let hub = LiveHub::new();
        hub.job_started("factorize", 10);
        hub.on_iteration(0, 0.5, true, 128, &delta_for(0, &[("pack", 10), ("gemm", 30)]));
        hub.on_iteration(1, 0.4, true, 256, &delta_for(1, &[("pack", 10), ("gemm", 40)]));
        let p = hub.progress_json();
        assert_eq!(p.get("iter").and_then(Json::as_f64), Some(1.0));
        assert_eq!(p.get("iters_total").and_then(Json::as_f64), Some(10.0));
        assert_eq!(p.get("wire_bytes").and_then(Json::as_f64), Some(256.0));
        assert_eq!(p.get("done").and_then(Json::as_bool), Some(false));
        let phases = p.get("phase_ns").unwrap();
        assert_eq!(phases.get("pack").and_then(Json::as_f64), Some(20.0));
        assert_eq!(phases.get("gemm").and_then(Json::as_f64), Some(70.0));
        assert_eq!(p.get("history").and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        // delta is prev - cur on fresh readings
        let hist = p.get("history").and_then(Json::as_arr).unwrap();
        let d = hist[1].get("delta").and_then(Json::as_f64).unwrap();
        assert!((d - 0.1).abs() < 1e-6);
    }

    #[test]
    fn metrics_exposition_has_the_advertised_families() {
        let hub = LiveHub::new();
        hub.job_started("factorize", 5);
        hub.on_iteration(0, 0.5, true, 64, &delta_for(0, &[("mu_update", 1_000_000)]));
        hub.gauge_set("workspace_bytes", 4096.0);
        let text = hub.metrics_text();
        for family in [
            "# TYPE drescal_iterations_total counter",
            "drescal_iterations_total 1",
            "# TYPE drescal_rel_error gauge",
            "drescal_phase_seconds_total{phase=\"mu_update\"}",
            "drescal_kernel_info{variant=",
            "drescal_workspace_bytes 4096",
            "drescal_iteration_seconds_count 1",
            "drescal_wire_bytes_total 64",
        ] {
            assert!(text.contains(family), "missing {family:?} in:\n{text}");
        }
    }

    #[test]
    fn absorbed_timelines_survive_for_dead_pids() {
        let hub = LiveHub::new();
        hub.job_started("factorize", 5);
        hub.absorb(delta_for(0, &[("pack", 10)]));
        let mut other = delta_for(0, &[("pack", 20)]);
        other.rank = 1;
        other.pid = 200;
        hub.absorb(other);
        // pid 200 died: only rank 0's pid survives to the final gather
        let live: BTreeSet<u64> = [100u64].into_iter().collect();
        let orphans = hub.orphan_timelines(&live);
        assert_eq!(orphans.len(), 1);
        assert_eq!(orphans[0].pid, 200);
        assert_eq!(orphans[0].spans.len(), 1);
        // and the /trace document carries both processes
        let trace = hub.trace_json().to_string();
        assert!(trace.contains("\"pid\":100"));
        assert!(trace.contains("\"pid\":200"));
    }

    #[test]
    fn transport_degradation_and_watchdog_reach_progress_and_metrics() {
        let hub = LiveHub::new();
        hub.job_started("factorize", 5);
        hub.note_transport_degraded(1, "worker 2 replaced");
        let p = hub.progress_json();
        assert_eq!(p.get("restarts").and_then(Json::as_f64), Some(1.0));
        let warnings = p.get("warnings").and_then(Json::as_arr).unwrap();
        assert_eq!(warnings.len(), 1);
        assert_eq!(warnings[0].get("kind").and_then(Json::as_str), Some("transport_degraded"));
        let text = hub.metrics_text();
        assert!(text.contains("drescal_transport_restarts_total 1"));
        assert!(text.contains("drescal_watchdog_events_total{kind=\"transport_degraded\"} 1"));
        assert_eq!(hub.finish(0.1).len(), 1);
        assert_eq!(
            hub.progress_json().get("done").and_then(Json::as_bool),
            Some(true)
        );
    }

    #[test]
    fn status_server_serves_all_routes_over_real_http() {
        let hub = Arc::new(LiveHub::new());
        hub.job_started("factorize", 3);
        hub.on_iteration(0, 0.5, true, 32, &delta_for(0, &[("pack", 5)]));
        hub.absorb(delta_for(0, &[("pack", 5)]));
        let server = StatusServer::start(0, Arc::clone(&hub)).unwrap();
        let addr = server.addr().to_string();
        let t = Duration::from_secs(5);
        assert_eq!(http_get(&addr, "/healthz", t).unwrap(), "ok\n");
        let metrics = http_get(&addr, "/metrics", t).unwrap();
        assert!(metrics.contains("drescal_iterations_total 1"));
        let progress = Json::parse(&http_get(&addr, "/progress", t).unwrap()).unwrap();
        assert_eq!(progress.get("iter").and_then(Json::as_f64), Some(0.0));
        let trace = Json::parse(&http_get(&addr, "/trace", t).unwrap()).unwrap();
        assert!(trace.get("traceEvents").and_then(Json::as_arr).is_some());
        assert!(http_get(&addr, "/nope", t).is_err());
        drop(server);
        // server is down after drop
        assert!(http_get(&addr, "/healthz", Duration::from_millis(200)).is_err());
    }
}
