//! The dataset ingestion & storage plane: real corpora, from text to
//! rank-resident tiles.
//!
//! The paper's exascale premise is that **no process ever holds the
//! global tensor** — each rank owns one `X^(i,j)` tile. The engine's
//! data plane already enforced that at compute time
//! ([`crate::engine::dataset`]); this subsystem extends it to *storage*,
//! mirroring the engine/serve split with a third plane:
//!
//! * **ingest** ([`triples`]) — a streaming importer takes a
//!   `subject<TAB>relation<TAB>object[<TAB>weight]` triple list,
//!   interns names to deterministic first-appearance ids, and routes
//!   every triple through per-shard spill files so peak memory is
//!   `O(dictionaries + largest tile)`, never `O(triples)`. CLI:
//!   `drescal ingest`.
//! * **store** ([`shard`], [`manifest`]) — one versioned binary file
//!   per (grid-row, grid-col) tile: CSR slices for sparse corpora,
//!   contiguous row-major f32 blocks for dense ones, each carrying its
//!   own FNV-1a 64 payload checksum; a JSON `manifest.json` records
//!   dims, grid, layout, per-shard checksums, the entity/relation name
//!   dictionaries, and provenance. Truncation, bit-flips, and
//!   manifest/shard mismatches surface as typed errors, never panics.
//! * **load** ([`rank_tile`], [`mmap`]) — each rank of a loading engine
//!   reads **only its own shard(s)**: the leader parses the manifest and
//!   nothing else. When the engine grid matches the ingest grid, dense
//!   tiles are memory-mapped and handed to the rank **zero-copy**
//!   ([`crate::tensor::Mat::from_shared`] windows into the mapping, with
//!   copy-on-write semantics the read-only training loop never
//!   triggers). Any other grid size re-shards at load time by splicing
//!   the overlapping shards. Wired into the engine as
//!   [`crate::engine::DatasetSpec::File`] (CLI: `--data
//!   file:<manifest>`).
//!
//! The [`stats`] counters (shard reads, mapped vs spliced tiles) make
//! the locality guarantees counter-assertable in tests, the same way
//! `EngineStats::tile_builds` proves tile reuse.

pub mod manifest;
pub mod mmap;
pub mod shard;
pub mod triples;

pub use manifest::{IngestProvenance, Layout, ShardMeta, StoreManifest};
pub use mmap::{MappedF32, MappedU16, MmapFile};
pub use shard::{ShardDigest, ShardHeader};
pub use triples::{ingest_triples_file, IngestOptions, IngestReport};

use crate::comm::Grid;
use crate::coordinator::JobData;
use crate::error::Result;
use crate::rescal::LocalTile;
use crate::tensor::{Csr, HalfMat, HalfTensor3, Mat, Tensor3};
use crate::{bail, err};

/// Process-wide storage-plane counters, for tests and diagnostics.
pub mod stats {
    use std::sync::atomic::{AtomicUsize, Ordering};

    static SHARD_READS: AtomicUsize = AtomicUsize::new(0);
    static SHARD_BYTES_READ: AtomicUsize = AtomicUsize::new(0);
    static MAPPED_TILES: AtomicUsize = AtomicUsize::new(0);
    static MAPPED_BYTES: AtomicUsize = AtomicUsize::new(0);
    static SPLICED_TILES: AtomicUsize = AtomicUsize::new(0);

    /// A snapshot of the cumulative counters.
    #[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
    pub struct StoreStats {
        /// Shard payloads opened and checksum-verified.
        pub shard_reads: usize,
        /// Total shard bytes those reads covered.
        pub shard_bytes_read: usize,
        /// Dense tiles resident as zero-copy mmap windows.
        pub mapped_tiles: usize,
        /// Payload bytes backing those mapped tiles.
        pub mapped_bytes: usize,
        /// Tiles materialized by re-sharding (grid mismatch).
        pub spliced_tiles: usize,
    }

    pub fn snapshot() -> StoreStats {
        StoreStats {
            shard_reads: SHARD_READS.load(Ordering::SeqCst),
            shard_bytes_read: SHARD_BYTES_READ.load(Ordering::SeqCst),
            mapped_tiles: MAPPED_TILES.load(Ordering::SeqCst),
            mapped_bytes: MAPPED_BYTES.load(Ordering::SeqCst),
            spliced_tiles: SPLICED_TILES.load(Ordering::SeqCst),
        }
    }

    pub(crate) fn note_shard_read(bytes: usize) {
        SHARD_READS.fetch_add(1, Ordering::SeqCst);
        SHARD_BYTES_READ.fetch_add(bytes, Ordering::SeqCst);
    }

    pub(crate) fn note_mapped_tile(bytes: usize) {
        MAPPED_TILES.fetch_add(1, Ordering::SeqCst);
        MAPPED_BYTES.fetch_add(bytes, Ordering::SeqCst);
    }

    pub(crate) fn note_spliced_tile() {
        SPLICED_TILES.fetch_add(1, Ordering::SeqCst);
    }
}

/// Read one shard's tile exactly as stored (no re-sharding).
fn read_tile_direct(man: &StoreManifest, row: usize, col: usize) -> Result<LocalTile> {
    let meta = man.shard(row, col)?;
    let path = man.shard_path(meta);
    let (hd, map) = shard::read_shard(&path, Some(meta))?;
    let src_grid = Grid::new(man.grid * man.grid);
    let (r0, r1) = src_grid.chunk(man.n, row);
    let (c0, c1) = src_grid.chunk(man.n, col);
    if hd.rows != r1 - r0 || hd.cols != c1 - c0 || hd.m != man.m {
        bail!(
            "shard {} holds a {}×{}×{} tile but the manifest expects {}×{}×{} at \
             ({row}, {col})",
            path.display(),
            hd.rows,
            hd.cols,
            hd.m,
            r1 - r0,
            c1 - c0,
            man.m
        );
    }
    if hd.dtype != man.dtype {
        bail!(
            "shard {} stores {} elements but the manifest says {}",
            path.display(),
            hd.dtype.as_str(),
            man.dtype.as_str()
        );
    }
    match man.layout {
        Layout::Dense => {
            if hd.kind != shard::KIND_DENSE {
                bail!("shard {} is sparse but the manifest says dense", path.display());
            }
            if hd.dtype.is_half() {
                let (tile, mapped) = shard::dense_half_tile_from(map, &hd, &path)?;
                if mapped {
                    stats::note_mapped_tile(hd.payload_len as usize);
                }
                return Ok(LocalTile::DenseHalf(tile));
            }
            let (tile, mapped) = shard::dense_tile_from(map, &hd, &path)?;
            if mapped {
                stats::note_mapped_tile(hd.payload_len as usize);
            }
            Ok(LocalTile::Dense(tile))
        }
        Layout::Sparse => {
            if hd.kind != shard::KIND_SPARSE {
                bail!("shard {} is dense but the manifest says sparse", path.display());
            }
            Ok(LocalTile::Sparse(shard::sparse_tile_from(&map, &hd, &path)?))
        }
    }
}

/// Materialize rank (row, col)'s tile of an engine grid from an ingested
/// dataset. Runs **on the rank**: only the shards overlapping this tile
/// are opened; the leader never reads a payload.
///
/// * engine grid == ingest grid: the tile *is* one shard — dense tiles
///   become zero-copy mmap windows;
/// * otherwise the corpus is **re-sharded at load time**: the rank
///   splices its row/col range out of every overlapping shard. Dense
///   source shards are read through the mapping and only the overlap is
///   copied; sparse shards are decoded as a row *window*
///   ([`shard::sparse_rows_from`]) — so splice memory stays
///   O(target tile), never O(source shard), even when many ranks load a
///   grid-1 corpus concurrently.
pub fn rank_tile(
    man: &StoreManifest,
    grid: &Grid,
    row: usize,
    col: usize,
) -> Result<LocalTile> {
    if grid.q == man.grid {
        return read_tile_direct(man, row, col);
    }
    stats::note_spliced_tile();
    let (r0, r1) = grid.chunk(man.n, row);
    let (c0, c1) = grid.chunk(man.n, col);
    let (rows, cols) = (r1 - r0, c1 - c0);
    let src_grid = Grid::new(man.grid * man.grid);
    let splice_half = man.layout == Layout::Dense && man.dtype.is_half();
    let mut dense_slices: Vec<Mat> = match man.layout {
        Layout::Dense if !splice_half => (0..man.m).map(|_| Mat::zeros(rows, cols)).collect(),
        _ => Vec::new(),
    };
    // half tiles splice as raw u16 payloads — the 16-bit patterns move
    // without ever widening (0x0000 is +0.0 in both f16 and bf16)
    let mut half_slices: Vec<Vec<u16>> = if splice_half {
        (0..man.m).map(|_| vec![0u16; rows * cols]).collect()
    } else {
        Vec::new()
    };
    let mut sparse_trips: Vec<Vec<(usize, usize, f32)>> = match man.layout {
        Layout::Sparse => vec![Vec::new(); man.m],
        Layout::Dense => Vec::new(),
    };
    for si in 0..man.grid {
        let (sr0, sr1) = src_grid.chunk(man.n, si);
        if sr1 <= r0 || sr0 >= r1 {
            continue;
        }
        for sj in 0..man.grid {
            let (sc0, sc1) = src_grid.chunk(man.n, sj);
            if sc1 <= c0 || sc0 >= c1 {
                continue;
            }
            let (rlo, rhi) = (r0.max(sr0), r1.min(sr1));
            let (clo, chi) = (c0.max(sc0), c1.min(sc1));
            match man.layout {
                Layout::Dense => match read_tile_direct(man, si, sj)? {
                    LocalTile::Dense(t3) => {
                        for (t, dst) in dense_slices.iter_mut().enumerate() {
                            let src = t3.slice(t);
                            for gr in rlo..rhi {
                                let srow = &src.row(gr - sr0)[clo - sc0..chi - sc0];
                                dst.row_mut(gr - r0)[clo - c0..chi - c0]
                                    .copy_from_slice(srow);
                            }
                        }
                    }
                    LocalTile::DenseHalf(t3) => {
                        for (t, dst) in half_slices.iter_mut().enumerate() {
                            let src = t3.slice(t);
                            let sd = src.as_u16_slice();
                            let scols = src.cols();
                            for gr in rlo..rhi {
                                let sbase = (gr - sr0) * scols;
                                let dbase = (gr - r0) * cols;
                                dst[dbase + (clo - c0)..dbase + (chi - c0)].copy_from_slice(
                                    &sd[sbase + (clo - sc0)..sbase + (chi - sc0)],
                                );
                            }
                        }
                    }
                    LocalTile::Sparse(_) => {
                        bail!("dense manifest produced a sparse tile")
                    }
                },
                Layout::Sparse => {
                    // decode only this rank's row window of the shard —
                    // never the shard's full CSR arrays
                    let meta = man.shard(si, sj)?;
                    let path = man.shard_path(meta);
                    let (hd, map) = shard::read_shard(&path, Some(meta))?;
                    if hd.rows != sr1 - sr0 || hd.cols != sc1 - sc0 || hd.m != man.m {
                        bail!(
                            "shard {} holds a {}×{}×{} tile but the manifest expects \
                             {}×{}×{} at ({si}, {sj})",
                            path.display(),
                            hd.rows,
                            hd.cols,
                            hd.m,
                            sr1 - sr0,
                            sc1 - sc0,
                            man.m
                        );
                    }
                    let window =
                        shard::sparse_rows_from(&map, &hd, &path, rlo - sr0, rhi - sr0)?;
                    for (t, csr) in window.iter().enumerate() {
                        for wr in 0..csr.rows() {
                            let gr = rlo + wr;
                            let (cols_idx, vals) = csr.row_entries(wr);
                            for (&j, &v) in cols_idx.iter().zip(vals) {
                                let gc = sc0 + j;
                                if gc >= clo && gc < chi {
                                    sparse_trips[t].push((gr - r0, gc - c0, v));
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(match man.layout {
        Layout::Dense if splice_half => LocalTile::DenseHalf(HalfTensor3::from_slices(
            half_slices
                .into_iter()
                .map(|v| HalfMat::from_raw(rows, cols, man.dtype, v))
                .collect(),
        )),
        Layout::Dense => LocalTile::Dense(Tensor3::from_slices(dense_slices)),
        Layout::Sparse => LocalTile::Sparse(
            sparse_trips
                .into_iter()
                .map(|t| Csr::from_triplets(rows, cols, t))
                .collect(),
        ),
    })
}

/// Materialize the whole corpus on the caller — the legacy leader-side
/// form, for parity tests and the `DataSpec::load` compatibility path.
/// Production loading goes through [`rank_tile`] instead.
pub fn read_dataset_inline(man: &StoreManifest) -> Result<JobData> {
    match rank_tile(man, &Grid::new(1), 0, 0)? {
        LocalTile::Dense(t3) => Ok(JobData::dense(t3)),
        // the inline compat path widens — callers of this legacy form
        // want a plain f32 tensor; rank-resident loading keeps half
        LocalTile::DenseHalf(t3) => Ok(JobData::dense(t3.to_f32())),
        LocalTile::Sparse(slices) => {
            // an ingested corpus is always square (n×n×m) by construction
            if slices.iter().any(|c| c.rows() != man.n || c.cols() != man.n) {
                return Err(err!("corpus tiles do not assemble to an n×n tensor"));
            }
            Ok(JobData::sparse(slices))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use std::path::PathBuf;

    fn tmp(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("drescal_store_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn toy_corpus(dir: &PathBuf, grid: usize, dense: bool) -> StoreManifest {
        let input = dir.join("kg.tsv");
        let mut text = String::new();
        let mut rng = Rng::new(41);
        for _ in 0..300 {
            text.push_str(&format!(
                "e{}\tr{}\te{}\n",
                rng.below(19),
                rng.below(2),
                rng.below(19)
            ));
        }
        std::fs::write(&input, &text).unwrap();
        let out = dir.join(format!("corpus_g{grid}_{dense}"));
        let report = ingest_triples_file(
            &input,
            &out,
            &IngestOptions { grid, dense, source: "kg.tsv".into(), ..IngestOptions::default() },
        )
        .unwrap();
        StoreManifest::load(&report.manifest_path).unwrap()
    }

    /// Re-sharding: any (ingest grid, engine grid) pair assembles the
    /// same global tensor, tile by tile.
    #[test]
    fn resharding_is_grid_invariant() {
        let dir = tmp("reshard");
        for dense in [false, true] {
            let man1 = toy_corpus(&dir, 1, dense);
            let man2 = toy_corpus(&dir, 2, dense);
            let full1 = match read_dataset_inline(&man1).unwrap() {
                JobData::Dense(x) => (*x).clone(),
                JobData::Sparse(s) => {
                    Tensor3::from_slices(s.iter().map(|c| c.to_dense()).collect())
                }
            };
            let full2 = match read_dataset_inline(&man2).unwrap() {
                JobData::Dense(x) => (*x).clone(),
                JobData::Sparse(s) => {
                    Tensor3::from_slices(s.iter().map(|c| c.to_dense()).collect())
                }
            };
            for t in 0..man1.m {
                assert_eq!(
                    full1.slice(t).as_slice(),
                    full2.slice(t).as_slice(),
                    "dense={dense} slice {t}: grid-1 and grid-2 ingests disagree"
                );
            }
            // loading the grid-1 corpus on a 2×2 engine matches the
            // grid-2 corpus's direct shards
            let grid = Grid::new(4);
            for row in 0..2 {
                for col in 0..2 {
                    let spliced = rank_tile(&man1, &grid, row, col).unwrap();
                    let direct = rank_tile(&man2, &grid, row, col).unwrap();
                    match (spliced, direct) {
                        (LocalTile::Dense(a), LocalTile::Dense(b)) => {
                            for t in 0..man1.m {
                                assert_eq!(a.slice(t).as_slice(), b.slice(t).as_slice());
                            }
                        }
                        (LocalTile::Sparse(a), LocalTile::Sparse(b)) => {
                            for t in 0..man1.m {
                                assert_eq!(
                                    a[t].to_dense().as_slice(),
                                    b[t].to_dense().as_slice()
                                );
                            }
                        }
                        _ => panic!("tile kind mismatch"),
                    }
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Half-precision corpora load as [`LocalTile::DenseHalf`] and
    /// re-shard u16-exactly: splicing moves 16-bit patterns, never
    /// widens.
    #[test]
    fn half_corpus_reshards_u16_exactly() {
        use crate::tensor::DType;
        let dir = tmp("half");
        let input = dir.join("kg.tsv");
        let mut text = String::new();
        let mut rng = Rng::new(43);
        for _ in 0..300 {
            text.push_str(&format!(
                "e{}\tr{}\te{}\t{:.3}\n",
                rng.below(19),
                rng.below(2),
                rng.below(19),
                rng.uniform_range(0.1, 2.0)
            ));
        }
        std::fs::write(&input, &text).unwrap();
        let mk = |grid| IngestOptions {
            grid,
            dense: true,
            dtype: DType::Bf16,
            source: String::new(),
        };
        let load = |g: usize, out: &str| {
            let report = ingest_triples_file(&input, &dir.join(out), &mk(g)).unwrap();
            StoreManifest::load(&report.manifest_path).unwrap()
        };
        let man1 = load(1, "g1");
        let man2 = load(2, "g2");
        let grid = Grid::new(4);
        for row in 0..2 {
            for col in 0..2 {
                let spliced = rank_tile(&man1, &grid, row, col).unwrap();
                let direct = rank_tile(&man2, &grid, row, col).unwrap();
                match (spliced, direct) {
                    (LocalTile::DenseHalf(a), LocalTile::DenseHalf(b)) => {
                        assert_eq!(b.dtype(), DType::Bf16);
                        for t in 0..man1.m {
                            assert_eq!(
                                a.slice(t).as_u16_slice(),
                                b.slice(t).as_u16_slice(),
                                "tile ({row}, {col}) slice {t}"
                            );
                        }
                    }
                    _ => panic!("expected half tiles"),
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Matching grids memory-map dense tiles zero-copy (on unix,
    /// little-endian): the resident slices still read from shared
    /// storage.
    #[test]
    fn matching_grid_dense_tiles_are_mapped() {
        let dir = tmp("mapped");
        let man = toy_corpus(&dir, 2, true);
        let grid = Grid::new(4);
        let before = stats::snapshot();
        let tile = rank_tile(&man, &grid, 1, 0).unwrap();
        let after = stats::snapshot();
        assert!(after.shard_reads > before.shard_reads);
        match tile {
            LocalTile::Dense(t3) => {
                if cfg!(unix) && cfg!(target_endian = "little") {
                    assert!(
                        t3.slice(0).is_shared(),
                        "dense tile must window the mapping zero-copy"
                    );
                    assert!(after.mapped_tiles > before.mapped_tiles);
                }
            }
            _ => panic!("expected dense"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
