//! Streaming triple-list importer: text triples → binary tile shards.
//!
//! Input is one triple per line, `subject<TAB>relation<TAB>object` with
//! an optional fourth `weight` column (default 1.0); blank lines and
//! `#` comments are skipped, and plain whitespace separation is accepted
//! when names contain no spaces. Entity and relation names are interned
//! to deterministic ids in **first-appearance order** (subject before
//! object within a line), so re-ingesting the same file always yields
//! the same ids and dictionaries.
//!
//! The importer never holds the triple set in memory:
//!
//! 1. **pass 1** streams the file to build the name dictionaries and
//!    count triples (memory: the dictionaries);
//! 2. **pass 2** streams the file again, routing each triple's 16-byte
//!    COO record to a per-shard spill file through bounded in-memory
//!    buffers appended one file at a time (memory: g² × 16 KiB buffers;
//!    file descriptors: O(1), so the grid is not capped by the fd
//!    limit);
//! 3. **finalize** materializes shards in parallel, one tile per
//!    worker thread: each worker reads a spill, builds CSR slices
//!    (duplicates summed) or a dense block, writes the checksummed
//!    shard file, and deletes the spill (memory: one tile per worker,
//!    workers capped at the machine's parallelism).
//!
//! Peak memory is therefore `O(dictionaries + workers × largest tile)`,
//! never `O(triples)`. Shard files and manifest order are byte-identical
//! to a sequential finalize — parallelism only changes wall-clock time.

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufRead, BufReader, Read, Write};
use std::path::{Path, PathBuf};

use crate::comm::Grid;
use crate::error::{Context as _, Result};
use crate::tensor::{Csr, DType, HalfTensor3, Mat, Tensor3};
use crate::{bail, err};

use super::manifest::{IngestProvenance, Layout, ShardMeta, StoreManifest};
use super::shard;

/// How `ingest_triples_file` shards a corpus.
#[derive(Clone, Debug)]
pub struct IngestOptions {
    /// Shard grid side length g: the output holds g×g tile shards.
    /// Matches engines of √p = g with zero re-sharding; any other grid
    /// size re-shards at load time.
    pub grid: usize,
    /// Store dense row-major blocks (memory-mappable) instead of CSR.
    pub dense: bool,
    /// Element type for dense shard payloads. `F16`/`Bf16` halve shard
    /// bytes (duplicates still sum in f32 before the final narrowing);
    /// requires `dense` — CSR payloads stay f32.
    pub dtype: DType,
    /// Provenance label recorded in the manifest (usually the input
    /// path).
    pub source: String,
}

impl Default for IngestOptions {
    fn default() -> Self {
        IngestOptions { grid: 1, dense: false, dtype: DType::F32, source: String::new() }
    }
}

/// What an ingest run produced.
#[derive(Clone, Debug)]
pub struct IngestReport {
    /// Distinct entities interned.
    pub n: usize,
    /// Distinct relations interned.
    pub m: usize,
    /// Triple lines imported (before duplicate merging).
    pub triples: u64,
    pub grid: usize,
    pub layout: Layout,
    /// Total shard bytes written.
    pub shard_bytes: u64,
    pub manifest_path: PathBuf,
}

impl IngestReport {
    /// JSON form (for `drescal ingest --json`).
    pub fn to_json(&self) -> crate::json::Json {
        use crate::json::Json;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("kind".to_string(), Json::Str("ingest_report".to_string()));
        obj.insert("n".to_string(), Json::Num(self.n as f64));
        obj.insert("m".to_string(), Json::Num(self.m as f64));
        obj.insert("triples".to_string(), Json::Num(self.triples as f64));
        obj.insert("grid".to_string(), Json::Num(self.grid as f64));
        obj.insert("layout".to_string(), Json::Str(self.layout.as_str().to_string()));
        obj.insert("shard_bytes".to_string(), Json::Num(self.shard_bytes as f64));
        obj.insert(
            "manifest".to_string(),
            Json::Str(self.manifest_path.display().to_string()),
        );
        Json::Obj(obj)
    }
}

/// First-appearance-order name interner.
#[derive(Default)]
struct Interner {
    ids: HashMap<String, u32>,
    names: Vec<String>,
}

impl Interner {
    fn intern(&mut self, name: &str) -> Result<u32> {
        if let Some(&id) = self.ids.get(name) {
            return Ok(id);
        }
        if self.names.len() >= u32::MAX as usize {
            bail!("dictionary overflow: more than {} distinct names", u32::MAX);
        }
        let id = self.names.len() as u32;
        self.ids.insert(name.to_string(), id);
        self.names.push(name.to_string());
        Ok(id)
    }

    fn get(&self, name: &str) -> Option<u32> {
        self.ids.get(name).copied()
    }

    fn len(&self) -> usize {
        self.names.len()
    }
}

/// One parsed triple line: `(subject, relation, object, weight)`.
type ParsedLine<'a> = (&'a str, &'a str, &'a str, f32);

/// Parse one line; `None` for blanks and `#` comments.
fn parse_line(line: &str, lineno: usize) -> Result<Option<ParsedLine<'_>>> {
    let t = line.trim();
    if t.is_empty() || t.starts_with('#') {
        return Ok(None);
    }
    // TSV first; fall back to any-whitespace separation for hand-written
    // files whose names contain no spaces
    let fields: Vec<&str> = if t.contains('\t') {
        t.split('\t').map(str::trim).collect()
    } else {
        t.split_whitespace().collect()
    };
    match fields.as_slice() {
        &[s, r, o] => Ok(Some((s, r, o, 1.0))),
        &[s, r, o, w] => {
            let w: f32 = w.parse().map_err(|_| {
                err!("line {lineno}: weight '{w}' is not a number")
            })?;
            Ok(Some((s, r, o, w)))
        }
        _ => Err(err!(
            "line {lineno}: expected subject<TAB>relation<TAB>object[<TAB>weight], got {} \
             field(s)",
            fields.len()
        )),
    }
}

/// Stream every triple of `input` through `f`.
fn for_each_triple(
    input: &Path,
    mut f: impl FnMut(ParsedLine<'_>) -> Result<()>,
) -> Result<()> {
    let file = File::open(input)
        .with_context(|| format!("opening triple list {}", input.display()))?;
    let reader = BufReader::new(file);
    for (i, line) in reader.lines().enumerate() {
        let lineno = i + 1;
        let line = line.with_context(|| format!("reading line {lineno}"))?;
        if let Some(parsed) = parse_line(&line, lineno)? {
            f(parsed)?;
        }
    }
    Ok(())
}

/// Spill record: one triple routed to its shard, in tile-local
/// coordinates. 16 little-endian bytes.
const SPILL_RECORD: usize = 16;

fn spill_record(li: u32, lj: u32, rel: u32, w: f32) -> [u8; SPILL_RECORD] {
    let mut rec = [0u8; SPILL_RECORD];
    rec[0..4].copy_from_slice(&li.to_le_bytes());
    rec[4..8].copy_from_slice(&lj.to_le_bytes());
    rec[8..12].copy_from_slice(&rel.to_le_bytes());
    rec[12..16].copy_from_slice(&w.to_le_bytes());
    rec
}

/// Flush a spill buffer once it holds this many bytes.
const SPILL_FLUSH_BYTES: usize = 16 << 10;

/// One shard's spill: records collect in a bounded memory buffer and
/// append to the file in chunks, so pass 2 holds **one** file
/// descriptor at a time however large the grid — keeping g² open
/// `BufWriter`s would hit the process fd limit around g ≈ 32.
struct Spill {
    path: PathBuf,
    buf: Vec<u8>,
}

impl Spill {
    fn create(path: PathBuf) -> Result<Spill> {
        // materialize an empty file now so finalize can read it even if
        // this shard receives no records
        File::create(&path)
            .with_context(|| format!("creating spill {}", path.display()))?;
        Ok(Spill { path, buf: Vec::new() })
    }

    fn push(&mut self, rec: &[u8; SPILL_RECORD]) -> Result<()> {
        self.buf.extend_from_slice(rec);
        if self.buf.len() >= SPILL_FLUSH_BYTES {
            self.flush()?;
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        if self.buf.is_empty() {
            return Ok(());
        }
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| format!("appending spill {}", self.path.display()))?;
        f.write_all(&self.buf).context("writing spill records")?;
        self.buf.clear();
        Ok(())
    }
}

/// Ingest a triple file into `out_dir`: g×g binary tile shards plus
/// `manifest.json`. Streaming — see the module docs for the memory
/// bound.
pub fn ingest_triples_file(
    input: &Path,
    out_dir: &Path,
    opts: &IngestOptions,
) -> Result<IngestReport> {
    if opts.grid == 0 {
        bail!("ingest grid must be >= 1");
    }
    if opts.dtype.is_half() && !opts.dense {
        bail!(
            "--dtype {} requires --dense: sparse shards interleave CSR index structure \
             and stay f32",
            opts.dtype.as_str()
        );
    }
    // pass 1: dictionaries + triple count
    let mut ents = Interner::default();
    let mut rels = Interner::default();
    let mut triples = 0u64;
    for_each_triple(input, |(s, r, o, _w)| {
        ents.intern(s)?;
        rels.intern(r)?;
        ents.intern(o)?;
        triples += 1;
        Ok(())
    })?;
    let (n, m) = (ents.len(), rels.len());
    if triples == 0 {
        bail!("{} holds no triples", input.display());
    }
    if opts.grid > n {
        bail!(
            "ingest grid {} exceeds the corpus's {} entities — every tile needs at \
             least one row",
            opts.grid,
            n
        );
    }

    // pass 2: route COO records to per-shard spill files
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating dataset directory {}", out_dir.display()))?;
    let g = opts.grid;
    let grid = Grid::new(g * g);
    // invert Grid::chunk: which chunk owns global index i
    let base = n / g;
    let rem = n % g;
    let chunk_of = move |i: usize| -> usize {
        if i < rem * (base + 1) {
            i / (base + 1)
        } else {
            rem + (i - rem * (base + 1)) / base
        }
    };
    let spill_path =
        |gi: usize, gj: usize| out_dir.join(format!(".spill_{gi}_{gj}.coo"));
    let mut spills: Vec<Spill> = Vec::with_capacity(g * g);
    for gi in 0..g {
        for gj in 0..g {
            spills.push(Spill::create(spill_path(gi, gj))?);
        }
    }
    for_each_triple(input, |(s, r, o, w)| {
        // pass-1 dictionaries must still cover the file
        let (si, ri, oi) = match (ents.get(s), rels.get(r), ents.get(o)) {
            (Some(si), Some(ri), Some(oi)) => (si as usize, ri as usize, oi as usize),
            _ => bail!("{} changed between ingest passes", input.display()),
        };
        let (gi, gj) = (chunk_of(si), chunk_of(oi));
        let (r0, _) = grid.chunk(n, gi);
        let (c0, _) = grid.chunk(n, gj);
        let rec =
            spill_record((si - r0) as u32, (oi - c0) as u32, ri as u32, w);
        spills[gi * g + gj].push(&rec)?;
        Ok(())
    })?;
    for s in &mut spills {
        s.flush()?;
    }
    drop(spills);

    // finalize: materialize shards in parallel — every tile is owned by
    // exactly one worker (spill read, tile build, checksummed write, and
    // spill cleanup are all tile-local), so workers share nothing but
    // the atomic work counter and their own result slots
    let layout = if opts.dense { Layout::Dense } else { Layout::Sparse };
    let finalize_tile = |gi: usize, gj: usize| -> Result<ShardMeta> {
        let (r0, r1) = grid.chunk(n, gi);
        let (c0, c1) = grid.chunk(n, gj);
        let (rows, cols) = (r1 - r0, c1 - c0);
        let spath = spill_path(gi, gj);
        let mut raw = Vec::new();
        File::open(&spath)
            .and_then(|mut f| f.read_to_end(&mut raw))
            .with_context(|| format!("reading spill {}", spath.display()))?;
        let records = raw.chunks_exact(SPILL_RECORD).map(|rec| {
            let u = |a: usize| {
                u32::from_le_bytes(rec[a..a + 4].try_into().unwrap()) as usize
            };
            let w = f32::from_le_bytes(rec[12..16].try_into().unwrap());
            (u(0), u(4), u(8), w)
        });
        let file_name = format!("shard_{gi}_{gj}.bin");
        let path = out_dir.join(&file_name);
        let digest = if opts.dense {
            let mut slices: Vec<Mat> = (0..m).map(|_| Mat::zeros(rows, cols)).collect();
            for (li, lj, t, w) in records {
                slices[t][(li, lj)] += w; // duplicates sum
            }
            let x = Tensor3::from_slices(slices);
            if opts.dtype.is_half() {
                // accumulate in f32, narrow once at the end
                shard::write_dense_half_shard(&path, &HalfTensor3::from_tensor3(&x, opts.dtype))?
            } else {
                shard::write_dense_shard(&path, &x)?
            }
        } else {
            let mut trips: Vec<Vec<(usize, usize, f32)>> = vec![Vec::new(); m];
            for (li, lj, t, w) in records {
                trips[t].push((li, lj, w));
            }
            let slices: Vec<Csr> = trips
                .into_iter()
                .map(|t| Csr::from_triplets(rows, cols, t)) // duplicates sum
                .collect();
            shard::write_sparse_shard(&path, rows, cols, &slices)?
        };
        std::fs::remove_file(&spath).ok();
        Ok(ShardMeta {
            row: gi,
            col: gj,
            file: file_name,
            bytes: digest.bytes,
            checksum: digest.checksum,
        })
    };
    let tiles: Vec<(usize, usize)> =
        (0..g).flat_map(|gi| (0..g).map(move |gj| (gi, gj))).collect();
    let workers = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(1)
        .min(tiles.len());
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<Result<ShardMeta>>>> =
        tiles.iter().map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let idx = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let Some(&(gi, gj)) = tiles.get(idx) else { break };
                let res = finalize_tile(gi, gj);
                *slots[idx].lock().unwrap() = Some(res);
            });
        }
    });
    // slots are in (gi, gj) row-major order, so the manifest's shard
    // order is identical to the old sequential finalize
    let mut shards = Vec::with_capacity(g * g);
    let mut shard_bytes = 0u64;
    for slot in slots {
        let meta = slot
            .into_inner()
            .unwrap()
            .expect("scope joined every finalize worker")?;
        shard_bytes += meta.bytes;
        shards.push(meta);
    }

    let manifest = StoreManifest {
        n,
        m,
        grid: g,
        layout,
        dtype: opts.dtype,
        shards,
        entities: ents.names,
        relations: rels.names,
        provenance: IngestProvenance { source: opts.source.clone(), triples },
        dir: out_dir.to_path_buf(),
    };
    manifest.validate()?;
    let manifest_path = manifest.save()?;
    Ok(IngestReport {
        n,
        m,
        triples,
        grid: g,
        layout,
        shard_bytes,
        manifest_path,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("drescal_triples_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn parse_line_grammar() {
        assert_eq!(parse_line("a\tb\tc", 1).unwrap(), Some(("a", "b", "c", 1.0)));
        assert_eq!(parse_line("a\tb\tc\t2.5", 1).unwrap(), Some(("a", "b", "c", 2.5)));
        assert_eq!(parse_line("a b c", 1).unwrap(), Some(("a", "b", "c", 1.0)));
        assert_eq!(parse_line("", 1).unwrap(), None);
        assert_eq!(parse_line("   ", 1).unwrap(), None);
        assert_eq!(parse_line("# comment", 1).unwrap(), None);
        assert!(parse_line("a\tb", 3).unwrap_err().to_string().contains("line 3"));
        assert!(parse_line("a\tb\tc\tx", 4).unwrap_err().to_string().contains("weight"));
    }

    #[test]
    fn interning_is_first_appearance_order() {
        let dir = tmp("intern");
        let input = dir.join("toy.tsv");
        std::fs::write(
            &input,
            "alice\tknows\tbob\nbob\tknows\tcarol\nalice\tlikes\tcarol\t2.5\nalice\tknows\tbob\n",
        )
        .unwrap();
        let out = dir.join("corpus");
        let report = ingest_triples_file(
            &input,
            &out,
            &IngestOptions { grid: 1, source: "toy.tsv".into(), ..IngestOptions::default() },
        )
        .unwrap();
        assert_eq!((report.n, report.m, report.triples), (3, 2, 4));
        let man = StoreManifest::load(&report.manifest_path).unwrap();
        assert_eq!(man.entities, vec!["alice", "bob", "carol"]);
        assert_eq!(man.relations, vec!["knows", "likes"]);
        assert_eq!(man.provenance.triples, 4);
        // the duplicate alice-knows-bob line summed to 2.0
        let meta = man.shard(0, 0).unwrap();
        let (hd, map) = shard::read_shard(&man.shard_path(meta), Some(meta)).unwrap();
        let slices = shard::sparse_tile_from(&map, &hd, &man.shard_path(meta)).unwrap();
        let knows = slices[0].to_dense();
        assert_eq!(knows[(0, 1)], 2.0, "duplicate triples must sum");
        assert_eq!(knows[(1, 2)], 1.0);
        let likes = slices[1].to_dense();
        assert_eq!(likes[(0, 2)], 2.5, "explicit weight column");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grid_routing_partitions_exactly() {
        let dir = tmp("routing");
        let input = dir.join("kg.tsv");
        let mut text = String::new();
        let mut rng = crate::rng::Rng::new(17);
        for _ in 0..400 {
            text.push_str(&format!(
                "e{}\tr{}\te{}\n",
                rng.below(23),
                rng.below(3),
                rng.below(23)
            ));
        }
        std::fs::write(&input, &text).unwrap();
        let g1 = dir.join("g1");
        let g2 = dir.join("g2");
        let mk = |grid| IngestOptions { grid, ..IngestOptions::default() };
        let r1 = ingest_triples_file(&input, &g1, &mk(1)).unwrap();
        let r2 = ingest_triples_file(&input, &g2, &mk(2)).unwrap();
        assert_eq!(r1.n, r2.n);
        assert_eq!(r1.triples, r2.triples);
        // the g=2 shards partition the corpus: total nnz matches g=1
        let nnz_of = |path: &Path| -> usize {
            let man = StoreManifest::load(path).unwrap();
            let mut nnz = 0;
            for meta in &man.shards {
                let p = man.shard_path(meta);
                let (hd, map) = shard::read_shard(&p, Some(meta)).unwrap();
                for c in shard::sparse_tile_from(&map, &hd, &p).unwrap() {
                    nnz += c.nnz();
                }
            }
            nnz
        };
        assert_eq!(nnz_of(&g1), nnz_of(&g2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn half_dense_ingest_halves_shard_bytes_and_quantizes() {
        let dir = tmp("half");
        let input = dir.join("kg.tsv");
        let mut text = String::new();
        let mut rng = crate::rng::Rng::new(19);
        for _ in 0..200 {
            text.push_str(&format!(
                "e{}\tr{}\te{}\t{:.3}\n",
                rng.below(11),
                rng.below(2),
                rng.below(11),
                rng.uniform_range(0.1, 3.0)
            ));
        }
        std::fs::write(&input, &text).unwrap();
        let mk = |dtype| IngestOptions { dense: true, dtype, ..IngestOptions::default() };
        let r32 = ingest_triples_file(&input, &dir.join("f32"), &mk(DType::F32)).unwrap();
        let r16 = ingest_triples_file(&input, &dir.join("f16"), &mk(DType::F16)).unwrap();
        // per-shard payloads halve; only the fixed 64-byte headers remain
        assert_eq!(
            r16.shard_bytes - 64,
            (r32.shard_bytes - 64) / 2,
            "f16 shards must hold half the payload bytes"
        );
        // the loaded corpus is the f32 corpus, element-wise quantized
        let man32 = StoreManifest::load(&r32.manifest_path).unwrap();
        let man16 = StoreManifest::load(&r16.manifest_path).unwrap();
        assert_eq!(man16.dtype, DType::F16);
        let full32 = match super::super::read_dataset_inline(&man32).unwrap() {
            crate::coordinator::JobData::Dense(x) => (*x).clone(),
            _ => panic!("expected dense"),
        };
        let full16 = match super::super::read_dataset_inline(&man16).unwrap() {
            crate::coordinator::JobData::Dense(x) => (*x).clone(),
            _ => panic!("expected dense"),
        };
        for t in 0..man32.m {
            let (a, b) = (full32.slice(t).as_slice(), full16.slice(t).as_slice());
            for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
                assert_eq!(y, DType::F16.quantize(x), "slice {t} element {i}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_inputs_are_typed_errors() {
        let dir = tmp("bad");
        let input = dir.join("bad.tsv");
        std::fs::write(&input, "only_two\tfields\n").unwrap();
        let out = dir.join("corpus");
        let e = ingest_triples_file(&input, &out, &IngestOptions::default()).unwrap_err();
        assert!(e.to_string().contains("line 1"), "{e}");
        std::fs::write(&input, "# nothing but comments\n\n").unwrap();
        let e = ingest_triples_file(&input, &out, &IngestOptions::default()).unwrap_err();
        assert!(e.to_string().contains("no triples"), "{e}");
        std::fs::write(&input, "a\tr\tb\n").unwrap();
        let e = ingest_triples_file(
            &input,
            &out,
            &IngestOptions { grid: 5, ..IngestOptions::default() },
        )
        .unwrap_err();
        assert!(e.to_string().contains("grid"), "{e}");
        // half-precision storage is dense-only
        let e = ingest_triples_file(
            &input,
            &out,
            &IngestOptions { dtype: DType::F16, ..IngestOptions::default() },
        )
        .unwrap_err();
        assert!(e.to_string().contains("--dense"), "{e}");
        assert!(ingest_triples_file(Path::new("/nonexistent.tsv"), &out, &IngestOptions::default())
            .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
