//! Small internal memory-map wrapper (the offline crate set has no
//! `memmap2`).
//!
//! [`MmapFile`] is a read-only byte view of a file: a real private
//! `mmap(2)` on unix (declared directly against the C library std
//! already links — no new dependency), and a plain heap read everywhere
//! else or when the mapping syscall fails. Callers branch on
//! [`MmapFile::is_mapped`] only for accounting; the byte view behaves
//! identically either way.
//!
//! [`MappedF32`] reinterprets an aligned little-endian window of the
//! bytes as `[f32]` so dense shard payloads can back
//! [`crate::tensor::Mat::from_shared`] windows with zero copies.

use std::fs::File;
use std::path::Path;

use crate::error::{Context as _, Result};

enum Inner {
    /// A live `mmap(2)` region, unmapped on drop.
    #[cfg(unix)]
    Mapped { ptr: *mut u8, len: usize },
    /// Fallback: the whole file read onto the heap.
    Heap(Vec<u8>),
}

/// A read-only byte view of a file (memory-mapped where possible).
pub struct MmapFile {
    inner: Inner,
}

// The mapped region is private and read-only for the struct's lifetime.
unsafe impl Send for MmapFile {}
unsafe impl Sync for MmapFile {}

#[cfg(unix)]
mod sys {
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
}

impl MmapFile {
    /// Map (or read) a whole file.
    pub fn open(path: &Path) -> Result<MmapFile> {
        let file =
            File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len() as usize;
        #[cfg(unix)]
        if len > 0 {
            use std::os::unix::io::AsRawFd;
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if !ptr.is_null() && ptr as isize != -1 {
                return Ok(MmapFile { inner: Inner::Mapped { ptr, len } });
            }
            // mmap refused (weird filesystem?) — fall through to a read
        }
        let bytes =
            std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Ok(MmapFile { inner: Inner::Heap(bytes) })
    }

    /// Total length in bytes.
    pub fn len(&self) -> usize {
        match &self.inner {
            #[cfg(unix)]
            Inner::Mapped { len, .. } => *len,
            Inner::Heap(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this view is a real mapping (vs the heap-read fallback).
    pub fn is_mapped(&self) -> bool {
        match &self.inner {
            #[cfg(unix)]
            Inner::Mapped { .. } => true,
            Inner::Heap(_) => false,
        }
    }

    /// The full byte view.
    pub fn bytes(&self) -> &[u8] {
        match &self.inner {
            #[cfg(unix)]
            Inner::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
            Inner::Heap(v) => v,
        }
    }
}

impl Drop for MmapFile {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Inner::Mapped { ptr, len } = &self.inner {
            unsafe {
                sys::munmap(*ptr, *len);
            }
        }
    }
}

/// An f32 window of a file view, shareable across the relation slices of
/// one dense tile through [`crate::tensor::Mat::from_shared`].
pub struct MappedF32 {
    map: MmapFile,
    /// Byte offset of the first f32.
    off: usize,
    /// Window length in f32s.
    len: usize,
}

impl MappedF32 {
    /// Wrap `byte_len` payload bytes starting at `byte_off` as f32s.
    /// Gives the file view back (`Err`) when zero-copy reinterpretation
    /// is unsound: misaligned pointer, out-of-range window, or a
    /// big-endian host (shards are little-endian on disk).
    pub fn new(map: MmapFile, byte_off: usize, byte_len: usize) -> Result<MappedF32, MmapFile> {
        let ok = cfg!(target_endian = "little")
            && byte_len % 4 == 0
            && byte_off + byte_len <= map.len()
            && (map.bytes().as_ptr() as usize + byte_off) % std::mem::align_of::<f32>() == 0;
        if ok {
            Ok(MappedF32 { map, off: byte_off, len: byte_len / 4 })
        } else {
            Err(map)
        }
    }

    /// Whether the underlying view is a real mapping.
    pub fn is_mapped(&self) -> bool {
        self.map.is_mapped()
    }
}

impl AsRef<[f32]> for MappedF32 {
    fn as_ref(&self) -> &[f32] {
        let b = &self.map.bytes()[self.off..self.off + self.len * 4];
        // alignment and endianness were checked at construction
        unsafe { std::slice::from_raw_parts(b.as_ptr() as *const f32, self.len) }
    }
}

/// A u16 window of a file view — the half-precision (f16/bf16) analogue
/// of [`MappedF32`], backing [`crate::tensor::HalfMat::from_shared`]
/// windows with zero copies.
pub struct MappedU16 {
    map: MmapFile,
    /// Byte offset of the first u16.
    off: usize,
    /// Window length in u16s.
    len: usize,
}

impl MappedU16 {
    /// Wrap `byte_len` payload bytes starting at `byte_off` as u16s.
    /// Gives the file view back (`Err`) when zero-copy reinterpretation
    /// is unsound (see [`MappedF32::new`]).
    pub fn new(map: MmapFile, byte_off: usize, byte_len: usize) -> Result<MappedU16, MmapFile> {
        let ok = cfg!(target_endian = "little")
            && byte_len % 2 == 0
            && byte_off + byte_len <= map.len()
            && (map.bytes().as_ptr() as usize + byte_off) % std::mem::align_of::<u16>() == 0;
        if ok {
            Ok(MappedU16 { map, off: byte_off, len: byte_len / 2 })
        } else {
            Err(map)
        }
    }

    /// Whether the underlying view is a real mapping.
    pub fn is_mapped(&self) -> bool {
        self.map.is_mapped()
    }
}

impl AsRef<[u16]> for MappedU16 {
    fn as_ref(&self) -> &[u16] {
        let b = &self.map.bytes()[self.off..self.off + self.len * 2];
        // alignment and endianness were checked at construction
        unsafe { std::slice::from_raw_parts(b.as_ptr() as *const u16, self.len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_and_reads_a_file() {
        let dir = std::env::temp_dir().join(format!("drescal_mmap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        let payload: Vec<u8> = (0u8..255).collect();
        std::fs::write(&path, &payload).unwrap();
        let map = MmapFile::open(&path).unwrap();
        assert_eq!(map.len(), payload.len());
        assert_eq!(map.bytes(), &payload[..]);
        #[cfg(unix)]
        assert!(map.is_mapped(), "unix must take the real mmap path");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn f32_window_round_trips() {
        let dir = std::env::temp_dir().join(format!("drescal_mmapf_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f32s.bin");
        let mut bytes = Vec::new();
        for v in [1.5f32, -2.25, 0.0, 123.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let map = MmapFile::open(&path).unwrap();
        let win = MappedF32::new(map, 0, 16).ok().expect("aligned LE window");
        assert_eq!(win.as_ref(), &[1.5, -2.25, 0.0, 123.0]);
        // an odd byte offset cannot be reinterpreted
        let map = MmapFile::open(&path).unwrap();
        assert!(MappedF32::new(map, 1, 8).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn u16_window_round_trips() {
        let dir = std::env::temp_dir().join(format!("drescal_mmaph_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("u16s.bin");
        let mut bytes = Vec::new();
        for v in [0x3c00u16, 0xbc00, 0x0000, 0x7bff] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let map = MmapFile::open(&path).unwrap();
        let win = MappedU16::new(map, 0, 8).ok().expect("aligned LE window");
        assert_eq!(win.as_ref(), &[0x3c00, 0xbc00, 0x0000, 0x7bff]);
        let map = MmapFile::open(&path).unwrap();
        assert!(MappedU16::new(map, 1, 4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_a_typed_error() {
        let e = MmapFile::open(Path::new("/nonexistent/drescal.shard")).unwrap_err();
        assert!(e.to_string().contains("opening"), "{e}");
    }
}
