//! The versioned binary tile-shard format.
//!
//! One shard file holds one (grid-row, grid-col) tile of the relational
//! tensor, dense or sparse. Everything is little-endian; integers are
//! u64, values are f32.
//!
//! ```text
//! offset  size  field
//!      0     8  magic "DRSHRD01"
//!      8     4  format version (u32, = 2; version-1 files still read)
//!     12     4  kind (u32): 1 = dense, 2 = sparse
//!     16     8  rows (u64)        — tile rows
//!     24     8  cols (u64)        — tile cols
//!     32     8  m (u64)           — relation slices
//!     40     8  payload_len (u64) — bytes after the header
//!     48     8  checksum (u64)    — FNV-1a 64 over the payload bytes
//!     56     4  dtype (u32): 0 = f32, 1 = f16, 2 = bf16   (v2; was reserved)
//!     60     4  reserved (zeros)
//!     64     …  payload
//! ```
//!
//! Version 2 spends four reserved bytes on a payload **dtype**. A
//! version-1 file is read as version 2 with dtype 0 (its reserved bytes
//! were written as zeros, which is exactly the f32 encoding), so every
//! pre-dtype shard on disk remains readable. An unknown version or dtype
//! code is a typed error, and only dense shards may carry a 16-bit
//! dtype — sparse payloads interleave u64 index structure and stay f32.
//!
//! * **Dense payload**: `m` consecutive row-major `rows×cols` blocks of
//!   the header dtype — f32, or 16-bit f16/bf16 written by
//!   [`write_dense_half_shard`] at half the bytes. The payload starts at
//!   byte 64, so within a page-aligned mapping it is element-aligned and
//!   [`dense_tile_from`] / [`dense_half_tile_from`] can hand the mapping
//!   to [`Mat::from_shared`] / [`HalfMat::from_shared`] with zero
//!   copies.
//! * **Sparse payload**, per relation slice: `nnz` (u64), `rows+1`
//!   indptr u64s, `nnz` column-index u64s, `nnz` f32 values.
//!
//! Every read re-verifies the magic, version, shape arithmetic, and
//! payload checksum, and cross-checks the manifest's recorded size and
//! checksum when one is supplied — truncation and bit-flips surface as
//! typed [`crate::error::Error`]s, never panics.

use std::fs::File;
use std::io::{BufWriter, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

use crate::error::{Context as _, Result};
use crate::tensor::half::SharedHalfBuf;
use crate::tensor::{Csr, DType, HalfMat, HalfTensor3, Mat, SharedBuf, Tensor3};
use crate::{bail, err};

use super::manifest::ShardMeta;
use super::mmap::{MappedF32, MappedU16, MmapFile};

pub const MAGIC: &[u8; 8] = b"DRSHRD01";
/// Current write version. Version 1 (pre-dtype) files are still read.
pub const VERSION: u32 = 2;
pub const VERSION_V1: u32 = 1;
pub const HEADER_LEN: usize = 64;
pub const KIND_DENSE: u32 = 1;
pub const KIND_SPARSE: u32 = 2;

/// On-disk dtype codes (header offset 56).
fn dtype_code(d: DType) -> u32 {
    match d {
        DType::F32 => 0,
        DType::F16 => 1,
        DType::Bf16 => 2,
    }
}

fn dtype_from_code(code: u32) -> Option<DType> {
    match code {
        0 => Some(DType::F32),
        1 => Some(DType::F16),
        2 => Some(DType::Bf16),
        _ => None,
    }
}

/// What a writer reports back for the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardDigest {
    /// Total file size (header + payload) in bytes.
    pub bytes: u64,
    /// FNV-1a 64 checksum of the payload bytes.
    pub checksum: u64,
}

/// Incremental FNV-1a 64.
pub struct Fnv1a64 {
    hash: u64,
}

impl Default for Fnv1a64 {
    fn default() -> Self {
        Fnv1a64 { hash: 0xcbf2_9ce4_8422_2325 }
    }
}

impl Fnv1a64 {
    pub fn update(&mut self, data: &[u8]) {
        for &b in data {
            self.hash ^= b as u64;
            self.hash = self.hash.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.hash
    }
}

/// One-shot FNV-1a 64 of a byte slice.
pub fn fnv1a64(data: &[u8]) -> u64 {
    let mut f = Fnv1a64::default();
    f.update(data);
    f.finish()
}

/// The decoded fixed-size header of a shard file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    pub kind: u32,
    pub rows: usize,
    pub cols: usize,
    pub m: usize,
    pub payload_len: u64,
    pub checksum: u64,
    /// Payload element type (always `F32` for version-1 files and sparse
    /// shards).
    pub dtype: DType,
}

// ---------------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------------

/// A payload writer that hashes everything it forwards.
struct HashingWriter<W: Write> {
    w: W,
    fnv: Fnv1a64,
    bytes: u64,
}

impl<W: Write> HashingWriter<W> {
    fn put(&mut self, data: &[u8]) -> Result<()> {
        self.fnv.update(data);
        self.bytes += data.len() as u64;
        self.w.write_all(data).context("writing shard payload")?;
        Ok(())
    }

    fn put_u64(&mut self, v: u64) -> Result<()> {
        self.put(&v.to_le_bytes())
    }

    fn put_f32(&mut self, v: f32) -> Result<()> {
        self.put(&v.to_le_bytes())
    }
}

#[allow(clippy::too_many_arguments)]
fn header_bytes(
    kind: u32,
    rows: usize,
    cols: usize,
    m: usize,
    payload_len: u64,
    checksum: u64,
    dtype: DType,
) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0..8].copy_from_slice(MAGIC);
    h[8..12].copy_from_slice(&VERSION.to_le_bytes());
    h[12..16].copy_from_slice(&kind.to_le_bytes());
    h[16..24].copy_from_slice(&(rows as u64).to_le_bytes());
    h[24..32].copy_from_slice(&(cols as u64).to_le_bytes());
    h[32..40].copy_from_slice(&(m as u64).to_le_bytes());
    h[40..48].copy_from_slice(&payload_len.to_le_bytes());
    h[48..56].copy_from_slice(&checksum.to_le_bytes());
    h[56..60].copy_from_slice(&dtype_code(dtype).to_le_bytes());
    h
}

/// Stream a payload out behind a placeholder header, then patch the real
/// checksum in — the payload is never buffered whole.
#[allow(clippy::too_many_arguments)]
fn write_shard_file(
    path: &Path,
    kind: u32,
    rows: usize,
    cols: usize,
    m: usize,
    dtype: DType,
    payload: impl FnOnce(&mut HashingWriter<&mut BufWriter<File>>) -> Result<()>,
) -> Result<ShardDigest> {
    let file = File::create(path)
        .with_context(|| format!("creating shard {}", path.display()))?;
    let mut buf = BufWriter::new(file);
    buf.write_all(&header_bytes(kind, rows, cols, m, 0, 0, dtype))
        .context("writing shard header")?;
    let mut hw = HashingWriter { w: &mut buf, fnv: Fnv1a64::default(), bytes: 0 };
    payload(&mut hw)?;
    let (payload_len, checksum) = (hw.bytes, hw.fnv.finish());
    buf.flush().context("flushing shard")?;
    let mut file = buf
        .into_inner()
        .map_err(|e| err!("flushing shard {}: {e}", path.display()))?;
    file.seek(SeekFrom::Start(0)).context("rewinding shard header")?;
    file.write_all(&header_bytes(kind, rows, cols, m, payload_len, checksum, dtype))
        .context("patching shard header")?;
    Ok(ShardDigest { bytes: HEADER_LEN as u64 + payload_len, checksum })
}

/// Write one dense f32 tile (`rows×cols×m`, row-major slices back to
/// back).
pub fn write_dense_shard(path: &Path, x: &Tensor3) -> Result<ShardDigest> {
    let (rows, cols, m) = x.shape();
    write_shard_file(path, KIND_DENSE, rows, cols, m, DType::F32, |w| {
        let mut chunk = Vec::with_capacity(4096);
        for t in 0..m {
            for v in x.slice(t).as_slice() {
                chunk.extend_from_slice(&v.to_le_bytes());
                if chunk.len() >= 4096 {
                    w.put(&chunk)?;
                    chunk.clear();
                }
            }
        }
        if !chunk.is_empty() {
            w.put(&chunk)?;
        }
        Ok(())
    })
}

/// Write one dense 16-bit tile — same layout as [`write_dense_shard`]
/// with 2-byte elements of the tensor's dtype, at half the payload
/// bytes.
pub fn write_dense_half_shard(path: &Path, x: &HalfTensor3) -> Result<ShardDigest> {
    let (rows, cols) = (x.n1(), x.n2());
    let m = x.m();
    write_shard_file(path, KIND_DENSE, rows, cols, m, x.dtype(), |w| {
        let mut chunk = Vec::with_capacity(4096);
        for t in 0..m {
            for v in x.slice(t).as_u16_slice() {
                chunk.extend_from_slice(&v.to_le_bytes());
                if chunk.len() >= 4096 {
                    w.put(&chunk)?;
                    chunk.clear();
                }
            }
        }
        if !chunk.is_empty() {
            w.put(&chunk)?;
        }
        Ok(())
    })
}

/// Write one sparse tile: `m` CSR slices that must all be `rows×cols`.
pub fn write_sparse_shard(
    path: &Path,
    rows: usize,
    cols: usize,
    slices: &[Csr],
) -> Result<ShardDigest> {
    for (t, c) in slices.iter().enumerate() {
        if c.rows() != rows || c.cols() != cols {
            bail!(
                "sparse shard slice {t} is {}×{}, expected {rows}×{cols}",
                c.rows(),
                c.cols()
            );
        }
    }
    write_shard_file(path, KIND_SPARSE, rows, cols, slices.len(), DType::F32, |w| {
        for c in slices {
            w.put_u64(c.nnz() as u64)?;
            for &p in c.indptr() {
                w.put_u64(p as u64)?;
            }
            for &j in c.indices() {
                w.put_u64(j as u64)?;
            }
            for &v in c.values() {
                w.put_f32(v)?;
            }
        }
        Ok(())
    })
}

// ---------------------------------------------------------------------------
// Reading
// ---------------------------------------------------------------------------

/// Decode and sanity-check the 64-byte header.
pub fn parse_header(bytes: &[u8], path: &Path) -> Result<ShardHeader> {
    if bytes.len() < HEADER_LEN {
        bail!(
            "shard {} is truncated: {} bytes is smaller than the {HEADER_LEN}-byte header",
            path.display(),
            bytes.len()
        );
    }
    if &bytes[0..8] != MAGIC {
        bail!("{} is not a drescal shard (bad magic)", path.display());
    }
    let u32_at = |off: usize| u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
    let u64_at = |off: usize| u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    let version = u32_at(8);
    if version != VERSION && version != VERSION_V1 {
        bail!(
            "shard {} has format version {version}, this build reads versions \
             {VERSION_V1} and {VERSION}",
            path.display()
        );
    }
    let kind = u32_at(12);
    if kind != KIND_DENSE && kind != KIND_SPARSE {
        bail!("shard {} has unknown kind {kind}", path.display());
    }
    // version 1 predates the dtype field; its reserved bytes were zeros,
    // which is the f32 code
    let dtype_raw = u32_at(56);
    let dtype = match dtype_from_code(dtype_raw) {
        Some(d) => d,
        None => bail!(
            "shard {} has unknown payload dtype code {dtype_raw} (this build reads \
             f32/f16/bf16)",
            path.display()
        ),
    };
    if kind == KIND_SPARSE && dtype.is_half() {
        bail!(
            "shard {} is sparse with a {} payload — sparse shards are always f32",
            path.display(),
            dtype.as_str()
        );
    }
    let hd = ShardHeader {
        kind,
        rows: u64_at(16) as usize,
        cols: u64_at(24) as usize,
        m: u64_at(32) as usize,
        payload_len: u64_at(40),
        checksum: u64_at(48),
        dtype,
    };
    let have = (bytes.len() - HEADER_LEN) as u64;
    if hd.payload_len != have {
        bail!(
            "shard {} is truncated or padded: header promises {} payload bytes, file \
             holds {have}",
            path.display(),
            hd.payload_len
        );
    }
    Ok(hd)
}

/// Map a shard file, verify its header + payload checksum, and
/// cross-check the manifest's recorded size/checksum when given.
pub fn read_shard(path: &Path, expect: Option<&ShardMeta>) -> Result<(ShardHeader, MmapFile)> {
    let map = MmapFile::open(path)?;
    let hd = parse_header(map.bytes(), path)?;
    let actual = fnv1a64(&map.bytes()[HEADER_LEN..]);
    if actual != hd.checksum {
        bail!(
            "shard {} failed its checksum ({actual:016x} != recorded {:016x}) — the file \
             is corrupt",
            path.display(),
            hd.checksum
        );
    }
    if let Some(meta) = expect {
        if meta.bytes != map.len() as u64 {
            bail!(
                "shard {}: manifest records {} bytes but the file holds {}",
                path.display(),
                meta.bytes,
                map.len()
            );
        }
        if meta.checksum != hd.checksum {
            bail!(
                "shard {}: manifest checksum {:016x} does not match the shard's \
                 {:016x} — manifest and shard are out of sync",
                path.display(),
                meta.checksum,
                hd.checksum
            );
        }
    }
    super::stats::note_shard_read(map.len());
    Ok((hd, map))
}

/// Decode a dense shard into a `Tensor3`. Zero-copy when the view can be
/// reinterpreted as f32s in place (little-endian host, aligned mapping):
/// every relation slice becomes a [`Mat::from_shared`] window into one
/// shared mapping. Returns whether the tile reads from a real mapping.
pub fn dense_tile_from(map: MmapFile, hd: &ShardHeader, path: &Path) -> Result<(Tensor3, bool)> {
    if hd.kind != KIND_DENSE {
        bail!("shard {} is not dense", path.display());
    }
    if hd.dtype != DType::F32 {
        bail!(
            "shard {} stores {} elements — decode it with dense_half_tile_from",
            path.display(),
            hd.dtype.as_str()
        );
    }
    let slice_len = hd.rows * hd.cols;
    let payload_bytes = slice_len
        .checked_mul(hd.m)
        .and_then(|x| x.checked_mul(4))
        .ok_or_else(|| err!("shard {}: dense shape overflows", path.display()))?;
    if payload_bytes as u64 != hd.payload_len {
        bail!(
            "shard {}: dense payload is {} bytes but {}×{}×{} f32s need {payload_bytes}",
            path.display(),
            hd.payload_len,
            hd.rows,
            hd.cols,
            hd.m
        );
    }
    match MappedF32::new(map, HEADER_LEN, payload_bytes) {
        Ok(shared) => {
            let mapped = shared.is_mapped();
            let src: SharedBuf = Arc::new(shared);
            let slices = (0..hd.m)
                .map(|t| Mat::from_shared(hd.rows, hd.cols, Arc::clone(&src), t * slice_len))
                .collect();
            Ok((Tensor3::from_slices(slices), mapped))
        }
        Err(map) => {
            // misaligned or big-endian: decode a copy
            let b = map.bytes();
            let slices = (0..hd.m)
                .map(|t| {
                    let off = HEADER_LEN + t * slice_len * 4;
                    let mut v = Vec::with_capacity(slice_len);
                    for i in 0..slice_len {
                        let p = off + i * 4;
                        v.push(f32::from_le_bytes([b[p], b[p + 1], b[p + 2], b[p + 3]]));
                    }
                    Mat::from_vec(hd.rows, hd.cols, v)
                })
                .collect();
            Ok((Tensor3::from_slices(slices), false))
        }
    }
}

/// Decode a 16-bit dense shard into a [`HalfTensor3`] — the
/// half-precision analogue of [`dense_tile_from`], with every relation
/// slice a [`HalfMat::from_shared`] window into one shared mapping when
/// zero-copy reinterpretation is sound. Returns whether the tile reads
/// from a real mapping.
pub fn dense_half_tile_from(
    map: MmapFile,
    hd: &ShardHeader,
    path: &Path,
) -> Result<(HalfTensor3, bool)> {
    if hd.kind != KIND_DENSE {
        bail!("shard {} is not dense", path.display());
    }
    if !hd.dtype.is_half() {
        bail!(
            "shard {} stores f32 elements — decode it with dense_tile_from",
            path.display()
        );
    }
    let slice_len = hd.rows * hd.cols;
    let payload_bytes = slice_len
        .checked_mul(hd.m)
        .and_then(|x| x.checked_mul(2))
        .ok_or_else(|| err!("shard {}: dense shape overflows", path.display()))?;
    if payload_bytes as u64 != hd.payload_len {
        bail!(
            "shard {}: dense payload is {} bytes but {}×{}×{} {} elements need \
             {payload_bytes}",
            path.display(),
            hd.payload_len,
            hd.rows,
            hd.cols,
            hd.m,
            hd.dtype.as_str()
        );
    }
    match MappedU16::new(map, HEADER_LEN, payload_bytes) {
        Ok(shared) => {
            let mapped = shared.is_mapped();
            let src: SharedHalfBuf = Arc::new(shared);
            let slices = (0..hd.m)
                .map(|t| {
                    HalfMat::from_shared(hd.rows, hd.cols, hd.dtype, Arc::clone(&src), t * slice_len)
                })
                .collect();
            Ok((HalfTensor3::from_slices(slices), mapped))
        }
        Err(map) => {
            // misaligned or big-endian: decode a copy
            let b = map.bytes();
            let slices = (0..hd.m)
                .map(|t| {
                    let off = HEADER_LEN + t * slice_len * 2;
                    let mut v = Vec::with_capacity(slice_len);
                    for i in 0..slice_len {
                        let p = off + i * 2;
                        v.push(u16::from_le_bytes([b[p], b[p + 1]]));
                    }
                    HalfMat::from_raw(hd.rows, hd.cols, hd.dtype, v)
                })
                .collect();
            Ok((HalfTensor3::from_slices(slices), false))
        }
    }
}

/// A bounds-checked little-endian payload reader.
struct PayloadReader<'a> {
    b: &'a [u8],
    pos: usize,
    path: &'a Path,
}

impl<'a> PayloadReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!(
                "shard {} payload is truncated at byte {} (wanted {n} more)",
                self.path.display(),
                self.pos
            );
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u64s(&mut self, count: usize) -> Result<Vec<usize>> {
        let bytes = self.take(count.checked_mul(8).ok_or_else(|| {
            err!("shard {} declares an absurd element count", self.path.display())
        })?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect())
    }

    fn f32s(&mut self, count: usize) -> Result<Vec<f32>> {
        let bytes = self.take(count.checked_mul(4).ok_or_else(|| {
            err!("shard {} declares an absurd element count", self.path.display())
        })?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Decode a sparse shard into its CSR slices, validating every structural
/// invariant ([`Csr::from_parts`]) so corrupt files become typed errors.
pub fn sparse_tile_from(map: &MmapFile, hd: &ShardHeader, path: &Path) -> Result<Vec<Csr>> {
    if hd.kind != KIND_SPARSE {
        bail!("shard {} is not sparse", path.display());
    }
    let mut r = PayloadReader { b: &map.bytes()[HEADER_LEN..], pos: 0, path };
    let mut slices = Vec::with_capacity(hd.m);
    for t in 0..hd.m {
        let nnz = r.u64()? as usize;
        let indptr = r.u64s(hd.rows + 1)?;
        let indices = r.u64s(nnz)?;
        let values = r.f32s(nnz)?;
        let csr = Csr::from_parts(hd.rows, hd.cols, indptr, indices, values)
            .with_context(|| format!("shard {} relation {t}", path.display()))?;
        slices.push(csr);
    }
    if r.pos != r.b.len() {
        bail!(
            "shard {} has {} trailing payload bytes after {} relation slices",
            path.display(),
            r.b.len() - r.pos,
            hd.m
        );
    }
    Ok(slices)
}

/// Decode only global rows `r0..r1` of every relation slice of a sparse
/// shard, by direct offset arithmetic into the payload — no whole-tile
/// materialization. This is what keeps the re-sharding load path at
/// O(target tile) memory: a rank splicing its range out of a coarser
/// ingest (e.g. a grid-1 corpus loaded on 16 ranks) reads only its row
/// window of each relation, never the full shard's CSR arrays.
///
/// The returned slices are `(r1-r0) × cols` with the window's rows
/// re-based to 0.
pub fn sparse_rows_from(
    map: &MmapFile,
    hd: &ShardHeader,
    path: &Path,
    r0: usize,
    r1: usize,
) -> Result<Vec<Csr>> {
    if hd.kind != KIND_SPARSE {
        bail!("shard {} is not sparse", path.display());
    }
    if r0 > r1 || r1 > hd.rows {
        bail!(
            "row window {r0}..{r1} out of range for {}-row shard {}",
            hd.rows,
            path.display()
        );
    }
    let b = &map.bytes()[HEADER_LEN..];
    let err_trunc = || err!("shard {} payload is truncated", path.display());
    let u64_at = |off: usize| -> Result<u64> {
        let end = off.checked_add(8).ok_or_else(err_trunc)?;
        if end > b.len() {
            return Err(err_trunc());
        }
        Ok(u64::from_le_bytes(b[off..end].try_into().unwrap()))
    };
    let checked = |base: usize, count: usize, width: usize| -> Result<usize> {
        count
            .checked_mul(width)
            .and_then(|len| base.checked_add(len))
            .ok_or_else(err_trunc)
    };
    let mut cur = 0usize;
    let mut out = Vec::with_capacity(hd.m);
    for t in 0..hd.m {
        let nnz = u64_at(cur)? as usize;
        let indptr_base = cur.checked_add(8).ok_or_else(err_trunc)?;
        let indices_base = checked(indptr_base, hd.rows + 1, 8)?;
        let values_base = checked(indices_base, nnz, 8)?;
        let next = checked(values_base, nnz, 4)?;
        if next > b.len() {
            return Err(err_trunc());
        }
        // the window of indptr we need: entries r0..=r1
        let mut window = Vec::with_capacity(r1 - r0 + 1);
        for i in r0..=r1 {
            window.push(u64_at(indptr_base + i * 8)? as usize);
        }
        for w in window.windows(2) {
            if w[1] < w[0] {
                bail!(
                    "shard {} relation {t} has a non-monotone indptr window",
                    path.display()
                );
            }
        }
        let (start, end) = (window[0], window[r1 - r0]);
        if end > nnz {
            bail!(
                "shard {} relation {t} indptr window exceeds nnz {nnz}",
                path.display()
            );
        }
        let indices = b[indices_base + start * 8..indices_base + end * 8]
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
            .collect();
        let values = b[values_base + start * 4..values_base + end * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let indptr = window.iter().map(|&p| p - start).collect();
        let csr = Csr::from_parts(r1 - r0, hd.cols, indptr, indices, values)
            .with_context(|| format!("shard {} relation {t}", path.display()))?;
        out.push(csr);
        cur = next;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("drescal_shard_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn dense_shard_round_trips() {
        let dir = tmp("dense");
        let path = dir.join("s.bin");
        let mut rng = Rng::new(5);
        let x = Tensor3::random_uniform(6, 4, 3, -1.0, 1.0, &mut rng);
        let digest = write_dense_shard(&path, &x).unwrap();
        assert_eq!(digest.bytes, 64 + 6 * 4 * 3 * 4);
        let (hd, map) = read_shard(&path, None).unwrap();
        assert_eq!((hd.rows, hd.cols, hd.m), (6, 4, 3));
        let (back, _mapped) = dense_tile_from(map, &hd, &path).unwrap();
        for t in 0..3 {
            assert_eq!(back.slice(t).as_slice(), x.slice(t).as_slice(), "slice {t}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn half_dense_shard_round_trips_at_half_the_bytes() {
        let dir = tmp("half");
        let mut rng = Rng::new(51);
        let x = Tensor3::random_uniform(6, 4, 3, -1.0, 1.0, &mut rng);
        let f32_digest = write_dense_shard(&dir.join("f32.bin"), &x).unwrap();
        for dtype in [DType::F16, DType::Bf16] {
            let path = dir.join(format!("{}.bin", dtype.as_str()));
            let hx = HalfTensor3::from_tensor3(&x, dtype);
            let digest = write_dense_half_shard(&path, &hx).unwrap();
            // the dtype axis is the whole point: payload bytes halve
            assert_eq!(
                digest.bytes - HEADER_LEN as u64,
                (f32_digest.bytes - HEADER_LEN as u64) / 2,
                "{} payload must be half the f32 payload",
                dtype.as_str()
            );
            let (hd, map) = read_shard(&path, None).unwrap();
            assert_eq!((hd.rows, hd.cols, hd.m, hd.dtype), (6, 4, 3, dtype));
            let (back, _mapped) = dense_half_tile_from(map, &hd, &path).unwrap();
            assert_eq!(back.dtype(), dtype);
            for t in 0..3 {
                assert_eq!(
                    back.slice(t).as_u16_slice(),
                    hx.slice(t).as_u16_slice(),
                    "slice {t}"
                );
            }
            // the wrong decoder is a typed error, not a garbage tensor
            let (hd, map) = read_shard(&path, None).unwrap();
            let e = dense_tile_from(map, &hd, &path).unwrap_err();
            assert!(e.to_string().contains("dense_half_tile_from"), "{e}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn version_1_files_read_as_f32_and_bad_dtypes_are_typed_errors() {
        let dir = tmp("dtype");
        let path = dir.join("s.bin");
        let mut rng = Rng::new(52);
        let x = Tensor3::random_uniform(4, 3, 2, 0.0, 1.0, &mut rng);
        write_dense_shard(&path, &x).unwrap();
        let clean = std::fs::read(&path).unwrap();

        // a version-1 header (zeroed reserved bytes) still reads, as f32
        let mut v1 = clean.clone();
        v1[8..12].copy_from_slice(&VERSION_V1.to_le_bytes());
        v1[56..64].copy_from_slice(&[0u8; 8]);
        std::fs::write(&path, &v1).unwrap();
        let (hd, map) = read_shard(&path, None).unwrap();
        assert_eq!(hd.dtype, DType::F32);
        let (back, _) = dense_tile_from(map, &hd, &path).unwrap();
        assert_eq!(back.slice(0).as_slice(), x.slice(0).as_slice());

        // an unknown dtype code is a typed error (header is not covered
        // by the payload checksum, so this is a pure header check)
        let mut bad = clean.clone();
        bad[56..60].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&path, &bad).unwrap();
        let e = read_shard(&path, None).unwrap_err();
        assert!(e.to_string().contains("dtype"), "{e}");

        // an unknown version is still rejected
        let mut vx = clean.clone();
        vx[8..12].copy_from_slice(&3u32.to_le_bytes());
        std::fs::write(&path, &vx).unwrap();
        let e = read_shard(&path, None).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");

        // sparse shards must stay f32
        let slices: Vec<Csr> = (0..2).map(|_| Csr::random(5, 4, 0.4, &mut rng)).collect();
        write_sparse_shard(&path, 5, 4, &slices).unwrap();
        let mut sp = std::fs::read(&path).unwrap();
        sp[56..60].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &sp).unwrap();
        let e = read_shard(&path, None).unwrap_err();
        assert!(e.to_string().contains("sparse"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sparse_shard_round_trips() {
        let dir = tmp("sparse");
        let path = dir.join("s.bin");
        let mut rng = Rng::new(6);
        let slices: Vec<Csr> = (0..2).map(|_| Csr::random(8, 5, 0.3, &mut rng)).collect();
        write_sparse_shard(&path, 8, 5, &slices).unwrap();
        let (hd, map) = read_shard(&path, None).unwrap();
        let back = sparse_tile_from(&map, &hd, &path).unwrap();
        assert_eq!(back.len(), 2);
        for t in 0..2 {
            assert_eq!(back[t], slices[t], "slice {t}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Every row window of a sparse shard equals the corresponding rows
    /// of the fully decoded tile.
    #[test]
    fn sparse_row_windows_match_full_decode() {
        let dir = tmp("window");
        let path = dir.join("s.bin");
        let mut rng = Rng::new(8);
        let slices: Vec<Csr> = (0..2).map(|_| Csr::random(9, 7, 0.35, &mut rng)).collect();
        write_sparse_shard(&path, 9, 7, &slices).unwrap();
        let (hd, map) = read_shard(&path, None).unwrap();
        let full = sparse_tile_from(&map, &hd, &path).unwrap();
        for (r0, r1) in [(0usize, 9usize), (0, 4), (3, 7), (8, 9), (5, 5)] {
            let window = sparse_rows_from(&map, &hd, &path, r0, r1).unwrap();
            for t in 0..2 {
                assert_eq!(window[t].rows(), r1 - r0);
                for wr in 0..(r1 - r0) {
                    assert_eq!(
                        window[t].row_entries(wr),
                        full[t].row_entries(r0 + wr),
                        "rows {r0}..{r1}, relation {t}, window row {wr}"
                    );
                }
            }
        }
        assert!(sparse_rows_from(&map, &hd, &path, 4, 12).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_is_a_typed_error() {
        let dir = tmp("corrupt");
        let path = dir.join("s.bin");
        let mut rng = Rng::new(7);
        let x = Tensor3::random_uniform(4, 4, 2, 0.0, 1.0, &mut rng);
        write_dense_shard(&path, &x).unwrap();
        let clean = std::fs::read(&path).unwrap();

        // any payload bit-flip fails the checksum
        let mut bad = clean.clone();
        bad[HEADER_LEN + 5] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let e = read_shard(&path, None).unwrap_err();
        assert!(e.to_string().contains("checksum"), "{e}");

        // truncation is detected before any decode
        std::fs::write(&path, &clean[..clean.len() - 7]).unwrap();
        let e = read_shard(&path, None).unwrap_err();
        assert!(e.to_string().contains("truncated"), "{e}");

        // a foreign file is rejected by magic
        std::fs::write(&path, b"definitely not a shard, but 64+ bytes long padding padding")
            .unwrap();
        let e = read_shard(&path, None).unwrap_err();
        assert!(e.to_string().contains("magic") || e.to_string().contains("truncated"), "{e}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
