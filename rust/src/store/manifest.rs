//! The JSON manifest that describes an ingested dataset directory.
//!
//! `manifest.json` sits next to the shard files and records everything a
//! loader needs without touching any payload: global dims, the ingest
//! grid, layout, per-shard file names with sizes and checksums, the
//! interned entity/relation name dictionaries (deterministic
//! first-appearance IDs), and provenance. The leader reads *only* this
//! file; shard payloads are read rank-locally.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Context as _, Result};
use crate::json::Json;
use crate::tensor::DType;
use crate::{bail, err};

/// Current manifest format version.
pub const MANIFEST_VERSION: u64 = 1;
/// File name of the manifest inside a dataset directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// How tiles are stored on disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// Contiguous row-major f32 blocks (memory-mappable).
    Dense,
    /// CSR slices per relation.
    Sparse,
}

impl Layout {
    pub fn as_str(&self) -> &'static str {
        match self {
            Layout::Dense => "dense",
            Layout::Sparse => "sparse",
        }
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, Layout::Sparse)
    }

    pub fn parse(s: &str) -> Result<Layout> {
        match s {
            "dense" => Ok(Layout::Dense),
            "sparse" => Ok(Layout::Sparse),
            other => Err(err!("unknown shard layout '{other}' (dense|sparse)")),
        }
    }
}

/// One shard file's manifest entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMeta {
    /// Grid row of the tile this shard holds.
    pub row: usize,
    /// Grid column.
    pub col: usize,
    /// File name, relative to the manifest's directory.
    pub file: String,
    /// Total file size (header + payload) in bytes.
    pub bytes: u64,
    /// FNV-1a 64 of the payload, mirrored in the shard header.
    pub checksum: u64,
}

/// Where the corpus came from.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct IngestProvenance {
    /// Source label (the input triple file's path at ingest time).
    pub source: String,
    /// Triple lines imported (before duplicate merging).
    pub triples: u64,
}

/// A parsed dataset manifest plus the directory it lives in.
#[derive(Clone, Debug)]
pub struct StoreManifest {
    /// Global entity count (the tensor is n×n×m).
    pub n: usize,
    /// Relation count.
    pub m: usize,
    /// Ingest grid side length g — the directory holds g×g shards.
    pub grid: usize,
    pub layout: Layout,
    /// Element type of dense shard payloads. `F32` unless the corpus was
    /// ingested with a 16-bit storage dtype; always `F32` for sparse
    /// layouts. Serialized only when not `F32`, so pre-dtype manifests
    /// parse unchanged.
    pub dtype: DType,
    pub shards: Vec<ShardMeta>,
    /// Entity names by interned id (first-appearance order).
    pub entities: Vec<String>,
    /// Relation names by interned id.
    pub relations: Vec<String>,
    pub provenance: IngestProvenance,
    /// Directory holding the manifest and shards (not serialized).
    pub dir: PathBuf,
}

impl StoreManifest {
    /// Structural validation: sane dims, a complete g×g shard set with no
    /// duplicates, and name dictionaries matching the dims.
    pub fn validate(&self) -> Result<()> {
        if self.n == 0 || self.m == 0 {
            bail!("manifest has empty dims n={} m={}", self.n, self.m);
        }
        if self.grid == 0 {
            bail!("manifest grid must be >= 1");
        }
        if self.grid > self.n {
            bail!("manifest grid {} exceeds entity count {}", self.grid, self.n);
        }
        if self.dtype.is_half() && self.layout.is_sparse() {
            bail!(
                "manifest declares a {} sparse dataset — 16-bit storage is dense-only",
                self.dtype.as_str()
            );
        }
        if self.shards.len() != self.grid * self.grid {
            bail!(
                "manifest lists {} shards for a {g}×{g} grid (need {})",
                self.shards.len(),
                self.grid * self.grid,
                g = self.grid
            );
        }
        let mut seen = vec![false; self.grid * self.grid];
        for s in &self.shards {
            if s.row >= self.grid || s.col >= self.grid {
                bail!("shard {} is at ({}, {}), outside the grid", s.file, s.row, s.col);
            }
            let idx = s.row * self.grid + s.col;
            if seen[idx] {
                bail!("duplicate shard entry for tile ({}, {})", s.row, s.col);
            }
            seen[idx] = true;
        }
        if self.entities.len() != self.n {
            bail!(
                "manifest has {} entity names for n={} entities",
                self.entities.len(),
                self.n
            );
        }
        if self.relations.len() != self.m {
            bail!(
                "manifest has {} relation names for m={} relations",
                self.relations.len(),
                self.m
            );
        }
        Ok(())
    }

    /// The manifest entry of tile (row, col).
    pub fn shard(&self, row: usize, col: usize) -> Result<&ShardMeta> {
        self.shards
            .iter()
            .find(|s| s.row == row && s.col == col)
            .ok_or_else(|| err!("manifest has no shard for tile ({row}, {col})"))
    }

    /// Absolute path of a shard file.
    pub fn shard_path(&self, meta: &ShardMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// Total on-disk size of all shards.
    pub fn shard_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.bytes).sum()
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("kind".to_string(), Json::Str("drescal_dataset".to_string()));
        obj.insert("version".to_string(), Json::Num(MANIFEST_VERSION as f64));
        obj.insert("n".to_string(), Json::Num(self.n as f64));
        obj.insert("m".to_string(), Json::Num(self.m as f64));
        obj.insert("grid".to_string(), Json::Num(self.grid as f64));
        obj.insert("layout".to_string(), Json::Str(self.layout.as_str().to_string()));
        if self.dtype.is_half() {
            obj.insert("dtype".to_string(), Json::Str(self.dtype.as_str().to_string()));
        }
        obj.insert(
            "shards".to_string(),
            Json::Arr(
                self.shards
                    .iter()
                    .map(|s| {
                        let mut row = BTreeMap::new();
                        row.insert("row".to_string(), Json::Num(s.row as f64));
                        row.insert("col".to_string(), Json::Num(s.col as f64));
                        row.insert("file".to_string(), Json::Str(s.file.clone()));
                        row.insert("bytes".to_string(), Json::Num(s.bytes as f64));
                        // u64 checksums don't fit an f64 exactly — hex string
                        row.insert(
                            "checksum".to_string(),
                            Json::Str(format!("{:016x}", s.checksum)),
                        );
                        Json::Obj(row)
                    })
                    .collect(),
            ),
        );
        let names = |v: &[String]| Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect());
        obj.insert("entities".to_string(), names(&self.entities));
        obj.insert("relations".to_string(), names(&self.relations));
        let mut prov = BTreeMap::new();
        prov.insert("source".to_string(), Json::Str(self.provenance.source.clone()));
        prov.insert("triples".to_string(), Json::Num(self.provenance.triples as f64));
        obj.insert("provenance".to_string(), Json::Obj(prov));
        Json::Obj(obj)
    }

    /// Parse a manifest rooted at `dir`.
    pub fn from_json(v: &Json, dir: PathBuf) -> Result<StoreManifest> {
        match v.get("kind").and_then(|k| k.as_str()) {
            Some("drescal_dataset") => {}
            Some(other) => bail!("expected a drescal_dataset manifest, got kind '{other}'"),
            None => bail!("manifest is missing 'kind'"),
        }
        let version = v
            .get("version")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| err!("manifest is missing 'version'"))? as u64;
        if version != MANIFEST_VERSION {
            bail!(
                "manifest version {version} is not supported (this build reads \
                 {MANIFEST_VERSION})"
            );
        }
        let usize_field = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_f64())
                .map(|x| x as usize)
                .ok_or_else(|| err!("manifest is missing '{key}'"))
        };
        let layout = Layout::parse(
            v.get("layout")
                .and_then(|l| l.as_str())
                .ok_or_else(|| err!("manifest is missing 'layout'"))?,
        )?;
        let dtype = match v.get("dtype") {
            None => DType::F32,
            Some(d) => {
                let name = d
                    .as_str()
                    .ok_or_else(|| err!("manifest 'dtype' must be a string"))?;
                DType::parse(name)
                    .ok_or_else(|| err!("unknown manifest dtype '{name}' (f32|f16|bf16)"))?
            }
        };
        let mut shards = Vec::new();
        for (i, row) in v
            .get("shards")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| err!("manifest is missing 'shards'"))?
            .iter()
            .enumerate()
        {
            let field = |key: &str| -> Result<&Json> {
                row.get(key).ok_or_else(|| err!("shard entry {i} is missing '{key}'"))
            };
            let checksum_hex = field("checksum")?
                .as_str()
                .ok_or_else(|| err!("shard entry {i}: 'checksum' must be a hex string"))?;
            let checksum = u64::from_str_radix(checksum_hex, 16)
                .map_err(|_| err!("shard entry {i}: bad checksum '{checksum_hex}'"))?;
            shards.push(ShardMeta {
                row: field("row")?
                    .as_usize()
                    .ok_or_else(|| err!("shard entry {i}: 'row' must be a number"))?,
                col: field("col")?
                    .as_usize()
                    .ok_or_else(|| err!("shard entry {i}: 'col' must be a number"))?,
                file: field("file")?
                    .as_str()
                    .ok_or_else(|| err!("shard entry {i}: 'file' must be a string"))?
                    .to_string(),
                bytes: field("bytes")?
                    .as_f64()
                    .ok_or_else(|| err!("shard entry {i}: 'bytes' must be a number"))?
                    as u64,
                checksum,
            });
        }
        let names = |key: &str| -> Result<Vec<String>> {
            v.get(key)
                .and_then(|a| a.as_arr())
                .ok_or_else(|| err!("manifest is missing '{key}'"))?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| err!("'{key}' entries must be strings"))
                })
                .collect()
        };
        let provenance = match v.get("provenance") {
            Some(p) => IngestProvenance {
                source: p
                    .get("source")
                    .and_then(|s| s.as_str())
                    .unwrap_or_default()
                    .to_string(),
                triples: p.get("triples").and_then(|t| t.as_f64()).unwrap_or(0.0) as u64,
            },
            None => IngestProvenance::default(),
        };
        let manifest = StoreManifest {
            n: usize_field("n")?,
            m: usize_field("m")?,
            grid: usize_field("grid")?,
            layout,
            dtype,
            shards,
            entities: names("entities")?,
            relations: names("relations")?,
            provenance,
            dir,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Write `manifest.json` into `self.dir`, returning its path.
    pub fn save(&self) -> Result<PathBuf> {
        let path = self.dir.join(MANIFEST_FILE);
        std::fs::write(&path, self.to_json().to_string())
            .with_context(|| format!("writing manifest to {}", path.display()))?;
        Ok(path)
    }

    /// Load a manifest from a `manifest.json` path or a dataset
    /// directory containing one.
    pub fn load(path: impl AsRef<Path>) -> Result<StoreManifest> {
        let given = path.as_ref();
        let file = if given.is_dir() { given.join(MANIFEST_FILE) } else { given.to_path_buf() };
        let text = std::fs::read_to_string(&file)
            .with_context(|| format!("reading dataset manifest {}", file.display()))?;
        let v = Json::parse(&text).map_err(|e| err!("manifest JSON: {e}"))?;
        let dir = file.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
        StoreManifest::from_json(&v, dir)
            .with_context(|| format!("loading {}", file.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StoreManifest {
        StoreManifest {
            n: 3,
            m: 2,
            grid: 1,
            layout: Layout::Sparse,
            dtype: DType::F32,
            shards: vec![ShardMeta {
                row: 0,
                col: 0,
                file: "shard_0_0.bin".to_string(),
                bytes: 128,
                checksum: 0xdead_beef_cafe_f00d,
            }],
            entities: vec!["alice".into(), "bob".into(), "carol".into()],
            relations: vec!["knows".into(), "likes".into()],
            provenance: IngestProvenance { source: "toy.tsv".into(), triples: 4 },
            dir: PathBuf::from("/tmp"),
        }
    }

    #[test]
    fn json_round_trip_preserves_everything() {
        let man = sample();
        let text = man.to_json().to_string();
        let back =
            StoreManifest::from_json(&Json::parse(&text).unwrap(), PathBuf::from("/tmp"))
                .unwrap();
        assert_eq!(back.n, man.n);
        assert_eq!(back.m, man.m);
        assert_eq!(back.grid, man.grid);
        assert_eq!(back.layout, man.layout);
        assert_eq!(back.shards, man.shards);
        assert_eq!(back.entities, man.entities);
        assert_eq!(back.relations, man.relations);
        assert_eq!(back.provenance, man.provenance);
    }

    #[test]
    fn dtype_round_trips_and_is_validated() {
        // default f32 is not serialized, so old manifests stay byte-stable
        let man = sample();
        assert!(!man.to_json().to_string().contains("dtype"));
        // a half dtype round-trips (dense layout)
        let mut man = sample();
        man.layout = Layout::Dense;
        man.dtype = DType::F16;
        let text = man.to_json().to_string();
        let back =
            StoreManifest::from_json(&Json::parse(&text).unwrap(), PathBuf::from("/tmp")).unwrap();
        assert_eq!(back.dtype, DType::F16);
        // sparse + half is structurally invalid
        let mut man = sample();
        man.dtype = DType::Bf16;
        assert!(man.validate().unwrap_err().to_string().contains("dense-only"));
        // an unknown dtype name is a typed parse error
        let text = sample().to_json().to_string().replacen(
            "\"layout\"",
            "\"dtype\":\"f64\",\"layout\"",
            1,
        );
        let e = StoreManifest::from_json(&Json::parse(&text).unwrap(), PathBuf::from("/tmp"))
            .unwrap_err();
        assert!(e.to_string().contains("dtype"), "{e}");
    }

    #[test]
    fn validation_rejects_inconsistency() {
        let mut man = sample();
        man.entities.pop();
        assert!(man.validate().unwrap_err().to_string().contains("entity names"));
        let mut man = sample();
        man.grid = 2; // 1 shard for a 2×2 grid
        assert!(man.validate().is_err());
        let mut man = sample();
        man.shards.push(man.shards[0].clone());
        man.grid = 1;
        assert!(man.validate().is_err());
        let mut man = sample();
        man.grid = 9; // grid larger than n
        assert!(man.validate().is_err());
    }

    #[test]
    fn foreign_json_is_rejected() {
        let bad = Json::parse(r#"{"kind":"factor_model"}"#).unwrap();
        let e = StoreManifest::from_json(&bad, PathBuf::from(".")).unwrap_err();
        assert!(e.to_string().contains("drescal_dataset"), "{e}");
        assert!(StoreManifest::from_json(&Json::parse("{}").unwrap(), PathBuf::from("."))
            .is_err());
        assert!(StoreManifest::load("/nonexistent/manifest.json").is_err());
    }
}
