//! The typed query layer: micro-batching, answer caching, and serve
//! counters.
//!
//! A [`QueryEngine`] owns a [`FactorModel`] and answers typed
//! [`Query`]s with typed [`Answer`]s, mirroring the engine's
//! `JobSpec`/`Report` pair on the write path. [`QueryEngine::submit_batch`]
//! is the serving hot path:
//!
//! 1. every query is bounds-checked up front (typed errors, no partial
//!    batches);
//! 2. cache hits are answered from the LRU answer cache without scoring
//!    anything;
//! 3. the remaining completion queries are grouped by
//!    `(relation, direction, top)` and each group runs **one GEMM**
//!    over the model's cached projection — duplicate anchors within a
//!    group are scored once;
//! 4. pointwise score queries are answered with a length-k dot each.
//!
//! [`ServeStats`] counts cache hits, GEMM batches, and scored
//! candidates so tests can *prove* the reuse guarantees (a repeated
//! query must add zero scored candidates). Every answered query also
//! lands in a log-bucketed latency [`Histogram`]
//! (each query in a batch is charged the batch's wall time — what the
//! caller actually waited), surfaced as p50/p95/p99 in [`ServeStats`]
//! and `drescal serve-bench`.

use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

use crate::backend::Workspace;
use crate::error::Result;
use crate::json::Json;
use crate::obs::Histogram;

use super::model::FactorModel;
use super::score::{self, Direction, Hit};

/// One typed serving request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Query {
    /// Pointwise triple score `aₛᵀ·R_r·aₒ`.
    Score { s: usize, r: usize, o: usize },
    /// `(s, r, ?)`: the `top` best candidate objects.
    TopObjects { s: usize, r: usize, top: usize },
    /// `(?, r, o)`: the `top` best candidate subjects.
    TopSubjects { o: usize, r: usize, top: usize },
}

/// The typed result of one [`Query`].
#[derive(Clone, Debug, PartialEq)]
pub enum Answer {
    Score(f32),
    TopK(Vec<Hit>),
}

impl Answer {
    /// JSON form (for `drescal query --json`).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        match self {
            Answer::Score(v) => {
                obj.insert("kind".to_string(), Json::Str("score".to_string()));
                obj.insert("score".to_string(), Json::Num(*v as f64));
            }
            Answer::TopK(hits) => {
                obj.insert("kind".to_string(), Json::Str("top_k".to_string()));
                obj.insert(
                    "hits".to_string(),
                    Json::Arr(
                        hits.iter()
                            .map(|h| {
                                let mut hit = BTreeMap::new();
                                hit.insert("entity".to_string(), Json::Num(h.entity as f64));
                                hit.insert("score".to_string(), Json::Num(h.score as f64));
                                Json::Obj(hit)
                            })
                            .collect(),
                    ),
                );
            }
        }
        Json::Obj(obj)
    }
}

/// Serving counters, cumulative since the engine was built.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Queries answered (hits and misses).
    pub queries: usize,
    /// Queries answered from the LRU cache — zero candidates scored.
    pub cache_hits: usize,
    /// GEMM micro-batches issued (one per `(relation, direction, top)`
    /// group of cache-missing completion queries per submit).
    pub batches: usize,
    /// Candidate entities scored (n per completion anchor, 1 per
    /// pointwise score). Unchanged by cache hits.
    pub scored_candidates: usize,
    /// Workspace checkouts that allocated a fresh GEMM buffer. Stops
    /// growing once the arena is warm — the serving analogue of the
    /// training plane's zero-allocation steady state.
    pub ws_allocs: usize,
    /// Workspace checkouts served by arena reuse (no allocation).
    pub ws_reuses: usize,
    /// Bytes of projection precompute the served model avoided by
    /// keeping its cores diagonal (`2·m·n·k·4` for a `distmult` model,
    /// 0 for dense-core families). Fixed at engine construction — the
    /// counter-assert that the diagonal serving fast path never
    /// densified.
    pub projection_bytes_saved: usize,
    /// Median per-query latency in microseconds (log-bucket resolution,
    /// ~2x). A query's latency is the wall time of the batch that
    /// answered it. 0 until a query completes.
    pub latency_p50_us: u64,
    /// 95th-percentile per-query latency in microseconds.
    pub latency_p95_us: u64,
    /// 99th-percentile per-query latency in microseconds.
    pub latency_p99_us: u64,
}

/// How many answers the LRU cache keeps by default.
const DEFAULT_CACHE_CAPACITY: usize = 1024;

/// One cached answer plus its last-use stamp (monotonic clock). Stamps
/// keep the hot path O(1): a hit refreshes one entry's stamp; only an
/// over-capacity insert scans for the minimum stamp to evict.
struct CacheEntry {
    answer: Answer,
    stamp: u64,
}

/// A serving engine over one loaded [`FactorModel`].
pub struct QueryEngine {
    model: FactorModel,
    cache: HashMap<Query, CacheEntry>,
    /// Monotonic use clock backing the LRU stamps.
    clock: u64,
    capacity: usize,
    stats: ServeStats,
    /// Arena for the batched-GEMM temporaries (anchor block + score
    /// matrix): steady-state batches are served entirely from reuse.
    ws: Workspace,
    /// Per-query latency distribution (nanoseconds, log buckets).
    latency: Histogram,
}

impl QueryEngine {
    /// Serving engine with the default answer-cache capacity.
    pub fn new(model: FactorModel) -> QueryEngine {
        QueryEngine::with_cache_capacity(model, DEFAULT_CACHE_CAPACITY)
    }

    /// Serving engine with an explicit answer-cache capacity
    /// (0 disables caching).
    pub fn with_cache_capacity(model: FactorModel, capacity: usize) -> QueryEngine {
        let stats = ServeStats {
            projection_bytes_saved: model.projection_bytes_saved(),
            ..ServeStats::default()
        };
        QueryEngine {
            model,
            cache: HashMap::new(),
            clock: 0,
            capacity,
            stats,
            ws: Workspace::new(),
            latency: Histogram::new(),
        }
    }

    /// The model being served.
    pub fn model(&self) -> &FactorModel {
        &self.model
    }

    /// Cumulative serving counters, with latency percentiles read from
    /// the live histogram.
    pub fn stats(&self) -> ServeStats {
        let mut s = self.stats;
        s.latency_p50_us = self.latency.quantile_ns(0.50) / 1000;
        s.latency_p95_us = self.latency.quantile_ns(0.95) / 1000;
        s.latency_p99_us = self.latency.quantile_ns(0.99) / 1000;
        s
    }

    /// The per-query latency distribution (nanoseconds, log buckets).
    pub fn latency_histogram(&self) -> &Histogram {
        &self.latency
    }

    /// Answer one query (a batch of one).
    pub fn query(&mut self, q: Query) -> Result<Answer> {
        let mut answers = self.submit_batch(std::slice::from_ref(&q))?;
        Ok(answers.pop().expect("one answer per query"))
    }

    /// Answer a batch of concurrent queries. Cache-missing completion
    /// queries that share `(relation, direction, top)` are scored by a
    /// single GEMM; answers come back in query order.
    pub fn submit_batch(&mut self, queries: &[Query]) -> Result<Vec<Answer>> {
        let t0 = Instant::now();
        // validate everything before scoring anything
        for q in queries {
            match *q {
                Query::Score { s, r, o } => {
                    score::check_query_bounds(&self.model, s, r)?;
                    score::check_query_bounds(&self.model, o, r)?;
                }
                Query::TopObjects { s, r, .. } => {
                    score::check_query_bounds(&self.model, s, r)?;
                }
                Query::TopSubjects { o, r, .. } => {
                    score::check_query_bounds(&self.model, o, r)?;
                }
            }
        }
        self.stats.queries += queries.len();

        let mut answers: Vec<Option<Answer>> = vec![None; queries.len()];
        // (rel, dir, top) → slots awaiting a completion answer
        let mut groups: BTreeMap<(usize, Direction, usize), Vec<usize>> = BTreeMap::new();
        for (slot, q) in queries.iter().enumerate() {
            if let Some(hit) = self.cache_get(q) {
                self.stats.cache_hits += 1;
                answers[slot] = Some(hit);
                continue;
            }
            match *q {
                Query::Score { s, r, o } => {
                    let ans = Answer::Score(score::score_one(&self.model, s, r, o)?);
                    self.stats.scored_candidates += 1;
                    self.cache_insert(*q, ans.clone());
                    answers[slot] = Some(ans);
                }
                Query::TopObjects { r, top, .. } => {
                    groups.entry((r, Direction::Objects, top)).or_default().push(slot);
                }
                Query::TopSubjects { r, top, .. } => {
                    groups.entry((r, Direction::Subjects, top)).or_default().push(slot);
                }
            }
        }

        for ((rel, dir, top), slots) in groups {
            // dedupe anchors: identical queries in one batch score once
            let mut anchors: Vec<usize> = Vec::with_capacity(slots.len());
            let mut anchor_row: HashMap<usize, usize> = HashMap::new();
            for &slot in &slots {
                let anchor = anchor_of(&queries[slot]);
                anchor_row.entry(anchor).or_insert_with(|| {
                    anchors.push(anchor);
                    anchors.len() - 1
                });
            }
            let per_anchor =
                score::complete_batch(&self.model, dir, rel, &anchors, top, &mut self.ws)?;
            self.stats.batches += 1;
            self.stats.scored_candidates += anchors.len() * self.model.n();
            for &slot in &slots {
                let row = anchor_row[&anchor_of(&queries[slot])];
                let ans = Answer::TopK(per_anchor[row].clone());
                self.cache_insert(queries[slot], ans.clone());
                answers[slot] = Some(ans);
            }
        }

        let w = self.ws.stats();
        self.stats.ws_allocs = w.mat_allocs;
        self.stats.ws_reuses = w.mat_reuses;
        // every query in the batch waited for the whole batch
        let batch_ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        for _ in 0..queries.len() {
            self.latency.record_ns(batch_ns);
        }
        Ok(answers
            .into_iter()
            .map(|a| a.expect("every query slot answered"))
            .collect())
    }

    fn cache_get(&mut self, q: &Query) -> Option<Answer> {
        self.clock += 1;
        let clock = self.clock;
        let entry = self.cache.get_mut(q)?;
        entry.stamp = clock; // refresh LRU position, O(1)
        Some(entry.answer.clone())
    }

    fn cache_insert(&mut self, q: Query, answer: Answer) {
        if self.capacity == 0 {
            return;
        }
        self.clock += 1;
        self.cache.insert(q, CacheEntry { answer, stamp: self.clock });
        if self.cache.len() > self.capacity {
            // over-capacity insert (not the hit path): evict the
            // least-recently-used entry; stamps are unique, so the
            // minimum is deterministic
            if let Some(oldest) =
                self.cache.iter().min_by_key(|(_, e)| e.stamp).map(|(k, _)| *k)
            {
                self.cache.remove(&oldest);
            }
        }
    }
}

/// The entity a completion query is anchored on (its projection row).
fn anchor_of(q: &Query) -> usize {
    match *q {
        Query::TopObjects { s, .. } => s,
        Query::TopSubjects { o, .. } => o,
        Query::Score { s, .. } => s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::serve::model::Provenance;
    use crate::tensor::{Mat, Tensor3};

    fn engine(n: usize, capacity: usize) -> QueryEngine {
        let mut rng = Rng::new(11);
        let a = Mat::random_uniform(n, 3, 0.0, 1.0, &mut rng);
        let r = Tensor3::random_uniform(3, 3, 2, 0.0, 1.0, &mut rng);
        let model = FactorModel::new(a, r, Provenance::external()).unwrap();
        QueryEngine::with_cache_capacity(model, capacity)
    }

    #[test]
    fn repeated_query_is_served_from_cache() {
        let mut qe = engine(16, 8);
        let q = Query::TopObjects { s: 3, r: 1, top: 4 };
        let first = qe.query(q).unwrap();
        let after_first = qe.stats();
        assert_eq!(after_first.batches, 1);
        assert_eq!(after_first.scored_candidates, 16);
        assert_eq!(after_first.cache_hits, 0);
        // same query again: zero additional scored candidates
        let second = qe.query(q).unwrap();
        let after_second = qe.stats();
        assert_eq!(first, second);
        assert_eq!(after_second.cache_hits, 1);
        assert_eq!(after_second.batches, 1, "no new GEMM for a cache hit");
        assert_eq!(after_second.scored_candidates, 16, "zero additional candidates");
    }

    #[test]
    fn batch_groups_one_gemm_per_relation_direction() {
        let mut qe = engine(10, 0);
        let batch = [
            Query::TopObjects { s: 0, r: 0, top: 3 },
            Query::TopObjects { s: 1, r: 0, top: 3 },
            Query::TopObjects { s: 0, r: 0, top: 3 }, // duplicate: scored once
            Query::TopSubjects { o: 2, r: 0, top: 3 },
            Query::TopObjects { s: 4, r: 1, top: 3 },
        ];
        let answers = qe.submit_batch(&batch).unwrap();
        assert_eq!(answers.len(), 5);
        assert_eq!(answers[0], answers[2], "duplicate queries agree");
        let stats = qe.stats();
        // groups: (r0, obj), (r0, subj), (r1, obj)
        assert_eq!(stats.batches, 3);
        // anchors scored: {0,1} + {2} + {4} = 4 anchors × 10 candidates
        assert_eq!(stats.scored_candidates, 40);
        assert_eq!(stats.queries, 5);
    }

    #[test]
    fn steady_state_batches_stop_allocating() {
        let mut qe = engine(32, 0); // cache off: every batch runs the GEMM
        let batch = [
            Query::TopObjects { s: 0, r: 0, top: 4 },
            Query::TopObjects { s: 3, r: 0, top: 4 },
        ];
        qe.submit_batch(&batch).unwrap();
        let warm = qe.stats();
        assert!(warm.ws_allocs > 0, "first batch populates the arena");
        for _ in 0..4 {
            qe.submit_batch(&batch).unwrap();
        }
        let steady = qe.stats();
        assert_eq!(steady.ws_allocs, warm.ws_allocs, "warm batches allocate nothing");
        assert!(steady.ws_reuses > warm.ws_reuses);
    }

    #[test]
    fn lru_evicts_oldest_answer() {
        let mut qe = engine(8, 1);
        let q1 = Query::TopObjects { s: 0, r: 0, top: 2 };
        let q2 = Query::TopObjects { s: 1, r: 0, top: 2 };
        qe.query(q1).unwrap();
        qe.query(q2).unwrap(); // evicts q1
        let scored_before = qe.stats().scored_candidates;
        qe.query(q1).unwrap(); // must rescore
        assert_eq!(qe.stats().cache_hits, 0);
        assert_eq!(qe.stats().scored_candidates, scored_before + 8);
        // q1 is now cached again
        qe.query(q1).unwrap();
        assert_eq!(qe.stats().cache_hits, 1);
    }

    #[test]
    fn pointwise_scores_count_one_candidate() {
        let mut qe = engine(12, 4);
        let q = Query::Score { s: 1, r: 0, o: 2 };
        let a1 = qe.query(q).unwrap();
        assert_eq!(qe.stats().scored_candidates, 1);
        assert_eq!(qe.stats().batches, 0, "pointwise scores issue no GEMM batch");
        let a2 = qe.query(q).unwrap();
        assert_eq!(a1, a2);
        assert_eq!(qe.stats().cache_hits, 1);
        assert_eq!(qe.stats().scored_candidates, 1);
    }

    #[test]
    fn invalid_queries_fail_before_scoring() {
        let mut qe = engine(6, 4);
        let bad = [
            Query::TopObjects { s: 0, r: 0, top: 2 },
            Query::TopObjects { s: 99, r: 0, top: 2 },
        ];
        assert!(qe.submit_batch(&bad).is_err());
        assert_eq!(qe.stats().queries, 0, "failed batches answer nothing");
        assert_eq!(qe.stats().scored_candidates, 0);
        assert!(qe.query(Query::Score { s: 0, r: 5, o: 0 }).is_err());
        assert!(qe.query(Query::TopSubjects { o: 6, r: 0, top: 1 }).is_err());
    }

    #[test]
    fn latency_histogram_charges_every_answered_query() {
        let mut qe = engine(16, 8);
        assert_eq!(qe.latency_histogram().count(), 0);
        assert_eq!(qe.stats().latency_p50_us, 0, "no data yet");
        let batch = [
            Query::TopObjects { s: 0, r: 0, top: 3 },
            Query::TopObjects { s: 1, r: 0, top: 3 },
            Query::Score { s: 0, r: 0, o: 1 },
        ];
        qe.submit_batch(&batch).unwrap();
        assert_eq!(qe.latency_histogram().count(), 3, "one sample per query");
        // cache hits are still answered queries: they get charged too
        qe.query(Query::Score { s: 0, r: 0, o: 1 }).unwrap();
        assert_eq!(qe.latency_histogram().count(), 4);
        let s = qe.stats();
        assert!(s.latency_p99_us >= s.latency_p95_us);
        assert!(s.latency_p95_us >= s.latency_p50_us);
        // a failed batch answers nothing and charges nothing
        assert!(qe.submit_batch(&[Query::Score { s: 99, r: 0, o: 0 }]).is_err());
        assert_eq!(qe.latency_histogram().count(), 4);
    }

    #[test]
    fn answer_json_forms() {
        let score = Answer::Score(0.5).to_json();
        assert_eq!(score.get("kind").and_then(|k| k.as_str()), Some("score"));
        let topk = Answer::TopK(vec![Hit { entity: 3, score: 1.0 }]).to_json();
        let hits = topk.get("hits").and_then(|h| h.as_arr()).unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].get("entity").and_then(|e| e.as_f64()), Some(3.0));
    }
}
