//! Scoring kernels: pointwise triple scores and batched top-k
//! completion over all candidate entities.
//!
//! `score(s,r,o) = aₛᵀ·R_r·aₒ`. With the model's cached projection
//! `P_r = A·R_r`, a pointwise score is one length-k dot
//! (`P_r[s,:]·aₒ`), and a `(s,r,?)` completion is one GEMV
//! (`A·P_r[s,:]ᵀ`) followed by a partial top-k selection over the n
//! candidates. A batch of B completion queries on one relation gathers
//! the B query rows into a B×k matrix and runs a single
//! `B×k · k×n` GEMM — the batched-GEMM shape that dominates
//! link-prediction serving (DGL-KE, arXiv 2004.08532) — which threads
//! through the existing blocked GEMM above its work threshold.
//!
//! The query rows come from [`FactorModel::fill_query_row`], which
//! makes every family serve through the same GEMM: dense-core models
//! copy cached projection rows, diagonal (`distmult`) models compute
//! `a_anchor ∘ d_r` on the fly without ever densifying a core, and
//! logistic models score densely with `σ` applied to the reported
//! scores (σ is monotone, so selection order never changes and the
//! sigmoid runs only on what the caller sees: one value per pointwise
//! score, `top` values per completion).
//!
//! Top-k selection breaks score ties toward the **lower entity index**.
//! The comparator is a strict total order, so the selected set and its
//! order are unique: results are reproducible across thread counts,
//! chunk shapes, and batch compositions.

use std::cmp::Ordering;

use crate::backend::Workspace;
use crate::bail;
use crate::error::Result;
use crate::rescal::model::sigmoid;
use crate::rescal::ModelKind;
use crate::tensor::dense::num_threads;
use crate::tensor::kernel;

use super::model::FactorModel;

/// Which side of a triple a completion query fills in.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Direction {
    /// `(s, r, ?)` — rank candidate objects.
    Objects,
    /// `(?, r, o)` — rank candidate subjects.
    Subjects,
}

/// One ranked completion candidate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    /// Candidate entity index.
    pub entity: usize,
    /// Its score `aₛᵀ·R_r·aₒ`.
    pub score: f32,
}

/// Strict total order on hits: higher score first, ties toward the
/// lower entity index. Every pair of distinct hits compares unequal
/// (entity indices are unique), which is what makes top-k selection
/// deterministic however the candidates are chunked.
pub fn cmp_hits(a: &Hit, b: &Hit) -> Ordering {
    b.score.total_cmp(&a.score).then(a.entity.cmp(&b.entity))
}

/// Pointwise `score(s, rel, o)`: one length-k dot against the (cached
/// or virtual) query row; `σ` on top for the logistic family.
pub fn score_one(model: &FactorModel, s: usize, rel: usize, o: usize) -> Result<f32> {
    check_entity(model, s)?;
    check_entity(model, o)?;
    check_relation(model, rel)?;
    let raw = if model.is_diagonal() {
        // Σ_j a[s,j]·d[j]·a[o,j] — no densified core, no projection
        let d = model.r().slice(rel).row(0);
        let a_s = model.a().row(s);
        let a_o = model.a().row(o);
        let mut acc = 0.0f32;
        for j in 0..model.k() {
            acc += a_s[j] * d[j] * a_o[j];
        }
        acc
    } else {
        let p = model.projection(Direction::Objects, rel);
        dot(p.row(s), model.a().row(o))
    };
    Ok(finish_score(model, raw))
}

/// Map a raw bilinear score to what the family reports: `σ(x)` for
/// logistic models (a Bernoulli probability), identity otherwise.
#[inline]
fn finish_score(model: &FactorModel, raw: f32) -> f32 {
    if model.model() == ModelKind::Logistic {
        sigmoid(raw)
    } else {
        raw
    }
}

#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (a, b) in x.iter().zip(y) {
        acc += a * b;
    }
    acc
}

fn check_entity(model: &FactorModel, e: usize) -> Result<()> {
    if e >= model.n() {
        bail!("entity index {e} out of range (model has {} entities)", model.n());
    }
    Ok(())
}

fn check_relation(model: &FactorModel, rel: usize) -> Result<()> {
    if rel >= model.m() {
        bail!("relation index {rel} out of range (model has {} relations)", model.m());
    }
    Ok(())
}

/// Batched completion: for each anchor entity, rank all n candidates on
/// relation `rel` and return the top `top` hits (deterministic order).
///
/// All anchors share one `B×k · k×n` GEMM over the cached projection,
/// with the anchor block and the score matrix checked out of `ws` — a
/// query engine serving a steady stream of same-sized batches allocates
/// no GEMM temporaries after warm-up. The per-row selection then runs
/// threaded when the candidate count crosses [`SELECT_PAR_THRESHOLD`].
/// Returns one hit list per anchor, anchor order preserved.
pub fn complete_batch(
    model: &FactorModel,
    dir: Direction,
    rel: usize,
    anchors: &[usize],
    top: usize,
    ws: &mut Workspace,
) -> Result<Vec<Vec<Hit>>> {
    check_relation(model, rel)?;
    for &anchor in anchors {
        check_entity(model, anchor)?;
    }
    if anchors.is_empty() {
        return Ok(Vec::new());
    }
    let k = model.k();
    // gather the anchors' query rows into one B×k block (cached
    // projection rows, or a ∘ d for diagonal models)
    let mut q = ws.acquire(anchors.len(), k);
    for (i, &anchor) in anchors.iter().enumerate() {
        model.fill_query_row(dir, rel, anchor, q.row_mut(i));
    }
    // one GEMM scores every candidate for every anchor: B×k · (n×k)ᵀ,
    // straight into the workspace score buffer on the packed kernel
    let mut scores = ws.acquire(anchors.len(), model.n());
    kernel::gemm_nt_into(&q, model.a(), &mut scores);
    let mut hits: Vec<Vec<Hit>> =
        (0..anchors.len()).map(|i| top_k(scores.row(i), top)).collect();
    // σ is monotone, so applying it after selection changes no ranking
    // and touches only the reported top scores
    if model.model() == ModelKind::Logistic {
        for list in &mut hits {
            for h in list {
                h.score = sigmoid(h.score);
            }
        }
    }
    ws.release(q);
    ws.release(scores);
    Ok(hits)
}

/// Candidate count above which top-k selection splits across threads.
pub const SELECT_PAR_THRESHOLD: usize = 1 << 15;

/// Select the `top` best-scoring candidates from a dense score vector
/// (candidate index = position). Deterministic: see [`cmp_hits`].
pub fn top_k(scores: &[f32], top: usize) -> Vec<Hit> {
    let nt = num_threads();
    let chunks = if scores.len() >= SELECT_PAR_THRESHOLD && nt > 1 {
        nt.min(scores.len())
    } else {
        1
    };
    top_k_chunked(scores, top, chunks)
}

/// Chunked top-k: split the candidates into `chunks` contiguous ranges,
/// select each range's local top-k, and merge. Ranges run on scoped
/// threads when the chunk count is near the host's parallelism (the
/// shape [`top_k`] produces); a pathological chunk count falls back to
/// a sequential sweep rather than spawning unbounded threads. Either
/// way the merge is pure, and because [`cmp_hits`] is a strict total
/// order the result is identical for every chunk count — the property
/// the determinism tests pin down.
pub fn top_k_chunked(scores: &[f32], top: usize, chunks: usize) -> Vec<Hit> {
    if top == 0 || scores.is_empty() {
        return Vec::new();
    }
    let chunks = chunks.clamp(1, scores.len());
    if chunks == 1 {
        return select_range(scores, 0, top);
    }
    let chunk_len = scores.len().div_ceil(chunks);
    let ranges = scores.chunks(chunk_len).enumerate();
    let locals: Vec<Vec<Hit>> = if chunks <= num_threads().max(1) * 2 {
        let mut locals = Vec::with_capacity(chunks);
        std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .map(|(c, range)| s.spawn(move || select_range(range, c * chunk_len, top)))
                .collect();
            for h in handles {
                locals.push(h.join().expect("top-k selection thread"));
            }
        });
        locals
    } else {
        ranges.map(|(c, range)| select_range(range, c * chunk_len, top)).collect()
    };
    let mut merged: Vec<Hit> = locals.into_iter().flatten().collect();
    merged.sort_by(cmp_hits);
    merged.truncate(top);
    merged
}

/// Serial top-k over one contiguous candidate range whose first
/// candidate has global index `base`.
fn select_range(scores: &[f32], base: usize, top: usize) -> Vec<Hit> {
    let mut hits: Vec<Hit> = scores
        .iter()
        .enumerate()
        .map(|(i, &score)| Hit { entity: base + i, score })
        .collect();
    if hits.len() > top {
        // partial selection: O(n) partition puts the best `top` first
        hits.select_nth_unstable_by(top - 1, cmp_hits);
        hits.truncate(top);
    }
    hits.sort_by(cmp_hits);
    hits
}

/// Brute-force reference: score every candidate pointwise and fully
/// sort. Used by the parity tests and the `serve-bench` baseline; the
/// batched path must match it exactly.
pub fn brute_force_top_k(
    model: &FactorModel,
    dir: Direction,
    rel: usize,
    anchor: usize,
    top: usize,
) -> Result<Vec<Hit>> {
    check_relation(model, rel)?;
    check_entity(model, anchor)?;
    let hits: Result<Vec<Hit>> = (0..model.n())
        .map(|cand| {
            let score = match dir {
                Direction::Objects => score_one(model, anchor, rel, cand)?,
                Direction::Subjects => score_one(model, cand, rel, anchor)?,
            };
            Ok(Hit { entity: cand, score })
        })
        .collect();
    let mut hits = hits?;
    hits.sort_by(cmp_hits);
    hits.truncate(top);
    Ok(hits)
}

/// A full dense score vector for one anchor (no selection) — the
/// serving analogue of a probability row, handy for calibration and
/// tests.
pub fn score_row(
    model: &FactorModel,
    dir: Direction,
    rel: usize,
    anchor: usize,
) -> Result<Vec<f32>> {
    check_relation(model, rel)?;
    check_entity(model, anchor)?;
    let mut anchor_row = vec![0.0f32; model.k()];
    model.fill_query_row(dir, rel, anchor, &mut anchor_row);
    Ok((0..model.n())
        .map(|cand| finish_score(model, dot(&anchor_row, model.a().row(cand))))
        .collect())
}

/// Validate that `top_k` inputs describe a well-formed query (used by
/// the query layer before any compute).
pub fn check_query_bounds(model: &FactorModel, anchor: usize, rel: usize) -> Result<()> {
    check_entity(model, anchor)?;
    check_relation(model, rel)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::serve::model::Provenance;
    use crate::tensor::{Mat, Tensor3};

    fn model(n: usize, k: usize, m: usize, seed: u64) -> FactorModel {
        let mut rng = Rng::new(seed);
        let a = Mat::random_uniform(n, k, 0.0, 1.0, &mut rng);
        let r = Tensor3::random_uniform(k, k, m, 0.0, 1.0, &mut rng);
        FactorModel::new(a, r, Provenance::external()).unwrap()
    }

    fn family_model(n: usize, k: usize, m: usize, seed: u64, kind: ModelKind) -> FactorModel {
        let mut rng = Rng::new(seed);
        let a = Mat::random_uniform(n, k, 0.0, 1.0, &mut rng);
        let r = Tensor3::random_uniform(kind.core_rows(k), k, m, 0.0, 1.0, &mut rng);
        FactorModel::new_with_model(a, r, kind, Provenance::external()).unwrap()
    }

    #[test]
    fn score_one_matches_definition() {
        let m = model(8, 3, 2, 1);
        for s in 0..8 {
            for o in 0..8 {
                for t in 0..2 {
                    // aₛᵀ·R_t·aₒ computed longhand in f64
                    let mut want = 0.0f64;
                    for i in 0..3 {
                        for j in 0..3 {
                            want += m.a()[(s, i)] as f64
                                * m.r().slice(t)[(i, j)] as f64
                                * m.a()[(o, j)] as f64;
                        }
                    }
                    let got = score_one(&m, s, t, o).unwrap();
                    assert!((got as f64 - want).abs() < 1e-4, "s={s} o={o} t={t}");
                }
            }
        }
    }

    #[test]
    fn top_k_breaks_ties_by_entity_index() {
        // plateau of equal scores: selection must prefer lower indices
        let scores = [1.0f32, 3.0, 3.0, 2.0, 3.0, 1.0];
        let hits = top_k_chunked(&scores, 4, 1);
        let idx: Vec<usize> = hits.iter().map(|h| h.entity).collect();
        assert_eq!(idx, [1, 2, 4, 3]);
        // identical under any chunking
        for chunks in [2, 3, 4, 6] {
            assert_eq!(top_k_chunked(&scores, 4, chunks), hits, "chunks={chunks}");
        }
    }

    #[test]
    fn top_k_edge_cases() {
        assert!(top_k_chunked(&[], 3, 2).is_empty());
        assert!(top_k_chunked(&[1.0, 2.0], 0, 1).is_empty());
        // top larger than n returns all, sorted
        let hits = top_k_chunked(&[1.0, 2.0], 10, 3);
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].entity, 1);
        assert_eq!(hits[1].entity, 0);
    }

    #[test]
    fn chunked_selection_is_chunk_invariant_on_random_scores() {
        let mut rng = Rng::new(7);
        let mut scores = vec![0.0f32; 500];
        rng.fill_uniform(&mut scores, -1.0, 1.0);
        // inject exact ties to stress the tie-break
        for i in (0..500).step_by(7) {
            scores[i] = 0.5;
        }
        let want = top_k_chunked(&scores, 25, 1);
        for chunks in [2, 3, 8, 16, 499, 500] {
            assert_eq!(top_k_chunked(&scores, 25, chunks), want, "chunks={chunks}");
        }
    }

    #[test]
    fn batched_completion_matches_brute_force() {
        let m = model(30, 4, 3, 9);
        let mut ws = Workspace::new();
        for dir in [Direction::Objects, Direction::Subjects] {
            let anchors = [0usize, 7, 29, 7];
            let batched = complete_batch(&m, dir, 1, &anchors, 5, &mut ws).unwrap();
            assert_eq!(batched.len(), anchors.len());
            for (i, &anchor) in anchors.iter().enumerate() {
                let brute = brute_force_top_k(&m, dir, 1, anchor, 5).unwrap();
                let got: Vec<usize> = batched[i].iter().map(|h| h.entity).collect();
                let want: Vec<usize> = brute.iter().map(|h| h.entity).collect();
                assert_eq!(got, want, "dir={dir:?} anchor={anchor}");
                for (g, w) in batched[i].iter().zip(&brute) {
                    assert!((g.score - w.score).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn diagonal_score_matches_densified_core() {
        // a distmult model and a rescal model whose dense core is the
        // densification of the same diagonal must score identically
        let diag = family_model(10, 3, 2, 13, ModelKind::DistMult);
        let dense_cores: Vec<Mat> = (0..2)
            .map(|t| Mat::from_fn(3, 3, |i, j| if i == j { diag.r().slice(t)[(0, j)] } else { 0.0 }))
            .collect();
        let dense = FactorModel::new(
            diag.a().clone(),
            Tensor3::from_slices(dense_cores),
            Provenance::external(),
        )
        .unwrap();
        for s in 0..10 {
            for o in 0..10 {
                for t in 0..2 {
                    let got = score_one(&diag, s, t, o).unwrap();
                    let want = score_one(&dense, s, t, o).unwrap();
                    assert!((got - want).abs() < 1e-5, "s={s} t={t} o={o}: {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn diagonal_batched_completion_matches_brute_force() {
        let m = family_model(24, 4, 3, 17, ModelKind::DistMult);
        assert_eq!(m.projection_bytes_saved(), 2 * 3 * 24 * 4 * 4);
        let mut ws = Workspace::new();
        for dir in [Direction::Objects, Direction::Subjects] {
            let anchors = [0usize, 11, 23];
            let batched = complete_batch(&m, dir, 2, &anchors, 6, &mut ws).unwrap();
            for (i, &anchor) in anchors.iter().enumerate() {
                let brute = brute_force_top_k(&m, dir, 2, anchor, 6).unwrap();
                let got: Vec<usize> = batched[i].iter().map(|h| h.entity).collect();
                let want: Vec<usize> = brute.iter().map(|h| h.entity).collect();
                assert_eq!(got, want, "dir={dir:?} anchor={anchor}");
                for (g, w) in batched[i].iter().zip(&brute) {
                    assert!((g.score - w.score).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn logistic_scores_are_probabilities() {
        let m = family_model(12, 3, 2, 19, ModelKind::Logistic);
        let mut ws = Workspace::new();
        for s in 0..12 {
            let got = score_one(&m, s, 0, (s + 1) % 12).unwrap();
            assert!((0.0..=1.0).contains(&got), "σ output out of range: {got}");
        }
        // batched hits carry σ'd scores and match the pointwise path
        let hits = complete_batch(&m, Direction::Objects, 1, &[4], 5, &mut ws).unwrap();
        let brute = brute_force_top_k(&m, Direction::Objects, 1, 4, 5).unwrap();
        for (g, w) in hits[0].iter().zip(&brute) {
            assert_eq!(g.entity, w.entity);
            assert!((0.0..=1.0).contains(&g.score));
            assert!((g.score - w.score).abs() < 1e-5);
        }
    }

    #[test]
    fn repeated_batches_reuse_the_workspace() {
        let m = model(40, 4, 2, 21);
        let mut ws = Workspace::new();
        let anchors = [1usize, 5, 9];
        complete_batch(&m, Direction::Objects, 0, &anchors, 3, &mut ws).unwrap();
        let warm = ws.stats();
        assert!(warm.mat_allocs > 0, "first batch must populate the arena");
        for _ in 0..5 {
            complete_batch(&m, Direction::Subjects, 1, &anchors, 3, &mut ws).unwrap();
        }
        let steady = ws.stats();
        assert_eq!(steady.mat_allocs, warm.mat_allocs, "steady-state batches allocate nothing");
        assert_eq!(steady.mat_reuses, warm.mat_reuses + 10, "2 buffers per batch, all reused");
    }

    #[test]
    fn typed_errors_on_out_of_range() {
        let m = model(5, 2, 2, 3);
        assert!(score_one(&m, 5, 0, 0).is_err());
        assert!(score_one(&m, 0, 2, 0).is_err());
        assert!(score_one(&m, 0, 0, 9).is_err());
        let mut ws = Workspace::new();
        assert!(complete_batch(&m, Direction::Objects, 0, &[4, 5], 3, &mut ws).is_err());
        assert!(complete_batch(&m, Direction::Objects, 7, &[0], 3, &mut ws).is_err());
        assert!(brute_force_top_k(&m, Direction::Subjects, 0, 99, 3).is_err());
    }
}
