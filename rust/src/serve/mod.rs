//! The serving plane: the read path that mirrors the engine's write path.
//!
//! Training produces factors (`X_t ≈ A R_t Aᵀ`); this module is the
//! subsystem that *answers queries* from them — the paper's motivating
//! use of a factorized knowledge tensor is "predictions of missing
//! relations", which is a batched-GEMM scoring problem of its own,
//! distinct from training (cf. DGL-KE, arXiv 2004.08532).
//!
//! # Lifecycle: train → export → persist → serve
//!
//! * **export** — [`crate::engine::Engine::export_model`] turns a
//!   [`crate::engine::Report`] (`Factorize` or `ModelSelect`) into a
//!   [`FactorModel`]: the entity factors `A`, the relation cores `R`,
//!   optional entity/relation names, and the provenance of the producing
//!   job. The model precomputes per-relation projections `A·R_t` and
//!   `A·R_tᵀ`, so any completion query is one dense GEMV over the
//!   candidate entities.
//! * **persist** — [`FactorModel::save`]/[`FactorModel::load`] round-trip
//!   the artifact through the crate's own JSON (`drescal export` writes
//!   it, `drescal query` reads it). Projections are recomputed on load,
//!   never serialized.
//! * **serve** — a [`QueryEngine`] answers typed [`Query`]s with typed
//!   [`Answer`]s (mirroring `JobSpec`/`Report` on the write path):
//!   pointwise scores `score(s,r,o) = aₛᵀ·R_r·aₒ` and batched top-k
//!   completion `(s,r,?)` / `(?,r,o)`. Concurrent completion queries on
//!   one relation are micro-batched into a single GEMM, answers are
//!   LRU-cached by query, and [`ServeStats`] counters (cache hits,
//!   GEMM batches, scored candidates) make the reuse guarantees
//!   testable.
//!
//! Top-k selection is deterministic under score ties (ties break toward
//! the lower entity index), so serving results are reproducible across
//! thread counts and batch shapes.

pub mod model;
pub mod query;
pub mod score;

pub use model::{FactorModel, Provenance};
pub use query::{Answer, Query, QueryEngine, ServeStats};
pub use score::{Direction, Hit};
