//! The persisted factor-model artifact.
//!
//! A [`FactorModel`] is what training leaves behind for the serving
//! plane: entity factors `A` (n×k), relation cores `R` (k×k×m), optional
//! names, and the provenance of the producing job. It is constructed
//! from a [`Report`] (via [`crate::engine::Engine::export_model`]) and
//! round-trips through the crate's own JSON, so a trained model can be
//! archived and served by a process that never ran the factorization.
//!
//! On construction (and again on load) a dense-core model precomputes
//! the per-relation projections `P_t = A·R_t` and `Q_t = A·R_tᵀ`. With
//! them, every query is cheap:
//!
//! * `score(s,r,o) = aₛᵀ·R_r·aₒ = P_r[s,:] · aₒ` — one length-k dot;
//! * `(s,r,?)` completion: scores over all objects are `A · P_r[s,:]ᵀ` —
//!   one GEMV over the n candidates;
//! * `(?,r,o)` completion: scores over all subjects are `A · Q_r[o,:]ᵀ`.
//!
//! The projections cost `2·m·n·k` floats and are never serialized.
//!
//! A **diagonal-core** model ([`ModelKind::DistMult`], cores persisted
//! as 1×k vectors) skips the precompute entirely: a virtual projection
//! row is `a_anchor ∘ d_r` — k multiplies, identical in both directions
//! because a diagonal core is symmetric — so serving it saves the whole
//! `2·m·n·k·4` bytes ([`FactorModel::projection_bytes_saved`], asserted
//! by [`super::query::ServeStats`]). Logistic models score through the
//! dense path with `σ` applied on top (see [`super::score`]).

use std::collections::BTreeMap;
use std::path::Path;

use crate::engine::report::{
    mat_from_json, mat_to_json, model_from_json, tensor_from_json, tensor_to_json,
};
use crate::engine::Report;
use crate::error::{Context as _, Result};
use crate::json::Json;
use crate::rescal::ModelKind;
use crate::tensor::{DType, Mat, Tensor3};
use crate::{bail, err};

use super::score::Direction;

/// Where a model came from: the job kind that produced it and, when
/// exported through an [`crate::engine::Engine`], the grid and backend
/// it was trained on.
#[derive(Clone, Debug, PartialEq)]
pub struct Provenance {
    /// Producing job kind: `"factorize"`, `"model_select"`, or
    /// `"external"` for models built directly from factors.
    pub job: String,
    /// Grid size of the producing engine (0 = unknown/external).
    pub p: usize,
    /// Backend of the producing engine (empty = unknown/external).
    pub backend: String,
    /// Final relative reconstruction error of the training job
    /// (negative = unknown).
    pub rel_error: f64,
    /// Wall-clock seconds of the training job (0 = unknown).
    pub wall_seconds: f64,
}

impl Provenance {
    /// Provenance for a model built directly from factors, outside any
    /// engine job.
    pub fn external() -> Self {
        Provenance {
            job: "external".to_string(),
            p: 0,
            backend: String::new(),
            rel_error: -1.0,
            wall_seconds: 0.0,
        }
    }
}

/// A trained, servable factor model `X_t ≈ A R_t Aᵀ`.
#[derive(Clone, Debug)]
pub struct FactorModel {
    /// Entity factors, n×k, row i = latent vector of entity i.
    a: Mat,
    /// Relation cores, k×k×m.
    r: Tensor3,
    entity_names: Option<Vec<String>>,
    relation_names: Option<Vec<String>>,
    provenance: Provenance,
    /// Model family the factors were trained under; fixes the core
    /// shape and the scoring rule.
    model: ModelKind,
    /// Storage precision the factors were quantized to at export time
    /// (`f32` = never quantized). Scoring math is always f32 — a half
    /// artifact just guarantees every factor value is exactly
    /// representable at that precision.
    dtype: DType,
    /// Per-relation `A·R_t` (n×k); row s scores `(s, t, ?)` queries.
    /// Empty for diagonal-core models, which never densify.
    proj_obj: Vec<Mat>,
    /// Per-relation `A·R_tᵀ` (n×k); row o scores `(?, t, o)` queries.
    /// Empty for diagonal-core models.
    proj_subj: Vec<Mat>,
}

impl FactorModel {
    /// Build (and validate) a Gaussian-RESCAL model from factors (`a` is
    /// n×k, `r` holds k×k cores). See [`FactorModel::new_with_model`]
    /// for the other families.
    pub fn new(a: Mat, r: Tensor3, provenance: Provenance) -> Result<FactorModel> {
        FactorModel::new_with_model(a, r, ModelKind::Rescal, provenance)
    }

    /// Build (and validate) a model of any family. `a` is n×k; `r` must
    /// hold `core_rows(k)`×k relation cores (k×k for `rescal` and
    /// `logistic`, 1×k diagonals for `distmult`). Dense-core models
    /// precompute the serving projections; diagonal-core models skip
    /// them.
    pub fn new_with_model(
        a: Mat,
        r: Tensor3,
        model: ModelKind,
        provenance: Provenance,
    ) -> Result<FactorModel> {
        let (n, k) = a.shape();
        if n == 0 || k == 0 {
            bail!("factor model needs a non-empty A, got {n}×{k}");
        }
        let core_rows = model.core_rows(k);
        if r.n1() != core_rows || r.n2() != k {
            bail!(
                "{} relation cores must be {core_rows}×{k} to match A's {k} columns, \
                 got {}×{}×{}",
                model.as_str(),
                r.n1(),
                r.n2(),
                r.m()
            );
        }
        let (proj_obj, proj_subj) = if model == ModelKind::DistMult {
            (Vec::new(), Vec::new())
        } else {
            (
                r.slices().iter().map(|rt| a.matmul(rt)).collect(),
                r.slices().iter().map(|rt| a.matmul_t(rt)).collect(),
            )
        };
        Ok(FactorModel {
            a,
            r,
            entity_names: None,
            relation_names: None,
            provenance,
            model,
            dtype: DType::F32,
            proj_obj,
            proj_subj,
        })
    }

    /// Quantize the factors to a half-precision storage dtype: every
    /// element of `A` and `R` is rounded to its nearest representable
    /// `f16`/`bf16` value (round-to-nearest-even) and widened back to
    /// f32, so the in-memory model — and everything serialized from it
    /// — carries only values exactly representable at that precision.
    /// The serving projections are recomputed from the quantized
    /// factors; quantizing to `f32` is a no-op. This is the
    /// `drescal export --dtype f16|bf16` path.
    pub fn quantize(self, dtype: DType) -> Result<FactorModel> {
        if !dtype.is_half() {
            return Ok(self);
        }
        let mut a = self.a;
        let mut r = self.r;
        for v in a.as_mut_slice() {
            *v = dtype.quantize(*v);
        }
        for t in 0..r.m() {
            for v in r.slice_mut(t).as_mut_slice() {
                *v = dtype.quantize(*v);
            }
        }
        let mut model = FactorModel::new_with_model(a, r, self.model, self.provenance)?;
        model.dtype = dtype;
        model.entity_names = self.entity_names;
        model.relation_names = self.relation_names;
        Ok(model)
    }

    /// Storage precision of the factors (`f32` unless the artifact was
    /// exported with `--dtype f16|bf16`).
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Export a model from a training report. `Factorize` and
    /// `ModelSelect` reports carry factors; a `Simulate` report does not
    /// and is a typed error.
    pub fn from_report(report: &Report) -> Result<FactorModel> {
        match report {
            Report::Factorize(r) => FactorModel::new_with_model(
                r.a.clone(),
                r.r.clone(),
                r.model,
                Provenance {
                    job: "factorize".to_string(),
                    p: 0,
                    backend: String::new(),
                    rel_error: r.rel_error as f64,
                    wall_seconds: r.wall_seconds,
                },
            ),
            Report::ModelSelect(r) => {
                let rel_error = r
                    .scores
                    .iter()
                    .find(|s| s.k == r.k_opt)
                    .map(|s| s.rel_error as f64)
                    .unwrap_or(-1.0);
                FactorModel::new_with_model(
                    r.a.clone(),
                    r.r.clone(),
                    r.model,
                    Provenance {
                        job: "model_select".to_string(),
                        p: 0,
                        backend: String::new(),
                        rel_error,
                        wall_seconds: r.wall_seconds,
                    },
                )
            }
            Report::Simulate(_) => {
                Err(err!("cannot export a factor model from a simulate report (no factors)"))
            }
        }
    }

    /// Attach entity names (must be one per entity).
    pub fn with_entity_names(mut self, names: Vec<String>) -> Result<FactorModel> {
        if names.len() != self.n() {
            bail!("{} entity names for {} entities", names.len(), self.n());
        }
        self.entity_names = Some(names);
        Ok(self)
    }

    /// Attach relation names (must be one per relation).
    pub fn with_relation_names(mut self, names: Vec<String>) -> Result<FactorModel> {
        if names.len() != self.m() {
            bail!("{} relation names for {} relations", names.len(), self.m());
        }
        self.relation_names = Some(names);
        Ok(self)
    }

    /// Number of entities n.
    pub fn n(&self) -> usize {
        self.a.rows()
    }

    /// Number of relations m.
    pub fn m(&self) -> usize {
        self.r.m()
    }

    /// Latent dimension k.
    pub fn k(&self) -> usize {
        self.a.cols()
    }

    /// Entity factors A (n×k).
    pub fn a(&self) -> &Mat {
        &self.a
    }

    /// Relation cores R (k×k×m).
    pub fn r(&self) -> &Tensor3 {
        &self.r
    }

    pub fn entity_names(&self) -> Option<&[String]> {
        self.entity_names.as_deref()
    }

    pub fn relation_names(&self) -> Option<&[String]> {
        self.relation_names.as_deref()
    }

    /// Index of the entity with this exact interned name.
    pub fn entity_id(&self, name: &str) -> Option<usize> {
        self.entity_names.as_ref()?.iter().position(|n| n == name)
    }

    /// Index of the relation with this exact interned name.
    pub fn relation_id(&self, name: &str) -> Option<usize> {
        self.relation_names.as_ref()?.iter().position(|n| n == name)
    }

    /// Resolve a CLI token to an entity index. An exact interned-name
    /// match wins first — knowledge graphs routinely intern numeric
    /// names like "1984", which would otherwise be shadowed by index
    /// parsing and silently resolve to the wrong entity — then a decimal
    /// integer is taken as an index (bounds-checked). Typed errors
    /// either way.
    pub fn resolve_entity(&self, token: &str) -> Result<usize> {
        if let Some(i) = self.entity_id(token) {
            return Ok(i);
        }
        if let Ok(i) = token.parse::<usize>() {
            if i < self.n() {
                return Ok(i);
            }
            bail!("entity index {i} out of range (model has {} entities)", self.n());
        }
        match &self.entity_names {
            Some(_) => Err(err!("unknown entity name '{token}'")),
            None => Err(err!(
                "entity '{token}' is not an index and this model carries no entity \
                 names (export from an ingested corpus to query by name)"
            )),
        }
    }

    /// Resolve a CLI token to a relation index — the relation analogue
    /// of [`FactorModel::resolve_entity`] (exact name first, then
    /// integer index).
    pub fn resolve_relation(&self, token: &str) -> Result<usize> {
        if let Some(r) = self.relation_id(token) {
            return Ok(r);
        }
        if let Ok(r) = token.parse::<usize>() {
            if r < self.m() {
                return Ok(r);
            }
            bail!("relation index {r} out of range (model has {} relations)", self.m());
        }
        match &self.relation_names {
            Some(_) => Err(err!("unknown relation name '{token}'")),
            None => Err(err!(
                "relation '{token}' is not an index and this model carries no relation \
                 names (export from an ingested corpus to query by name)"
            )),
        }
    }

    pub fn provenance(&self) -> &Provenance {
        &self.provenance
    }

    pub fn provenance_mut(&mut self) -> &mut Provenance {
        &mut self.provenance
    }

    /// Model family the factors were trained under.
    pub fn model(&self) -> ModelKind {
        self.model
    }

    /// Whether the relation cores are stored as 1×k diagonals (the
    /// `distmult` family), which serving scores without densifying.
    pub fn is_diagonal(&self) -> bool {
        self.model == ModelKind::DistMult
    }

    /// Typed check that this artifact was trained under the expected
    /// family — the error a warm-start or `drescal query --family`
    /// mismatch surfaces as, instead of silently scoring with the wrong
    /// rule.
    pub fn ensure_model(&self, expect: ModelKind) -> Result<()> {
        if self.model != expect {
            bail!(
                "model family mismatch: this artifact was trained as '{}' but '{}' was \
                 requested",
                self.model.as_str(),
                expect.as_str()
            );
        }
        Ok(())
    }

    /// Bytes of projection precompute this model avoids by storing
    /// diagonal cores: `2·m·n·k·4` for a diagonal model (both direction
    /// caches), 0 for dense-core families.
    pub fn projection_bytes_saved(&self) -> usize {
        if self.is_diagonal() {
            2 * self.m() * self.n() * self.k() * std::mem::size_of::<f32>()
        } else {
            0
        }
    }

    /// The cached projection that answers completion queries in the
    /// given direction for relation `rel`: `A·R_rel` for `(s, rel, ?)`,
    /// `A·R_relᵀ` for `(?, rel, o)`. Row `anchor` of the returned matrix
    /// dotted with `A`'s rows yields the candidate scores. Dense-core
    /// families only — diagonal models never materialize projections
    /// (use [`FactorModel::fill_query_row`], which covers every family).
    pub fn projection(&self, dir: Direction, rel: usize) -> &Mat {
        assert!(
            !self.is_diagonal(),
            "diagonal-core models have no cached projections; use fill_query_row"
        );
        match dir {
            Direction::Objects => &self.proj_obj[rel],
            Direction::Subjects => &self.proj_subj[rel],
        }
    }

    /// Write the (virtual) projection row for `anchor` into `out`
    /// (length k): the vector whose dot with each row of `A` scores that
    /// candidate. Dense-core families copy the cached row; diagonal
    /// models compute `a_anchor ∘ d_rel` on the fly — k multiplies, no
    /// `m·n·k` precompute, and direction-independent because a diagonal
    /// core is symmetric.
    pub fn fill_query_row(&self, dir: Direction, rel: usize, anchor: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.k());
        if self.is_diagonal() {
            let d = self.r.slice(rel).row(0);
            let a = self.a.row(anchor);
            for (o, (&av, &dv)) in out.iter_mut().zip(a.iter().zip(d)) {
                *o = av * dv;
            }
        } else {
            out.copy_from_slice(self.projection(dir, rel).row(anchor));
        }
    }

    /// Serialize the artifact (factors + metadata, not the projections).
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("kind".to_string(), Json::Str("factor_model".to_string()));
        obj.insert("k".to_string(), Json::Num(self.k() as f64));
        obj.insert("model".to_string(), Json::Str(self.model.as_str().to_string()));
        // only half artifacts carry a dtype key, so f32 exports are
        // byte-identical to pre-precision-plane ones
        if self.dtype.is_half() {
            obj.insert("dtype".to_string(), Json::Str(self.dtype.as_str().to_string()));
        }
        obj.insert("a".to_string(), mat_to_json(&self.a));
        obj.insert("r".to_string(), tensor_to_json(&self.r));
        let mut prov = BTreeMap::new();
        prov.insert("job".to_string(), Json::Str(self.provenance.job.clone()));
        prov.insert("p".to_string(), Json::Num(self.provenance.p as f64));
        prov.insert("backend".to_string(), Json::Str(self.provenance.backend.clone()));
        prov.insert("rel_error".to_string(), Json::Num(self.provenance.rel_error));
        prov.insert("wall_seconds".to_string(), Json::Num(self.provenance.wall_seconds));
        obj.insert("provenance".to_string(), Json::Obj(prov));
        if let Some(names) = &self.entity_names {
            obj.insert(
                "entity_names".to_string(),
                Json::Arr(names.iter().map(|s| Json::Str(s.clone())).collect()),
            );
        }
        if let Some(names) = &self.relation_names {
            obj.insert(
                "relation_names".to_string(),
                Json::Arr(names.iter().map(|s| Json::Str(s.clone())).collect()),
            );
        }
        Json::Obj(obj)
    }

    /// Rebuild a model from its JSON artifact (recomputing projections).
    pub fn from_json(v: &Json) -> Result<FactorModel> {
        match v.get("kind").and_then(|k| k.as_str()) {
            Some("factor_model") => {}
            Some(other) => bail!("expected a factor_model artifact, got kind '{other}'"),
            None => bail!("model artifact missing 'kind'"),
        }
        let a = mat_from_json(v.get("a").ok_or_else(|| err!("model missing 'a'"))?)?;
        let r = tensor_from_json(v.get("r").ok_or_else(|| err!("model missing 'r'"))?)?;
        let k = v
            .get("k")
            .and_then(|x| x.as_f64())
            .ok_or_else(|| err!("model missing 'k'"))? as usize;
        if a.cols() != k {
            bail!("model declares k={k} but A has {} columns", a.cols());
        }
        let provenance = match v.get("provenance") {
            Some(p) => Provenance {
                job: p
                    .get("job")
                    .and_then(|j| j.as_str())
                    .unwrap_or("external")
                    .to_string(),
                p: p.get("p").and_then(|x| x.as_f64()).unwrap_or(0.0) as usize,
                backend: p
                    .get("backend")
                    .and_then(|b| b.as_str())
                    .unwrap_or("")
                    .to_string(),
                rel_error: p.get("rel_error").and_then(|x| x.as_f64()).unwrap_or(-1.0),
                wall_seconds: p.get("wall_seconds").and_then(|x| x.as_f64()).unwrap_or(0.0),
            },
            None => Provenance::external(),
        };
        // artifacts exported before the model-family plane carry no
        // `model` field and are all Gaussian RESCAL (model_from_json
        // defaults accordingly)
        let kind = model_from_json(v)?;
        let dtype = match v.get("dtype") {
            None => DType::F32,
            Some(d) => d
                .as_str()
                .and_then(DType::parse)
                .ok_or_else(|| err!("model 'dtype' must be one of f32/f16/bf16, got {d}"))?,
        };
        let mut model = FactorModel::new_with_model(a, r, kind, provenance)?;
        model.dtype = dtype;
        if let Some(names) = v.get("entity_names") {
            model = model.with_entity_names(string_array(names, "entity_names")?)?;
        }
        if let Some(names) = v.get("relation_names") {
            model = model.with_relation_names(string_array(names, "relation_names")?)?;
        }
        Ok(model)
    }

    /// Write the JSON artifact to a file (the `drescal export` output).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing factor model to {}", path.display()))?;
        Ok(())
    }

    /// Load a JSON artifact from a file (the `drescal query` input).
    pub fn load(path: impl AsRef<Path>) -> Result<FactorModel> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading factor model from {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| err!("model JSON: {e}"))?;
        FactorModel::from_json(&v).with_context(|| format!("loading {}", path.display()))
    }
}

fn string_array(v: &Json, what: &str) -> Result<Vec<String>> {
    v.as_arr()
        .ok_or_else(|| err!("'{what}' must be an array"))?
        .iter()
        .map(|s| {
            s.as_str()
                .map(|s| s.to_string())
                .ok_or_else(|| err!("'{what}' entries must be strings"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tiny_model() -> FactorModel {
        let mut rng = Rng::new(3);
        let a = Mat::random_uniform(6, 2, 0.0, 1.0, &mut rng);
        let r = Tensor3::random_uniform(2, 2, 3, 0.0, 1.0, &mut rng);
        FactorModel::new(a, r, Provenance::external()).unwrap()
    }

    #[test]
    fn shape_validation() {
        let a = Mat::zeros(4, 3);
        let r = Tensor3::zeros(2, 2, 1);
        let e = FactorModel::new(a, r, Provenance::external()).unwrap_err();
        assert!(e.to_string().contains("3 columns"), "{e}");
        let e = FactorModel::new(Mat::zeros(0, 0), Tensor3::zeros(1, 1, 1), Provenance::external())
            .unwrap_err();
        assert!(e.to_string().contains("non-empty"), "{e}");
    }

    #[test]
    fn projections_match_definition() {
        let m = tiny_model();
        for t in 0..m.m() {
            let want_obj = m.a().matmul(m.r().slice(t));
            let want_subj = m.a().matmul(&m.r().slice(t).transpose());
            assert_eq!(m.projection(Direction::Objects, t), &want_obj);
            assert_eq!(m.projection(Direction::Subjects, t), &want_subj);
        }
    }

    #[test]
    fn json_roundtrip_preserves_factors_and_metadata() {
        let m = tiny_model()
            .with_entity_names((0..6).map(|i| format!("e{i}")).collect())
            .unwrap()
            .with_relation_names(vec!["likes".into(), "knows".into(), "owns".into()])
            .unwrap();
        let json = m.to_json();
        let reparsed = Json::parse(&json.to_string()).unwrap();
        let back = FactorModel::from_json(&reparsed).unwrap();
        assert_eq!(back.a(), m.a());
        assert_eq!(back.r(), m.r());
        assert_eq!(back.provenance(), m.provenance());
        assert_eq!(back.entity_names(), m.entity_names());
        assert_eq!(back.relation_names(), m.relation_names());
    }

    #[test]
    fn name_length_validation() {
        assert!(tiny_model().with_entity_names(vec!["a".into()]).is_err());
        assert!(tiny_model().with_relation_names(vec!["a".into()]).is_err());
    }

    #[test]
    fn name_resolution_accepts_ids_and_names() {
        let named = tiny_model()
            .with_entity_names((0..6).map(|i| format!("node{i}")).collect())
            .unwrap()
            .with_relation_names(vec!["likes".into(), "knows".into(), "owns".into()])
            .unwrap();
        assert_eq!(named.entity_id("node4"), Some(4));
        assert_eq!(named.relation_id("owns"), Some(2));
        assert_eq!(named.resolve_entity("node2").unwrap(), 2);
        assert_eq!(named.resolve_entity("5").unwrap(), 5, "integers stay indices");
        assert_eq!(named.resolve_relation("knows").unwrap(), 1);
        // a numeric *name* beats index parsing — entity "3" at index 0
        // must not silently resolve to index 3
        let numeric = tiny_model()
            .with_entity_names(vec![
                "3".into(),
                "1984".into(),
                "a".into(),
                "b".into(),
                "c".into(),
                "d".into(),
            ])
            .unwrap();
        assert_eq!(numeric.resolve_entity("3").unwrap(), 0, "exact name wins");
        assert_eq!(numeric.resolve_entity("1984").unwrap(), 1);
        assert_eq!(numeric.resolve_entity("4").unwrap(), 4, "non-name integer = index");
        let e = named.resolve_entity("nobody").unwrap_err();
        assert!(e.to_string().contains("unknown entity name"), "{e}");
        let e = named.resolve_entity("99").unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
        let e = named.resolve_relation("99").unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
        // a nameless model still resolves indices, with a pointed error
        // for names
        let bare = tiny_model();
        assert_eq!(bare.resolve_entity("3").unwrap(), 3);
        let e = bare.resolve_entity("alice").unwrap_err();
        assert!(e.to_string().contains("no entity names"), "{e}");
        assert!(bare.resolve_relation("knows").is_err());
    }

    fn tiny_diagonal_model() -> FactorModel {
        let mut rng = Rng::new(5);
        let a = Mat::random_uniform(6, 2, 0.0, 1.0, &mut rng);
        let r = Tensor3::random_uniform(1, 2, 3, 0.0, 1.0, &mut rng);
        FactorModel::new_with_model(a, r, ModelKind::DistMult, Provenance::external())
            .unwrap()
    }

    #[test]
    fn diagonal_model_skips_projection_precompute() {
        let m = tiny_diagonal_model();
        assert!(m.is_diagonal());
        assert_eq!(m.model(), ModelKind::DistMult);
        // 2 directions × m=3 × n=6 × k=2 × 4 bytes
        assert_eq!(m.projection_bytes_saved(), 2 * 3 * 6 * 2 * 4);
        assert_eq!(tiny_model().projection_bytes_saved(), 0);
        // the virtual projection row is a ∘ d, same in both directions
        let mut row = vec![0.0f32; 2];
        for t in 0..3 {
            for anchor in 0..6 {
                for dir in [Direction::Objects, Direction::Subjects] {
                    m.fill_query_row(dir, t, anchor, &mut row);
                    for j in 0..2 {
                        let want = m.a()[(anchor, j)] * m.r().slice(t)[(0, j)];
                        assert_eq!(row[j], want, "t={t} anchor={anchor} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn fill_query_row_matches_dense_projection() {
        let m = tiny_model();
        let mut row = vec![0.0f32; 2];
        for dir in [Direction::Objects, Direction::Subjects] {
            for t in 0..3 {
                for anchor in 0..6 {
                    m.fill_query_row(dir, t, anchor, &mut row);
                    assert_eq!(&row[..], m.projection(dir, t).row(anchor));
                }
            }
        }
    }

    #[test]
    fn core_shape_validation_is_per_family() {
        let a = Mat::full(4, 3, 0.5);
        // distmult wants 1×k, not k×k
        let e = FactorModel::new_with_model(
            a.clone(),
            Tensor3::zeros(3, 3, 1),
            ModelKind::DistMult,
            Provenance::external(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("1×3"), "{e}");
        // and the dense families reject 1×k diagonals
        let e = FactorModel::new_with_model(
            a,
            Tensor3::zeros(1, 3, 1),
            ModelKind::Logistic,
            Provenance::external(),
        )
        .unwrap_err();
        assert!(e.to_string().contains("3×3"), "{e}");
    }

    #[test]
    fn model_family_roundtrips_and_legacy_artifacts_default_to_rescal() {
        let m = tiny_diagonal_model();
        let back = FactorModel::from_json(&Json::parse(&m.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.model(), ModelKind::DistMult);
        assert_eq!(back.r().n1(), 1);
        // strip the model field the way a pre-model-family export looks
        let dense = tiny_model();
        let mut obj = match dense.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!("model artifacts serialize as objects"),
        };
        obj.remove("model");
        let legacy = FactorModel::from_json(&Json::Obj(obj)).unwrap();
        assert_eq!(legacy.model(), ModelKind::Rescal);
        // a present-but-unknown family is a typed error
        let mut bad = match dense.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        bad.insert("model".to_string(), Json::Str("tucker".to_string()));
        let e = FactorModel::from_json(&Json::Obj(bad)).unwrap_err();
        assert!(e.to_string().contains("unknown model family"), "{e}");
    }

    #[test]
    fn ensure_model_mismatch_is_typed() {
        let m = tiny_diagonal_model();
        assert!(m.ensure_model(ModelKind::DistMult).is_ok());
        let e = m.ensure_model(ModelKind::Rescal).unwrap_err();
        assert!(e.to_string().contains("model family mismatch"), "{e}");
        assert!(e.to_string().contains("distmult"), "{e}");
    }

    #[test]
    fn quantized_artifacts_carry_their_dtype_and_stay_servable() {
        let m = tiny_model()
            .with_entity_names((0..6).map(|i| format!("e{i}")).collect())
            .unwrap();
        // f32 is a no-op and serializes without a dtype key
        let f32_json = m.clone().quantize(DType::F32).unwrap().to_json().to_string();
        assert!(!f32_json.contains("dtype"));
        for dtype in [DType::F16, DType::Bf16] {
            let q = m.clone().quantize(dtype).unwrap();
            assert_eq!(q.dtype(), dtype);
            assert_eq!(q.entity_names(), m.entity_names(), "names survive quantization");
            // every factor value is the RNE-quantized original ...
            for (got, want) in q.a().as_slice().iter().zip(m.a().as_slice()) {
                assert_eq!(*got, dtype.quantize(*want));
            }
            for t in 0..m.m() {
                for (got, want) in
                    q.r().slice(t).as_slice().iter().zip(m.r().slice(t).as_slice())
                {
                    assert_eq!(*got, dtype.quantize(*want));
                }
            }
            // ... projections are rebuilt from the quantized factors ...
            let want_obj = q.a().matmul(q.r().slice(0));
            assert_eq!(q.projection(Direction::Objects, 0), &want_obj);
            // ... and the dtype round-trips through the JSON artifact
            let back =
                FactorModel::from_json(&Json::parse(&q.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(back.dtype(), dtype);
            assert_eq!(back.a(), q.a());
        }
        // a present-but-unknown dtype is a typed error
        let mut obj = match m.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        obj.insert("dtype".to_string(), Json::Str("f64".to_string()));
        let e = FactorModel::from_json(&Json::Obj(obj)).unwrap_err();
        assert!(e.to_string().contains("dtype"), "{e}");
    }

    #[test]
    fn rejects_foreign_artifacts() {
        let e = FactorModel::from_json(&Json::parse(r#"{"kind":"report"}"#).unwrap())
            .unwrap_err();
        assert!(e.to_string().contains("factor_model"), "{e}");
        assert!(FactorModel::from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
