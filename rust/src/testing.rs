//! Test support: tolerance assertions and a seeded property-test harness.
//!
//! The offline crate set has no `proptest`, so property-style tests use
//! [`property`] — a fixed number of seeded random cases with the failing
//! seed printed for reproduction. Coverage style is the same (randomized
//! inputs, invariant assertions); there is no shrinking, but every failure
//! is replayable from the printed seed.

use crate::rng::Rng;

/// Assert two slices are elementwise close with a mixed abs/rel tolerance.
pub fn assert_close(got: &[f32], want: &[f32], tol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch: {} vs {}", got.len(), want.len());
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let scale = 1.0f32.max(w.abs());
        assert!(
            (g - w).abs() <= tol * scale,
            "mismatch at {i}: got {g}, want {w} (tol {tol}, scale {scale})"
        );
    }
}

/// f64-accumulated reference GEMM for kernel parity tests. Operands are
/// `(row, col)` lookup closures, so a transposed operand is just a
/// swapped closure — one reference covers every transpose variant.
pub fn naive_gemm(
    m: usize,
    k: usize,
    n: usize,
    at: impl Fn(usize, usize) -> f32,
    bt: impl Fn(usize, usize) -> f32,
) -> crate::tensor::Mat {
    let mut c = crate::tensor::Mat::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += at(i, p) as f64 * bt(p, j) as f64;
            }
            c[(i, j)] = acc as f32;
        }
    }
    c
}

/// Assert two scalars are close.
pub fn assert_close_scalar(got: f32, want: f32, tol: f32) {
    let scale = 1.0f32.max(want.abs());
    assert!((got - want).abs() <= tol * scale, "got {got}, want {want} (tol {tol})");
}

/// Run `cases` seeded random test cases. On panic the failing seed is in
/// the message: rerun with `property_seeded(seed, 1, f)`.
pub fn property(cases: u64, mut f: impl FnMut(&mut Rng)) {
    property_seeded(0xD5EA5CA1, cases, &mut f)
}

/// Same with an explicit base seed.
pub fn property_seeded(base_seed: u64, cases: u64, f: &mut impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property case {case} FAILED with seed {seed:#x}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-9);
    }

    #[test]
    #[should_panic]
    fn assert_close_rejects_far() {
        assert_close(&[1.0], &[2.0], 1e-3);
    }

    #[test]
    fn property_runs_all_cases() {
        let mut count = 0;
        property(10, |_| count += 1);
        assert_eq!(count, 10);
    }

    #[test]
    fn property_seeds_are_deterministic() {
        let mut first = Vec::new();
        property(3, |rng| first.push(rng.next_u64()));
        let mut second = Vec::new();
        property(3, |rng| second.push(rng.next_u64()));
        assert_eq!(first, second);
    }
}
