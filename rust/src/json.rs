//! Minimal JSON parser/serializer (the offline crate set has no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest (`artifacts/manifest.json`) and run configuration.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`value.to_string()` comes with it for free —
/// an inherent `to_string` would shadow this blanket impl, which is
/// exactly the `clippy::inherent_to_string` lint).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"x"],"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ✓"));
    }
}
