//! drescal CLI — leader entrypoint for the distributed RESCAL(k) system.
//!
//! Flags are parsed and validated once by [`drescal::config::RunConfig`];
//! each subcommand then builds an [`Engine`] from its typed
//! [`EngineConfig`] and submits jobs, printing the unified report (add
//! `--json` for the machine-readable form).
//!
//! Subcommands:
//! * `run`          — one distributed factorization on synthetic/real data
//! * `model-select` — full RESCALk sweep with automatic k determination
//! * `exascale`     — replay the paper's Fig 13 runs through the model
//! * `artifacts`    — inspect the AOT artifact manifest
//!
//! Examples:
//! ```text
//! drescal run --data synthetic --n 64 --m 3 --k 4 --p 4 --iters 200
//! drescal model-select --data nations --p 4 --k-min 1 --k-max 7
//! drescal run --config run.json --backend xla --trace
//! ```

use drescal::bench_util;
use drescal::config::{
    ArtifactsCmd, Command, ExascaleCmd, FactorizeCmd, MachineSpec, ModelSelectCmd, RunConfig,
};
use drescal::coordinator::metrics::RunMetrics;
use drescal::engine::{Engine, EngineConfig, Report, SimScenario, SimSpec};
use drescal::error::Result;
use drescal::simulate::Machine;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return;
    }
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    match RunConfig::from_args(argv)?.command {
        Command::Run(cmd) => cmd_run(cmd),
        Command::ModelSelect(cmd) => cmd_model_select(cmd),
        Command::Exascale(cmd) => cmd_exascale(cmd),
        Command::Artifacts(cmd) => cmd_artifacts(cmd),
        Command::Help => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "drescal — distributed non-negative RESCAL with automatic model selection

USAGE: drescal <subcommand> [--flag value ...]

SUBCOMMANDS
  run           one distributed factorization
                  --data synthetic|blocks|nations|trade  (default synthetic)
                  --n --m --k-true   synthetic tensor shape/truth
                  --density D        sparse synthetic tensor (CSR path)
                  --p P              virtual ranks, perfect square (4)
                  --k K              rank of the factorization (4)
                  --iters N          MU iterations (200)
                  --backend native|xla  [--artifacts DIR]
                  --seed S  --trace  --json
  model-select  RESCALk sweep with automatic k determination
                  (run flags plus) --k-min --k-max --perturbations --delta
                  --tol --err-every --regress-iters
  exascale      replay Fig 13 (11.5TB dense + 9.5EB sparse) via the model
                  --machine cpu|gpu|calibrated
  artifacts     list the AOT artifact manifest [--artifacts DIR]
  help          this text

Flags may also come from --config FILE (JSON object; CLI wins).
Tracing is opt-in (--trace): per-op timing costs on every hot-path op."
    );
}

fn cmd_run(cmd: FactorizeCmd) -> Result<()> {
    let data = cmd.data.load(cmd.seed);
    let mut engine = Engine::new(cmd.engine)?;
    println!(
        "distributed RESCAL: n={} m={} k={} p={} backend={:?}",
        data.n(),
        data.m(),
        cmd.opts.k,
        engine.config().p,
        engine.config().backend
    );
    let report = engine.factorize(&data, &cmd.opts, cmd.seed)?;
    println!(
        "done in {}: rel_error={:.4} ({} iterations)",
        bench_util::fmt_secs(report.wall_seconds),
        report.rel_error,
        report.iters_run
    );
    if let Some(kt) = cmd.data.k_true() {
        println!("(ground-truth latent dimension of this dataset: {kt})");
    }
    if engine.config().trace {
        let metrics = RunMetrics::from_traces(&report.traces);
        print!("{}", metrics.format_breakdown());
    }
    if cmd.json {
        println!("{}", Report::Factorize(report).to_json().to_string());
    }
    Ok(())
}

fn cmd_model_select(cmd: ModelSelectCmd) -> Result<()> {
    let data = cmd.data.load(cmd.sweep.seed);
    let mut engine = Engine::new(cmd.engine)?;
    println!(
        "RESCALk sweep: n={} m={} k∈[{},{}] r={} p={} backend={:?}",
        data.n(),
        data.m(),
        cmd.sweep.k_min,
        cmd.sweep.k_max,
        cmd.sweep.perturbations,
        engine.config().p,
        engine.config().backend
    );
    let report = engine.model_select(&data, &cmd.sweep)?;
    let rows: Vec<Vec<String>> = report
        .scores
        .iter()
        .map(|s| {
            vec![
                s.k.to_string(),
                format!("{:.3}", s.sil_min),
                format!("{:.3}", s.sil_avg),
                format!("{:.4}", s.rel_error),
            ]
        })
        .collect();
    bench_util::print_table(
        "model selection",
        &["k", "min silhouette", "avg silhouette", "rel error"],
        &rows,
    );
    println!(
        "\nk_opt = {}  (wall {})",
        report.k_opt,
        bench_util::fmt_secs(report.wall_seconds)
    );
    match cmd.data.k_true() {
        Some(kt) if kt == report.k_opt => println!("matches the dataset's ground truth ✓"),
        Some(kt) => println!("(ground truth is {kt})"),
        None => {}
    }
    if engine.config().trace {
        let metrics = RunMetrics::from_traces(&report.traces);
        print!("{}", metrics.format_breakdown());
    }
    if cmd.json {
        println!("{}", Report::ModelSelect(report).to_json().to_string());
    }
    Ok(())
}

fn cmd_exascale(cmd: ExascaleCmd) -> Result<()> {
    let machine = match cmd.machine {
        MachineSpec::Cpu => Machine::cpu_cluster(),
        MachineSpec::Gpu => Machine::gpu_cluster(),
        MachineSpec::Calibrated => {
            let flops = bench_util::calibrate_dense_flops();
            println!("calibrated dense rate: {:.1} GFLOP/s", flops / 1e9);
            Machine::calibrated(flops, 2e-6, 1e-10)
        }
    };
    // modeled replays run on the leader; a 1-rank engine keeps the job
    // API uniform without spawning an idle grid
    let mut engine = Engine::new(EngineConfig::new(1))?;
    let dense_report =
        engine.simulate(SimSpec { machine, scenario: SimScenario::Dense11Tb })?;
    let dense = &dense_report.rows[0];
    println!(
        "\nFig 13a replay — {}\n  logical size {:.1} TB on {} ranks\n  modeled: compute {} + comm {} = {} ({:.0}% comm)",
        dense.label,
        dense.logical_bytes() / 1e12,
        dense.p,
        bench_util::fmt_secs(dense.compute_seconds),
        bench_util::fmt_secs(dense.comm_seconds),
        bench_util::fmt_secs(dense.total()),
        100.0 * dense.comm_fraction()
    );
    let sparse_report =
        engine.simulate(SimSpec { machine, scenario: SimScenario::SparseExabyte })?;
    let rows: Vec<Vec<String>> = sparse_report
        .rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0e}", r.density),
                bench_util::fmt_secs(r.compute_seconds),
                bench_util::fmt_secs(r.comm_seconds),
                bench_util::fmt_secs(r.total()),
                format!("{:.1}%", 100.0 * r.comm_fraction()),
            ]
        })
        .collect();
    bench_util::print_table(
        "Fig 13b replay — 9.5EB sparse, 22801 ranks, 100 iters",
        &["density", "compute", "comm", "total", "comm%"],
        &rows,
    );
    Ok(())
}

fn cmd_artifacts(cmd: ArtifactsCmd) -> Result<()> {
    let manifest = drescal::runtime::Manifest::load(std::path::Path::new(&cmd.dir))?;
    let rows: Vec<Vec<String>> = manifest
        .entries
        .iter()
        .map(|e| {
            vec![
                e.kind.clone(),
                e.shapes
                    .iter()
                    .map(|(r, c)| format!("{r}×{c}"))
                    .collect::<Vec<_>>()
                    .join(", "),
                e.file.clone(),
            ]
        })
        .collect();
    bench_util::print_table(
        &format!("{} artifacts in {}", manifest.entries.len(), cmd.dir),
        &["kind", "input shapes", "file"],
        &rows,
    );
    Ok(())
}
