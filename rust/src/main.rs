//! drescal CLI — leader entrypoint for the distributed RESCAL(k) system.
//!
//! Subcommands:
//! * `run`          — one distributed factorization on synthetic/real data
//! * `model-select` — full RESCALk sweep with automatic k determination
//! * `exascale`     — replay the paper's Fig 13 runs through the model
//! * `artifacts`    — inspect the AOT artifact manifest
//!
//! Examples:
//! ```text
//! drescal run --data synthetic --n 64 --m 3 --k 4 --p 4 --iters 200
//! drescal model-select --data nations --p 4 --k-min 1 --k-max 7
//! drescal run --config run.json --backend xla
//! ```

use anyhow::{bail, Result};

use drescal::bench_util;
use drescal::config::Args;
use drescal::coordinator::metrics::RunMetrics;
use drescal::coordinator::{run_rescal, run_rescalk, JobConfig, JobData};
use drescal::data::{nations, synthetic, trade};
use drescal::model_selection::{InitStrategy, RescalkConfig, SelectionRule};
use drescal::rescal::RescalOptions;
use drescal::simulate::{exascale, Machine};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return;
    }
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let mut args = Args::parse(argv)?;
    if let Some(path) = args.get("config").map(|s| s.to_string()) {
        args.merge_config_file(&path)?;
    }
    match args.subcommand.as_str() {
        "run" => cmd_run(&args),
        "model-select" => cmd_model_select(&args),
        "exascale" => cmd_exascale(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' — try `drescal help`"),
    }
}

fn print_help() {
    println!(
        "drescal — distributed non-negative RESCAL with automatic model selection

USAGE: drescal <subcommand> [--flag value ...]

SUBCOMMANDS
  run           one distributed factorization
                  --data synthetic|blocks|nations|trade  (default synthetic)
                  --n --m --k-true   synthetic tensor shape/truth
                  --density D        sparse synthetic tensor (CSR path)
                  --p P              virtual ranks, perfect square (4)
                  --k K              rank of the factorization (4)
                  --iters N          MU iterations (200)
                  --backend native|xla  [--artifacts DIR]
                  --seed S
  model-select  RESCALk sweep with automatic k determination
                  (run flags plus) --k-min --k-max --perturbations --delta
  exascale      replay Fig 13 (11.5TB dense + 9.5EB sparse) via the model
                  --machine cpu|gpu|calibrated
  artifacts     list the AOT artifact manifest [--artifacts DIR]
  help          this text

Flags may also come from --config FILE (JSON object; CLI wins)."
    );
}

fn load_data(args: &Args) -> Result<(JobData, Option<usize>)> {
    let kind = args.get("data").unwrap_or("synthetic");
    let seed = args.get_u64("seed", 42)?;
    Ok(match kind {
        "synthetic" => {
            let n = args.get_usize("n", 64)?;
            let m = args.get_usize("m", 4)?;
            let k_true = args.get_usize("k-true", 4)?;
            let density = args.get_f64("density", 1.0)?;
            if density < 1.0 {
                let x = synthetic::sparse_planted(n, m, k_true, density, seed);
                (JobData::sparse(x), Some(k_true))
            } else {
                let p = synthetic::planted_tensor(n, m, k_true, 0.0, seed);
                (JobData::dense(p.x), Some(k_true))
            }
        }
        "blocks" => {
            let n = args.get_usize("n", 64)?;
            let m = args.get_usize("m", 4)?;
            let k_true = args.get_usize("k-true", 4)?;
            let p = synthetic::block_tensor(n, m, k_true, 0.01, seed);
            (JobData::dense(p.x), Some(k_true))
        }
        "nations" => (JobData::dense(nations::nations_tensor(seed)), Some(4)),
        "trade" => {
            // padded to 24 so 2×2 and 3×3 grids divide the axis (paper §6.2.2)
            (JobData::dense(trade::trade_tensor_padded(seed, 24)), Some(5))
        }
        other => bail!("unknown --data '{other}'"),
    })
}

fn job_config(args: &Args) -> Result<JobConfig> {
    Ok(JobConfig {
        p: args.get_usize("p", 4)?,
        backend: args.backend()?,
        trace: !args.get_bool("no-trace"),
    })
}

fn cmd_run(args: &Args) -> Result<()> {
    let (data, k_true) = load_data(args)?;
    let job = job_config(args)?;
    let opts = RescalOptions::new(args.get_usize("k", 4)?, args.get_usize("iters", 200)?);
    println!(
        "distributed RESCAL: n={} m={} k={} p={} backend={:?}",
        data.n(),
        data.m(),
        opts.k,
        job.p,
        job.backend
    );
    let report = run_rescal(&data, &job, &opts, args.get_u64("seed", 42)?);
    println!(
        "done in {}: rel_error={:.4} ({} iterations)",
        bench_util::fmt_secs(report.wall_seconds),
        report.rel_error,
        report.iters_run
    );
    if let Some(kt) = k_true {
        println!("(ground-truth latent dimension of this dataset: {kt})");
    }
    if job.trace {
        let metrics = RunMetrics::from_traces(&report.traces);
        print!("{}", metrics.format_breakdown());
    }
    Ok(())
}

fn cmd_model_select(args: &Args) -> Result<()> {
    let (data, k_true) = load_data(args)?;
    let job = job_config(args)?;
    let cfg = RescalkConfig {
        k_min: args.get_usize("k-min", 2)?,
        k_max: args.get_usize("k-max", 8)?,
        perturbations: args.get_usize("perturbations", 10)?,
        delta: args.get_f64("delta", 0.02)? as f32,
        rescal_iters: args.get_usize("iters", 200)?,
        tol: args.get_f64("tol", 0.0)? as f32,
        err_every: args.get_usize("err-every", 25)?,
        regress_iters: args.get_usize("regress-iters", 30)?,
        seed: args.get_u64("seed", 42)?,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
    };
    println!(
        "RESCALk sweep: n={} m={} k∈[{},{}] r={} p={} backend={:?}",
        data.n(),
        data.m(),
        cfg.k_min,
        cfg.k_max,
        cfg.perturbations,
        job.p,
        job.backend
    );
    let report = run_rescalk(&data, &job, &cfg);
    let rows: Vec<Vec<String>> = report
        .scores
        .iter()
        .map(|s| {
            vec![
                s.k.to_string(),
                format!("{:.3}", s.sil_min),
                format!("{:.3}", s.sil_avg),
                format!("{:.4}", s.rel_error),
            ]
        })
        .collect();
    bench_util::print_table(
        "model selection",
        &["k", "min silhouette", "avg silhouette", "rel error"],
        &rows,
    );
    println!(
        "\nk_opt = {}  (wall {})",
        report.k_opt,
        bench_util::fmt_secs(report.wall_seconds)
    );
    match k_true {
        Some(kt) if kt == report.k_opt => println!("matches the dataset's ground truth ✓"),
        Some(kt) => println!("(ground truth is {kt})"),
        None => {}
    }
    Ok(())
}

fn cmd_exascale(args: &Args) -> Result<()> {
    let machine = match args.get("machine").unwrap_or("cpu") {
        "cpu" => Machine::cpu_cluster(),
        "gpu" => Machine::gpu_cluster(),
        "calibrated" => {
            let flops = bench_util::calibrate_dense_flops();
            println!("calibrated dense rate: {:.1} GFLOP/s", flops / 1e9);
            Machine::calibrated(flops, 2e-6, 1e-10)
        }
        other => bail!("unknown --machine '{other}'"),
    };
    let dense = exascale::dense_11tb_run(&machine);
    println!(
        "\nFig 13a replay — {}\n  logical size {:.1} TB on {} ranks\n  modeled: compute {} + comm {} = {} ({:.0}% comm)",
        dense.label,
        dense.logical_bytes() / 1e12,
        dense.p,
        bench_util::fmt_secs(dense.compute_seconds),
        bench_util::fmt_secs(dense.comm_seconds),
        bench_util::fmt_secs(dense.total()),
        100.0 * dense.comm_fraction()
    );
    let rows: Vec<Vec<String>> = exascale::sparse_exabyte_runs(&machine)
        .iter()
        .map(|r| {
            vec![
                format!("{:.0e}", r.density),
                bench_util::fmt_secs(r.compute_seconds),
                bench_util::fmt_secs(r.comm_seconds),
                bench_util::fmt_secs(r.total()),
                format!("{:.1}%", 100.0 * r.comm_fraction()),
            ]
        })
        .collect();
    bench_util::print_table(
        "Fig 13b replay — 9.5EB sparse, 22801 ranks, 100 iters",
        &["density", "compute", "comm", "total", "comm%"],
        &rows,
    );
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let manifest = drescal::runtime::Manifest::load(std::path::Path::new(dir))?;
    let rows: Vec<Vec<String>> = manifest
        .entries
        .iter()
        .map(|e| {
            vec![
                e.kind.clone(),
                e.shapes
                    .iter()
                    .map(|(r, c)| format!("{r}×{c}"))
                    .collect::<Vec<_>>()
                    .join(", "),
                e.file.clone(),
            ]
        })
        .collect();
    bench_util::print_table(
        &format!("{} artifacts in {dir}", manifest.entries.len()),
        &["kind", "input shapes", "file"],
        &rows,
    );
    Ok(())
}
