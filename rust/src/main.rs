//! drescal CLI — leader entrypoint for the distributed RESCAL(k) system.
//!
//! Flags are parsed and validated once by [`drescal::config::RunConfig`];
//! each subcommand then builds an [`Engine`] from its typed
//! [`EngineConfig`] and submits jobs, printing the unified report (add
//! `--json` for the machine-readable form).
//!
//! Subcommands:
//! * `run`          — one distributed factorization on synthetic/real data
//! * `train`        — the same factorization led over a TCP cluster of
//!   `worker` processes (bit-identical factors to `run`)
//! * `worker`       — join a `train` leader and serve rank jobs
//! * `model-select` — full RESCALk sweep with automatic k determination
//! * `export`       — train and persist a servable factor-model artifact
//! * `query`        — answer link-prediction queries from a saved model
//! * `serve-bench`  — serving-throughput harness (batched vs unbatched)
//! * `exascale`     — replay the paper's Fig 13 runs through the model
//! * `artifacts`    — inspect the AOT artifact manifest
//! * `bench`        — fixed-shape perf harness, emits `BENCH_rescal.json`
//!   and diffs it against the previous run (`--max-regression` gates CI)
//! * `trace-summary` — per-op runtime table from a `--trace-out` file
//! * `monitor`      — live view of a running leader's `--status-port`
//!   endpoint: one row per MU iteration plus watchdog warnings
//!
//! Synthetic datasets are registered as [`drescal::engine::DatasetSpec`]
//! and generated **rank-locally** — the leader never materializes the
//! global tensor, so `--n` is not bounded by leader RAM.
//!
//! Examples:
//! ```text
//! drescal run --data synthetic --n 64 --m 3 --k 4 --p 4 --iters 200
//! drescal model-select --data nations --p 4 --k-min 1 --k-max 7
//! drescal run --config run.json --backend xla --trace
//! ```

use std::collections::BTreeMap;

use drescal::bench_util;
use drescal::config::{
    ArtifactsCmd, BenchCmd, Command, ExascaleCmd, ExportCmd, FactorizeCmd, IngestCmd,
    MachineSpec, ModelSelectCmd, MonitorCmd, QueryCmd, RunConfig, ServeBenchCmd,
    TraceSummaryCmd, TrainCmd, TuneCmd,
};
use drescal::coordinator::metrics::RunMetrics;
use drescal::data::synthetic::SyntheticSpec;
use drescal::engine::{Engine, EngineConfig, JobSpec, Report, SimScenario, SimSpec};
use drescal::error::{Context as _, Result};
use drescal::json::Json;
use drescal::model_selection::RescalkConfig;
use drescal::rescal::{DistInit, ModelKind, RescalOptions};
use drescal::serve::{Answer, FactorModel, Query, QueryEngine};
use drescal::simulate::Machine;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return;
    }
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let command = RunConfig::from_args(argv)?.command;
    // apply this machine's persisted blocking profile (if any, and if its
    // ISA matches the dispatched kernel) before any GEMM runs; `tune`
    // manages the blocking itself
    if !matches!(command, Command::Tune(_)) {
        drescal::tensor::kernel::tune::autoload();
    }
    match command {
        Command::Run(cmd) => cmd_run(cmd),
        Command::Train(cmd) => cmd_train(cmd),
        Command::Worker(cmd) => drescal::engine::cluster::run_worker(&cmd.connect),
        Command::ModelSelect(cmd) => cmd_model_select(cmd),
        Command::Exascale(cmd) => cmd_exascale(cmd),
        Command::Artifacts(cmd) => cmd_artifacts(cmd),
        Command::Bench(cmd) => cmd_bench(cmd),
        Command::Export(cmd) => cmd_export(cmd),
        Command::Query(cmd) => cmd_query(cmd),
        Command::ServeBench(cmd) => cmd_serve_bench(cmd),
        Command::Ingest(cmd) => cmd_ingest(cmd),
        Command::Tune(cmd) => cmd_tune(cmd),
        Command::TraceSummary(cmd) => cmd_trace_summary(cmd),
        Command::Monitor(cmd) => cmd_monitor(cmd),
        Command::Help => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "drescal — distributed non-negative RESCAL with automatic model selection

USAGE: drescal <subcommand> [--flag value ...]

SUBCOMMANDS
  run           one distributed factorization
                  --data synthetic|blocks|nations|trade|file:<manifest>
                  --n --m --k-true   synthetic tensor shape/truth
                  --density D        sparse synthetic tensor (CSR path)
                  --p P              virtual ranks, perfect square (4)
                  --k K              rank of the factorization (4)
                  --iters N          MU iterations (200)
                  --model rescal|distmult|logistic   model family (rescal)
                  --backend native|xla  [--artifacts DIR]
                  --cache-bytes B    resident-tile budget, LRU-evicted (0 = off)
                  --trace-out FILE   write a Chrome/Perfetto trace of the run's
                                     per-rank spans (implies --trace)
                  --seed S  --trace  --json
  train         lead a multi-process TCP cluster factorization: this
                process runs rank 0 and waits for --workers processes
                  --workers W (3; W+1 must be a perfect square)
                  --listen ADDR (127.0.0.1:0)  --port-file FILE
                  --comm-timeout-ms MS (10000)  --max-replacements K (1)
                  --data synthetic|blocks|nations|trade|file:<manifest>
                  --n --m --k-true --density --k --iters --model --seed
                  --trace --trace-out FILE --json
                  --status-port P    serve /healthz /metrics /progress /trace
                                     over HTTP while the job runs (0 =
                                     ephemeral port; implies --trace)
                  (--trace-out gathers spans from every worker process
                  into one cross-process trace file on the leader)
  worker        join a train leader and serve rank jobs until shutdown
                  --connect ADDR
  model-select  RESCALk sweep with automatic k determination
                  (run flags plus) --k-min --k-max --perturbations --delta
                  --tol --err-every --regress-iters
                  (--model family needs random init; NNDSVD is rescal-only)
  export        train, then persist the factors as a servable model
                  (run flags; --sweep adds the model-select flags and
                  exports the k_opt model)  --model FILE (model.json)
                  --family rescal|distmult|logistic   model family (rescal)
                  --dtype f32|f16|bf16   quantize the stored factors (f32)
  ingest        triples -> binary tile shards + manifest (see --data file:)
                  --input FILE   subject<TAB>relation<TAB>object[<TAB>weight]
                  --out DIR (corpus)  --grid G (1; GxG shards)
                  --dense        dense mmap-able blocks instead of CSR
                  --dtype f32|f16|bf16   dense shard element precision
                                 (half = half the bytes; requires --dense)
                  --json
  query         answer a link-prediction query from a saved model
                  --model FILE  --r REL  --top K (5)  --json
                  --family rescal|distmult|logistic   assert the artifact's
                  training family (typed mismatch error otherwise)
                  --s S --o O = score   --s S = (s,r,?)   --o O = (?,r,o)
                  anchors/--r take indices or names (ingested corpora
                  carry interned dictionaries into exported models)
  serve-bench   serving-throughput harness on a synthetic model
                  --n --m --k --iters   model shape / training depth
                  --queries Q (2048)  --batch B (64)  --top K (10)
                  --status-port P    live status endpoint during training
  monitor       poll a leader's --status-port endpoint and render one
                live row per MU iteration, plus a final summary:
                  drescal monitor 127.0.0.1:8650 [--interval-ms MS (250)]
  exascale      replay Fig 13 (11.5TB dense + 9.5EB sparse) via the model
                  --machine cpu|gpu|calibrated
  tune          time the packed-GEMM blocking grid (MC/KC/NC) with the
                dispatched SIMD microkernel and persist the winner; every
                other subcommand auto-loads the profile when its ISA
                matches (or set DRESCAL_TUNE_PROFILE to point elsewhere)
                  --out FILE (KERNEL_tune.json)  --quick  --json
  trace-summary per-op runtime table (paper §6.3 style) aggregated from
                a --trace-out trace file:  drescal trace-summary trace.json
  artifacts     list the AOT artifact manifest [--artifacts DIR]
  bench         fixed-shape perf harness; emits machine-readable JSON
                  (covers all three model families at one equal shape)
                  --iters N (10; 1 = smoke)  --out FILE (BENCH_rescal.json)
                  --baseline FILE (prev out)  --max-regression X (0 = off)
                  --gate-floor SECS (0.01; smaller walls are not gated)
                  --p P  --model M  --backend native|xla  --trace
  help          this text

Flags may also come from --config FILE (JSON object; CLI wins).
Tracing is opt-in (--trace): per-op timing costs on every hot-path op.
Kernel dispatch picks the best SIMD microkernel for this CPU at startup;
DRESCAL_FORCE_SCALAR=1 or DRESCAL_KERNEL=<name> override it."
    );
}

fn cmd_run(cmd: FactorizeCmd) -> Result<()> {
    let mut engine = Engine::new(cmd.engine)?;
    // synthetic data is generated rank-locally, file corpora are read
    // shard-by-shard on the ranks — the leader never holds X
    let data = engine.load_dataset(cmd.data.to_dataset_spec(cmd.seed)?)?;
    let info = engine.dataset_info(data).expect("dataset just registered");
    println!(
        "distributed RESCAL: n={} m={} k={} p={} model={} backend={:?}{}",
        info.n,
        info.m,
        cmd.opts.k,
        engine.config().p,
        engine.config().model.as_str(),
        engine.config().backend,
        if info.sparse { " (sparse tiles)" } else { "" }
    );
    let report = engine.factorize(data, &cmd.opts, cmd.seed)?;
    println!(
        "done in {}: rel_error={:.4} ({} iterations, workspace {} allocs / {} reuses)",
        bench_util::fmt_secs(report.wall_seconds),
        report.rel_error,
        report.iters_run,
        report.workspace.mat_allocs,
        report.workspace.mat_reuses
    );
    if let Some(kt) = cmd.data.k_true() {
        println!("(ground-truth latent dimension of this dataset: {kt})");
    }
    println!("factor digest: {:016x}", factor_digest(&report.a, &report.r));
    if engine.config().trace {
        let metrics = RunMetrics::from_traces(&report.traces);
        print!("{}", metrics.format_breakdown());
    }
    if let Some(path) = &cmd.trace_out {
        write_trace_out(path, &report.timeline)?;
    }
    if cmd.json {
        println!("{}", Report::Factorize(report).to_json());
    }
    Ok(())
}

/// Write a report's gathered span timeline as Chrome trace-event JSON
/// (loadable in Perfetto or chrome://tracing) and print the per-op
/// summary table.
fn write_trace_out(path: &str, timeline: &[drescal::obs::RankTimeline]) -> Result<()> {
    let trace = drescal::obs::chrome_trace_json(timeline);
    std::fs::write(path, trace.to_string())
        .with_context(|| format!("writing trace to {path}"))?;
    let spans: usize = timeline.iter().map(|t| t.spans.len()).sum();
    println!("wrote {spans} spans from {} rank(s) to {path}", timeline.len());
    let dropped: u64 = timeline.iter().map(|t| t.dropped).sum();
    print!(
        "{}",
        drescal::obs::format_summary(&drescal::obs::summarize_timelines(timeline), dropped)
    );
    Ok(())
}

/// Aggregate a `--trace-out` file back into the per-op runtime table.
fn cmd_trace_summary(cmd: TraceSummaryCmd) -> Result<()> {
    let text = std::fs::read_to_string(&cmd.input)
        .with_context(|| format!("reading trace file {}", cmd.input))?;
    let v = Json::parse(&text).map_err(|e| drescal::err!("trace JSON: {e}"))?;
    let rows = drescal::obs::summarize_chrome_trace(&v)?;
    let dropped = drescal::obs::chrome_trace_dropped(&v);
    print!("{}", drescal::obs::format_summary(&rows, dropped));
    Ok(())
}

/// Poll a running leader's `--status-port` endpoint and render a live
/// one-row-per-iteration view; on job completion print the convergence
/// and watchdog summary and exit.
fn cmd_monitor(cmd: MonitorCmd) -> Result<()> {
    use std::time::Duration;
    let timeout = Duration::from_secs(2);
    // connect window: the leader may still be rendezvousing with its
    // workers when the monitor starts
    let mut body = None;
    for _ in 0..40 {
        match drescal::obs::http_get(&cmd.addr, "/progress", timeout) {
            Ok(b) => {
                body = Some(b);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(250)),
        }
    }
    let mut body = body.ok_or_else(|| {
        drescal::err!(
            "no status endpoint at {} after 10s — is the leader running with --status-port?",
            cmd.addr
        )
    })?;
    println!("monitoring http://{}/progress every {} ms", cmd.addr, cmd.interval_ms);
    println!(
        "{:>6} {:>12} {:>12} {:>10} {:>12}",
        "iter", "rel_error", "delta", "iter ms", "wire MiB"
    );
    let mut last_printed: i64 = -1;
    let mut warned = 0usize;
    loop {
        let v = Json::parse(&body).map_err(|e| drescal::err!("bad /progress JSON: {e}"))?;
        let num = |o: &Json, k: &str| o.get(k).and_then(Json::as_f64);
        // stale-aware float cell: rel_error/delta are null until the next
        // --err-every checkpoint refreshes them
        let cell = |x: Option<f64>| match x {
            Some(x) => format!("{x:.4}"),
            None => "-".to_string(),
        };
        if let Some(hist) = v.get("history").and_then(Json::as_arr) {
            for ev in hist {
                let iter = num(ev, "iter").unwrap_or(-1.0) as i64;
                if iter <= last_printed {
                    continue;
                }
                last_printed = iter;
                println!(
                    "{:>6} {:>12} {:>12} {:>10.1} {:>12.2}",
                    iter,
                    cell(num(ev, "rel_error")),
                    cell(num(ev, "delta")),
                    num(ev, "iter_ms").unwrap_or(0.0),
                    num(ev, "wire_bytes").unwrap_or(0.0) / (1024.0 * 1024.0)
                );
            }
        }
        // surface watchdog warnings as they appear, once each
        if let Some(warnings) = v.get("warnings").and_then(Json::as_arr) {
            for w in warnings.iter().skip(warned) {
                println!(
                    "  ⚠ [{}] iter {}: {}",
                    w.get("kind").and_then(Json::as_str).unwrap_or("?"),
                    num(w, "iter").unwrap_or(0.0) as u64,
                    w.get("detail").and_then(Json::as_str).unwrap_or("")
                );
            }
            warned = warnings.len();
        }
        if v.get("done").and_then(Json::as_bool).unwrap_or(false) {
            println!(
                "\njob '{}' done: {} iteration(s) in {}, final rel_error {}, {} transport \
                 restart(s), {} watchdog warning(s)",
                v.get("job").and_then(Json::as_str).unwrap_or("?"),
                last_printed + 1,
                bench_util::fmt_secs(num(&v, "elapsed_ms").unwrap_or(0.0) / 1e3),
                cell(num(&v, "rel_error")),
                num(&v, "restarts").unwrap_or(0.0) as u64,
                warned
            );
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(cmd.interval_ms));
        body = match drescal::obs::http_get(&cmd.addr, "/progress", timeout) {
            Ok(b) => b,
            Err(e) => {
                // the leader exits (and its endpoint with it) as soon as
                // the job completes — not an error if we saw progress
                if last_printed >= 0 {
                    println!(
                        "\nstatus endpoint at {} closed ({e}); job finished or leader exited",
                        cmd.addr
                    );
                    return Ok(());
                }
                return Err(e.context(format!("polling http://{}/progress", cmd.addr)));
            }
        };
    }
}

/// FNV-1a over the factors' exact f32 bit patterns: two runs print the
/// same digest iff their gathered factors are bit-identical. The CI
/// multi-process smoke compares this line between `run` (in-process)
/// and `train` (TCP cluster).
fn factor_digest(a: &drescal::tensor::Mat, r: &drescal::tensor::Tensor3) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |h: &mut u64, bits: u32| {
        for b in bits.to_le_bytes() {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    for v in a.as_slice() {
        eat(&mut h, v.to_bits());
    }
    for s in r.slices() {
        for v in s.as_slice() {
            eat(&mut h, v.to_bits());
        }
    }
    h
}

/// Lead a TCP cluster factorization: construction rendezvouses with the
/// workers, then the job runs exactly like `run` — same collectives,
/// same deterministic factors, different transport.
fn cmd_train(cmd: TrainCmd) -> Result<()> {
    let mut engine = Engine::new(cmd.engine)?;
    let data = engine.load_dataset(cmd.data.to_dataset_spec(cmd.seed)?)?;
    let info = engine.dataset_info(data).expect("dataset just registered");
    println!(
        "cluster RESCAL: n={} m={} k={} p={} model={} transport=tcp{}",
        info.n,
        info.m,
        cmd.opts.k,
        engine.config().p,
        engine.config().model.as_str(),
        if info.sparse { " (sparse tiles)" } else { "" }
    );
    let report = engine.factorize(data, &cmd.opts, cmd.seed)?;
    println!(
        "done in {}: rel_error={:.4} ({} iterations, transport {})",
        bench_util::fmt_secs(report.wall_seconds),
        report.rel_error,
        report.iters_run,
        report.transport_backend
    );
    println!("factor digest: {:016x}", factor_digest(&report.a, &report.r));
    if engine.config().trace {
        let metrics = RunMetrics::from_traces(&report.traces);
        print!("{}", metrics.format_breakdown());
    }
    if let Some(path) = &cmd.trace_out {
        // spans from every worker process were gathered to this leader
        // over the mesh at job end
        write_trace_out(path, &report.timeline)?;
    }
    if cmd.json {
        println!("{}", Report::Factorize(report).to_json());
    }
    Ok(())
}

fn cmd_model_select(cmd: ModelSelectCmd) -> Result<()> {
    let mut engine = Engine::new(cmd.engine)?;
    let data = engine.load_dataset(cmd.data.to_dataset_spec(cmd.sweep.seed)?)?;
    let info = engine.dataset_info(data).expect("dataset just registered");
    println!(
        "RESCALk sweep: n={} m={} k∈[{},{}] r={} p={} backend={:?}",
        info.n,
        info.m,
        cmd.sweep.k_min,
        cmd.sweep.k_max,
        cmd.sweep.perturbations,
        engine.config().p,
        engine.config().backend
    );
    let report = engine.model_select(data, &cmd.sweep)?;
    let rows: Vec<Vec<String>> = report
        .scores
        .iter()
        .map(|s| {
            vec![
                s.k.to_string(),
                format!("{:.3}", s.sil_min),
                format!("{:.3}", s.sil_avg),
                format!("{:.4}", s.rel_error),
            ]
        })
        .collect();
    bench_util::print_table(
        "model selection",
        &["k", "min silhouette", "avg silhouette", "rel error"],
        &rows,
    );
    println!(
        "\nk_opt = {}  (wall {})",
        report.k_opt,
        bench_util::fmt_secs(report.wall_seconds)
    );
    match cmd.data.k_true() {
        Some(kt) if kt == report.k_opt => println!("matches the dataset's ground truth ✓"),
        Some(kt) => println!("(ground truth is {kt})"),
        None => {}
    }
    if engine.config().trace {
        let metrics = RunMetrics::from_traces(&report.traces);
        print!("{}", metrics.format_breakdown());
    }
    if let Some(path) = &cmd.trace_out {
        write_trace_out(path, &report.timeline)?;
    }
    if cmd.json {
        println!("{}", Report::ModelSelect(report).to_json());
    }
    Ok(())
}

fn cmd_exascale(cmd: ExascaleCmd) -> Result<()> {
    let machine = match cmd.machine {
        MachineSpec::Cpu => Machine::cpu_cluster(),
        MachineSpec::Gpu => Machine::gpu_cluster(),
        MachineSpec::Calibrated => {
            let flops = bench_util::calibrate_dense_flops();
            println!("calibrated dense rate: {:.1} GFLOP/s", flops / 1e9);
            Machine::calibrated(flops, 2e-6, 1e-10)
        }
    };
    // modeled replays run on the leader; a 1-rank engine keeps the job
    // API uniform without spawning an idle grid
    let mut engine = Engine::new(EngineConfig::new(1))?;
    let dense_report =
        engine.simulate(SimSpec { machine, scenario: SimScenario::Dense11Tb })?;
    let dense = &dense_report.rows[0];
    println!(
        "\nFig 13a replay — {}\n  logical size {:.1} TB on {} ranks\n  modeled: compute {} + comm {} = {} ({:.0}% comm)",
        dense.label,
        dense.logical_bytes() / 1e12,
        dense.p,
        bench_util::fmt_secs(dense.compute_seconds),
        bench_util::fmt_secs(dense.comm_seconds),
        bench_util::fmt_secs(dense.total()),
        100.0 * dense.comm_fraction()
    );
    let sparse_report =
        engine.simulate(SimSpec { machine, scenario: SimScenario::SparseExabyte })?;
    let rows: Vec<Vec<String>> = sparse_report
        .rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0e}", r.density),
                bench_util::fmt_secs(r.compute_seconds),
                bench_util::fmt_secs(r.comm_seconds),
                bench_util::fmt_secs(r.total()),
                format!("{:.1}%", 100.0 * r.comm_fraction()),
            ]
        })
        .collect();
    bench_util::print_table(
        "Fig 13b replay — 9.5EB sparse, 22801 ranks, 100 iters",
        &["density", "compute", "comm", "total", "comm%"],
        &rows,
    );
    Ok(())
}

/// Fixed-shape perf harness: factorize + model-select on dense and sparse
/// synthetic datasets (all through the dataset data plane), the serving
/// read path, the kernel plane (packed vs legacy GEMM at
/// representative RESCAL and serve shapes), and the storage plane
/// (triple ingestion + shard loading). Emits one JSON file so CI and
/// the perf trajectory have a stable artifact; when a baseline exists,
/// per-section deltas are printed and `--max-regression` turns a blow-up
/// into a hard error.
fn cmd_bench(cmd: BenchCmd) -> Result<()> {
    let iters = cmd.iters;
    let mut engine = Engine::new(cmd.engine)?;
    let p = engine.config().p;
    println!("bench: p={p} iters={iters} backend={:?}", engine.config().backend);
    // the kernel line pins the hardware context of every number below:
    // which microkernel dispatch selected and the blocking in effect
    // (default, or this machine's `drescal tune` profile)
    {
        use drescal::tensor::kernel;
        let kern = kernel::dispatch::active();
        let (mc, kc, nc) = kernel::blocking();
        println!(
            "kernel: {} (isa {}, {}x{} tile), blocking mc={mc} kc={kc} nc={nc}{}",
            kern.name,
            kern.isa,
            kern.mr,
            kern.nr,
            if (mc, kc, nc) == kernel::default_blocking() { "" } else { " [tuned]" }
        );
    }

    let mut results: Vec<(String, f64)> = Vec::new();
    let mut record = |name: &str, wall: f64| {
        println!("  {name}: {}", bench_util::fmt_secs(wall));
        results.push((name.to_string(), wall));
    };

    // factorize, dense and sparse, same shape
    let dense = engine.load_dataset(SyntheticSpec::dense(64, 3, 4, 42))?;
    let report = engine.factorize(dense, &RescalOptions::new(4, iters), 42)?;
    let dense_wall = report.wall_seconds;
    record("factorize_dense_n64_m3_k4", report.wall_seconds);
    // the dense factors double as the serve-section model below
    let model = engine.export_model(&Report::Factorize(report))?;
    let sparse = engine.load_dataset(SyntheticSpec::sparse(64, 3, 4, 0.05, 42))?;
    let report = engine.factorize(sparse, &RescalOptions::new(4, iters), 42)?;
    record("factorize_sparse_n64_m3_k4_d0.05", report.wall_seconds);

    // model families at one equal shape on the 2×2 grid: the paper's
    // Gaussian rule as the reference row, diagonal-core distmult (whose
    // O(k) core update must beat the dense k×k row), and Bernoulli
    // logistic (which pays an extra sigmoid reconstruction per sweep).
    // All three ride the --max-regression gate like every other row.
    let family_data = engine.load_dataset(SyntheticSpec::dense(128, 3, 8, 44))?;
    for kind in [ModelKind::Rescal, ModelKind::DistMult, ModelKind::Logistic] {
        let report = match engine.submit(JobSpec::Factorize {
            data: (&family_data).into(),
            opts: RescalOptions::new(32, iters),
            init: DistInit::Random { seed: 44 },
            model: kind,
        })? {
            Report::Factorize(r) => r,
            _ => unreachable!("factorize jobs return factorize reports"),
        };
        record(&format!("factorize_{}_dense_g2", kind.as_str()), report.wall_seconds);
    }
    engine.unload_dataset(family_data)?;

    // telemetry plane: the same dense factorize with span recording and
    // per-op tracing enabled, on a fresh traced 2×2 engine. The row
    // rides the --max-regression gate, so instrumentation-overhead
    // regressions (allocation on the hot path, timestamp storms) fail
    // CI just like a kernel regression would.
    {
        let mut traced = Engine::new(EngineConfig::new(4).with_trace(true))?;
        let tdata = traced.load_dataset(SyntheticSpec::dense(64, 3, 4, 42))?;
        let treport = traced.factorize(tdata, &RescalOptions::new(4, iters), 42)?;
        record("telemetry_overhead_dense_g2", treport.wall_seconds);
        println!(
            "  traced vs untraced dense factorize: {:.2}x",
            treport.wall_seconds / dense_wall.max(1e-12)
        );
    }

    // live plane: the same traced factorize with the status endpoint
    // serving while a poller hammers /metrics and /progress every 10ms —
    // the row rides the --max-regression gate so endpoint overhead (hub
    // lock contention on the MU path, per-request allocation storms)
    // fails CI like a kernel regression would.
    {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let mut live = Engine::new(EngineConfig::new(4).with_trace(true).with_status_port(0))?;
        let addr = live.status_addr().expect("status endpoint requested").to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let poller = {
            let stop = Arc::clone(&stop);
            let addr = addr.clone();
            std::thread::spawn(move || {
                let timeout = std::time::Duration::from_millis(500);
                while !stop.load(Ordering::Relaxed) {
                    let _ = drescal::obs::http_get(&addr, "/metrics", timeout);
                    let _ = drescal::obs::http_get(&addr, "/progress", timeout);
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
            })
        };
        let ldata = live.load_dataset(SyntheticSpec::dense(64, 3, 4, 42))?;
        let lreport = live.factorize(ldata, &RescalOptions::new(4, iters), 42)?;
        record("status_endpoint_overhead_dense_g2", lreport.wall_seconds);
        stop.store(true, Ordering::Relaxed);
        poller.join().ok();
    }

    // model-select, dense and sparse, small sweep
    let sweep = RescalkConfig {
        k_min: 2,
        k_max: 3,
        perturbations: 2,
        rescal_iters: iters,
        regress_iters: 5,
        seed: 42,
        ..Default::default()
    };
    let dense_ms = engine.load_dataset(SyntheticSpec::dense(24, 2, 2, 43))?;
    let report = engine.model_select(dense_ms, &sweep)?;
    record("model_select_dense_n24_m2", report.wall_seconds);
    let sparse_ms = engine.load_dataset(SyntheticSpec::sparse(24, 2, 2, 0.1, 43))?;
    let report = engine.model_select(sparse_ms, &sweep)?;
    record("model_select_sparse_n24_m2_d0.1", report.wall_seconds);

    // serving: batched vs unbatched top-k completion on the dense model
    let point = bench_util::measure_serve_topk(&model, 64, 256, 10)?;
    record("serve_topk_batched_n64_q256", point.wall_seconds);
    let point = bench_util::measure_serve_topk(&model, 1, 256, 10)?;
    record("serve_topk_unbatched_n64_q256", point.wall_seconds);

    // kernel plane: the packed microkernel vs the legacy unpacked kernel
    // at representative shapes. The large dense square is the headline
    // number — the packed kernel must beat legacy there; both rows also
    // feed the --max-regression gate so kernel regressions fail CI.
    {
        use drescal::rng::Rng;
        use drescal::tensor::dense::{gemm, gemm_legacy};
        use drescal::tensor::{kernel, DType, HalfMat, Mat};
        let mut rng = Rng::new(77);
        // roofline readout: every kernel-plane shape reports its
        // achieved GFLOP/s next to the wall time, so a perf dip is
        // attributable to a shape, not just a row name
        let mut roofline: Vec<(String, f64, f64)> = Vec::new();
        let mut roof = |label: &str, m: usize, kdim: usize, n: usize, wall: f64| {
            roofline.push((label.to_string(), wall, bench_util::gemm_gflops(m, kdim, n, wall)));
        };
        // large dense GEMM (512³)
        let a = Mat::random_uniform(512, 512, 0.0, 1.0, &mut rng);
        let b = Mat::random_uniform(512, 512, 0.0, 1.0, &mut rng);
        let mut c = Mat::zeros(512, 512);
        let packed = bench_util::time_fn(1, 3, || gemm(&a, &b, &mut c, false));
        record("kernel_packed_gemm_512", packed.median);
        roof("packed 512^3 f32", 512, 512, 512, packed.median);
        let legacy = bench_util::time_fn(1, 3, || gemm_legacy(&a, &b, &mut c, false));
        record("kernel_legacy_gemm_512", legacy.median);
        roof("legacy 512^3 f32", 512, 512, 512, legacy.median);
        println!(
            "  packed kernel speedup at 512^3: {:.2}x",
            legacy.median / packed.median.max(1e-12)
        );
        // the same square through the dispatched microkernel API in f32
        // and with A stored bf16 (widen-on-pack into f32 accumulators) —
        // the precision axis at the headline shape
        let st = bench_util::time_fn(1, 3, || kernel::gemm_nn_into(&a, &b, &mut c, false));
        record("gemm_f32_512", st.median);
        roof("dispatch 512^3 f32", 512, 512, 512, st.median);
        let ah = HalfMat::from_f32(&a, DType::Bf16);
        let st =
            bench_util::time_fn(1, 3, || kernel::gemm_nn_half_into(&ah, &b, &mut c, false));
        record("gemm_bf16_512", st.median);
        roof("dispatch 512^3 bf16 A", 512, 512, 512, st.median);
        // RESCAL training shape: X_t·A (n×n · n×k)
        let x = Mat::random_uniform(768, 768, 0.0, 1.0, &mut rng);
        let f = Mat::random_uniform(768, 16, 0.0, 1.0, &mut rng);
        let mut xa = Mat::zeros(768, 16);
        let st = bench_util::time_fn(1, 3, || gemm(&x, &f, &mut xa, false));
        record("kernel_packed_xa_n768_k16", st.median);
        roof("XA 768x768x16", 768, 768, 16, st.median);
        // batched serve shape: B×k · (n×k)ᵀ completion scoring
        let q = Mat::random_uniform(64, 16, 0.0, 1.0, &mut rng);
        let entities = Mat::random_uniform(8192, 16, 0.0, 1.0, &mut rng);
        let mut scores = Mat::zeros(64, 8192);
        let st = bench_util::time_fn(1, 3, || kernel::gemm_nt_into(&q, &entities, &mut scores));
        record("kernel_packed_serve_b64_n8192", st.median);
        roof("serve 64x16x8192", 64, 16, 8192, st.median);
        let rows: Vec<Vec<String>> = roofline
            .iter()
            .map(|(label, wall, gflops)| {
                vec![label.clone(), bench_util::fmt_secs(*wall), format!("{gflops:.2}")]
            })
            .collect();
        bench_util::print_table(
            "kernel roofline (2mnk flops / median wall)",
            &["shape", "wall", "GFLOP/s"],
            &rows,
        );
    }

    // transport plane: ring all-reduce throughput over 4 ranks, 1 MiB of
    // f32 payload per rank per round, in-process vs TCP loopback — both
    // rows ride the --max-regression gate so a collective regression
    // (extra copies, lost batching, frame bloat) fails CI
    {
        use drescal::comm::transport::tcp::{loopback_meshes, TcpConfig, TcpGroup};
        use drescal::comm::Group;
        use std::sync::{Arc, Mutex};
        const FLOATS: usize = 262_144; // 1 MiB of f32 per rank
        const ROUNDS: usize = 8;
        let time_allreduce = |groups: Vec<Group>| {
            let t0 = std::time::Instant::now();
            std::thread::scope(|s| {
                for g in groups {
                    s.spawn(move || {
                        let mut v = vec![1.0f32; FLOATS];
                        for _ in 0..ROUNDS {
                            g.all_reduce_sum(&mut v).expect("bench all_reduce");
                            v.iter_mut().for_each(|x| *x = 1.0);
                        }
                    });
                }
            });
            t0.elapsed().as_secs_f64()
        };
        record("transport_allreduce_inprocess_4x1mb", time_allreduce(Group::create(4)));
        let meshes = loopback_meshes(4, TcpConfig::default())?;
        let tcp_groups = meshes
            .into_iter()
            .map(|m| {
                TcpGroup::new(Arc::new(Mutex::new(m)), (0..4).collect(), 0)
                    .map(Group::from_transport)
            })
            .collect::<std::result::Result<Vec<_>, _>>()?;
        record("transport_allreduce_tcp_4x1mb", time_allreduce(tcp_groups));
    }

    // storage plane: synthesize a triple corpus, ingest it to binary
    // shards, and load it back through DatasetSpec::File — both rows ride
    // the same --max-regression gate as the compute sections
    {
        use std::io::Write as _;
        let dir =
            std::env::temp_dir().join(format!("drescal_bench_ingest_{}", std::process::id()));
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let triples_path = dir.join("triples.tsv");
        {
            let file = std::fs::File::create(&triples_path)
                .with_context(|| format!("creating {}", triples_path.display()))?;
            let mut w = std::io::BufWriter::new(file);
            let mut rng = drescal::rng::Rng::new(91);
            for _ in 0..8192 {
                writeln!(w, "e{}\tr{}\te{}", rng.below(256), rng.below(2), rng.below(256))
                    .context("writing bench triples")?;
            }
            w.flush().context("flushing bench triples")?;
        }
        let corpus = dir.join("corpus");
        let opts = drescal::store::IngestOptions {
            grid: 2,
            dense: false,
            source: "bench".to_string(),
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        drescal::store::ingest_triples_file(&triples_path, &corpus, &opts)?;
        record("ingest_triples_8k_g2", t0.elapsed().as_secs_f64());
        let spec = drescal::engine::DatasetSpec::from_manifest_path(&corpus)?;
        let t0 = std::time::Instant::now();
        let handle = engine.load_dataset(spec)?;
        record("load_from_file_sparse_g2", t0.elapsed().as_secs_f64());
        engine.unload_dataset(handle)?;

        // the half-precision storage path end to end: the same triples
        // ingested as dense f16 shards (half the mapped bytes), loaded
        // rank-resident without widening, and factorized through the
        // widen-on-pack kernel path
        let half_corpus = dir.join("corpus_f16");
        let opts = drescal::store::IngestOptions {
            grid: 2,
            dense: true,
            dtype: drescal::tensor::DType::F16,
            source: "bench".to_string(),
        };
        drescal::store::ingest_triples_file(&triples_path, &half_corpus, &opts)?;
        let spec = drescal::engine::DatasetSpec::from_manifest_path(&half_corpus)?;
        let handle = engine.load_dataset(spec)?;
        let report = engine.factorize(handle, &RescalOptions::new(4, iters), 42)?;
        record("factorize_f16_store_dense_g2", report.wall_seconds);
        engine.unload_dataset(handle)?;
        std::fs::remove_dir_all(&dir).ok();
    }

    let mut obj = BTreeMap::new();
    obj.insert("bench".to_string(), Json::Str("rescal".to_string()));
    obj.insert("iters".to_string(), Json::Num(iters as f64));
    obj.insert("p".to_string(), Json::Num(p as f64));
    obj.insert(
        "results".to_string(),
        Json::Arr(
            results
                .iter()
                .map(|(name, wall)| {
                    let mut row = BTreeMap::new();
                    row.insert("name".to_string(), Json::Str(name.clone()));
                    row.insert("wall_seconds".to_string(), Json::Num(*wall));
                    Json::Obj(row)
                })
                .collect(),
        ),
    );
    // perf trajectory: per-section deltas vs the previous run, and an
    // optional hard gate on wall-time regressions (the CI smoke step
    // passes --max-regression 2). The gate runs *before* the results are
    // written: a failed run must not replace the baseline with its own
    // regressed numbers, or the second run would silently pass.
    // Sections where both walls sit under --gate-floor seconds are
    // reported but not gated — sub-10ms timings on shared runners swing
    // severalfold without any code change; a genuine blow-up crosses
    // the floor and is still caught.
    if let Some(base) = load_bench_baseline(&cmd.baseline) {
        let mut rows: Vec<Vec<String>> = Vec::new();
        let mut worst_name = String::new();
        let mut worst_ratio = 0.0f64;
        for (name, wall) in &results {
            match base.get(name) {
                Some(&prev) if prev > 0.0 => {
                    let ratio = wall / prev;
                    rows.push(vec![
                        name.clone(),
                        bench_util::fmt_secs(prev),
                        bench_util::fmt_secs(*wall),
                        format!("{ratio:.2}x"),
                    ]);
                    let gated = prev >= cmd.gate_floor || *wall >= cmd.gate_floor;
                    if gated && ratio > worst_ratio {
                        worst_ratio = ratio;
                        worst_name = name.clone();
                    }
                }
                _ => rows.push(vec![
                    name.clone(),
                    "-".to_string(),
                    bench_util::fmt_secs(*wall),
                    "new".to_string(),
                ]),
            }
        }
        bench_util::print_table(
            &format!("perf trajectory vs {}", cmd.baseline),
            &["section", "baseline", "now", "ratio"],
            &rows,
        );
        if cmd.max_regression > 0.0 && worst_ratio > cmd.max_regression {
            return Err(drescal::err!(
                "perf regression: {worst_name} is {worst_ratio:.2}x its baseline \
                 (limit {:.2}x; baseline kept — {} was not overwritten)",
                cmd.max_regression,
                cmd.out
            ));
        }
    } else {
        println!("(no baseline at {} — deltas start next run)", cmd.baseline);
    }

    let json = Json::Obj(obj);
    std::fs::write(&cmd.out, json.to_string())
        .with_context(|| format!("writing bench results to {}", cmd.out))?;
    println!("wrote {} results to {}", results.len(), cmd.out);
    Ok(())
}

/// Parse a previous `BENCH_rescal.json` into section → wall seconds.
/// Missing or malformed files mean "no baseline", never an error: the
/// first run of a fresh checkout must succeed.
fn load_bench_baseline(path: &str) -> Option<BTreeMap<String, f64>> {
    let text = std::fs::read_to_string(path).ok()?;
    let v = Json::parse(&text).ok()?;
    let mut map = BTreeMap::new();
    for row in v.get("results")?.as_arr()? {
        let name = row.get("name")?.as_str()?.to_string();
        let wall = row.get("wall_seconds")?.as_f64()?;
        map.insert(name, wall);
    }
    Some(map)
}

/// Train (factorize or full sweep), export the factors through the
/// engine, and persist the servable model artifact.
fn cmd_export(cmd: ExportCmd) -> Result<()> {
    let mut engine = Engine::new(cmd.engine)?;
    let data = engine.load_dataset(cmd.data.to_dataset_spec(cmd.seed)?)?;
    let info = engine.dataset_info(data).expect("dataset just registered");
    let report = match &cmd.sweep {
        Some(sweep) => {
            println!(
                "export: RESCALk sweep n={} m={} k∈[{},{}] p={}",
                info.n,
                info.m,
                sweep.k_min,
                sweep.k_max,
                engine.config().p
            );
            let r = engine.model_select(data, sweep)?;
            println!("k_opt = {} (wall {})", r.k_opt, bench_util::fmt_secs(r.wall_seconds));
            Report::ModelSelect(r)
        }
        None => {
            println!(
                "export: factorize n={} m={} k={} p={}",
                info.n,
                info.m,
                cmd.opts.k,
                engine.config().p
            );
            let r = engine.factorize(data, &cmd.opts, cmd.seed)?;
            println!(
                "rel_error = {:.4} after {} iterations (wall {})",
                r.rel_error,
                r.iters_run,
                bench_util::fmt_secs(r.wall_seconds)
            );
            Report::Factorize(r)
        }
    };
    // an ingested corpus's interned names ride into the model, so the
    // served answers are resolvable by entity/relation name
    let model = engine.export_model_for(&report, data)?.quantize(cmd.dtype)?;
    model.save(&cmd.model)?;
    println!(
        "exported factor model (n={} entities, m={} relations, k={}{}{}) to {}",
        model.n(),
        model.m(),
        model.k(),
        if model.entity_names().is_some() { ", named" } else { "" },
        if model.dtype().is_half() {
            format!(", {} factors", model.dtype().as_str())
        } else {
            String::new()
        },
        cmd.model
    );
    match model.entity_names().and_then(|names| names.first().cloned()) {
        Some(first) => println!(
            "query it:  drescal query --model {} --s {first} --r {} --top 5",
            cmd.model,
            model.relation_names().and_then(|r| r.first().cloned()).unwrap_or_default()
        ),
        None => println!("query it:  drescal query --model {} --s 0 --r 0 --top 5", cmd.model),
    }
    Ok(())
}

/// Load a persisted model and answer one link-prediction query.
fn cmd_query(cmd: QueryCmd) -> Result<()> {
    let model = FactorModel::load(&cmd.model)?;
    // `--family` pins the expected training family: a warm-start or
    // scoring pipeline built for one family must not silently consume an
    // artifact trained under another
    if let Some(family) = cmd.family {
        model.ensure_model(family)?;
    }
    println!(
        "model {}: n={} m={} k={} family={} (from {} job{})",
        cmd.model,
        model.n(),
        model.m(),
        model.k(),
        model.model().as_str(),
        model.provenance().job,
        if model.provenance().rel_error >= 0.0 {
            format!(", train rel_error {:.4}", model.provenance().rel_error)
        } else {
            String::new()
        }
    );
    // anchors and relation are tokens: integer indices, or names resolved
    // through the model's interned dictionaries (typed errors either way)
    let r = model.resolve_relation(&cmd.r)?;
    let s = cmd.s.as_deref().map(|t| model.resolve_entity(t)).transpose()?;
    let o = cmd.o.as_deref().map(|t| model.resolve_entity(t)).transpose()?;
    let query = match (s, o) {
        (Some(s), Some(o)) => Query::Score { s, r, o },
        (Some(s), None) => Query::TopObjects { s, r, top: cmd.top },
        (None, Some(o)) => Query::TopSubjects { o, r, top: cmd.top },
        (None, None) => unreachable!("config validation requires --s and/or --o"),
    };
    let mut qe = QueryEngine::new(model);
    let answer = qe.query(query)?;
    let entity_label = |i: usize| match qe.model().entity_names() {
        Some(names) => format!("{} ({})", i, names[i]),
        None => i.to_string(),
    };
    match &answer {
        Answer::Score(v) => println!("score = {v:.6}"),
        Answer::TopK(hits) => {
            let rows: Vec<Vec<String>> = hits
                .iter()
                .enumerate()
                .map(|(rank, h)| {
                    vec![
                        (rank + 1).to_string(),
                        entity_label(h.entity),
                        format!("{:.6}", h.score),
                    ]
                })
                .collect();
            let title = match query {
                Query::TopObjects { s, r, .. } => format!("top objects for (s={s}, r={r}, ?)"),
                Query::TopSubjects { o, r, .. } => format!("top subjects for (?, r={r}, o={o})"),
                Query::Score { .. } => unreachable!("score answers are scalar"),
            };
            bench_util::print_table(&title, &["rank", "entity", "score"], &rows);
        }
    }
    if cmd.json {
        println!("{}", answer.to_json());
    }
    Ok(())
}

/// Serving-throughput harness: train a synthetic model, then measure
/// batched, unbatched, and cached top-k completion.
fn cmd_serve_bench(cmd: ServeBenchCmd) -> Result<()> {
    let mut engine = Engine::new(cmd.engine)?;
    println!(
        "serve-bench: training n={} m={} k={} ({} iters, p={})",
        cmd.n,
        cmd.m,
        cmd.k,
        cmd.iters,
        engine.config().p
    );
    let data = engine.load_dataset(SyntheticSpec::dense(cmd.n, cmd.m, cmd.k, cmd.seed))?;
    let report = engine.factorize(data, &RescalOptions::new(cmd.k, cmd.iters), cmd.seed)?;
    let model = engine.export_model(&Report::Factorize(report))?;
    println!(
        "model ready (train rel_error {:.4}); serving {} top-{} completions",
        model.provenance().rel_error,
        cmd.queries,
        cmd.top
    );

    let batched = bench_util::measure_serve_topk(&model, cmd.batch, cmd.queries, cmd.top)?;
    let unbatched = bench_util::measure_serve_topk(&model, 1, cmd.queries, cmd.top)?;
    let (cold, warm) =
        bench_util::measure_serve_cached_replay(&model, cmd.batch, cmd.queries, cmd.top)?;
    let row = |label: &str, batch: usize, p: &bench_util::ServePoint| {
        vec![
            label.to_string(),
            batch.to_string(),
            bench_util::fmt_secs(p.wall_seconds),
            format!("{:.0}", p.qps()),
            p.stats.latency_p50_us.to_string(),
            p.stats.latency_p95_us.to_string(),
            p.stats.latency_p99_us.to_string(),
            p.stats.batches.to_string(),
            p.stats.scored_candidates.to_string(),
        ]
    };
    bench_util::print_table(
        &format!("serving throughput — n={} m={} k={}", cmd.n, cmd.m, cmd.k),
        &["pass", "batch", "wall", "qps", "p50 µs", "p95 µs", "p99 µs", "gemm batches", "scored"],
        &[
            row("batched", cmd.batch, &batched),
            row("unbatched", 1, &unbatched),
            row("cached cold", cmd.batch, &cold),
            row("cached warm", cmd.batch, &warm),
        ],
    );
    println!(
        "(per-query latency = wall time of the micro-batch that answered it, \
         log-bucket resolution ~2x; warm-pass percentiles are cumulative)"
    );
    println!(
        "\nwarm pass: {} cache hits, {} candidates scored (a replay never \
         touches the scoring kernels)",
        warm.stats.cache_hits, warm.stats.scored_candidates
    );
    Ok(())
}

/// Stream a triple list into binary tile shards + manifest — the entry
/// point of the storage plane (`--data file:<manifest>` consumes it).
fn cmd_ingest(cmd: IngestCmd) -> Result<()> {
    let t0 = std::time::Instant::now();
    let opts = drescal::store::IngestOptions {
        grid: cmd.grid,
        dense: cmd.dense,
        dtype: cmd.dtype,
        source: cmd.input.clone(),
    };
    let report = drescal::store::ingest_triples_file(
        std::path::Path::new(&cmd.input),
        std::path::Path::new(&cmd.out),
        &opts,
    )?;
    println!(
        "ingested {} triples in {}: {} entities x {} relations -> {} {}{} shard(s), {} \
         on disk",
        report.triples,
        bench_util::fmt_secs(t0.elapsed().as_secs_f64()),
        report.n,
        report.m,
        report.grid * report.grid,
        report.layout.as_str(),
        if cmd.dtype.is_half() { format!(" {}", cmd.dtype.as_str()) } else { String::new() },
        bench_util::fmt_bytes(report.shard_bytes as usize),
    );
    println!(
        "train from it:  drescal run --data file:{} --p {}",
        report.manifest_path.display(),
        report.grid * report.grid
    );
    if cmd.json {
        println!("{}", report.to_json());
    }
    Ok(())
}

/// Time the packed-GEMM blocking grid on this machine's dispatched
/// microkernel and persist the winning MC/KC/NC as a JSON profile that
/// every other subcommand auto-loads at startup.
fn cmd_tune(cmd: TuneCmd) -> Result<()> {
    use drescal::tensor::kernel;
    let kern = kernel::dispatch::active();
    println!(
        "tune: {} (isa {}, {}x{} tile), {} grid",
        kern.name,
        kern.isa,
        kern.mr,
        kern.nr,
        if cmd.quick { "quick" } else { "full" }
    );
    let (profile, points) = kernel::tune::sweep(cmd.quick);
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.mc.to_string(),
                p.kc.to_string(),
                p.nc.to_string(),
                format!("{:.2}", p.gflops),
                if (p.mc, p.kc, p.nc) == (profile.mc, profile.kc, profile.nc) {
                    "◀ winner".to_string()
                } else {
                    String::new()
                },
            ]
        })
        .collect();
    bench_util::print_table(
        "blocking sweep",
        &["mc", "kc", "nc", "GFLOP/s", ""],
        &rows,
    );
    profile.save(&cmd.out)?;
    // the tuned blocking takes effect immediately in this process too
    profile.apply();
    println!(
        "\nwinner: mc={} kc={} nc={} at {:.2} GFLOP/s — saved to {}",
        profile.mc, profile.kc, profile.nc, profile.gflops, cmd.out
    );
    if cmd.json {
        println!("{}", profile.to_json());
    }
    Ok(())
}

fn cmd_artifacts(cmd: ArtifactsCmd) -> Result<()> {
    let manifest = drescal::runtime::Manifest::load(std::path::Path::new(&cmd.dir))?;
    let rows: Vec<Vec<String>> = manifest
        .entries
        .iter()
        .map(|e| {
            vec![
                e.kind.clone(),
                e.shapes
                    .iter()
                    .map(|(r, c)| format!("{r}×{c}"))
                    .collect::<Vec<_>>()
                    .join(", "),
                e.file.clone(),
            ]
        })
        .collect();
    bench_util::print_table(
        &format!("{} artifacts in {}", manifest.entries.len(), cmd.dir),
        &["kind", "input shapes", "file"],
        &rows,
    );
    Ok(())
}
