//! Reconstruction of the IMF *Trade* dataset (direction-of-trade
//! statistics; paper §6.2.2): 23 countries × 23 × 420 months of
//! continuous import/export volumes.
//!
//! Substitution note (DESIGN.md §3): we regenerate the tensor from the
//! five economic blocs the paper reports recovering (USA, NAFTA, China,
//! Europe, Asia-Pacific) with a trade volume that grows over the 420
//! months (the paper: "minimal trade interaction for month 1 … maximum
//! for month 420"). The k=5 recovery and the temporal R-slice analysis
//! (Fig 6b/6d/6f) depend only on that structure. Like the paper, the
//! 23-entity axis is zero-padded to 24 when the grid needs divisibility.

use crate::rng::Rng;
use crate::tensor::{Mat, Tensor3};

/// The 23 trading nations, in the paper's order.
pub const COUNTRIES: [&str; 23] = [
    "Australia", "Canada", "ChinaMainland", "Denmark", "Finland", "France", "Germany",
    "HongKong", "Indonesia", "Ireland", "Italy", "Japan", "Korea", "Malaysia", "Mexico",
    "Netherlands", "NewZealand", "Singapore", "Spain", "Sweden", "Thailand", "UK", "USA",
];

/// Number of monthly slices.
pub const N_MONTHS: usize = 420;

/// Ground-truth bloc memberships (paper Fig 6d): 23×5.
/// Blocs: 0 = USA, 1 = NAFTA, 2 = China, 3 = Europe, 4 = Asia-Pacific.
pub fn trade_communities() -> Mat {
    let mut a = Mat::zeros(23, 5);
    let set = |a: &mut Mat, name: &str, c: usize, w: f32| {
        let i = COUNTRIES.iter().position(|&n| n == name).unwrap();
        a[(i, c)] = w;
    };
    // USA anchors its own component; the Canada/Mexico component plays
    // the NAFTA role, tied to the USA through strong bloc 0<->1 flows in
    // the core tensor rather than overlapping membership — an overlapping
    // column would make the non-negative factorization non-identifiable
    // (no pure anchor), which is why the recovered matrix, like the
    // paper's Fig 6d, shows USA loading on both communities through R.
    set(&mut a, "USA", 0, 1.0);
    for n in ["Canada", "Mexico"] {
        set(&mut a, n, 1, 1.0);
    }
    set(&mut a, "ChinaMainland", 2, 1.0);
    for n in [
        "Denmark", "Finland", "France", "Germany", "Ireland", "Italy", "Netherlands",
        "Spain", "Sweden", "UK",
    ] {
        set(&mut a, n, 3, 1.0);
    }
    for n in [
        "Australia", "HongKong", "Indonesia", "Japan", "Korea", "Malaysia", "NewZealand",
        "Singapore", "Thailand",
    ] {
        set(&mut a, n, 4, 1.0);
    }
    a
}

/// Generate the 23×23×420 continuous trade tensor (not padded).
pub fn trade_tensor(seed: u64) -> Tensor3 {
    trade_tensor_padded(seed, 23)
}

/// Generate with the entity axis zero-padded to `n ≥ 23` (the paper pads
/// 23 → 24 so a 2×2 grid divides the axis).
pub fn trade_tensor_padded(seed: u64, n: usize) -> Tensor3 {
    assert!(n >= 23);
    let mut rng = Rng::new(seed);
    let a = trade_communities();
    // bloc-level trade intensities with slow temporal evolution: overall
    // volume grows with month; a few bloc pairs dominate (paper Fig 6f).
    // Diagonal dominance plus distinct off-diagonal profiles keep the five
    // components identifiable; China's (bloc 2) flows are scaled up so the
    // single-entity bloc carries comparable energy.
    // Stylized, strongly contrasting bloc-flow profile (rows = exporter
    // bloc, cols = importer bloc): USA leans on Europe and NAFTA, the
    // Canada/Mexico pair leans on the USA, China on Asia-Pacific and the
    // USA, Europe and Asia-Pacific are internally heavy. The row profiles
    // are deliberately far apart so the five components are identifiable
    // even though three blocs hold only 1-2 countries.
    let profile: [[f32; 5]; 5] = [
        [4.0, 0.40, 0.10, 0.35, 0.10], // USA
        [0.45, 3.0, 0.05, 0.10, 0.05], // NAFTA (Canada, Mexico)
        [0.30, 0.05, 6.0, 0.10, 0.50], // China
        [0.20, 0.05, 0.10, 2.5, 0.15], // Europe
        [0.10, 0.05, 0.40, 0.20, 2.2], // Asia-Pacific
    ];
    let base = Mat::from_fn(5, 5, |i, j| {
        profile[i][j] * (0.9 + 0.2 * rng.uniform_f32())
    });
    // distinct per-bloc temporal signatures (China's trade grew much
    // faster than the established blocs over these decades) — these break
    // the rotational degeneracy between the small blocs, which is what
    // lets RESCALk separate all five (Fig 6b finds k=5, not 3)
    let growth_exp = [0.3f32, 0.8, 2.2, 1.0, 1.5];
    let slices = (0..N_MONTHS)
        .map(|t| {
            let tau = 0.2 + 0.8 * (t as f32 / (N_MONTHS - 1) as f32);
            // month-specific wobble on the bloc pattern
            let p = Mat::from_fn(5, 5, |i, j| {
                let g = tau.powf(0.5 * (growth_exp[i] + growth_exp[j]));
                base[(i, j)] * g * (0.95 + 0.1 * rng.uniform_f32())
            });
            let score = a.matmul(&p).matmul_t(&a);
            Mat::from_fn(n, n, |i, j| {
                if i >= 23 || j >= 23 {
                    0.0
                } else {
                    // trade volumes: bloc-driven mean with noise; the
                    // diagonal keeps its model value (domestic flows) so
                    // the tensor is exactly RESCAL-representable
                    score[(i, j)] * (0.9 + 0.2 * rng.uniform_f32())
                }
            })
        })
        .collect();
    Tensor3::from_slices(slices)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_unpadded_and_padded() {
        assert_eq!(trade_tensor(1).shape(), (23, 23, 420));
        assert_eq!(trade_tensor_padded(1, 24).shape(), (24, 24, 420));
    }

    #[test]
    fn padding_rows_are_zero() {
        let x = trade_tensor_padded(2, 24);
        for t in [0, 100, 419] {
            let s = x.slice(t);
            for j in 0..24 {
                assert_eq!(s[(23, j)], 0.0);
                assert_eq!(s[(j, 23)], 0.0);
            }
        }
    }

    #[test]
    fn volume_grows_over_time() {
        let x = trade_tensor(3);
        let early: f32 = (0..12).map(|t| x.slice(t).sum()).sum();
        let late: f32 = (408..420).map(|t| x.slice(t).sum()).sum();
        assert!(late > 2.0 * early, "early {early}, late {late}");
    }

    #[test]
    fn nonnegative_entries() {
        let x = trade_tensor(4);
        for t in [0, 200, 419] {
            let s = x.slice(t);
            for i in 0..23 {
                for j in 0..23 {
                    assert!(s[(i, j)] >= 0.0);
                }
            }
        }
    }

    #[test]
    fn communities_cover_all_countries() {
        let a = trade_communities();
        for i in 0..23 {
            let total: f32 = (0..5).map(|c| a[(i, c)]).sum();
            assert!(total > 0.0, "{} in no bloc", COUNTRIES[i]);
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(trade_tensor(9).slice(7), trade_tensor(9).slice(7));
    }
}
