//! Datasets: synthetic generators (paper §6.2.1) and reconstructions of
//! the Nations and Trade relational datasets (§6.2.2).

pub mod nations;
pub mod synthetic;
pub mod trade;

pub use nations::nations_tensor;
pub use synthetic::{planted_tensor, Planted, SyntheticSpec};
pub use trade::trade_tensor;
