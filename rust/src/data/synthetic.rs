//! Synthetic relational tensors with planted latent communities
//! (paper §6.2.1).
//!
//! Generation follows the paper exactly: latent feature matrix A with
//! Gaussian-bump columns (controllable inter-feature correlation), core
//! tensor R with Exp(1) entries, X⁰ = A R Aᵀ, plus uniform noise
//! `D ∈ [−0.01·X, +0.01·X]`, i.e. X = X⁰ ∘ (1 + U[−0.01, 0.01]).

use crate::rng::{hash_cell, hash_cell_unit, Rng};
use crate::tensor::{Csr, Mat, Tensor3};

/// A generated tensor together with its ground truth.
pub struct Planted {
    pub x: Tensor3,
    pub a_true: Mat,
    pub r_true: Tensor3,
    pub k_true: usize,
}

/// Gaussian-bump latent features: column c is a Gaussian profile over the
/// entity axis centred at a per-community location. `overlap` ∈ [0, 1]
/// controls inter-feature correlation (0 = well-separated bumps, →1 =
/// heavily overlapping, the paper's "highly correlated factors" case).
pub fn gaussian_features(n: usize, k: usize, overlap: f32, rng: &mut Rng) -> Mat {
    assert!(k >= 1 && n >= k);
    let mut a = Mat::zeros(n, k);
    let spacing = n as f32 / k as f32;
    // width grows with the overlap knob
    let sigma = spacing * (0.18 + 0.8 * overlap.clamp(0.0, 1.0));
    for c in 0..k {
        // jitter the centre a little so features aren't perfectly regular
        let centre = (c as f32 + 0.5) * spacing + rng.normal(0.0, spacing * 0.05);
        for i in 0..n {
            let d = (i as f32 - centre) / sigma;
            a[(i, c)] = (-0.5 * d * d).exp();
        }
    }
    a
}

/// Planted tensor per §6.2.1: X = (A R Aᵀ) ∘ (1 + U[−noise, +noise]).
/// The paper uses noise = 0.01 (±1%).
pub fn planted_tensor(n: usize, m: usize, k: usize, overlap: f32, seed: u64) -> Planted {
    planted_tensor_noise(n, m, k, overlap, 0.01, seed)
}

/// Planted tensor with an explicit multiplicative noise level.
pub fn planted_tensor_noise(
    n: usize,
    m: usize,
    k: usize,
    overlap: f32,
    noise: f32,
    seed: u64,
) -> Planted {
    let mut rng = Rng::new(seed);
    let a_true = gaussian_features(n, k, overlap, &mut rng);
    let r_true = Tensor3::from_slices(
        (0..m)
            .map(|_| Mat::from_fn(k, k, |_, _| rng.exponential(1.0)))
            .collect(),
    );
    let slices = (0..m)
        .map(|t| {
            let mut xt = a_true.matmul(r_true.slice(t)).matmul_t(&a_true);
            if noise > 0.0 {
                for v in xt.as_mut_slice() {
                    *v *= 1.0 + rng.uniform_range(-noise, noise);
                }
            }
            xt
        })
        .collect();
    Planted { x: Tensor3::from_slices(slices), a_true, r_true, k_true: k }
}

/// Block-community relational tensor: `k` disjoint communities of entities
/// with Exp(1) inter-community relation strengths — the sharper-structured
/// workload used by the end-to-end example and integration tests.
pub fn block_tensor(n: usize, m: usize, k: usize, noise: f32, seed: u64) -> Planted {
    let mut rng = Rng::new(seed);
    let mut a_true = Mat::zeros(n, k);
    for i in 0..n {
        let c = (i * k) / n;
        a_true[(i, c)] = 0.75 + 0.5 * rng.uniform_f32();
    }
    let r_true = Tensor3::from_slices(
        (0..m)
            .map(|_| Mat::from_fn(k, k, |_, _| rng.exponential(1.0)))
            .collect(),
    );
    let slices = (0..m)
        .map(|t| {
            let mut xt = a_true.matmul(r_true.slice(t)).matmul_t(&a_true);
            for v in xt.as_mut_slice() {
                *v *= 1.0 + rng.uniform_range(-noise, noise);
            }
            xt
        })
        .collect();
    Planted { x: Tensor3::from_slices(slices), a_true, r_true, k_true: k }
}

/// Sparse synthetic tensor: planted sparse community structure at a target
/// density, stored CSR per relation slice (the §6.3.2/Fig 10 workload).
pub fn sparse_planted(n: usize, m: usize, k: usize, density: f64, seed: u64) -> Vec<Csr> {
    let mut rng = Rng::new(seed);
    // community of each entity
    let comm: Vec<usize> = (0..n).map(|i| (i * k) / n).collect();
    let nnz_per_slice = ((n * n) as f64 * density).round().max(1.0) as usize;
    (0..m)
        .map(|_| {
            let strength = Mat::from_fn(k, k, |_, _| rng.exponential(1.0));
            let mut trips = Vec::with_capacity(nnz_per_slice);
            for _ in 0..nnz_per_slice {
                let i = rng.below(n);
                let j = rng.below(n);
                let s = strength[(comm[i], comm[j])];
                trips.push((i, j, s * (0.5 + rng.uniform_f32())));
            }
            Csr::from_triplets(n, n, trips)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Block-addressable generation (the engine's rank-local data plane)
// ---------------------------------------------------------------------------

/// RNG stream tags for [`SyntheticSpec`]; disjoint from the `1`/`2`
/// streams `DistInit::Random` uses for factor initialization.
const STREAM_CENTRES: u64 = 16;
const STREAM_CORE: u64 = 17;
const STREAM_NOISE: u64 = 18;
const STREAM_PATTERN: u64 = 19;
const STREAM_VALUE: u64 = 20;
const STREAM_STRENGTH: u64 = 21;

/// A synthetic planted tensor that any rank can materialize **one tile at
/// a time**, without the global tensor ever existing anywhere.
///
/// The generators above ([`planted_tensor`], [`sparse_planted`]) walk one
/// sequential RNG stream, so producing tile `(i, j)` requires producing
/// the whole tensor first — exactly the leader bottleneck the engine's
/// dataset plane removes. This spec instead keys every random decision by
/// its *global coordinates* (via [`hash_cell`], the per-cell analogue of
/// the `Rng::for_rank` per-block scheme): the result is grid-invariant
/// (the same global tensor for any √p) and block-addressable (rank (i, j)
/// generates exactly its rows×cols window at O(n²·m/p) cost).
///
/// Dense (`density == 1`): X_t = A·R_t·Aᵀ ∘ (1 + U[−noise, +noise]) with
/// Gaussian-bump latent features A (paper §6.2.1); the per-entry noise
/// factor is keyed by `(t, i, j)`. Sparse (`density < 1`): each cell is
/// present with probability `density` (Bernoulli, keyed by `(t, i, j)`),
/// with planted community strengths as in [`sparse_planted`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SyntheticSpec {
    /// Global entity count (tensor is n×n×m).
    pub n: usize,
    /// Relation count.
    pub m: usize,
    /// Planted latent dimension.
    pub k: usize,
    /// Cell fill probability of the CSR generator (ignored dense).
    pub density: f64,
    /// Multiplicative noise amplitude on dense entries (paper: 0.01).
    pub noise: f32,
    /// Storage/generator choice: CSR community tiles vs the dense
    /// planted tensor. Explicit rather than inferred from `density`, so
    /// a fully-filled CSR workload (`density = 1.0`) still exercises the
    /// sparse kernels.
    pub sparse: bool,
    pub seed: u64,
}

impl SyntheticSpec {
    /// Dense planted tensor at the paper's ±1% noise.
    pub fn dense(n: usize, m: usize, k: usize, seed: u64) -> Self {
        SyntheticSpec { n, m, k, density: 1.0, noise: 0.01, sparse: false, seed }
    }

    /// Sparse planted tensor at the given cell fill probability.
    pub fn sparse(n: usize, m: usize, k: usize, density: f64, seed: u64) -> Self {
        SyntheticSpec { n, m, k, density, noise: 0.0, sparse: true, seed }
    }

    pub fn is_sparse(&self) -> bool {
        self.sparse
    }

    /// Gaussian-bump centres + width, shared by every block of A. Only k
    /// draws — every rank recomputes them instead of communicating.
    fn centres(&self) -> (Vec<f32>, f32) {
        let mut rng = Rng::for_rank(self.seed, 0, STREAM_CENTRES);
        let spacing = self.n as f32 / self.k as f32;
        let sigma = spacing * 0.18;
        let centres = (0..self.k)
            .map(|c| (c as f32 + 0.5) * spacing + rng.normal(0.0, spacing * 0.05))
            .collect();
        (centres, sigma)
    }

    /// Rows `r0..r1` of the planted latent feature matrix A (n×k). Each
    /// entry is a pure function of its global row index, so any block of
    /// rows can be produced independently and bit-identically.
    pub fn a_block(&self, r0: usize, r1: usize) -> Mat {
        let (centres, sigma) = self.centres();
        Mat::from_fn(r1 - r0, self.k, |i, c| {
            let d = ((r0 + i) as f32 - centres[c]) / sigma;
            (-0.5 * d * d).exp()
        })
    }

    /// The planted core tensor R (k×k×m), replicated on every rank.
    pub fn core(&self) -> Tensor3 {
        let mut rng = Rng::for_rank(self.seed, 0, STREAM_CORE);
        Tensor3::from_slices(
            (0..self.m)
                .map(|_| Mat::from_fn(self.k, self.k, |_, _| rng.exponential(1.0)))
                .collect(),
        )
    }

    /// Community strength matrix of relation slice `t` (sparse path).
    fn strengths(&self, t: usize) -> Mat {
        let mut rng = Rng::for_rank(self.seed, t, STREAM_STRENGTH);
        Mat::from_fn(self.k, self.k, |_, _| rng.exponential(1.0))
    }

    /// Dense tile `rows r0..r1 × cols c0..c1 × m`. `dense_tile(0, n, 0, n)`
    /// is the leader-materialized tensor; any sub-tile of it equals the
    /// directly generated sub-tile (asserted in tests).
    pub fn dense_tile(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Tensor3 {
        assert!(r0 <= r1 && r1 <= self.n && c0 <= c1 && c1 <= self.n, "tile out of range");
        let a_rows = self.a_block(r0, r1);
        let a_cols = self.a_block(c0, c1);
        let r = self.core();
        let slices = (0..self.m)
            .map(|t| {
                let mut xt = a_rows.matmul(r.slice(t)).matmul_t(&a_cols);
                if self.noise > 0.0 {
                    for i in 0..xt.rows() {
                        for j in 0..xt.cols() {
                            let u = hash_cell_unit(self.seed, STREAM_NOISE, t, r0 + i, c0 + j);
                            xt[(i, j)] *= 1.0 + self.noise * (2.0 * u - 1.0);
                        }
                    }
                }
                xt
            })
            .collect();
        Tensor3::from_slices(slices)
    }

    /// Sparse CSR tile `rows r0..r1 × cols c0..c1`, one CSR per relation
    /// slice. Cell presence and value are keyed by global coordinates, so
    /// the union of a grid's tiles is exactly `sparse_tile(0, n, 0, n)`.
    pub fn sparse_tile(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Vec<Csr> {
        assert!(r0 <= r1 && r1 <= self.n && c0 <= c1 && c1 <= self.n, "tile out of range");
        let comm: fn(usize, usize, usize) -> usize = |i, k, n| (i * k) / n;
        let threshold = if self.density >= 1.0 {
            u64::MAX
        } else {
            (self.density * u64::MAX as f64) as u64
        };
        (0..self.m)
            .map(|t| {
                let strength = self.strengths(t);
                let mut trips = Vec::new();
                for i in r0..r1 {
                    let ci = comm(i, self.k, self.n);
                    for j in c0..c1 {
                        if hash_cell(self.seed, STREAM_PATTERN, t, i, j) < threshold {
                            let u = hash_cell_unit(self.seed, STREAM_VALUE, t, i, j);
                            let s = strength[(ci, comm(j, self.k, self.n))];
                            trips.push((i - r0, j - c0, s * (0.5 + u)));
                        }
                    }
                }
                Csr::from_triplets(r1 - r0, c1 - c0, trips)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::pearson::pearson;
    use crate::tensor::ops::is_nonnegative;

    #[test]
    fn planted_is_nonnegative_and_shaped() {
        let p = planted_tensor(32, 4, 5, 0.0, 1);
        assert_eq!(p.x.shape(), (32, 32, 4));
        assert_eq!(p.a_true.shape(), (32, 5));
        for t in 0..4 {
            assert!(is_nonnegative(p.x.slice(t)));
        }
    }

    #[test]
    fn noise_is_within_one_percent() {
        let p = planted_tensor(16, 2, 3, 0.0, 2);
        // rebuild noiseless and compare ratio
        let clean = {
            let s = (0..2)
                .map(|t| p.a_true.matmul(p.r_true.slice(t)).matmul_t(&p.a_true))
                .collect();
            Tensor3::from_slices(s)
        };
        for t in 0..2 {
            for (got, want) in p.x.slice(t).as_slice().iter().zip(clean.slice(t).as_slice()) {
                if *want > 1e-6 {
                    let ratio = got / want;
                    assert!(ratio > 0.989 && ratio < 1.011, "ratio={ratio}");
                }
            }
        }
    }

    #[test]
    fn low_overlap_features_weakly_correlated() {
        let mut rng = Rng::new(3);
        let a = gaussian_features(128, 4, 0.0, &mut rng);
        for i in 0..4 {
            for j in (i + 1)..4 {
                let r = pearson(&a.col(i), &a.col(j));
                assert!(r < 0.35, "features {i},{j} correlated r={r}");
            }
        }
    }

    #[test]
    fn high_overlap_raises_correlation() {
        let mut rng = Rng::new(4);
        let lo = gaussian_features(128, 4, 0.0, &mut rng);
        let hi = gaussian_features(128, 4, 0.9, &mut rng);
        let mean_corr = |a: &Mat| {
            let mut s = 0.0;
            let mut c = 0;
            for i in 0..4 {
                for j in (i + 1)..4 {
                    s += pearson(&a.col(i), &a.col(j)).abs();
                    c += 1;
                }
            }
            s / c as f32
        };
        assert!(mean_corr(&hi) > mean_corr(&lo) + 0.2);
    }

    #[test]
    fn block_tensor_has_disjoint_communities() {
        let p = block_tensor(24, 2, 4, 0.01, 5);
        // each entity row of A_true has exactly one nonzero
        for i in 0..24 {
            let nz = (0..4).filter(|&c| p.a_true[(i, c)] > 0.0).count();
            assert_eq!(nz, 1);
        }
    }

    #[test]
    fn sparse_planted_density() {
        let xs = sparse_planted(64, 3, 4, 0.05, 6);
        assert_eq!(xs.len(), 3);
        for s in &xs {
            let d = s.density();
            assert!(d > 0.03 && d <= 0.06, "density={d}");
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let a = planted_tensor(16, 2, 3, 0.0, 7);
        let b = planted_tensor(16, 2, 3, 0.0, 7);
        assert_eq!(a.x.slice(0), b.x.slice(0));
    }

    /// The rank-local generation contract: a directly generated sub-tile
    /// equals the same window cut out of the leader-materialized tensor,
    /// for every tile of a 2×2 and a ragged 3×3 grid.
    #[test]
    fn dense_tiles_match_leader_materialization() {
        let spec = SyntheticSpec::dense(14, 2, 3, 900);
        let full = spec.dense_tile(0, 14, 0, 14);
        for q in [2usize, 3] {
            let grid = crate::comm::Grid::new(q * q);
            for row in 0..q {
                for col in 0..q {
                    let (r0, r1) = grid.chunk(14, row);
                    let (c0, c1) = grid.chunk(14, col);
                    let direct = spec.dense_tile(r0, r1, c0, c1);
                    let cut = full.tile(r0, r1, c0, c1);
                    for t in 0..2 {
                        crate::testing::assert_close(
                            direct.slice(t).as_slice(),
                            cut.slice(t).as_slice(),
                            1e-5,
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sparse_tiles_match_leader_materialization() {
        let spec = SyntheticSpec::sparse(20, 3, 4, 0.3, 901);
        let full = spec.sparse_tile(0, 20, 0, 20);
        let grid = crate::comm::Grid::new(4);
        for row in 0..2 {
            for col in 0..2 {
                let (r0, r1) = grid.chunk(20, row);
                let (c0, c1) = grid.chunk(20, col);
                let direct = spec.sparse_tile(r0, r1, c0, c1);
                for t in 0..3 {
                    assert_eq!(direct[t], full[t].tile(r0, r1, c0, c1), "slice {t}");
                }
            }
        }
        // nonzeros actually land in every tile of this density
        assert!(full.iter().all(|s| s.nnz() > 0));
    }

    #[test]
    fn synthetic_spec_is_grid_invariant_and_plausible() {
        let spec = SyntheticSpec::sparse(40, 2, 4, 0.1, 902);
        let full = spec.sparse_tile(0, 40, 0, 40);
        for s in &full {
            let d = s.density();
            assert!(d > 0.06 && d < 0.14, "density={d}");
        }
        let dense_spec = SyntheticSpec::dense(16, 2, 3, 903);
        let x = dense_spec.dense_tile(0, 16, 0, 16);
        assert_eq!(x.shape(), (16, 16, 2));
        for t in 0..2 {
            assert!(is_nonnegative(x.slice(t)));
        }
        // noise stays within the ±1% band relative to the noiseless product
        let clean = SyntheticSpec { noise: 0.0, ..dense_spec }.dense_tile(0, 16, 0, 16);
        for t in 0..2 {
            for (got, want) in x.slice(t).as_slice().iter().zip(clean.slice(t).as_slice()) {
                if *want > 1e-6 {
                    let ratio = got / want;
                    assert!(ratio > 0.989 && ratio < 1.011, "ratio={ratio}");
                }
            }
        }
    }
}
