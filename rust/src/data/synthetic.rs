//! Synthetic relational tensors with planted latent communities
//! (paper §6.2.1).
//!
//! Generation follows the paper exactly: latent feature matrix A with
//! Gaussian-bump columns (controllable inter-feature correlation), core
//! tensor R with Exp(1) entries, X⁰ = A R Aᵀ, plus uniform noise
//! `D ∈ [−0.01·X, +0.01·X]`, i.e. X = X⁰ ∘ (1 + U[−0.01, 0.01]).

use crate::rng::Rng;
use crate::tensor::{Csr, Mat, Tensor3};

/// A generated tensor together with its ground truth.
pub struct Planted {
    pub x: Tensor3,
    pub a_true: Mat,
    pub r_true: Tensor3,
    pub k_true: usize,
}

/// Gaussian-bump latent features: column c is a Gaussian profile over the
/// entity axis centred at a per-community location. `overlap` ∈ [0, 1]
/// controls inter-feature correlation (0 = well-separated bumps, →1 =
/// heavily overlapping, the paper's "highly correlated factors" case).
pub fn gaussian_features(n: usize, k: usize, overlap: f32, rng: &mut Rng) -> Mat {
    assert!(k >= 1 && n >= k);
    let mut a = Mat::zeros(n, k);
    let spacing = n as f32 / k as f32;
    // width grows with the overlap knob
    let sigma = spacing * (0.18 + 0.8 * overlap.clamp(0.0, 1.0));
    for c in 0..k {
        // jitter the centre a little so features aren't perfectly regular
        let centre = (c as f32 + 0.5) * spacing + rng.normal(0.0, spacing * 0.05);
        for i in 0..n {
            let d = (i as f32 - centre) / sigma;
            a[(i, c)] = (-0.5 * d * d).exp();
        }
    }
    a
}

/// Planted tensor per §6.2.1: X = (A R Aᵀ) ∘ (1 + U[−noise, +noise]).
/// The paper uses noise = 0.01 (±1%).
pub fn planted_tensor(n: usize, m: usize, k: usize, overlap: f32, seed: u64) -> Planted {
    planted_tensor_noise(n, m, k, overlap, 0.01, seed)
}

/// Planted tensor with an explicit multiplicative noise level.
pub fn planted_tensor_noise(
    n: usize,
    m: usize,
    k: usize,
    overlap: f32,
    noise: f32,
    seed: u64,
) -> Planted {
    let mut rng = Rng::new(seed);
    let a_true = gaussian_features(n, k, overlap, &mut rng);
    let r_true = Tensor3::from_slices(
        (0..m)
            .map(|_| Mat::from_fn(k, k, |_, _| rng.exponential(1.0)))
            .collect(),
    );
    let slices = (0..m)
        .map(|t| {
            let mut xt = a_true.matmul(r_true.slice(t)).matmul_t(&a_true);
            if noise > 0.0 {
                for v in xt.as_mut_slice() {
                    *v *= 1.0 + rng.uniform_range(-noise, noise);
                }
            }
            xt
        })
        .collect();
    Planted { x: Tensor3::from_slices(slices), a_true, r_true, k_true: k }
}

/// Block-community relational tensor: `k` disjoint communities of entities
/// with Exp(1) inter-community relation strengths — the sharper-structured
/// workload used by the end-to-end example and integration tests.
pub fn block_tensor(n: usize, m: usize, k: usize, noise: f32, seed: u64) -> Planted {
    let mut rng = Rng::new(seed);
    let mut a_true = Mat::zeros(n, k);
    for i in 0..n {
        let c = (i * k) / n;
        a_true[(i, c)] = 0.75 + 0.5 * rng.uniform_f32();
    }
    let r_true = Tensor3::from_slices(
        (0..m)
            .map(|_| Mat::from_fn(k, k, |_, _| rng.exponential(1.0)))
            .collect(),
    );
    let slices = (0..m)
        .map(|t| {
            let mut xt = a_true.matmul(r_true.slice(t)).matmul_t(&a_true);
            for v in xt.as_mut_slice() {
                *v *= 1.0 + rng.uniform_range(-noise, noise);
            }
            xt
        })
        .collect();
    Planted { x: Tensor3::from_slices(slices), a_true, r_true, k_true: k }
}

/// Sparse synthetic tensor: planted sparse community structure at a target
/// density, stored CSR per relation slice (the §6.3.2/Fig 10 workload).
pub fn sparse_planted(n: usize, m: usize, k: usize, density: f64, seed: u64) -> Vec<Csr> {
    let mut rng = Rng::new(seed);
    // community of each entity
    let comm: Vec<usize> = (0..n).map(|i| (i * k) / n).collect();
    let nnz_per_slice = ((n * n) as f64 * density).round().max(1.0) as usize;
    (0..m)
        .map(|_| {
            let strength = Mat::from_fn(k, k, |_, _| rng.exponential(1.0));
            let mut trips = Vec::with_capacity(nnz_per_slice);
            for _ in 0..nnz_per_slice {
                let i = rng.below(n);
                let j = rng.below(n);
                let s = strength[(comm[i], comm[j])];
                trips.push((i, j, s * (0.5 + rng.uniform_f32())));
            }
            Csr::from_triplets(n, n, trips)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::pearson::pearson;
    use crate::tensor::ops::is_nonnegative;

    #[test]
    fn planted_is_nonnegative_and_shaped() {
        let p = planted_tensor(32, 4, 5, 0.0, 1);
        assert_eq!(p.x.shape(), (32, 32, 4));
        assert_eq!(p.a_true.shape(), (32, 5));
        for t in 0..4 {
            assert!(is_nonnegative(p.x.slice(t)));
        }
    }

    #[test]
    fn noise_is_within_one_percent() {
        let p = planted_tensor(16, 2, 3, 0.0, 2);
        // rebuild noiseless and compare ratio
        let clean = {
            let s = (0..2)
                .map(|t| p.a_true.matmul(p.r_true.slice(t)).matmul_t(&p.a_true))
                .collect();
            Tensor3::from_slices(s)
        };
        for t in 0..2 {
            for (got, want) in p.x.slice(t).as_slice().iter().zip(clean.slice(t).as_slice()) {
                if *want > 1e-6 {
                    let ratio = got / want;
                    assert!(ratio > 0.989 && ratio < 1.011, "ratio={ratio}");
                }
            }
        }
    }

    #[test]
    fn low_overlap_features_weakly_correlated() {
        let mut rng = Rng::new(3);
        let a = gaussian_features(128, 4, 0.0, &mut rng);
        for i in 0..4 {
            for j in (i + 1)..4 {
                let r = pearson(&a.col(i), &a.col(j));
                assert!(r < 0.35, "features {i},{j} correlated r={r}");
            }
        }
    }

    #[test]
    fn high_overlap_raises_correlation() {
        let mut rng = Rng::new(4);
        let lo = gaussian_features(128, 4, 0.0, &mut rng);
        let hi = gaussian_features(128, 4, 0.9, &mut rng);
        let mean_corr = |a: &Mat| {
            let mut s = 0.0;
            let mut c = 0;
            for i in 0..4 {
                for j in (i + 1)..4 {
                    s += pearson(&a.col(i), &a.col(j)).abs();
                    c += 1;
                }
            }
            s / c as f32
        };
        assert!(mean_corr(&hi) > mean_corr(&lo) + 0.2);
    }

    #[test]
    fn block_tensor_has_disjoint_communities() {
        let p = block_tensor(24, 2, 4, 0.01, 5);
        // each entity row of A_true has exactly one nonzero
        for i in 0..24 {
            let nz = (0..4).filter(|&c| p.a_true[(i, c)] > 0.0).count();
            assert_eq!(nz, 1);
        }
    }

    #[test]
    fn sparse_planted_density() {
        let xs = sparse_planted(64, 3, 4, 0.05, 6);
        assert_eq!(xs.len(), 3);
        for s in &xs {
            let d = s.density();
            assert!(d > 0.03 && d <= 0.06, "density={d}");
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let a = planted_tensor(16, 2, 3, 0.0, 7);
        let b = planted_tensor(16, 2, 3, 0.0, 7);
        assert_eq!(a.x.slice(0), b.x.slice(0));
    }
}
