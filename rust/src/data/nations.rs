//! Reconstruction of the *Nations* relational dataset (Kemp et al. 2006;
//! paper §6.2.2): 14 countries × 14 × 56 binary relations.
//!
//! Substitution note (DESIGN.md §3): the original data file is not
//! shipped; we regenerate a binary tensor with the block structure the
//! paper reports recovering — four latent communities (Eastern bloc,
//! non-aligned movement, Western powers, and an overlapping mixed group) —
//! with per-relation interaction patterns between those communities. The
//! experiment this feeds (Fig 6a/6c/6e: k=4 recovery + community
//! extraction + R-slice interaction graphs) depends only on that
//! generative structure.

use crate::rng::Rng;
use crate::tensor::{Mat, Tensor3};

/// The 14 nations, in the paper's order.
pub const NATIONS: [&str; 14] = [
    "Brazil", "Burma", "China", "Cuba", "Egypt", "India", "Indonesia", "Israel", "Jordan",
    "Netherlands", "Poland", "USSR", "UK", "USA",
];

/// Number of relation slices in the original dataset.
pub const N_RELATIONS: usize = 56;

/// Ground-truth latent community memberships used by the generator
/// (paper Fig 6c): 14×4, overlapping (Egypt/India/Israel/Poland/UK appear
/// in two communities).
pub fn nations_communities() -> Mat {
    let mut a = Mat::zeros(14, 4);
    let set = |a: &mut Mat, name: &str, c: usize, w: f32| {
        let i = NATIONS.iter().position(|&n| n == name).unwrap();
        a[(i, c)] = w;
    };
    // community-1: Eastern bloc
    for n in ["China", "Cuba", "Poland", "USSR"] {
        set(&mut a, n, 0, 1.0);
    }
    // community-2: non-aligned
    for n in ["Burma", "Egypt", "India", "Indonesia", "Israel", "Jordan"] {
        set(&mut a, n, 1, 1.0);
    }
    // community-3: Western powers
    for n in ["USA", "UK"] {
        set(&mut a, n, 2, 1.0);
    }
    // community-4: mixed/overlapping group
    for n in ["Brazil", "Egypt", "India", "Israel", "Netherlands", "Poland", "UK"] {
        set(&mut a, n, 3, 0.8);
    }
    a
}

/// Generate the 14×14×56 binary tensor.
///
/// Each relation t draws a 4×4 community-interaction pattern (a few strong
/// directed entries, e.g. "exports", "treaties"), and an edge (i, j)
/// exists with probability driven by `aᵢ·P·aⱼ`.
pub fn nations_tensor(seed: u64) -> Tensor3 {
    let mut rng = Rng::new(seed);
    let a = nations_communities();
    let slices = (0..N_RELATIONS)
        .map(|_| {
            // sparse directed interaction pattern between communities
            let mut p = Mat::zeros(4, 4);
            let strong = 1 + rng.below(3); // 1..3 strong community pairs
            for _ in 0..strong {
                p[(rng.below(4), rng.below(4))] = 0.7 + 0.3 * rng.uniform_f32();
            }
            // mild within-community baseline
            for c in 0..4 {
                if rng.uniform_f32() < 0.4 {
                    p[(c, c)] = p[(c, c)].max(0.4 + 0.3 * rng.uniform_f32());
                }
            }
            let score = a.matmul(&p).matmul_t(&a);
            Mat::from_fn(14, 14, |i, j| {
                if i == j {
                    return 0.0;
                }
                let prob = score[(i, j)].min(0.95);
                if rng.uniform_f32() < prob {
                    1.0
                } else {
                    0.0
                }
            })
        })
        .collect();
    Tensor3::from_slices(slices)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_is_14x14x56() {
        let x = nations_tensor(1);
        assert_eq!(x.shape(), (14, 14, 56));
    }

    #[test]
    fn binary_entries_no_self_loops() {
        let x = nations_tensor(2);
        for t in 0..56 {
            let s = x.slice(t);
            for i in 0..14 {
                assert_eq!(s[(i, i)], 0.0, "self loop at slice {t}");
                for j in 0..14 {
                    let v = s[(i, j)];
                    assert!(v == 0.0 || v == 1.0, "non-binary {v}");
                }
            }
        }
    }

    #[test]
    fn communities_shape_and_membership() {
        let a = nations_communities();
        assert_eq!(a.shape(), (14, 4));
        // USSR in community 0 only
        let ussr = NATIONS.iter().position(|&n| n == "USSR").unwrap();
        assert!(a[(ussr, 0)] > 0.0);
        assert_eq!(a[(ussr, 1)], 0.0);
        // UK overlaps communities 2 and 3
        let uk = NATIONS.iter().position(|&n| n == "UK").unwrap();
        assert!(a[(uk, 2)] > 0.0 && a[(uk, 3)] > 0.0);
    }

    #[test]
    fn eastern_bloc_ties_exceed_cross_bloc() {
        // aggregate over relations: edges within community 0 should be
        // denser than edges between community 0 and community 2 members
        let x = nations_tensor(3);
        let idx = |n: &str| NATIONS.iter().position(|&m| m == n).unwrap();
        let bloc = [idx("China"), idx("Cuba"), idx("Poland"), idx("USSR")];
        let west = [idx("USA"), idx("UK")];
        let mut within = 0.0;
        let mut wc = 0;
        let mut cross = 0.0;
        let mut cc = 0;
        for t in 0..56 {
            let s = x.slice(t);
            for &i in &bloc {
                for &j in &bloc {
                    if i != j {
                        within += s[(i, j)];
                        wc += 1;
                    }
                }
                for &j in &west {
                    cross += s[(i, j)];
                    cc += 1;
                }
            }
        }
        let within_rate = within / wc as f32;
        let cross_rate = cross / cc as f32;
        assert!(
            within_rate > cross_rate,
            "within {within_rate} should exceed cross {cross_rate}"
        );
    }

    #[test]
    fn deterministic() {
        assert_eq!(nations_tensor(7).slice(10), nations_tensor(7).slice(10));
    }
}
