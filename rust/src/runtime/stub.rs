//! Offline stub runtime (the default, `--features pjrt` absent).
//!
//! Parses the artifact manifest so tooling (`drescal artifacts`, manifest
//! tests) works, but holds no compiled executables: every `execute` call
//! answers `Ok(None)` — the shared "no artifact for this shape" signal —
//! so the XLA backend falls back to the native GEMM for everything.

use std::path::{Path, PathBuf};

use super::Manifest;
use crate::error::Result;
use crate::tensor::Mat;

/// Stub artifact runtime: manifest metadata only, no execution.
pub struct Runtime {
    manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Parse `dir/manifest.json`; no artifacts are compiled.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        Ok(Runtime { manifest, dir })
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Platform name; marks the build as execution-less.
    pub fn platform(&self) -> String {
        "stub (build with --features pjrt for PJRT execution)".to_string()
    }

    /// Number of loaded executables (always 0 in the stub).
    pub fn len(&self) -> usize {
        0
    }

    pub fn is_empty(&self) -> bool {
        true
    }

    /// Manifest entries parsed from disk (metadata is still available).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The stub supports no shapes.
    pub fn supports(&self, _kind: &str, _inputs: &[&Mat]) -> bool {
        false
    }

    /// Always `Ok(None)`: caller falls back to the native backend.
    pub fn execute(&self, _kind: &str, _inputs: &[&Mat]) -> Result<Option<Mat>> {
        Ok(None)
    }

    /// Always `Ok(None)`: caller falls back to the native backend.
    pub fn execute_multi(&self, _kind: &str, _inputs: &[&Mat]) -> Result<Option<Vec<Mat>>> {
        Ok(None)
    }
}
