//! Real PJRT runtime (`--features pjrt`): compile each HLO-text artifact
//! once on the PJRT CPU client and expose typed execution over
//! [`crate::tensor::Mat`]. Requires the prebuilt `xla` bindings shipped in
//! the rust_pallas toolchain image.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::{key_of, Manifest, OpKey};
use crate::err;
use crate::error::{Context as _, Result};
use crate::tensor::Mat;

/// A compiled-and-loaded artifact set on the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<OpKey, xla::PjRtLoadedExecutable>,
    manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact listed in `dir/manifest.json` and compile it.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| err!("PJRT client: {e:?}"))?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
            )
            .map_err(|e| err!("parsing HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| err!("compiling {}: {e:?}", path.display()))?;
            executables.insert(
                OpKey { kind: entry.kind.clone(), shapes: entry.shapes.clone() },
                exe,
            );
        }
        Ok(Runtime { client, executables, manifest, dir })
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (e.g. "cpu" / "Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of loaded executables.
    pub fn len(&self) -> usize {
        self.executables.len()
    }

    pub fn is_empty(&self) -> bool {
        self.executables.is_empty()
    }

    /// Manifest entries parsed from disk.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True if an executable exists for this op kind and input shapes.
    pub fn supports(&self, kind: &str, inputs: &[&Mat]) -> bool {
        self.executables.contains_key(&key_of(kind, inputs))
    }

    /// Execute `kind` on the given inputs. Returns `None` when no artifact
    /// matches the shapes (caller falls back to the native backend);
    /// errors only on real PJRT failures.
    pub fn execute(&self, kind: &str, inputs: &[&Mat]) -> Result<Option<Mat>> {
        match self.execute_multi(kind, inputs)? {
            None => Ok(None),
            Some(mut outs) => {
                if outs.len() != 1 {
                    bail_arity(outs.len())?;
                }
                Ok(Some(outs.remove(0)))
            }
        }
    }

    /// Execute an artifact with a tuple of outputs (fused segments).
    pub fn execute_multi(&self, kind: &str, inputs: &[&Mat]) -> Result<Option<Vec<Mat>>> {
        let exe = match self.executables.get(&key_of(kind, inputs)) {
            Some(e) => e,
            None => return Ok(None),
        };
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|m| {
                xla::Literal::vec1(m.as_slice())
                    .reshape(&[m.rows() as i64, m.cols() as i64])
                    .map_err(|e| err!("literal reshape: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| err!("PJRT execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("PJRT sync: {e:?}"))?;
        let elems = result.to_tuple().map_err(|e| err!("PJRT tuple: {e:?}"))?;
        let mut outs = Vec::with_capacity(elems.len());
        for elem in elems {
            let shape = elem.array_shape().map_err(|e| err!("PJRT shape: {e:?}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            if dims.len() != 2 {
                return Err(err!("expected rank-2 output, got {:?}", dims));
            }
            let data = elem.to_vec::<f32>().map_err(|e| err!("PJRT to_vec: {e:?}"))?;
            outs.push(Mat::from_vec(dims[0], dims[1], data));
        }
        Ok(Some(outs))
    }
}

fn bail_arity(n: usize) -> Result<()> {
    Err(err!("expected 1 output, got {n}")).context("artifact execution")
}
