//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! `python/compile/aot.py` lowers the L2 JAX segments (which call the L1
//! Pallas kernels) to HLO **text** — the interchange format that survives
//! the jax≥0.5 / xla_extension 0.5.1 proto-id mismatch — plus a
//! `manifest.json` describing each op's input shapes. This module compiles
//! each artifact once on the PJRT CPU client and exposes typed execution
//! over [`crate::tensor::Mat`].

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::Json;
use crate::tensor::Mat;

/// Key identifying one compiled executable: op kind + exact input shapes.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct OpKey {
    pub kind: String,
    pub shapes: Vec<(usize, usize)>,
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub kind: String,
    pub file: String,
    pub shapes: Vec<(usize, usize)>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let ops = v
            .get("ops")
            .and_then(|o| o.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'ops' array"))?;
        let mut entries = Vec::new();
        for op in ops {
            let kind = op
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or_else(|| anyhow!("op missing 'kind'"))?
                .to_string();
            let file = op
                .get("file")
                .and_then(|k| k.as_str())
                .ok_or_else(|| anyhow!("op missing 'file'"))?
                .to_string();
            let shapes = op
                .get("shapes")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("op missing 'shapes'"))?
                .iter()
                .map(|sh| {
                    let dims = sh.as_arr().ok_or_else(|| anyhow!("shape not array"))?;
                    if dims.len() != 2 {
                        return Err(anyhow!("only rank-2 inputs supported"));
                    }
                    Ok((
                        dims[0].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                        dims[1].as_usize().ok_or_else(|| anyhow!("bad dim"))?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            entries.push(ManifestEntry { kind, file, shapes });
        }
        Ok(Manifest { entries })
    }
}

/// A compiled-and-loaded artifact set on the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<OpKey, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact listed in `dir/manifest.json` and compile it.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            executables.insert(
                OpKey { kind: entry.kind.clone(), shapes: entry.shapes.clone() },
                exe,
            );
        }
        Ok(Runtime { client, executables, dir })
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (e.g. "cpu" / "Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of loaded executables.
    pub fn len(&self) -> usize {
        self.executables.len()
    }

    pub fn is_empty(&self) -> bool {
        self.executables.is_empty()
    }

    /// True if an executable exists for this op kind and input shapes.
    pub fn supports(&self, kind: &str, inputs: &[&Mat]) -> bool {
        self.executables.contains_key(&key_of(kind, inputs))
    }

    /// Execute `kind` on the given inputs. Returns `None` when no artifact
    /// matches the shapes (caller falls back to the native backend);
    /// errors only on real PJRT failures.
    pub fn execute(&self, kind: &str, inputs: &[&Mat]) -> Result<Option<Mat>> {
        match self.execute_multi(kind, inputs)? {
            None => Ok(None),
            Some(mut outs) => {
                if outs.len() != 1 {
                    return Err(anyhow!("expected 1 output, got {}", outs.len()));
                }
                Ok(Some(outs.remove(0)))
            }
        }
    }

    /// Execute an artifact with a tuple of outputs (fused segments).
    pub fn execute_multi(&self, kind: &str, inputs: &[&Mat]) -> Result<Option<Vec<Mat>>> {
        let exe = match self.executables.get(&key_of(kind, inputs)) {
            Some(e) => e,
            None => return Ok(None),
        };
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|m| {
                xla::Literal::vec1(m.as_slice())
                    .reshape(&[m.rows() as i64, m.cols() as i64])
                    .map_err(|e| anyhow!("literal reshape: {e:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let elems = result.to_tuple()?;
        let mut outs = Vec::with_capacity(elems.len());
        for elem in elems {
            let shape = elem.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            if dims.len() != 2 {
                return Err(anyhow!("expected rank-2 output, got {:?}", dims));
            }
            let data = elem.to_vec::<f32>()?;
            outs.push(Mat::from_vec(dims[0], dims[1], data));
        }
        Ok(Some(outs))
    }
}

fn key_of(kind: &str, inputs: &[&Mat]) -> OpKey {
    OpKey { kind: kind.to_string(), shapes: inputs.iter().map(|m| m.shape()).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
            "dtype": "f32",
            "ops": [
                {"kind": "matmul", "file": "matmul_4x4.hlo.txt", "shapes": [[4, 4], [4, 4]]},
                {"kind": "gram", "file": "gram_8x2.hlo.txt", "shapes": [[8, 2]]}
            ]
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[0].kind, "matmul");
        assert_eq!(m.entries[0].shapes, vec![(4, 4), (4, 4)]);
        assert_eq!(m.entries[1].shapes, vec![(8, 2)]);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"ops": [{"kind": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }

    // Execution against real artifacts is covered by the integration test
    // `rust/tests/xla_runtime.rs`, which requires `make artifacts` first.
}
