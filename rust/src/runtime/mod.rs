//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! `python/compile/aot.py` lowers the L2 JAX segments (which call the L1
//! Pallas kernels) to HLO **text** — the interchange format that survives
//! the jax≥0.5 / xla_extension 0.5.1 proto-id mismatch — plus a
//! `manifest.json` describing each op's input shapes.
//!
//! Two implementations sit behind the same [`Runtime`] API:
//! * with `--features pjrt` (requires the prebuilt `xla` bindings from the
//!   rust_pallas toolchain image): each artifact compiles once on the PJRT
//!   CPU client and executes for real;
//! * by default (offline checkout): a stub that parses the manifest but
//!   serves no executables, so [`crate::backend::xla::XlaBackend`] reports
//!   every shape as unsupported and transparently falls back to the native
//!   GEMM path. `execute` returning `Ok(None)` is the same "no artifact
//!   for this shape" signal both implementations share.

use std::path::Path;

use crate::error::{Context as _, Result};
use crate::json::Json;
use crate::{bail, err};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// Key identifying one compiled executable: op kind + exact input shapes.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct OpKey {
    pub kind: String,
    pub shapes: Vec<(usize, usize)>,
}

/// One manifest entry.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub kind: String,
    pub file: String,
    pub shapes: Vec<(usize, usize)>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| err!("manifest JSON: {e}"))?;
        let ops = v
            .get("ops")
            .and_then(|o| o.as_arr())
            .ok_or_else(|| err!("manifest missing 'ops' array"))?;
        let mut entries = Vec::new();
        for op in ops {
            let kind = op
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or_else(|| err!("op missing 'kind'"))?
                .to_string();
            let file = op
                .get("file")
                .and_then(|k| k.as_str())
                .ok_or_else(|| err!("op missing 'file'"))?
                .to_string();
            let shapes = op
                .get("shapes")
                .and_then(|s| s.as_arr())
                .ok_or_else(|| err!("op missing 'shapes'"))?
                .iter()
                .map(|sh| {
                    let dims = sh.as_arr().ok_or_else(|| err!("shape not array"))?;
                    if dims.len() != 2 {
                        bail!("only rank-2 inputs supported");
                    }
                    Ok((
                        dims[0].as_usize().ok_or_else(|| err!("bad dim"))?,
                        dims[1].as_usize().ok_or_else(|| err!("bad dim"))?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            entries.push(ManifestEntry { kind, file, shapes });
        }
        Ok(Manifest { entries })
    }
}

/// Build the lookup key for an op invocation.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub(crate) fn key_of(kind: &str, inputs: &[&crate::tensor::Mat]) -> OpKey {
    OpKey { kind: kind.to_string(), shapes: inputs.iter().map(|m| m.shape()).collect() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
            "dtype": "f32",
            "ops": [
                {"kind": "matmul", "file": "matmul_4x4.hlo.txt", "shapes": [[4, 4], [4, 4]]},
                {"kind": "gram", "file": "gram_8x2.hlo.txt", "shapes": [[8, 2]]}
            ]
        }"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[0].kind, "matmul");
        assert_eq!(m.entries[0].shapes, vec![(4, 4), (4, 4)]);
        assert_eq!(m.entries[1].shapes, vec![(8, 2)]);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"ops": [{"kind": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }

    // Execution against real artifacts is covered by the integration test
    // `rust/tests/xla_runtime.rs`, which requires `make artifacts` and the
    // `pjrt` feature.
}
