//! Crate-local error type: the offline crate set has no `anyhow`, so this
//! module provides the small slice of it the system needs — a string-backed
//! error with context chaining, a `Result` alias, and the `bail!`/`err!`
//! macros. Validation layers ([`crate::config::RunConfig`], the
//! [`crate::engine::Engine`] job API) return these errors instead of
//! panicking so callers can surface actionable messages.

use std::fmt;

/// A human-readable error with an optional context chain, rendered
/// outermost-first (`loading config: reading run.json: No such file`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer.
    pub fn context(self, c: impl fmt::Display) -> Self {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error { msg: s.to_string() }
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to any `Result` whose error is displayable.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context(self, c: impl fmt::Display) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, c: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string (the `anyhow!` analogue).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context_chain() {
        let e = Error::msg("root cause").context("outer");
        assert_eq!(e.to_string(), "outer: root cause");
        assert_eq!(format!("{e:#}"), "outer: root cause");
    }

    #[test]
    fn result_context_trait() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("while testing").unwrap_err();
        assert_eq!(e.to_string(), "while testing: inner");
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3: inner");
    }

    #[test]
    fn macros_build_errors() {
        fn fails(x: usize) -> crate::error::Result<()> {
            if x > 2 {
                bail!("x too large: {x}");
            }
            Err(err!("always fails ({x})"))
        }
        assert_eq!(fails(5).unwrap_err().to_string(), "x too large: 5");
        assert_eq!(fails(1).unwrap_err().to_string(), "always fails (1)");
    }

    #[test]
    fn io_error_converts() {
        let r: Result<String> = std::fs::read_to_string("/nonexistent/drescal")
            .map_err(Error::from);
        assert!(r.is_err());
    }
}
