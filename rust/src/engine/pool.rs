//! The persistent rank pool behind [`super::Engine`].
//!
//! One OS thread per virtual rank, spawned **once** per engine: each
//! worker owns its [`RankCtx`] (grid coordinates + communicator handles),
//! builds its compute backend exactly once, and keeps a cache of resident
//! dataset tiles (its block of each registered dataset — see
//! [`super::dataset`]), then serves typed jobs from a channel until the
//! engine drops. This is what makes repeated-job workloads (k sweeps,
//! perturbation ensembles, bench loops) cheap — the old free functions
//! respawned every thread and rebuilt every backend (including the XLA
//! executable cache) per call, and jobs used to re-extract their tile
//! from a broadcast global tensor per submission.
//!
//! Collectives stay correct because the engine broadcasts every job to
//! all ranks before gathering any result, and each worker consumes its
//! queue in send order — so all ranks execute the same job sequence in
//! lockstep, exactly like the one-shot grid harness did. Dataset loads
//! ride the same queue, so a job can never observe a half-loaded dataset.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::{JoinHandle, ThreadId};

use crate::backend::{BackendSpec, Workspace};
use crate::comm::grid::RankCtx;
use crate::comm::{CommError, CommResult, Trace};
use crate::engine::dataset::DatasetSpec;
use crate::err;
use crate::error::Result;
use crate::model_selection::{rescalk_rank, RescalkConfig, RescalkResult};
use crate::obs;
use crate::rescal::distributed::{DistInit, DistRescalConfig};
use crate::rescal::{rescal_rank, ModelKind, RankResult, RescalOptions};

/// One job as seen by a single rank thread. Compute jobs name their data
/// by registry id; the tile itself is already resident from a prior
/// `LoadDataset`.
#[derive(Clone)]
pub(crate) enum RankJob {
    /// Materialize and cache this rank's tile of a dataset.
    LoadDataset { id: u64, spec: Arc<DatasetSpec>, n: usize },
    /// Drop this rank's tile of a dataset.
    UnloadDataset { id: u64 },
    /// Distributed RESCAL (Alg 3) on this rank's resident tile, under
    /// the given model family's update rule.
    Factorize { dataset: u64, n: usize, opts: RescalOptions, init: DistInit, model: ModelKind },
    /// Full RESCALk model-selection sweep (Alg 1) on the resident tile.
    ModelSelect { dataset: u64, n: usize, cfg: RescalkConfig },
    /// Health probe: reply with the worker's thread id (no collectives).
    Ping,
}

/// One rank's reply.
pub(crate) enum RankOut {
    /// Startup handshake: backend built, worker thread id attached.
    Ready(ThreadId),
    /// Startup failure (e.g. missing artifact directory).
    BuildError(String),
    /// Dataset tile materialized and cached; resident size attached.
    Loaded { bytes: usize },
    Unloaded,
    /// A job-level failure that did not kill the worker (e.g. a dataset
    /// id that is not resident). Deterministic across ranks, so no rank
    /// enters a collective the others skipped.
    JobError(String),
    /// A collective failed under this rank (peer death, timeout,
    /// protocol desync). The worker survives; over TCP the cluster pool
    /// treats this as a trigger for mesh rebuild + replacement admission
    /// rather than a deterministic job error.
    CommError(String),
    /// `timeline` is the cluster-wide span gather: non-empty only on
    /// world rank 0 of a traced run (every rank ships its recorder ring
    /// to rank 0 over the mesh at job end).
    Factorize {
        row: usize,
        col: usize,
        result: Box<RankResult>,
        trace: Trace,
        timeline: Vec<obs::RankTimeline>,
    },
    ModelSelect {
        row: usize,
        col: usize,
        result: Box<RescalkResult>,
        trace: Trace,
        timeline: Vec<obs::RankTimeline>,
    },
    Ping(ThreadId),
}

/// Counters shared between the engine and its workers.
#[derive(Default)]
struct PoolShared {
    /// Total backend constructions over the pool's lifetime. Stays equal
    /// to `p` however many jobs run — the reuse guarantee tests assert on.
    backend_builds: AtomicUsize,
    /// Total tile materializations (extractions or rank-local
    /// generations) over the pool's lifetime. Exactly `p` per registered
    /// dataset, however many jobs run on it — the data-plane reuse
    /// guarantee tests assert on.
    tile_builds: AtomicUsize,
}

struct Worker {
    job_tx: Sender<RankJob>,
    out_rx: Receiver<RankOut>,
    handle: JoinHandle<()>,
    thread_id: ThreadId,
}

/// A spawned set of rank workers plus their channels.
pub(crate) struct RankPool {
    workers: Vec<Worker>,
    shared: Arc<PoolShared>,
    /// Set when a worker died mid-job; Drop skips joining (surviving
    /// ranks may be parked in a collective barrier forever).
    poisoned: bool,
}

impl RankPool {
    /// Spawn `p` rank threads, each building its backend once. Fails if
    /// any rank's backend cannot be constructed. `hub` (if any) is
    /// handed to world rank 0 only — that is the rank whose
    /// [`Trace::iteration_boundary`] receives the cluster-wide gather.
    pub fn spawn(
        p: usize,
        backend: &BackendSpec,
        trace: bool,
        hub: Option<Arc<obs::LiveHub>>,
    ) -> Result<RankPool> {
        let ctxs = RankCtx::create_all(p);
        let shared = Arc::new(PoolShared::default());
        let mut pending = Vec::with_capacity(p);
        for ctx in ctxs {
            let (job_tx, job_rx) = channel::<RankJob>();
            let (out_tx, out_rx) = channel::<RankOut>();
            let spec = backend.clone();
            let shared2 = Arc::clone(&shared);
            let rank_hub = if ctx.rank == 0 { hub.clone() } else { None };
            let name = format!("drescal-rank-{}", ctx.rank);
            let handle = std::thread::Builder::new()
                .name(name)
                .spawn(move || worker_loop(ctx, spec, trace, rank_hub, shared2, job_rx, out_tx))
                .map_err(|e| err!("spawning rank thread: {e}"))?;
            pending.push((job_tx, out_rx, handle));
        }
        // startup handshake: every rank reports its backend construction
        let mut workers = Vec::with_capacity(p);
        for (rank, (job_tx, out_rx, handle)) in pending.into_iter().enumerate() {
            let thread_id = match out_rx.recv() {
                Ok(RankOut::Ready(id)) => id,
                Ok(RankOut::BuildError(e)) => {
                    return Err(err!("rank {rank}: backend build failed: {e}"))
                }
                Ok(_) => return Err(err!("rank {rank}: unexpected startup message")),
                Err(_) => return Err(err!("rank {rank}: thread died during startup")),
            };
            workers.push(Worker { job_tx, out_rx, handle, thread_id });
        }
        Ok(RankPool { workers, shared, poisoned: false })
    }

    /// Number of ranks.
    pub fn p(&self) -> usize {
        self.workers.len()
    }

    /// Total backend constructions since spawn (== p forever, by design).
    pub fn backend_builds(&self) -> usize {
        self.shared.backend_builds.load(Ordering::SeqCst)
    }

    /// Total tile materializations since spawn (== p per registered
    /// dataset, by design).
    pub fn tile_builds(&self) -> usize {
        self.shared.tile_builds.load(Ordering::SeqCst)
    }

    /// The worker thread ids recorded at spawn, rank order.
    pub fn thread_ids(&self) -> Vec<ThreadId> {
        self.workers.iter().map(|w| w.thread_id).collect()
    }

    /// Send one job to every rank (they all must run it, in lockstep).
    pub fn broadcast(&mut self, job: &RankJob) -> Result<()> {
        for (rank, w) in self.workers.iter().enumerate() {
            if w.job_tx.send(job.clone()).is_err() {
                self.poisoned = true;
                return Err(err!("rank {rank}: thread is gone"));
            }
        }
        Ok(())
    }

    /// Receive one reply from every rank, rank order.
    pub fn collect(&mut self) -> Result<Vec<RankOut>> {
        let mut outs = Vec::with_capacity(self.workers.len());
        for (rank, w) in self.workers.iter().enumerate() {
            match w.out_rx.recv() {
                Ok(o) => outs.push(o),
                Err(_) => {
                    self.poisoned = true;
                    return Err(err!("rank {rank}: thread died mid-job"));
                }
            }
        }
        Ok(outs)
    }
}

impl Drop for RankPool {
    fn drop(&mut self) {
        let workers: Vec<Worker> = self.workers.drain(..).collect();
        let mut handles = Vec::with_capacity(workers.len());
        // close every job channel first so all workers can exit their
        // recv loop before any join
        for w in workers {
            drop(w.job_tx);
            drop(w.out_rx);
            handles.push(w.handle);
        }
        if self.poisoned {
            // a dead rank can leave survivors parked in a collective
            // barrier; detach rather than hang the caller
            return;
        }
        for h in handles {
            let _ = h.join();
        }
    }
}

/// One rank's whole mutable execution state: grid context (communicator
/// handles), compute backend, resident dataset tiles, and the workspace
/// arena. [`RankState::step`] executes one [`RankJob`] against it.
///
/// Shared by the two places a rank can live: an in-process pool thread
/// ([`worker_loop`]) and a remote `drescal worker` process
/// ([`super::cluster`]) — so both execute byte-for-byte the same job
/// logic, which is what makes TCP runs bit-identical to in-process runs.
pub(crate) struct RankState {
    ctx: RankCtx,
    backend: Box<dyn crate::backend::Backend>,
    datasets: HashMap<u64, crate::rescal::LocalTile>,
    /// The workspace arena: iteration temporaries persist across jobs,
    /// so a warm rank's factorizations allocate nothing.
    ws: Workspace,
    trace_enabled: bool,
    /// The leader's live hub, present on world rank 0 of the leader
    /// process only; attached to every job's trace so iteration-boundary
    /// telemetry flushes land in it.
    hub: Option<Arc<obs::LiveHub>>,
}

impl RankState {
    /// Build the rank's backend (once) and an empty dataset cache.
    pub fn new(
        ctx: RankCtx,
        spec: &BackendSpec,
        trace_enabled: bool,
        hub: Option<Arc<obs::LiveHub>>,
    ) -> Result<RankState> {
        let backend = spec.build()?;
        Ok(RankState {
            ctx,
            backend,
            datasets: HashMap::new(),
            ws: Workspace::new(),
            trace_enabled,
            hub,
        })
    }

    /// Replace the grid context. Used after a crash-recovery mesh
    /// rebuild: the communicators change, the resident tiles and warm
    /// workspace survive.
    pub fn set_ctx(&mut self, ctx: RankCtx) {
        self.ctx = ctx;
    }

    /// Execute one job. Never panics on job-level failures: dataset
    /// errors become [`RankOut::JobError`], collective failures (a dead
    /// TCP peer, a timeout) become [`RankOut::CommError`] — the rank
    /// survives either and serves the next job.
    pub fn step(&mut self, job: RankJob) -> RankOut {
        let mut trace = if self.trace_enabled { Trace::new() } else { Trace::disabled() };
        if let Some(hub) = &self.hub {
            trace.set_hub(Arc::clone(hub));
        }
        match job {
            RankJob::Ping => RankOut::Ping(std::thread::current().id()),
            RankJob::LoadDataset { id, spec, n } => {
                debug_assert_eq!(spec.info().n, n);
                // a failed build (e.g. a corrupt or truncated shard on
                // this rank's disk) is a typed job error, not a worker
                // panic — the pool survives and the engine unloads the
                // partially loaded dataset from the other ranks
                match spec.build_tile(&self.ctx.grid, self.ctx.row, self.ctx.col) {
                    Ok(tile) => {
                        let bytes = tile.resident_bytes();
                        self.datasets.insert(id, tile);
                        RankOut::Loaded { bytes }
                    }
                    Err(e) => RankOut::JobError(format!("loading dataset {id}: {e}")),
                }
            }
            RankJob::UnloadDataset { id } => {
                self.datasets.remove(&id);
                RankOut::Unloaded
            }
            RankJob::Factorize { dataset, n, opts, init, model } => {
                match self.datasets.get(&dataset) {
                    None => RankOut::JobError(format!("dataset {dataset} is not resident")),
                    Some(tile) => {
                        let cfg = DistRescalConfig { opts, init, n, model };
                        match rescal_rank(
                            &self.ctx,
                            tile,
                            &cfg,
                            self.backend.as_mut(),
                            &mut self.ws,
                            &mut trace,
                        ) {
                            Ok(result) => match self.gather_timelines(&trace) {
                                Ok(timeline) => RankOut::Factorize {
                                    row: self.ctx.row,
                                    col: self.ctx.col,
                                    result: Box::new(result),
                                    trace,
                                    timeline,
                                },
                                Err(e) => {
                                    RankOut::CommError(format!("factorize telemetry gather: {e}"))
                                }
                            },
                            Err(e) => RankOut::CommError(format!("factorize: {e}")),
                        }
                    }
                }
            }
            RankJob::ModelSelect { dataset, n, cfg } => {
                match self.datasets.get(&dataset) {
                    None => RankOut::JobError(format!("dataset {dataset} is not resident")),
                    Some(tile) => {
                        match rescalk_rank(
                            &self.ctx,
                            tile,
                            n,
                            &cfg,
                            self.backend.as_mut(),
                            &mut self.ws,
                            &mut trace,
                        ) {
                            Ok(result) => match self.gather_timelines(&trace) {
                                Ok(timeline) => RankOut::ModelSelect {
                                    row: self.ctx.row,
                                    col: self.ctx.col,
                                    result: Box::new(result),
                                    trace,
                                    timeline,
                                },
                                Err(e) => RankOut::CommError(format!(
                                    "model-select telemetry gather: {e}"
                                )),
                            },
                            Err(e) => RankOut::CommError(format!("model-select: {e}")),
                        }
                    }
                }
            }
        }
    }

    /// Collective post-job span shipment: every rank snapshots its
    /// recorder ring and gathers the buffers to world rank 0 (which
    /// deserializes them into the cluster-wide timeline). A no-op on
    /// untraced runs — all ranks share the `trace_enabled` flag, so the
    /// collective is skipped consistently.
    fn gather_timelines(&self, trace: &Trace) -> CommResult<Vec<obs::RankTimeline>> {
        if !self.trace_enabled {
            return Ok(Vec::new());
        }
        let snap = trace.timeline_snapshot(self.ctx.world.rank);
        let bytes = obs::timeline_to_bytes(&snap);
        match self.ctx.world.gather_bytes_to_root(&bytes)? {
            None => Ok(Vec::new()),
            Some(payloads) => {
                let mut timelines = Vec::with_capacity(payloads.len());
                for (rank, payload) in payloads.iter().enumerate() {
                    timelines.push(obs::timeline_from_bytes(rank, payload).map_err(|e| {
                        CommError::Protocol {
                            reason: format!("telemetry payload from rank {rank}: {e}"),
                        }
                    })?);
                }
                Ok(timelines)
            }
        }
    }
}

/// Body of one rank thread: build the backend once, keep the resident
/// dataset tiles, and serve jobs until the engine closes the channel.
fn worker_loop(
    ctx: RankCtx,
    spec: BackendSpec,
    trace_enabled: bool,
    hub: Option<Arc<obs::LiveHub>>,
    shared: Arc<PoolShared>,
    jobs: Receiver<RankJob>,
    out: Sender<RankOut>,
) {
    let mut state = match RankState::new(ctx, &spec, trace_enabled, hub) {
        Ok(s) => {
            shared.backend_builds.fetch_add(1, Ordering::SeqCst);
            if out.send(RankOut::Ready(std::thread::current().id())).is_err() {
                return;
            }
            s
        }
        Err(e) => {
            let _ = out.send(RankOut::BuildError(e.to_string()));
            return;
        }
    };
    while let Ok(job) = jobs.recv() {
        let reply = state.step(job);
        if let RankOut::Loaded { .. } = reply {
            shared.tile_builds.fetch_add(1, Ordering::SeqCst);
        }
        if out.send(reply).is_err() {
            return;
        }
    }
}
