//! The engine's dataset data plane: register once, compute many.
//!
//! The paper's premise (§4, Fig 12–13) is that **no single node ever
//! holds the global tensor** — each rank owns one `X^(i,j)` tile and all
//! products are tile-local. The job plane used to invert that: every
//! `Factorize`/`ModelSelect` submission shipped the full tensor to every
//! rank, and each worker re-extracted its tile per job, so k-sweeps and
//! perturbation ensembles re-paid O(n²·m) tiling on every submission and
//! the leader's RAM capped the problem size.
//!
//! This module separates data distribution from job submission:
//!
//! * [`DatasetSpec`] describes a dataset — leader-resident
//!   [`DatasetSpec::InMemory`] data (tiled once, at registration), a
//!   rank-locally generated [`DatasetSpec::Synthetic`] tensor (each rank
//!   materializes its own tile from counter-keyed RNG streams; the global
//!   tensor never exists anywhere, so shapes can exceed leader RAM), or
//!   an ingested on-disk corpus [`DatasetSpec::File`] (each rank reads —
//!   dense corpora memory-map zero-copy — only its own shards; the
//!   leader parses `manifest.json` and nothing else — see
//!   [`crate::store`]);
//! * [`super::Engine::load_dataset`] broadcasts the spec once; every rank
//!   builds and caches its resident [`LocalTile`] and the engine returns a
//!   cheap [`DatasetHandle`];
//! * jobs reference data through [`DatasetRef`] — a handle, or (for
//!   migration) inline [`JobData`] that the engine auto-registers and
//!   caches by `Arc` identity so repeated inline submissions of the same
//!   tensor still tile exactly once per rank.
//!
//! The reuse guarantee is counter-asserted: `EngineStats::tile_builds`
//! counts per-rank tile materializations, and N consecutive jobs on one
//! handle perform exactly p of them.

use std::sync::Arc;

use crate::bail;
use crate::comm::Grid;
use crate::coordinator::JobData;
use crate::data::synthetic::SyntheticSpec;
use crate::error::Result;
use crate::rescal::LocalTile;
use crate::store::{self, StoreManifest};

/// Opaque reference to a dataset resident in an engine's rank pool.
/// Handles are engine-scoped: using one on a different engine is a typed
/// error at submit time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct DatasetHandle(pub(crate) u64);

/// What [`super::Engine::load_dataset`] distributes.
#[derive(Clone)]
pub enum DatasetSpec {
    /// Leader-resident data: each rank extracts (and caches) its tile
    /// once at registration.
    InMemory(JobData),
    /// Rank-locally generated planted tensor: each rank materializes its
    /// tile from block-keyed RNG streams; the leader never constructs the
    /// global `Tensor3`/CSR set (the generation API takes block ranges
    /// only — see [`SyntheticSpec`]).
    Synthetic(SyntheticSpec),
    /// An ingested on-disk corpus (see [`crate::store`]): the leader
    /// holds only the parsed manifest; each rank reads — and, for dense
    /// corpora at a matching grid, memory-maps zero-copy — exclusively
    /// its own shard(s). Grid mismatches re-shard at load time.
    File(Arc<StoreManifest>),
}

impl DatasetSpec {
    /// Load and validate a dataset manifest (`manifest.json` path or its
    /// directory) into a registrable spec — the `--data file:<manifest>`
    /// entry point.
    pub fn from_manifest_path(path: impl AsRef<std::path::Path>) -> Result<DatasetSpec> {
        Ok(DatasetSpec::File(Arc::new(StoreManifest::load(path)?)))
    }

    /// The interned (entity, relation) name dictionaries, for datasets
    /// that carry them — lets exported models answer by name.
    pub fn names(&self) -> Option<(&[String], &[String])> {
        match self {
            DatasetSpec::File(man) if !man.entities.is_empty() => {
                Some((&man.entities, &man.relations))
            }
            _ => None,
        }
    }
    /// Validate shape consistency without touching the rank pool: sparse
    /// relation lists must be non-empty with square, equal-shape slices;
    /// synthetic specs need sane dimensions and densities.
    pub fn validate(&self) -> Result<()> {
        match self {
            DatasetSpec::InMemory(data) => data.validate(),
            DatasetSpec::Synthetic(s) => {
                if s.n == 0 || s.m == 0 || s.k == 0 {
                    bail!(
                        "synthetic dataset dimensions must all be >= 1, got n={} m={} k={}",
                        s.n,
                        s.m,
                        s.k
                    );
                }
                if s.k > s.n {
                    bail!("synthetic dataset k={} exceeds n={}", s.k, s.n);
                }
                if s.density <= 0.0 || s.density > 1.0 {
                    bail!("synthetic dataset density must be in (0, 1], got {}", s.density);
                }
                Ok(())
            }
            DatasetSpec::File(man) => man.validate(),
        }
    }

    /// Leader-visible shape metadata (requires [`Self::validate`] to have
    /// passed).
    pub fn info(&self) -> DatasetInfo {
        match self {
            DatasetSpec::InMemory(data) => DatasetInfo {
                n: data.n(),
                m: data.m(),
                sparse: matches!(data, JobData::Sparse(_)),
                resident_bytes: 0,
            },
            DatasetSpec::Synthetic(s) => DatasetInfo {
                n: s.n,
                m: s.m,
                sparse: s.is_sparse(),
                resident_bytes: 0,
            },
            DatasetSpec::File(man) => DatasetInfo {
                n: man.n,
                m: man.m,
                sparse: man.layout.is_sparse(),
                resident_bytes: 0,
            },
        }
    }

    /// Materialize rank (row, col)'s tile. Runs **on the rank**, not the
    /// leader: `InMemory` extracts from the shared `Arc`; `Synthetic`
    /// generates the block directly; `File` reads (or memory-maps) only
    /// the shards overlapping this tile. Shard corruption surfaces here
    /// as a typed error, which the pool converts into a job error
    /// instead of a worker panic.
    pub(crate) fn build_tile(&self, grid: &Grid, row: usize, col: usize) -> Result<LocalTile> {
        match self {
            DatasetSpec::InMemory(data) => Ok(data.tile(grid, row, col)),
            DatasetSpec::Synthetic(s) => {
                let (r0, r1) = grid.chunk(s.n, row);
                let (c0, c1) = grid.chunk(s.n, col);
                Ok(if s.is_sparse() {
                    LocalTile::Sparse(s.sparse_tile(r0, r1, c0, c1))
                } else {
                    LocalTile::Dense(s.dense_tile(r0, r1, c0, c1))
                })
            }
            DatasetSpec::File(man) => store::rank_tile(man, grid, row, col),
        }
    }
}

impl From<Arc<StoreManifest>> for DatasetSpec {
    fn from(man: Arc<StoreManifest>) -> Self {
        DatasetSpec::File(man)
    }
}

impl From<StoreManifest> for DatasetSpec {
    fn from(man: StoreManifest) -> Self {
        DatasetSpec::File(Arc::new(man))
    }
}

impl From<JobData> for DatasetSpec {
    fn from(data: JobData) -> Self {
        DatasetSpec::InMemory(data)
    }
}

impl From<&JobData> for DatasetSpec {
    fn from(data: &JobData) -> Self {
        DatasetSpec::InMemory(data.clone())
    }
}

impl From<SyntheticSpec> for DatasetSpec {
    fn from(s: SyntheticSpec) -> Self {
        DatasetSpec::Synthetic(s)
    }
}

/// Shape metadata the leader keeps per registered dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DatasetInfo {
    /// Global entity count (the tensor is n×n×m).
    pub n: usize,
    /// Relation count.
    pub m: usize,
    pub sparse: bool,
    /// Total bytes resident across all rank tiles, sampled at load time.
    /// Sparse tiles may lazily build transpose caches during jobs (up to
    /// ~2× this figure) — see `Csr::resident_bytes`.
    pub resident_bytes: usize,
}

/// How a job names its input data.
#[derive(Clone)]
pub enum DatasetRef {
    /// A dataset previously registered with
    /// [`super::Engine::load_dataset`] — zero data movement at submit.
    Handle(DatasetHandle),
    /// Compatibility shim: leader-resident data registered on first use
    /// and cached by `Arc` identity, so resubmitting the same `JobData`
    /// does not re-tile.
    Inline(JobData),
}

impl From<DatasetHandle> for DatasetRef {
    fn from(h: DatasetHandle) -> Self {
        DatasetRef::Handle(h)
    }
}

impl From<&DatasetHandle> for DatasetRef {
    fn from(h: &DatasetHandle) -> Self {
        DatasetRef::Handle(*h)
    }
}

impl From<JobData> for DatasetRef {
    fn from(data: JobData) -> Self {
        DatasetRef::Inline(data)
    }
}

impl From<&JobData> for DatasetRef {
    fn from(data: &JobData) -> Self {
        DatasetRef::Inline(data.clone())
    }
}

/// One registry entry: the spec is retained so `Arc`-identity caching of
/// inline data can never alias a freed allocation — and so an **evicted**
/// dataset (see `EngineConfig::dataset_cache_bytes`) can be rebuilt on
/// its next use — plus leader-side shape info for gathers and
/// validation.
pub(crate) struct DatasetEntry {
    pub spec: Arc<DatasetSpec>,
    pub info: DatasetInfo,
    /// Whether the rank tiles are currently resident. Cleared by a cache
    /// eviction; jobs on a non-resident handle transparently reload it.
    pub resident: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Csr, Tensor3};

    #[test]
    fn validate_rejects_bad_synthetic_specs() {
        assert!(DatasetSpec::from(SyntheticSpec::dense(16, 2, 3, 1)).validate().is_ok());
        assert!(DatasetSpec::from(SyntheticSpec::sparse(16, 2, 3, 0.1, 1))
            .validate()
            .is_ok());
        let bad = |s: SyntheticSpec| DatasetSpec::Synthetic(s).validate().is_err();
        assert!(bad(SyntheticSpec::dense(0, 2, 3, 1)));
        assert!(bad(SyntheticSpec::dense(16, 0, 3, 1)));
        assert!(bad(SyntheticSpec::dense(16, 2, 0, 1)));
        assert!(bad(SyntheticSpec::dense(4, 2, 8, 1)));
        assert!(bad(SyntheticSpec::sparse(16, 2, 3, 0.0, 1)));
        assert!(bad(SyntheticSpec::sparse(16, 2, 3, 1.5, 1)));
    }

    #[test]
    fn info_reports_shape_and_kind() {
        let spec = DatasetSpec::from(SyntheticSpec::sparse(32, 5, 4, 0.2, 9));
        let info = spec.info();
        assert_eq!((info.n, info.m, info.sparse), (32, 5, true));
        let dense = DatasetSpec::InMemory(JobData::dense(Tensor3::zeros(8, 8, 2)));
        let info = dense.info();
        assert_eq!((info.n, info.m, info.sparse), (8, 2, false));
    }

    #[test]
    fn build_tile_covers_the_grid() {
        let spec = DatasetSpec::from(SyntheticSpec::sparse(10, 2, 2, 0.4, 11));
        let grid = Grid::new(4);
        let mut nnz = vec![0usize; 2];
        for row in 0..2 {
            for col in 0..2 {
                match spec.build_tile(&grid, row, col).unwrap() {
                    LocalTile::Sparse(s) => {
                        for (t, c) in s.iter().enumerate() {
                            nnz[t] += c.nnz();
                        }
                    }
                    _ => panic!("expected sparse tile"),
                }
            }
        }
        // the tiles partition the global nonzeros exactly
        let full: Vec<Csr> = SyntheticSpec::sparse(10, 2, 2, 0.4, 11).sparse_tile(0, 10, 0, 10);
        for (t, c) in full.iter().enumerate() {
            assert_eq!(nnz[t], c.nnz(), "slice {t}");
        }
    }
}
