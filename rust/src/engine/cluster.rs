//! Multi-process cluster execution: a TCP leader pool and the
//! `drescal worker` process loop.
//!
//! ## Roles and rendezvous
//!
//! `drescal train --workers N --listen <addr>` runs the **leader**: it
//! owns the [`super::Engine`], executes world rank 0 itself (on the
//! calling thread, with the same [`RankState`] the in-process pool
//! uses), and coordinates N remote **workers** (`drescal worker
//! --connect <addr>`) over a newline-delimited JSON control plane.
//! Rendezvous is leader-coordinated and epoch-stamped:
//!
//! ```text
//! worker → leader   hello   {version}
//! leader → worker   welcome {rank, p, epoch, timeout_ms, trace}
//! leader → worker   prepare {epoch}          (mesh build/rebuild begins)
//! worker → leader   listening {addr}         (fresh mesh listener per epoch)
//! leader → worker   assign  {epoch, addrs}   (addrs[r] = rank r's mesh addr)
//!      …all ranks run TcpMesh::establish concurrently…
//! worker → leader   ready
//! leader → worker   job     {job}            (repeated; replies are one
//! worker → leader   <rank reply>              out line per job)
//! leader → worker   shutdown
//! ```
//!
//! Collective traffic never touches the control plane: after `assign`,
//! ranks talk over the framed [`crate::comm::transport::tcp`] socket
//! mesh, and **no tensor data crosses any wire** — each worker
//! materializes its own tiles from the shipped [`DatasetSpec`]
//! (rank-local synthetic generation, or shard reads from an ingested
//! corpus's manifest directory). Leader-resident `InMemory` data is a
//! typed error in cluster mode.
//!
//! ## Crash recovery
//!
//! A worker death surfaces as a control-stream EOF on the leader and as
//! typed [`crate::comm::CommError`]s on the survivors (their collectives
//! time out or see the peer reset). The leader then: drains the
//! survivors' `comm_error` replies, admits a replacement worker from the
//! control listener, bumps the mesh **epoch** (stale-mesh hellos fail
//! the handshake, so survivors can never cross-connect old and new
//! meshes), runs the full mesh rebuild with everyone, replays the
//! resident `LoadDataset` jobs to the replacement (which reloads the
//! dead rank's tiles from its shards), and resubmits the failed job to
//! all ranks. Jobs are deterministic given (dataset, options, seed), so
//! the rerun is bit-identical to an undisturbed run. Admissions are
//! bounded by [`ClusterConfig::max_replacements`]; past the budget the
//! job fails with a typed error instead of waiting forever.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::backend::BackendSpec;
use crate::comm::transport::tcp::{
    rank_ctx_from_mesh, MeshListener, TcpConfig, TcpMesh, TRANSPORT_VERSION,
};
use crate::comm::{Grid, Trace};
use crate::data::synthetic::SyntheticSpec;
use crate::engine::dataset::DatasetSpec;
use crate::engine::pool::{RankJob, RankOut, RankState};
use crate::engine::report;
use crate::error::{Context as _, Result};
use crate::json::Json;
use crate::model_selection::{InitStrategy, RescalkConfig, RescalkResult, SelectionRule};
use crate::obs::LiveHub;
use crate::rescal::distributed::DistInit;
use crate::rescal::{ModelKind, RankResult, RescalOptions};
use crate::{bail, err};

/// Mesh-socket retry budget, fixed on both sides of the wire.
const RETRIES: u32 = 2;

/// Leader-side cluster parameters (`drescal train`).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Control-plane listen address, e.g. `127.0.0.1:0` (port 0 binds an
    /// ephemeral port; see `port_file`).
    pub listen: String,
    /// Per-read/-write socket deadline for mesh collectives, in
    /// milliseconds. Also paces failure detection: a dead peer is
    /// noticed within roughly `timeout_ms × (retries + 1)`.
    pub timeout_ms: u64,
    /// How many worker replacements the leader admits over its lifetime
    /// before a communication failure becomes a hard job error.
    pub max_replacements: u32,
    /// When set, the leader writes its bound control address here once
    /// it is listening — how scripts discover an ephemeral `--listen`
    /// port.
    pub port_file: Option<PathBuf>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            listen: "127.0.0.1:0".to_string(),
            timeout_ms: 10_000,
            max_replacements: 1,
            port_file: None,
        }
    }
}

/// One worker's control-plane link (mesh traffic flows elsewhere).
struct WorkerLink {
    /// The worker's world rank (1..p; the leader is rank 0).
    rank: usize,
    writer: TcpStream,
    reader: LineReader,
}

/// Why an exchange round could not complete: the world ranks whose
/// control links died, plus a human-readable cause (also covers pure
/// collective timeouts where every control link survived).
struct ExchangeFailure {
    dead: Vec<usize>,
    detail: String,
}

/// The multi-process counterpart of the in-process rank pool: rank 0
/// runs inside this struct (same [`RankState`], stepped synchronously on
/// the submitting thread), ranks 1..p are remote `drescal worker`
/// processes.
pub(crate) struct ClusterPool {
    p: usize,
    trace: bool,
    tcp: TcpConfig,
    cfg: ClusterConfig,
    /// Control listener; kept open after rendezvous so crash recovery
    /// can admit replacement workers.
    listener: TcpListener,
    workers: Vec<WorkerLink>,
    /// The leader's own rank 0 state.
    state: RankState,
    /// Mesh generation, bumped on every rebuild so stale peers fail the
    /// hello handshake instead of cross-connecting meshes.
    epoch: u64,
    /// Resident dataset loads in id order, replayed to a replacement
    /// worker so it reloads the dead rank's tiles from its shards.
    resident: BTreeMap<u64, RankJob>,
    replacements_used: u32,
    backend_builds: usize,
    tile_builds: usize,
    /// The live hub (when the engine runs a status endpoint or traced
    /// job): rank 0's traces feed it, and recoveries are noted on it as
    /// transport-degradation warnings.
    hub: Option<Arc<LiveHub>>,
}

impl ClusterPool {
    /// Bind the control listener, rendezvous with `p - 1` workers, build
    /// the epoch-0 mesh, and construct the leader's rank-0 state.
    pub fn new(
        p: usize,
        backend: &BackendSpec,
        trace: bool,
        cfg: ClusterConfig,
        hub: Option<Arc<LiveHub>>,
    ) -> Result<ClusterPool> {
        let addr = cfg
            .listen
            .to_socket_addrs()
            .with_context(|| format!("resolving --listen address '{}'", cfg.listen))?
            .next()
            .ok_or_else(|| err!("--listen address '{}' resolved to nothing", cfg.listen))?;
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding cluster control listener on {addr}"))?;
        let bound = listener.local_addr().context("resolving bound control address")?;
        if let Some(path) = &cfg.port_file {
            std::fs::write(path, format!("{bound}\n"))
                .with_context(|| format!("writing port file {}", path.display()))?;
        }
        eprintln!("drescal: leader listening on {bound}, waiting for {} worker(s)", p - 1);
        let tcp = TcpConfig { timeout: Duration::from_millis(cfg.timeout_ms.max(1)), retries: RETRIES };
        let mut pool = ClusterPool {
            p,
            trace,
            tcp,
            cfg,
            listener,
            workers: Vec::with_capacity(p - 1),
            // placeholder until the first mesh exists; replaced below
            state: RankState::new(
                crate::comm::grid::RankCtx::create_all(1).remove(0),
                backend,
                trace,
                None,
            )?,
            epoch: 0,
            resident: BTreeMap::new(),
            replacements_used: 0,
            backend_builds: 0,
            tile_builds: 0,
            hub,
        };
        let deadline = Instant::now() + pool.rendezvous_window();
        for rank in 1..p {
            let link = pool.admit(rank, deadline)?;
            pool.workers.push(link);
        }
        let ctx = pool.mesh_handshake()?;
        pool.state = RankState::new(ctx, backend, trace, pool.hub.clone())?;
        // one backend per rank: the leader's plus each worker's
        pool.backend_builds = p;
        eprintln!("drescal: cluster of {p} rank(s) established (epoch 0)");
        Ok(pool)
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn backend_builds(&self) -> usize {
        self.backend_builds
    }

    pub fn tile_builds(&self) -> usize {
        self.tile_builds
    }

    /// How long to wait for workers to appear (initial rendezvous and
    /// replacement admission).
    fn rendezvous_window(&self) -> Duration {
        (self.tcp.timeout * 10).max(Duration::from_secs(30))
    }

    /// How long to wait for every rank's reply to one job. Collectives
    /// bound their own stalls (`timeout × (retries + 1)` per blocked
    /// op), and the leader's rank 0 runs the same collectives before it
    /// starts reading, so replies trail its own step by at most one
    /// timeout cascade plus serialization.
    fn collect_window(&self) -> Duration {
        self.tcp.timeout * (RETRIES + 1) * 2 + Duration::from_secs(60)
    }

    fn write_window(&self) -> Duration {
        (self.tcp.timeout * (RETRIES + 1)).max(Duration::from_secs(30))
    }

    /// Accept one worker on the control listener, validate its hello,
    /// and welcome it as world rank `rank` at the current epoch.
    fn admit(&mut self, rank: usize, deadline: Instant) -> Result<WorkerLink> {
        self.listener
            .set_nonblocking(true)
            .context("configuring control listener")?;
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    stream.set_nonblocking(false).context("configuring control stream")?;
                    configure_control(&stream, self.write_window())?;
                    let writer = stream.try_clone().context("cloning control stream")?;
                    let mut link = WorkerLink { rank, writer, reader: LineReader::new(stream) };
                    let hello = Json::parse(&link.reader.read_line(deadline)?)
                        .map_err(|e| err!("malformed hello from {peer}: {e}"))?;
                    if get_str(&hello, "type")? != "hello" {
                        bail!("worker at {peer} opened with '{}', not hello", get_str(&hello, "type")?);
                    }
                    let version = get_usize(&hello, "version")? as u32;
                    if version != TRANSPORT_VERSION {
                        bail!(
                            "transport version mismatch: worker at {peer} speaks v{version}, \
                             leader speaks v{TRANSPORT_VERSION}"
                        );
                    }
                    let welcome = obj(vec![
                        ("type", jstr("welcome")),
                        ("rank", jnum(rank as f64)),
                        ("p", jnum(self.p as f64)),
                        ("epoch", u64_to_json(self.epoch)),
                        ("timeout_ms", u64_to_json(self.tcp.timeout.as_millis() as u64)),
                        ("trace", Json::Bool(self.trace)),
                    ]);
                    write_line(&mut link.writer, &welcome)?;
                    eprintln!("drescal: admitted worker at {peer} as rank {rank}");
                    return Ok(link);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "timed out waiting for a worker to claim rank {rank} — start \
                             `drescal worker --connect <leader addr>` processes"
                        );
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => bail!("accepting worker connection: {e}"),
            }
        }
    }

    /// Build (or rebuild) the socket mesh at the current epoch with every
    /// worker, returning the leader's rank-0 grid context. Fresh mesh
    /// listeners are bound on both sides each time, so a rebuild never
    /// races traffic from the torn-down mesh.
    fn mesh_handshake(&mut self) -> Result<crate::comm::grid::RankCtx> {
        let deadline = Instant::now() + self.rendezvous_window();
        let prepare = obj(vec![("type", jstr("prepare")), ("epoch", u64_to_json(self.epoch))]);
        for w in &mut self.workers {
            write_line(&mut w.writer, &prepare)
                .with_context(|| format!("sending prepare to rank {}", w.rank))?;
        }
        let bind_ip = self.listener.local_addr().context("control listener addr")?.ip();
        let mesh_listener = MeshListener::bind(bind_ip)?;
        let mut addrs: Vec<SocketAddr> = vec![mesh_listener.addr; self.p];
        for w in &mut self.workers {
            let line = w
                .reader
                .read_line(deadline)
                .with_context(|| format!("waiting for rank {}'s mesh listener", w.rank))?;
            let msg = Json::parse(&line).map_err(|e| err!("malformed listening message: {e}"))?;
            if get_str(&msg, "type")? != "listening" {
                bail!("rank {} sent '{}' instead of listening", w.rank, get_str(&msg, "type")?);
            }
            addrs[w.rank] = get_str(&msg, "addr")?
                .parse::<SocketAddr>()
                .map_err(|e| err!("rank {} sent an unparseable mesh address: {e}", w.rank))?;
        }
        let assign = obj(vec![
            ("type", jstr("assign")),
            ("epoch", u64_to_json(self.epoch)),
            (
                "addrs",
                Json::Arr(addrs.iter().map(|a| jstr(a.to_string())).collect()),
            ),
        ]);
        for w in &mut self.workers {
            write_line(&mut w.writer, &assign)
                .with_context(|| format!("sending mesh assignment to rank {}", w.rank))?;
        }
        let mesh = TcpMesh::establish(0, self.p, self.epoch, mesh_listener, &addrs, self.tcp)?;
        let ctx = rank_ctx_from_mesh(mesh, Grid::new(self.p))?;
        for w in &mut self.workers {
            let line = w
                .reader
                .read_line(deadline)
                .with_context(|| format!("waiting for rank {} to join the mesh", w.rank))?;
            let msg = Json::parse(&line).map_err(|e| err!("malformed ready message: {e}"))?;
            if get_str(&msg, "type")? != "ready" {
                bail!("rank {} sent '{}' instead of ready", w.rank, get_str(&msg, "type")?);
            }
        }
        Ok(ctx)
    }

    /// Run one job on every rank and gather the replies in rank order,
    /// recovering from worker crashes within the replacement budget.
    pub fn exchange(&mut self, job: &RankJob) -> Result<Vec<RankOut>> {
        // serialize once: unshippable jobs (in-memory data, explicit
        // init factors) fail here with a typed error, before any wire
        // traffic or recovery machinery
        let mut line = job_to_json(job)?.to_string().into_bytes();
        line.push(b'\n');
        loop {
            match self.try_exchange(&line, job) {
                Ok(outs) => {
                    self.note_job(job, &outs);
                    return Ok(outs);
                }
                Err(failure) => {
                    eprintln!("drescal: cluster job round failed: {}", failure.detail);
                    if self.replacements_used >= self.cfg.max_replacements {
                        bail!(
                            "cluster job failed ({}) and the worker-replacement budget \
                             ({}) is exhausted",
                            failure.detail,
                            self.cfg.max_replacements
                        );
                    }
                    self.replacements_used += 1;
                    self.recover(&failure.dead)
                        .with_context(|| format!("recovering from: {}", failure.detail))?;
                    // deterministic jobs make the resubmission below
                    // bit-identical to an undisturbed run
                }
            }
        }
    }

    /// One exchange round: fan the job line out, step rank 0 locally,
    /// read one reply per worker. Any dead control link or collective
    /// failure aborts the round.
    fn try_exchange(
        &mut self,
        line: &[u8],
        job: &RankJob,
    ) -> std::result::Result<Vec<RankOut>, ExchangeFailure> {
        let mut dead: Vec<usize> = Vec::new();
        let mut causes: Vec<String> = Vec::new();
        let mut sent = vec![false; self.workers.len()];
        for (i, w) in self.workers.iter_mut().enumerate() {
            match w.writer.write_all(line) {
                Ok(()) => sent[i] = true,
                Err(e) => {
                    dead.push(w.rank);
                    causes.push(format!("rank {} control link dead on send: {e}", w.rank));
                }
            }
        }
        // the leader executes its own rank synchronously; skipped when a
        // send already failed (its collectives could only time out
        // against the unreachable peer)
        let rank0 = if dead.is_empty() { Some(self.state.step(job.clone())) } else { None };
        if let Some(RankOut::CommError(e)) = &rank0 {
            causes.push(format!("rank 0: {e}"));
        }
        // drain one reply from every worker that received the job, even
        // after a failure — survivors unblock via their own socket
        // deadlines and must not leave stale replies queued on the
        // control stream
        let deadline = Instant::now() + self.collect_window();
        let mut replies: Vec<Option<RankOut>> = Vec::with_capacity(self.workers.len());
        for (i, w) in self.workers.iter_mut().enumerate() {
            if !sent[i] {
                replies.push(None);
                continue;
            }
            let reply = w
                .reader
                .read_line(deadline)
                .map_err(|e| e.to_string())
                .and_then(|l| Json::parse(&l).map_err(|e| format!("malformed reply: {e}")))
                .and_then(|v| out_from_json(&v).map_err(|e| e.to_string()));
            match reply {
                Ok(out) => {
                    if let RankOut::CommError(e) = &out {
                        causes.push(format!("rank {}: {e}", w.rank));
                    }
                    replies.push(Some(out));
                }
                Err(e) => {
                    dead.push(w.rank);
                    causes.push(format!("rank {} control link dead on reply: {e}", w.rank));
                    replies.push(None);
                }
            }
        }
        if dead.is_empty() && causes.is_empty() {
            let mut outs = Vec::with_capacity(self.p);
            outs.push(rank0.expect("rank 0 always steps when no send failed"));
            outs.extend(replies.into_iter().map(|r| r.expect("reply present when link alive")));
            return Ok(outs);
        }
        dead.sort_unstable();
        dead.dedup();
        Err(ExchangeFailure { dead, detail: causes.join("; ") })
    }

    /// Crash recovery: admit a replacement for every dead rank, rebuild
    /// the mesh at a fresh epoch with all workers, and replay the
    /// resident dataset loads to the replacements so they rebuild the
    /// dead ranks' tiles from their own shards.
    fn recover(&mut self, dead: &[usize]) -> Result<()> {
        self.epoch += 1;
        let deadline = Instant::now() + self.rendezvous_window();
        for &rank in dead {
            eprintln!(
                "drescal: rank {rank} lost; waiting for a replacement worker (epoch {})",
                self.epoch
            );
            let link = self.admit(rank, deadline)?;
            self.workers[rank - 1] = link;
            self.backend_builds += 1;
        }
        let ctx = self.mesh_handshake()?;
        // the leader's tiles and warm workspace survive; only its
        // communicators change
        self.state.set_ctx(ctx);
        let replay: Vec<RankJob> = self.resident.values().cloned().collect();
        for &rank in dead {
            for job in &replay {
                let mut line = job_to_json(job)?.to_string().into_bytes();
                line.push(b'\n');
                let w = &mut self.workers[rank - 1];
                w.writer
                    .write_all(&line)
                    .with_context(|| format!("replaying dataset load to rank {rank}"))?;
                let reply_deadline = Instant::now() + self.collect_window();
                let reply = Json::parse(&w.reader.read_line(reply_deadline)?)
                    .map_err(|e| err!("malformed replay reply: {e}"))
                    .and_then(|v| out_from_json(&v))?;
                match reply {
                    RankOut::Loaded { .. } => self.tile_builds += 1,
                    RankOut::JobError(e) => {
                        bail!("replacement rank {rank} failed to reload its tiles: {e}")
                    }
                    _ => bail!("replacement rank {rank} sent an unexpected replay reply"),
                }
            }
        }
        eprintln!("drescal: cluster recovered at epoch {}", self.epoch);
        if let Some(hub) = &self.hub {
            hub.note_transport_degraded(
                self.epoch,
                &format!("replaced dead rank(s) {dead:?}, mesh rebuilt"),
            );
        }
        Ok(())
    }

    /// Post-exchange bookkeeping: resident-dataset replay log and the
    /// tile-build counter the engine's reuse guarantees assert on.
    fn note_job(&mut self, job: &RankJob, outs: &[RankOut]) {
        match job {
            RankJob::LoadDataset { id, .. } => {
                let loaded = outs.iter().filter(|o| matches!(o, RankOut::Loaded { .. })).count();
                self.tile_builds += loaded;
                if loaded == outs.len() {
                    self.resident.insert(*id, job.clone());
                }
            }
            RankJob::UnloadDataset { id } => {
                self.resident.remove(id);
            }
            _ => {}
        }
    }
}

impl Drop for ClusterPool {
    fn drop(&mut self) {
        let bye = obj(vec![("type", jstr("shutdown"))]);
        for w in &mut self.workers {
            let _ = write_line(&mut w.writer, &bye);
        }
    }
}

/// The `drescal worker --connect <addr>` process body: join the leader's
/// rendezvous, build this rank's state once, then serve mesh rebuilds
/// and jobs until the leader says shutdown (or its control stream
/// closes, which means the leader is gone and the worker exits cleanly).
pub fn run_worker(connect: &str) -> Result<()> {
    let addr = connect
        .to_socket_addrs()
        .with_context(|| format!("resolving leader address '{connect}'"))?
        .next()
        .ok_or_else(|| err!("leader address '{connect}' resolved to nothing"))?;
    let stream = TcpStream::connect_timeout(&addr, Duration::from_secs(30))
        .with_context(|| format!("connecting to leader at {addr}"))?;
    configure_control(&stream, Duration::from_secs(30))?;
    let mut writer = stream.try_clone().context("cloning control stream")?;
    let local_ip = stream.local_addr().context("resolving local address")?.ip();
    let leader_ip = stream.peer_addr().context("resolving leader address")?.ip();
    let mut reader = LineReader::new(stream);
    write_line(
        &mut writer,
        &obj(vec![
            ("type", jstr("hello")),
            ("version", jnum(TRANSPORT_VERSION as f64)),
        ]),
    )?;
    let deadline = Instant::now() + Duration::from_secs(60);
    let welcome = Json::parse(&reader.read_line(deadline)?)
        .map_err(|e| err!("malformed welcome from leader: {e}"))?;
    if get_str(&welcome, "type")? != "welcome" {
        bail!("leader answered hello with '{}'", get_str(&welcome, "type")?);
    }
    let rank = get_usize(&welcome, "rank")?;
    let p = get_usize(&welcome, "p")?;
    let q = (p as f64).sqrt().round() as usize;
    if rank == 0 || rank >= p || q * q != p {
        bail!("leader assigned an invalid slot: rank {rank} of p {p}");
    }
    let timeout_ms = u64_from_json(&welcome, "timeout_ms")?;
    let trace = welcome.get("trace").and_then(|t| t.as_bool()).unwrap_or(false);
    let tcp = TcpConfig { timeout: Duration::from_millis(timeout_ms.max(1)), retries: RETRIES };
    eprintln!("drescal worker: joined as rank {rank} of {p}");
    let mut state: Option<RankState> = None;
    loop {
        // idle reads wait on the leader indefinitely; a closed control
        // stream (leader exit) ends the worker cleanly
        let line = match reader.read_line(Instant::now() + Duration::from_secs(86_400)) {
            Ok(l) => l,
            Err(e) if e.to_string().contains("closed by peer") => return Ok(()),
            Err(e) => return Err(e),
        };
        let msg = Json::parse(&line).map_err(|e| err!("malformed control message: {e}"))?;
        match get_str(&msg, "type")? {
            "shutdown" => return Ok(()),
            "prepare" => {
                let epoch = u64_from_json(&msg, "epoch")?;
                let listener = MeshListener::bind(local_ip)?;
                write_line(
                    &mut writer,
                    &obj(vec![
                        ("type", jstr("listening")),
                        ("addr", jstr(listener.addr.to_string())),
                    ]),
                )?;
                let assign_deadline = Instant::now() + (tcp.timeout * 10).max(Duration::from_secs(30));
                let assign = Json::parse(&reader.read_line(assign_deadline)?)
                    .map_err(|e| err!("malformed assign message: {e}"))?;
                if get_str(&assign, "type")? != "assign" {
                    bail!("leader sent '{}' instead of assign", get_str(&assign, "type")?);
                }
                if u64_from_json(&assign, "epoch")? != epoch {
                    bail!("mesh assignment is for a different epoch");
                }
                let addr_list = assign
                    .get("addrs")
                    .and_then(|a| a.as_arr())
                    .ok_or_else(|| err!("assign message missing 'addrs'"))?;
                if addr_list.len() != p {
                    bail!("assign lists {} mesh addresses, expected {p}", addr_list.len());
                }
                let mut addrs = Vec::with_capacity(p);
                for (r, a) in addr_list.iter().enumerate() {
                    let mut parsed = a
                        .as_str()
                        .ok_or_else(|| err!("mesh address {r} is not a string"))?
                        .parse::<SocketAddr>()
                        .map_err(|e| err!("unparseable mesh address for rank {r}: {e}"))?;
                    // a leader listening on an unspecified IP (0.0.0.0)
                    // advertises it verbatim; dial the IP its control
                    // plane actually answers on
                    if parsed.ip().is_unspecified() {
                        parsed.set_ip(leader_ip);
                    }
                    addrs.push(parsed);
                }
                let mesh = TcpMesh::establish(rank, p, epoch, listener, &addrs, tcp)?;
                let ctx = rank_ctx_from_mesh(mesh, Grid::new(p))?;
                match &mut state {
                    // first mesh: build the rank state (backend, empty
                    // tile cache, workspace arena) exactly once
                    None => state = Some(RankState::new(ctx, &BackendSpec::Native, trace, None)?),
                    // rebuild: tiles and warm workspace survive, only
                    // the communicators change
                    Some(s) => s.set_ctx(ctx),
                }
                write_line(&mut writer, &obj(vec![("type", jstr("ready"))]))?;
            }
            "job" => {
                let s = state
                    .as_mut()
                    .ok_or_else(|| err!("leader sent a job before the first mesh handshake"))?;
                let job = job_from_json(
                    msg.get("job").ok_or_else(|| err!("job message missing 'job'"))?,
                )?;
                let out = s.step(job);
                write_line(&mut writer, &out_to_json(&out)?)?;
            }
            other => bail!("unknown control message '{other}' from leader"),
        }
    }
}

// ---------------------------------------------------------------------
// Control-plane plumbing
// ---------------------------------------------------------------------

fn configure_control(stream: &TcpStream, write_timeout: Duration) -> Result<()> {
    let apply = || -> std::io::Result<()> {
        stream.set_nodelay(true)?;
        // short read slices keep LineReader's deadline granular; the
        // line-level deadline is what callers actually wait on
        stream.set_read_timeout(Some(Duration::from_millis(250)))?;
        stream.set_write_timeout(Some(write_timeout))?;
        Ok(())
    };
    apply().context("configuring control socket")
}

fn write_line(stream: &mut TcpStream, msg: &Json) -> Result<()> {
    let mut line = msg.to_string().into_bytes();
    line.push(b'\n');
    stream.write_all(&line).context("control write failed")
}

/// Newline-delimited message reader over a control socket: one JSON
/// document per line, each read bounded by a caller-supplied deadline.
struct LineReader {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl LineReader {
    fn new(stream: TcpStream) -> LineReader {
        LineReader { stream, buf: Vec::new() }
    }

    /// Read one line (without its newline) before `deadline`.
    fn read_line(&mut self, deadline: Instant) -> Result<String> {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let mut line: Vec<u8> = self.buf.drain(..=pos).collect();
                line.pop();
                return String::from_utf8(line).map_err(|_| err!("control line is not valid UTF-8"));
            }
            if Instant::now() >= deadline {
                bail!("timed out waiting for a control message");
            }
            let mut chunk = [0u8; 8192];
            match self.stream.read(&mut chunk) {
                Ok(0) => bail!("control connection closed by peer"),
                Ok(k) => self.buf.extend_from_slice(&chunk[..k]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(e) => bail!("control read failed: {e}"),
            }
        }
    }
}

// ---------------------------------------------------------------------
// Wire form: jobs, replies, and their parts
// ---------------------------------------------------------------------

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn jstr(s: impl Into<String>) -> Json {
    Json::Str(s.into())
}

fn jnum(x: f64) -> Json {
    Json::Num(x)
}

/// u64 values (dataset ids, seeds, epochs) cross the wire as strings:
/// JSON numbers are f64 and would silently round above 2^53.
fn u64_to_json(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn u64_from_json(v: &Json, key: &str) -> Result<u64> {
    match v.get(key).ok_or_else(|| err!("message missing '{key}'"))? {
        Json::Str(s) => s.parse::<u64>().map_err(|_| err!("field '{key}' is not a u64")),
        Json::Num(n) => Ok(*n as u64),
        _ => Err(err!("field '{key}' is not a u64")),
    }
}

fn get_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(|x| x.as_str())
        .ok_or_else(|| err!("message missing string field '{key}'"))
}

fn get_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| err!("message missing numeric field '{key}'"))
}

fn get_usize(v: &Json, key: &str) -> Result<usize> {
    Ok(get_f64(v, key)? as usize)
}

fn spec_to_json(spec: &DatasetSpec) -> Result<Json> {
    match spec {
        DatasetSpec::InMemory(_) => bail!(
            "in-memory datasets cannot be shipped to a TCP cluster (tensor data never \
             crosses the wire); ingest the corpus with `drescal ingest` and load it with \
             --data file:<manifest> so each worker reads its own shards"
        ),
        DatasetSpec::Synthetic(s) => Ok(obj(vec![
            ("type", jstr("synthetic")),
            ("n", jnum(s.n as f64)),
            ("m", jnum(s.m as f64)),
            ("k", jnum(s.k as f64)),
            ("density", jnum(s.density)),
            ("noise", jnum(s.noise as f64)),
            ("sparse", Json::Bool(s.sparse)),
            ("seed", u64_to_json(s.seed)),
        ])),
        DatasetSpec::File(man) => {
            let dir = man
                .dir
                .to_str()
                .ok_or_else(|| err!("manifest dir {} is not valid UTF-8", man.dir.display()))?;
            Ok(obj(vec![("type", jstr("file")), ("manifest", jstr(dir))]))
        }
    }
}

fn spec_from_json(v: &Json) -> Result<DatasetSpec> {
    match get_str(v, "type")? {
        "synthetic" => Ok(DatasetSpec::Synthetic(SyntheticSpec {
            n: get_usize(v, "n")?,
            m: get_usize(v, "m")?,
            k: get_usize(v, "k")?,
            density: get_f64(v, "density")?,
            noise: get_f64(v, "noise")? as f32,
            sparse: v.get("sparse").and_then(|s| s.as_bool()).unwrap_or(false),
            seed: u64_from_json(v, "seed")?,
        })),
        // the worker re-reads manifest + shards from its own filesystem;
        // only the path crosses the wire
        "file" => DatasetSpec::from_manifest_path(get_str(v, "manifest")?),
        other => Err(err!("unknown dataset spec kind '{other}'")),
    }
}

fn opts_to_json(o: &RescalOptions) -> Json {
    obj(vec![
        ("k", jnum(o.k as f64)),
        ("max_iters", jnum(o.max_iters as f64)),
        ("tol", jnum(o.tol as f64)),
        ("err_every", jnum(o.err_every as f64)),
        ("eps", jnum(o.eps as f64)),
    ])
}

fn opts_from_json(v: &Json) -> Result<RescalOptions> {
    Ok(RescalOptions {
        k: get_usize(v, "k")?,
        max_iters: get_usize(v, "max_iters")?,
        tol: get_f64(v, "tol")? as f32,
        err_every: get_usize(v, "err_every")?,
        eps: get_f64(v, "eps")? as f32,
    })
}

fn rule_to_json(r: &SelectionRule) -> Json {
    match r {
        SelectionRule::StableThreshold { threshold } => obj(vec![
            ("kind", jstr("stable_threshold")),
            ("threshold", jnum(*threshold as f64)),
        ]),
        SelectionRule::MaxSeparation => obj(vec![("kind", jstr("max_separation"))]),
        SelectionRule::StableElbow { threshold, min_gain } => obj(vec![
            ("kind", jstr("stable_elbow")),
            ("threshold", jnum(*threshold as f64)),
            ("min_gain", jnum(*min_gain as f64)),
        ]),
    }
}

fn rule_from_json(v: &Json) -> Result<SelectionRule> {
    match get_str(v, "kind")? {
        "stable_threshold" => Ok(SelectionRule::StableThreshold {
            threshold: get_f64(v, "threshold")? as f32,
        }),
        "max_separation" => Ok(SelectionRule::MaxSeparation),
        "stable_elbow" => Ok(SelectionRule::StableElbow {
            threshold: get_f64(v, "threshold")? as f32,
            min_gain: get_f64(v, "min_gain")? as f32,
        }),
        other => Err(err!("unknown selection rule '{other}'")),
    }
}

fn rescalk_cfg_to_json(c: &RescalkConfig) -> Result<Json> {
    if !matches!(c.init, InitStrategy::Random) {
        bail!(
            "NNDSVD-seeded model selection cannot run on a TCP cluster (the precomputed \
             factor map is leader-resident); use the random init"
        );
    }
    Ok(obj(vec![
        ("k_min", jnum(c.k_min as f64)),
        ("k_max", jnum(c.k_max as f64)),
        ("perturbations", jnum(c.perturbations as f64)),
        ("delta", jnum(c.delta as f64)),
        ("rescal_iters", jnum(c.rescal_iters as f64)),
        ("tol", jnum(c.tol as f64)),
        ("err_every", jnum(c.err_every as f64)),
        ("regress_iters", jnum(c.regress_iters as f64)),
        ("seed", u64_to_json(c.seed)),
        ("rule", rule_to_json(&c.rule)),
        ("model", jstr(c.model.as_str())),
    ]))
}

fn rescalk_cfg_from_json(v: &Json) -> Result<RescalkConfig> {
    Ok(RescalkConfig {
        k_min: get_usize(v, "k_min")?,
        k_max: get_usize(v, "k_max")?,
        perturbations: get_usize(v, "perturbations")?,
        delta: get_f64(v, "delta")? as f32,
        rescal_iters: get_usize(v, "rescal_iters")?,
        tol: get_f64(v, "tol")? as f32,
        err_every: get_usize(v, "err_every")?,
        regress_iters: get_usize(v, "regress_iters")?,
        seed: u64_from_json(v, "seed")?,
        rule: rule_from_json(v.get("rule").ok_or_else(|| err!("config missing 'rule'"))?)?,
        init: InitStrategy::Random,
        model: model_kind_from_json(v)?,
    })
}

/// Leaders older than the model-family plane send no `model` field;
/// they always ran the Gaussian RESCAL rule.
fn model_kind_from_json(v: &Json) -> Result<ModelKind> {
    match v.get("model").and_then(|m| m.as_str()) {
        Some(name) => ModelKind::parse(name),
        None => Ok(ModelKind::Rescal),
    }
}

/// Serialize one rank job as a `job` control message. Fails (typed) on
/// jobs that cannot cross process boundaries: in-memory datasets and
/// explicitly-given initial factors.
fn job_to_json(job: &RankJob) -> Result<Json> {
    let body = match job {
        RankJob::LoadDataset { id, spec, n } => obj(vec![
            ("type", jstr("load")),
            ("id", u64_to_json(*id)),
            ("n", jnum(*n as f64)),
            ("spec", spec_to_json(spec)?),
        ]),
        RankJob::UnloadDataset { id } => {
            obj(vec![("type", jstr("unload")), ("id", u64_to_json(*id))])
        }
        RankJob::Factorize { dataset, n, opts, init, model } => {
            let init_json = match init {
                DistInit::Random { seed } => {
                    obj(vec![("kind", jstr("random")), ("seed", u64_to_json(*seed))])
                }
                DistInit::Given(..) => bail!(
                    "factorize jobs with explicitly-given initial factors cannot run on a \
                     TCP cluster; use a seeded random init"
                ),
            };
            obj(vec![
                ("type", jstr("factorize")),
                ("dataset", u64_to_json(*dataset)),
                ("n", jnum(*n as f64)),
                ("opts", opts_to_json(opts)),
                ("init", init_json),
                ("model", jstr(model.as_str())),
            ])
        }
        RankJob::ModelSelect { dataset, n, cfg } => obj(vec![
            ("type", jstr("model_select")),
            ("dataset", u64_to_json(*dataset)),
            ("n", jnum(*n as f64)),
            ("cfg", rescalk_cfg_to_json(cfg)?),
        ]),
        RankJob::Ping => obj(vec![("type", jstr("ping"))]),
    };
    Ok(obj(vec![("type", jstr("job")), ("job", body)]))
}

fn job_from_json(v: &Json) -> Result<RankJob> {
    match get_str(v, "type")? {
        "load" => Ok(RankJob::LoadDataset {
            id: u64_from_json(v, "id")?,
            spec: std::sync::Arc::new(spec_from_json(
                v.get("spec").ok_or_else(|| err!("load job missing 'spec'"))?,
            )?),
            n: get_usize(v, "n")?,
        }),
        "unload" => Ok(RankJob::UnloadDataset { id: u64_from_json(v, "id")? }),
        "factorize" => {
            let init = v.get("init").ok_or_else(|| err!("factorize job missing 'init'"))?;
            if get_str(init, "kind")? != "random" {
                bail!("unknown init kind '{}'", get_str(init, "kind")?);
            }
            Ok(RankJob::Factorize {
                dataset: u64_from_json(v, "dataset")?,
                n: get_usize(v, "n")?,
                opts: opts_from_json(
                    v.get("opts").ok_or_else(|| err!("factorize job missing 'opts'"))?,
                )?,
                init: DistInit::Random { seed: u64_from_json(init, "seed")? },
                model: model_kind_from_json(v)?,
            })
        }
        "model_select" => Ok(RankJob::ModelSelect {
            dataset: u64_from_json(v, "dataset")?,
            n: get_usize(v, "n")?,
            cfg: rescalk_cfg_from_json(
                v.get("cfg").ok_or_else(|| err!("model-select job missing 'cfg'"))?,
            )?,
        }),
        other => Err(err!("unknown job kind '{other}'")),
    }
}

/// Serialize a rank reply. Factor blocks ride the factor JSON helpers
/// from [`report`], whose f32 → f64 → shortest-decimal path is exact —
/// the gathered factors are bitwise what the worker computed.
fn out_to_json(out: &RankOut) -> Result<Json> {
    Ok(match out {
        RankOut::Loaded { bytes } => {
            obj(vec![("type", jstr("loaded")), ("bytes", jnum(*bytes as f64))])
        }
        RankOut::Unloaded => obj(vec![("type", jstr("unloaded"))]),
        RankOut::JobError(e) => {
            obj(vec![("type", jstr("job_error")), ("error", jstr(e.clone()))])
        }
        RankOut::CommError(e) => {
            obj(vec![("type", jstr("comm_error")), ("error", jstr(e.clone()))])
        }
        RankOut::Ping(_) => obj(vec![("type", jstr("pong"))]),
        // `timeline` never rides the control plane: worker span buffers
        // already reached the leader through the mesh telemetry gather
        // (and are empty on every rank but world rank 0, the leader)
        RankOut::Factorize { row, col, result, trace, timeline: _ } => obj(vec![
            ("type", jstr("factorize")),
            ("row", jnum(*row as f64)),
            ("col", jnum(*col as f64)),
            ("a_row", report::mat_to_json(&result.a_row)),
            ("core", report::tensor_to_json(&result.r)),
            ("rel_error", jnum(result.rel_error as f64)),
            ("iters_run", jnum(result.iters_run as f64)),
            ("workspace", report::workspace_to_json(result.workspace)),
            ("trace", report::traces_to_json(std::slice::from_ref(trace))),
        ]),
        RankOut::ModelSelect { row, col, result, trace, timeline: _ } => obj(vec![
            ("type", jstr("model_select")),
            ("row", jnum(*row as f64)),
            ("col", jnum(*col as f64)),
            ("scores", Json::Arr(result.scores.iter().map(report::score_to_json).collect())),
            ("k_opt", jnum(result.k_opt as f64)),
            ("a_opt_row", report::mat_to_json(&result.a_opt_row)),
            ("core", report::tensor_to_json(&result.r_opt)),
            ("workspace", report::workspace_to_json(result.workspace)),
            ("trace", report::traces_to_json(std::slice::from_ref(trace))),
        ]),
        RankOut::Ready(_) | RankOut::BuildError(_) => {
            bail!("internal: startup messages never cross the cluster wire")
        }
    })
}

fn trace_from_json(v: Option<&Json>) -> Result<Trace> {
    match v {
        None => Ok(Trace::disabled()),
        Some(v) => {
            let mut traces = report::traces_from_json(v)?;
            if traces.len() != 1 {
                bail!("rank reply must carry exactly one trace, got {}", traces.len());
            }
            Ok(traces.remove(0))
        }
    }
}

fn out_from_json(v: &Json) -> Result<RankOut> {
    Ok(match get_str(v, "type")? {
        "loaded" => RankOut::Loaded { bytes: get_usize(v, "bytes")? },
        "unloaded" => RankOut::Unloaded,
        "job_error" => RankOut::JobError(get_str(v, "error")?.to_string()),
        "comm_error" => RankOut::CommError(get_str(v, "error")?.to_string()),
        // thread ids are process-local and cannot cross the wire; the
        // leader stamps its own so the engine's ping plumbing is
        // type-uniform across transports
        "pong" => RankOut::Ping(std::thread::current().id()),
        "factorize" => RankOut::Factorize {
            row: get_usize(v, "row")?,
            col: get_usize(v, "col")?,
            result: Box::new(RankResult {
                a_row: report::mat_from_json(
                    v.get("a_row").ok_or_else(|| err!("reply missing 'a_row'"))?,
                )?,
                r: report::tensor_from_json(
                    v.get("core").ok_or_else(|| err!("reply missing 'core'"))?,
                )?,
                rel_error: get_f64(v, "rel_error")? as f32,
                iters_run: get_usize(v, "iters_run")?,
                workspace: report::workspace_from_json(v.get("workspace")),
            }),
            trace: trace_from_json(v.get("trace"))?,
            timeline: Vec::new(),
        },
        "model_select" => RankOut::ModelSelect {
            row: get_usize(v, "row")?,
            col: get_usize(v, "col")?,
            result: Box::new(RescalkResult {
                scores: v
                    .get("scores")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| err!("reply missing 'scores'"))?
                    .iter()
                    .map(report::score_from_json)
                    .collect::<Result<Vec<_>>>()?,
                k_opt: get_usize(v, "k_opt")?,
                a_opt_row: report::mat_from_json(
                    v.get("a_opt_row").ok_or_else(|| err!("reply missing 'a_opt_row'"))?,
                )?,
                r_opt: report::tensor_from_json(
                    v.get("core").ok_or_else(|| err!("reply missing 'core'"))?,
                )?,
                workspace: report::workspace_from_json(v.get("workspace")),
            }),
            trace: trace_from_json(v.get("trace"))?,
            timeline: Vec::new(),
        },
        other => bail!("unknown rank reply kind '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Mat, Tensor3};

    #[test]
    fn job_wire_roundtrip_preserves_options() {
        let job = RankJob::Factorize {
            dataset: 3,
            n: 64,
            opts: RescalOptions::new(4, 120).with_tol(1e-5, 10),
            init: DistInit::Random { seed: 0xdead_beef_cafe },
            model: ModelKind::DistMult,
        };
        let wire = job_to_json(&job).unwrap();
        let body = wire.get("job").unwrap();
        let back = job_from_json(body).unwrap();
        match back {
            RankJob::Factorize { dataset, n, opts, init, model } => {
                assert_eq!((dataset, n), (3, 64));
                assert_eq!((opts.k, opts.max_iters, opts.err_every), (4, 120, 10));
                assert_eq!(opts.tol, 1e-5);
                assert_eq!(model, ModelKind::DistMult);
                match init {
                    DistInit::Random { seed } => assert_eq!(seed, 0xdead_beef_cafe),
                    _ => panic!("init kind changed in roundtrip"),
                }
            }
            _ => panic!("job kind changed in roundtrip"),
        }
    }

    /// A pre-model-family leader sends no `model` field; the worker must
    /// default it to the Gaussian rule rather than erroring out.
    #[test]
    fn factorize_job_without_model_field_defaults_to_rescal() {
        let body = obj(vec![
            ("type", jstr("factorize")),
            ("dataset", u64_to_json(1)),
            ("n", jnum(16.0)),
            ("opts", opts_to_json(&RescalOptions::new(2, 10))),
            ("init", obj(vec![("kind", jstr("random")), ("seed", u64_to_json(5))])),
        ]);
        match job_from_json(&body).unwrap() {
            RankJob::Factorize { model, .. } => assert_eq!(model, ModelKind::Rescal),
            _ => panic!("job kind changed"),
        }
    }

    #[test]
    fn synthetic_spec_roundtrips_and_inmemory_is_rejected() {
        let spec = DatasetSpec::Synthetic(SyntheticSpec::sparse(48, 3, 4, 0.15, 99));
        let back = spec_from_json(&spec_to_json(&spec).unwrap()).unwrap();
        match back {
            DatasetSpec::Synthetic(s) => {
                assert_eq!((s.n, s.m, s.k, s.seed), (48, 3, 4, 99));
                assert_eq!(s.density, 0.15);
                assert!(s.sparse);
            }
            _ => panic!("spec kind changed in roundtrip"),
        }
        let inline = DatasetSpec::InMemory(crate::coordinator::JobData::dense(
            Tensor3::zeros(4, 4, 1),
        ));
        let e = spec_to_json(&inline).unwrap_err();
        assert!(e.to_string().contains("ingest"), "{e}");
    }

    #[test]
    fn factorize_reply_roundtrips_factors_bitwise() {
        let mut rng = crate::rng::Rng::new(7);
        let a = Mat::random_uniform(5, 3, 0.0, 1.0, &mut rng);
        let r = Tensor3::from_slices(vec![Mat::random_uniform(3, 3, 0.0, 1.0, &mut rng)]);
        let out = RankOut::Factorize {
            row: 1,
            col: 0,
            result: Box::new(RankResult {
                a_row: a.clone(),
                r: r.clone(),
                rel_error: 0.123_456_79,
                iters_run: 17,
                workspace: Default::default(),
            }),
            trace: Trace::disabled(),
            timeline: Vec::new(),
        };
        let back = out_from_json(&out_to_json(&out).unwrap()).unwrap();
        match back {
            RankOut::Factorize { row, col, result, .. } => {
                assert_eq!((row, col), (1, 0));
                assert_eq!(result.a_row.as_slice(), a.as_slice());
                for (s, t) in result.r.slices().iter().zip(r.slices()) {
                    assert_eq!(s.as_slice(), t.as_slice());
                }
                assert_eq!(result.rel_error, 0.123_456_79);
                assert_eq!(result.iters_run, 17);
            }
            _ => panic!("reply kind changed in roundtrip"),
        }
    }

    #[test]
    fn rescalk_config_roundtrips_all_rules() {
        for rule in [
            SelectionRule::StableThreshold { threshold: 0.8 },
            SelectionRule::MaxSeparation,
            SelectionRule::StableElbow { threshold: 0.7, min_gain: 0.01 },
        ] {
            let cfg = RescalkConfig {
                rule,
                seed: u64::MAX,
                model: ModelKind::Logistic,
                ..Default::default()
            };
            let back = rescalk_cfg_from_json(&rescalk_cfg_to_json(&cfg).unwrap()).unwrap();
            assert_eq!(back.rule, cfg.rule);
            // u64::MAX survives because seeds cross the wire as strings
            assert_eq!(back.seed, u64::MAX);
            assert_eq!(back.k_max, cfg.k_max);
            assert_eq!(back.model, ModelKind::Logistic);
        }
    }
}
