//! The job engine: the crate's public entry point for running distributed
//! RESCAL(k) work.
//!
//! # Lifecycle: configure → submit → report
//!
//! An [`Engine`] is constructed **once** from a typed [`EngineConfig`]
//! (grid size `p`, [`BackendSpec`], trace policy). Construction spawns
//! the √p×√p grid of rank threads and builds each rank's compute backend
//! exactly once (see [`pool`]); the engine then accepts any number of
//! typed jobs:
//!
//! * [`JobSpec::Factorize`] — one distributed non-negative RESCAL
//!   factorization (paper Alg 3);
//! * [`JobSpec::ModelSelect`] — the full RESCALk sweep with automatic k
//!   determination (paper Alg 1);
//! * [`JobSpec::Simulate`] — a cluster-scale replay through the
//!   calibrated machine model (paper Fig 13).
//!
//! Every job returns a unified [`Report`] that serializes to JSON via
//! [`Report::to_json`]. Because the pool persists, repeated-job workloads
//! (k sweeps, perturbation ensembles, bench loops) skip the per-job
//! thread-spawn and backend-rebuild cost the old free functions paid —
//! including the XLA executable-cache rebuild on the PJRT path.
//!
//! ```no_run
//! use drescal::coordinator::JobData;
//! use drescal::data::synthetic;
//! use drescal::engine::{Engine, EngineConfig};
//! use drescal::rescal::RescalOptions;
//!
//! let mut engine = Engine::new(EngineConfig::default()).unwrap();
//! let data = JobData::dense(synthetic::block_tensor(64, 3, 4, 0.01, 7).x);
//! // two jobs on the same rank pool — no respawn between them
//! let coarse = engine.factorize(&data, &RescalOptions::new(4, 50), 42).unwrap();
//! let fine = engine.factorize(&data, &RescalOptions::new(4, 500), 42).unwrap();
//! assert!(fine.rel_error <= coarse.rel_error + 1e-4);
//! ```

mod pool;
pub mod report;

pub use report::{Report, SimReport, SimRow};

use std::time::Instant;

use crate::backend::BackendSpec;
use crate::comm::Grid;
use crate::coordinator::{JobData, RescalReport, RescalkReport};
use crate::err;
use crate::error::Result;
use crate::model_selection::RescalkConfig;
use crate::rescal::distributed::DistInit;
use crate::rescal::RescalOptions;
use crate::simulate::{exascale, Machine};
use crate::tensor::Mat;
use crate::{bail, comm::Trace};

/// Engine-level configuration, fixed for the engine's lifetime.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Number of virtual MPI ranks (must be a perfect square).
    pub p: usize,
    /// Compute backend each rank builds (once).
    pub backend: BackendSpec,
    /// Record per-op timing traces. Off by default: tracing taxes every
    /// hot-path op, so it is opt-in (`--trace` on the CLI).
    pub trace: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { p: 4, backend: BackendSpec::Native, trace: false }
    }
}

impl EngineConfig {
    /// Config with `p` ranks, native backend, tracing off.
    pub fn new(p: usize) -> Self {
        EngineConfig { p, ..Default::default() }
    }

    pub fn with_backend(mut self, backend: BackendSpec) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    /// Validate without spawning anything.
    pub fn validate(&self) -> Result<()> {
        if self.p == 0 {
            bail!("engine grid size p must be >= 1");
        }
        let q = (self.p as f64).sqrt().round() as usize;
        if q * q != self.p {
            bail!(
                "engine grid size p must be a perfect square (paper §6.1.3), got {}",
                self.p
            );
        }
        Ok(())
    }
}

/// One typed job submission.
pub enum JobSpec {
    /// Distributed non-negative RESCAL (Alg 3).
    Factorize { data: JobData, opts: RescalOptions, init: DistInit },
    /// RESCALk model-selection sweep (Alg 1).
    ModelSelect { data: JobData, cfg: RescalkConfig },
    /// Cluster-scale replay through the calibrated machine model; runs on
    /// the leader, not the rank pool.
    Simulate(SimSpec),
}

/// Which modeled scenario a [`JobSpec::Simulate`] job replays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimScenario {
    /// Fig 13a: the 11.5 TB dense RESCALk sweep on 4096 ranks.
    Dense11Tb,
    /// Fig 13b: the 9.5 EB sparse runs across densities on 22801 ranks.
    SparseExabyte,
}

impl SimScenario {
    pub fn name(&self) -> &'static str {
        match self {
            SimScenario::Dense11Tb => "dense_11tb",
            SimScenario::SparseExabyte => "sparse_exabyte",
        }
    }
}

/// Simulation job parameters.
#[derive(Clone)]
pub struct SimSpec {
    pub machine: Machine,
    pub scenario: SimScenario,
}

/// Pool health counters, for tests and diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineStats {
    /// Grid size p.
    pub ranks: usize,
    /// Backend constructions since the engine was built. Equal to
    /// `ranks` for the engine's whole lifetime — backends are never
    /// rebuilt between jobs.
    pub backend_builds: usize,
    /// Jobs completed successfully (pings not counted).
    pub jobs_completed: usize,
}

/// A persistent distributed-execution engine over a fixed rank pool.
pub struct Engine {
    cfg: EngineConfig,
    grid: Grid,
    pool: pool::RankPool,
    jobs_completed: usize,
}

impl Engine {
    /// Validate the config, spawn the rank pool, and build every rank's
    /// backend. Fails (instead of panicking mid-job) on a non-square grid
    /// or an unconstructible backend.
    pub fn new(cfg: EngineConfig) -> Result<Engine> {
        cfg.validate()?;
        let pool = pool::RankPool::spawn(cfg.p, &cfg.backend, cfg.trace)?;
        let grid = Grid::new(cfg.p);
        Ok(Engine { grid, pool, cfg, jobs_completed: 0 })
    }

    /// The configuration this engine was built from.
    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Submit one typed job and gather its unified report.
    pub fn submit(&mut self, job: JobSpec) -> Result<Report> {
        match job {
            JobSpec::Factorize { data, opts, init } => {
                self.run_factorize(data, opts, init).map(Report::Factorize)
            }
            JobSpec::ModelSelect { data, cfg } => {
                self.run_model_select(data, cfg).map(Report::ModelSelect)
            }
            JobSpec::Simulate(spec) => {
                let rows = match spec.scenario {
                    SimScenario::Dense11Tb => {
                        vec![SimRow::from(&exascale::dense_11tb_run(&spec.machine))]
                    }
                    SimScenario::SparseExabyte => exascale::sparse_exabyte_runs(&spec.machine)
                        .iter()
                        .map(SimRow::from)
                        .collect(),
                };
                self.jobs_completed += 1;
                Ok(Report::Simulate(SimReport {
                    scenario: spec.scenario.name().to_string(),
                    rows,
                }))
            }
        }
    }

    /// Convenience: one seeded-random factorization.
    pub fn factorize(
        &mut self,
        data: &JobData,
        opts: &RescalOptions,
        seed: u64,
    ) -> Result<RescalReport> {
        let report = self.submit(JobSpec::Factorize {
            data: data.clone(),
            opts: opts.clone(),
            init: DistInit::Random { seed },
        })?;
        match report {
            Report::Factorize(r) => Ok(r),
            _ => Err(err!("factorize job returned a non-factorize report")),
        }
    }

    /// Convenience: one model-selection sweep.
    pub fn model_select(
        &mut self,
        data: &JobData,
        cfg: &RescalkConfig,
    ) -> Result<RescalkReport> {
        let report =
            self.submit(JobSpec::ModelSelect { data: data.clone(), cfg: cfg.clone() })?;
        match report {
            Report::ModelSelect(r) => Ok(r),
            _ => Err(err!("model-select job returned a non-model-select report")),
        }
    }

    /// Convenience: one modeled replay.
    pub fn simulate(&mut self, spec: SimSpec) -> Result<SimReport> {
        let report = self.submit(JobSpec::Simulate(spec))?;
        match report {
            Report::Simulate(r) => Ok(r),
            _ => Err(err!("simulate job returned a non-simulate report")),
        }
    }

    /// Health probe: every rank replies with its worker thread id (rank
    /// order). Thread ids are stable across jobs — the pool never
    /// respawns.
    pub fn ping(&mut self) -> Result<Vec<std::thread::ThreadId>> {
        self.pool.broadcast(&pool::RankJob::Ping)?;
        let outs = self.pool.collect()?;
        outs.into_iter()
            .enumerate()
            .map(|(rank, o)| match o {
                pool::RankOut::Ping(id) => Ok(id),
                _ => Err(err!("rank {rank}: unexpected reply to ping")),
            })
            .collect()
    }

    /// Pool health counters.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            ranks: self.pool.p(),
            backend_builds: self.pool.backend_builds(),
            jobs_completed: self.jobs_completed,
        }
    }

    fn run_factorize(
        &mut self,
        data: JobData,
        opts: RescalOptions,
        init: DistInit,
    ) -> Result<RescalReport> {
        let n = data.n();
        let k = opts.k;
        let t0 = Instant::now();
        self.pool.broadcast(&pool::RankJob::Factorize { data, n, opts, init })?;
        let outs = self.pool.collect()?;
        let wall_seconds = t0.elapsed().as_secs_f64();
        let mut blocks: Vec<(usize, usize, Mat)> = Vec::with_capacity(outs.len());
        let mut traces: Vec<Trace> = Vec::with_capacity(outs.len());
        let mut first = None;
        for (rank, out) in outs.into_iter().enumerate() {
            match out {
                pool::RankOut::Factorize { row, col, result, trace } => {
                    // only diagonal ranks' row blocks enter the gathered A
                    if row == col {
                        blocks.push((row, col, result.a_row.clone()));
                    }
                    traces.push(trace);
                    if first.is_none() {
                        first = Some(result);
                    }
                }
                _ => bail!("rank {rank}: unexpected reply to factorize job"),
            }
        }
        let first = first.ok_or_else(|| err!("factorize job returned no rank results"))?;
        let a = gather_a(&self.grid, n, k, &blocks);
        self.jobs_completed += 1;
        Ok(RescalReport {
            a,
            r: first.r.clone(),
            rel_error: first.rel_error,
            iters_run: first.iters_run,
            traces,
            wall_seconds,
        })
    }

    fn run_model_select(
        &mut self,
        data: JobData,
        cfg: RescalkConfig,
    ) -> Result<RescalkReport> {
        let n = data.n();
        let t0 = Instant::now();
        self.pool.broadcast(&pool::RankJob::ModelSelect { data, n, cfg })?;
        let outs = self.pool.collect()?;
        let wall_seconds = t0.elapsed().as_secs_f64();
        let mut results = Vec::with_capacity(outs.len());
        let mut traces: Vec<Trace> = Vec::with_capacity(outs.len());
        for (rank, out) in outs.into_iter().enumerate() {
            match out {
                pool::RankOut::ModelSelect { row, col, result, trace } => {
                    results.push((row, col, result));
                    traces.push(trace);
                }
                _ => bail!("rank {rank}: unexpected reply to model-select job"),
            }
        }
        // deterministic collectives should force agreement; verify it for
        // real (in release builds too) instead of trusting a debug_assert
        let k_opts: Vec<usize> = results.iter().map(|(_, _, r)| r.k_opt).collect();
        let k_opt = check_k_agreement(&k_opts)?;
        // only diagonal ranks' row blocks enter the gathered A
        let blocks: Vec<(usize, usize, Mat)> = results
            .iter()
            .filter(|(row, col, _)| row == col)
            .map(|(row, col, r)| (*row, *col, r.a_opt_row.clone()))
            .collect();
        let a = gather_a(&self.grid, n, k_opt, &blocks);
        let (_, _, first) = &results[0];
        self.jobs_completed += 1;
        Ok(RescalkReport {
            scores: first.scores.clone(),
            k_opt,
            a,
            r: first.r_opt.clone(),
            traces,
            wall_seconds,
        })
    }
}

/// Verify every rank selected the same k; a disagreement means the
/// deterministic-collective contract was violated and the gathered factors
/// would be inconsistent, so it is a hard runtime error, not a debug
/// assertion.
pub fn check_k_agreement(k_opts: &[usize]) -> Result<usize> {
    let k0 = match k_opts.first() {
        Some(&k) => k,
        None => bail!("model-selection job returned no rank results"),
    };
    for (rank, &k) in k_opts.iter().enumerate() {
        if k != k0 {
            bail!(
                "cross-rank model-selection disagreement: rank 0 chose k={k0} \
                 but rank {rank} chose k={k} — rank results are inconsistent"
            );
        }
    }
    Ok(k0)
}

/// Assemble the full A from the diagonal ranks' row blocks.
pub(crate) fn gather_a(
    grid: &Grid,
    n: usize,
    k: usize,
    blocks: &[(usize, usize, Mat)],
) -> Mat {
    let mut a = Mat::zeros(n, k);
    for (row, col, block) in blocks {
        if row == col {
            let (s, _) = grid.chunk(n, *row);
            for i in 0..block.rows() {
                for j in 0..k {
                    a[(s + i, j)] = block[(i, j)];
                }
            }
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn config_validation_rejects_non_square_grids() {
        assert!(EngineConfig::new(4).validate().is_ok());
        assert!(EngineConfig::new(9).validate().is_ok());
        assert!(EngineConfig::new(1).validate().is_ok());
        let e = EngineConfig::new(8).validate().unwrap_err();
        assert!(e.to_string().contains("perfect square"), "{e}");
        assert!(EngineConfig::new(0).validate().is_err());
        assert!(Engine::new(EngineConfig::new(6)).is_err());
    }

    #[test]
    fn k_agreement_check_is_a_real_runtime_error() {
        assert_eq!(check_k_agreement(&[3, 3, 3, 3]).unwrap(), 3);
        assert_eq!(check_k_agreement(&[5]).unwrap(), 5);
        let e = check_k_agreement(&[3, 3, 4, 3]).unwrap_err();
        assert!(e.to_string().contains("disagreement"), "{e}");
        assert!(check_k_agreement(&[]).is_err());
    }

    #[test]
    fn engine_defaults_to_tracing_off() {
        let cfg = EngineConfig::default();
        assert!(!cfg.trace, "tracing must be opt-in");
        let mut engine = Engine::new(cfg).unwrap();
        let planted = synthetic::block_tensor(16, 2, 2, 0.01, 42);
        let data = JobData::dense(planted.x);
        let report = engine.factorize(&data, &RescalOptions::new(2, 20), 1).unwrap();
        for trace in &report.traces {
            assert!(trace.events().is_empty(), "untraced run recorded events");
        }
    }

    #[test]
    fn simulate_runs_on_the_leader() {
        let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
        let report = engine
            .simulate(SimSpec { machine: Machine::cpu_cluster(), scenario: SimScenario::SparseExabyte })
            .unwrap();
        assert_eq!(report.scenario, "sparse_exabyte");
        assert_eq!(report.rows.len(), 5);
        for row in &report.rows {
            assert!(row.comm_fraction() > 0.85);
        }
        assert_eq!(engine.stats().jobs_completed, 1);
    }
}
