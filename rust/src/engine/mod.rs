//! The job engine: the crate's public entry point for running distributed
//! RESCAL(k) work.
//!
//! # Lifecycle: ingest → configure → rendezvous → load → submit → report → export → serve
//!
//! Real corpora enter the system through the storage plane
//! ([`crate::store`]): `drescal ingest` streams a
//! `subject<TAB>relation<TAB>object` triple list into checksummed binary
//! tile shards plus a JSON manifest, once, offline. An [`Engine`] is
//! then constructed **once** from a typed [`EngineConfig`]
//! (grid size `p`, [`BackendSpec`], trace policy, resident-tile cache
//! budget, [`TransportKind`]). Construction spawns
//! the √p×√p grid of rank threads and builds each rank's compute backend
//! exactly once (see [`pool`]) — or, with
//! [`TransportKind::TcpLeader`], **rendezvouses** with `p − 1` remote
//! `drescal worker` processes over TCP (see [`cluster`]): the leader
//! runs rank 0 itself, workers claim ranks 1..p, and the ranks wire up
//! a framed socket mesh whose collectives are bit-identical to the
//! in-process transport. Data is then **loaded once**:
//! [`Engine::load_dataset`] distributes a [`DatasetSpec`] and every rank
//! caches its resident tile — extracted from leader memory
//! ([`DatasetSpec::InMemory`]), generated rank-locally from block-keyed
//! RNG streams ([`DatasetSpec::Synthetic`], where the global tensor never
//! exists anywhere), or read rank-locally from an ingested corpus's
//! shards ([`DatasetSpec::File`], where the leader parses only the
//! manifest and dense tiles memory-map zero-copy at a matching grid).
//! On a TCP cluster only the *spec* crosses the wire — every worker
//! materializes its own tiles, so tensor data never transits the
//! network and a dead worker's replacement can rebuild its rank's tiles
//! from the shards alone.
//! The returned [`DatasetHandle`] then feeds any number
//! of typed jobs with **zero per-job data movement**:
//!
//! * [`JobSpec::Factorize`] — one distributed non-negative RESCAL
//!   factorization (paper Alg 3), under any
//!   [`ModelKind`](crate::rescal::ModelKind) — the paper's Gaussian
//!   `rescal` rule, diagonal-core `distmult`, or Bernoulli `logistic`
//!   (set [`EngineConfig::model`] or the job's `model` field; CLI
//!   `--model`);
//! * [`JobSpec::ModelSelect`] — the full RESCALk sweep with automatic k
//!   determination (paper Alg 1), runnable under any model family via
//!   [`RescalkConfig::model`];
//! * [`JobSpec::Simulate`] — a cluster-scale replay through the
//!   calibrated machine model (paper Fig 13).
//!
//! Every job returns a unified [`Report`] that serializes to JSON via
//! [`Report::to_json`]. A factorize or model-select report can then be
//! **exported**: [`Engine::export_model`] turns its factors into a
//! [`crate::serve::FactorModel`] artifact (persisted with
//! `FactorModel::save`, reloaded with `FactorModel::load`) that a
//! [`crate::serve::QueryEngine`] **serves** — pointwise triple scores
//! and batched top-k link-prediction completions, with no engine or
//! rank pool in the serving process. On the CLI this is
//! `drescal export` (train → write model JSON) followed by
//! `drescal query` (load model → answer `(s,r,?)` / `(?,r,o)` / scored
//! triples). Because both the pool and the resident tiles
//! persist, repeated-job workloads (k sweeps, perturbation ensembles,
//! bench loops) skip the per-job thread-spawn, backend-rebuild, *and*
//! re-tiling costs the old free functions paid. Inline [`JobData`] is
//! still accepted everywhere a handle is (auto-registered and cached by
//! `Arc` identity) so pre-data-plane call sites keep working; auto
//! registrations are LRU-bounded so a fresh-tensor-per-job loop cannot
//! grow rank memory without bound. Orthogonally,
//! [`EngineConfig::dataset_cache_bytes`] puts a byte budget on *all*
//! resident tiles: exceeding it evicts the least-recently-used dataset's
//! tiles from the ranks (registration survives; the next job on the
//! handle rebuilds them), counter-asserted through
//! [`EngineStats::tile_evictions`]. Models exported with
//! [`Engine::export_model_for`] from an ingested corpus carry its
//! interned entity/relation names, so `drescal query` resolves names end
//! to end.
//!
//! ```no_run
//! use drescal::data::synthetic::SyntheticSpec;
//! use drescal::engine::{Engine, EngineConfig};
//! use drescal::rescal::RescalOptions;
//!
//! let mut engine = Engine::new(EngineConfig::default()).unwrap();
//! // tiled once, resident on the ranks; the leader never holds X
//! let data = engine.load_dataset(SyntheticSpec::dense(64, 3, 4, 7)).unwrap();
//! // two jobs on the same rank pool and the same resident tiles
//! let coarse = engine.factorize(data, &RescalOptions::new(4, 50), 42).unwrap();
//! let fine = engine.factorize(data, &RescalOptions::new(4, 500), 42).unwrap();
//! assert!(fine.rel_error <= coarse.rel_error + 1e-4);
//! ```

pub mod cluster;
pub mod dataset;
mod pool;
pub mod report;

pub use cluster::ClusterConfig;
pub use dataset::{DatasetHandle, DatasetInfo, DatasetRef, DatasetSpec};
pub use report::{Report, SimReport, SimRow};

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::backend::BackendSpec;
use crate::comm::Grid;
use crate::coordinator::{JobData, RescalReport, RescalkReport};
use crate::err;
use crate::error::Result;
use crate::model_selection::{InitStrategy, RescalkConfig};
use crate::obs;
use crate::rescal::distributed::DistInit;
use crate::rescal::{ModelKind, RescalOptions};
use crate::simulate::{exascale, Machine};
use crate::tensor::Mat;
use crate::{bail, comm::Trace};

use dataset::DatasetEntry;

/// Which transport the engine's rank collectives run over.
#[derive(Clone, Debug, Default)]
pub enum TransportKind {
    /// One OS thread per rank inside this process — the default, and
    /// the reference behavior every other transport must match
    /// bit-identically.
    #[default]
    InProcess,
    /// This process leads a multi-process TCP cluster: it executes rank
    /// 0 itself and coordinates `p − 1` `drescal worker` processes
    /// (control plane, mesh rendezvous, crash recovery — see
    /// [`cluster`]).
    TcpLeader(ClusterConfig),
}

/// Engine-level configuration, fixed for the engine's lifetime.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Number of virtual MPI ranks (must be a perfect square).
    pub p: usize,
    /// Compute backend each rank builds (once).
    pub backend: BackendSpec,
    /// Record per-op timing traces. Off by default: tracing taxes every
    /// hot-path op, so it is opt-in (`--trace` on the CLI).
    pub trace: bool,
    /// Memory budget (bytes, summed over all rank tiles) for resident
    /// datasets; 0 = unbounded. When a load pushes the total over the
    /// budget, the least-recently-used dataset's tiles are dropped from
    /// the ranks — the registration survives, and the next job on an
    /// evicted handle transparently rebuilds its tiles (counted in
    /// `EngineStats::{tile_builds, tile_evictions}`). CLI:
    /// `--cache-bytes`.
    pub dataset_cache_bytes: usize,
    /// Execution transport: in-process rank threads (default) or a
    /// leader-coordinated TCP cluster of worker processes.
    pub transport: TransportKind,
    /// Model family used by the [`Engine::factorize`] convenience (and
    /// any job that doesn't pin its own): the paper's Gaussian RESCAL
    /// rule by default. CLI: `--model`.
    pub model: ModelKind,
    /// When set, the engine runs a live HTTP status endpoint on
    /// `127.0.0.1:<port>` (0 binds an ephemeral port; see
    /// [`Engine::status_addr`]) serving `/healthz`, `/metrics`,
    /// `/progress`, and `/trace` from the live hub. Implies nothing
    /// about tracing by itself, but the CLI turns tracing on with it so
    /// the routes have spans to serve. CLI: `--status-port`.
    pub status_port: Option<u16>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            p: 4,
            backend: BackendSpec::Native,
            trace: false,
            dataset_cache_bytes: 0,
            transport: TransportKind::InProcess,
            model: ModelKind::Rescal,
            status_port: None,
        }
    }
}

impl EngineConfig {
    /// Config with `p` ranks, native backend, tracing off.
    pub fn new(p: usize) -> Self {
        EngineConfig { p, ..Default::default() }
    }

    pub fn with_backend(mut self, backend: BackendSpec) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    /// Set the resident-tile memory budget (0 = unbounded).
    pub fn with_dataset_cache_bytes(mut self, bytes: usize) -> Self {
        self.dataset_cache_bytes = bytes;
        self
    }

    /// Select the execution transport (default: in-process threads).
    pub fn with_transport(mut self, transport: TransportKind) -> Self {
        self.transport = transport;
        self
    }

    /// Select the model family (default: Gaussian RESCAL).
    pub fn with_model(mut self, model: ModelKind) -> Self {
        self.model = model;
        self
    }

    /// Serve the live status endpoint on this port (0 = ephemeral).
    pub fn with_status_port(mut self, port: u16) -> Self {
        self.status_port = Some(port);
        self
    }

    /// Validate without spawning anything.
    pub fn validate(&self) -> Result<()> {
        if self.p == 0 {
            bail!("engine grid size p must be >= 1");
        }
        let q = (self.p as f64).sqrt().round() as usize;
        if q * q != self.p {
            bail!(
                "engine grid size p must be a perfect square (paper §6.1.3), got {}",
                self.p
            );
        }
        Ok(())
    }
}

/// One typed job submission. Compute jobs name their data through a
/// [`DatasetRef`]: a registered [`DatasetHandle`] (zero data movement at
/// submit) or inline [`JobData`] (auto-registered, cached by `Arc`
/// identity).
pub enum JobSpec {
    /// Distributed non-negative RESCAL (Alg 3) under the named model
    /// family.
    Factorize { data: DatasetRef, opts: RescalOptions, init: DistInit, model: ModelKind },
    /// RESCALk model-selection sweep (Alg 1).
    ModelSelect { data: DatasetRef, cfg: RescalkConfig },
    /// Cluster-scale replay through the calibrated machine model; runs on
    /// the leader, not the rank pool.
    Simulate(SimSpec),
}

/// Which modeled scenario a [`JobSpec::Simulate`] job replays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimScenario {
    /// Fig 13a: the 11.5 TB dense RESCALk sweep on 4096 ranks.
    Dense11Tb,
    /// Fig 13b: the 9.5 EB sparse runs across densities on 22801 ranks.
    SparseExabyte,
}

impl SimScenario {
    pub fn name(&self) -> &'static str {
        match self {
            SimScenario::Dense11Tb => "dense_11tb",
            SimScenario::SparseExabyte => "sparse_exabyte",
        }
    }
}

/// Simulation job parameters.
#[derive(Clone)]
pub struct SimSpec {
    pub machine: Machine,
    pub scenario: SimScenario,
}

/// Pool health counters, for tests and diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineStats {
    /// Grid size p.
    pub ranks: usize,
    /// Backend constructions since the engine was built. Equal to
    /// `ranks` for the engine's whole lifetime — backends are never
    /// rebuilt between jobs.
    pub backend_builds: usize,
    /// Per-rank tile materializations since the engine was built. Exactly
    /// `ranks` per registered dataset, however many jobs run on it —
    /// tiles are never rebuilt between jobs — **plus** `ranks` per
    /// cache-eviction rebuild when `dataset_cache_bytes` is set.
    pub tile_builds: usize,
    /// Datasets currently registered (resident on the ranks unless
    /// evicted by the cache budget).
    pub datasets_resident: usize,
    /// Dataset evictions forced by `EngineConfig::dataset_cache_bytes`
    /// (0 forever when the budget is unbounded).
    pub tile_evictions: usize,
    /// Bytes of rank-resident tiles right now, summed across datasets
    /// (the quantity the cache budget bounds).
    pub resident_bytes: usize,
    /// Jobs completed successfully (pings and dataset loads not counted).
    pub jobs_completed: usize,
}

/// How many *auto-registered* inline datasets stay resident at once.
/// Submitting a fresh `JobData` per job (the pre-data-plane pattern)
/// evicts the least-recently-used auto-registration instead of growing
/// rank memory without bound; explicitly `load_dataset`-ed handles are
/// never evicted.
const INLINE_RESIDENT_MAX: usize = 4;

/// The engine's execution substrate: an in-process thread pool (the
/// default) or a TCP cluster of worker processes led by this one. Both
/// expose one primitive — run a job on every rank, gather replies in
/// rank order — so the engine's job logic is transport-blind.
enum PoolImpl {
    Local(pool::RankPool),
    Cluster(cluster::ClusterPool),
}

impl PoolImpl {
    fn p(&self) -> usize {
        match self {
            PoolImpl::Local(p) => p.p(),
            PoolImpl::Cluster(c) => c.p(),
        }
    }

    fn backend_builds(&self) -> usize {
        match self {
            PoolImpl::Local(p) => p.backend_builds(),
            PoolImpl::Cluster(c) => c.backend_builds(),
        }
    }

    fn tile_builds(&self) -> usize {
        match self {
            PoolImpl::Local(p) => p.tile_builds(),
            PoolImpl::Cluster(c) => c.tile_builds(),
        }
    }

    /// Transport name stamped into reports: `"in_process"` or `"tcp"`.
    fn backend_name(&self) -> &'static str {
        match self {
            PoolImpl::Local(_) => "in_process",
            PoolImpl::Cluster(_) => "tcp",
        }
    }

    /// Run one job on every rank and gather the replies in rank order.
    fn exchange(&mut self, job: &pool::RankJob) -> Result<Vec<pool::RankOut>> {
        match self {
            PoolImpl::Local(p) => {
                p.broadcast(job)?;
                p.collect()
            }
            PoolImpl::Cluster(c) => c.exchange(job),
        }
    }
}

/// A persistent distributed-execution engine over a fixed rank pool.
pub struct Engine {
    cfg: EngineConfig,
    grid: Grid,
    pool: PoolImpl,
    /// Registered datasets by id; entries keep their spec alive so the
    /// `Arc`-identity inline cache can never alias a freed allocation.
    datasets: HashMap<u64, DatasetEntry>,
    /// `Arc` pointer of inline [`JobData`] → the handle it registered
    /// under, so compat-path resubmissions tile zero times.
    inline_cache: HashMap<usize, DatasetHandle>,
    /// Keys of `inline_cache` entries that were **auto**-registered by
    /// [`Engine::submit`] (not by an explicit `load_dataset` call), in
    /// least-recently-used order; bounded by [`INLINE_RESIDENT_MAX`].
    inline_lru: Vec<usize>,
    /// Dataset ids whose tiles are currently rank-resident, in
    /// least-recently-used order — the eviction order when
    /// `dataset_cache_bytes` is exceeded.
    resident_lru: Vec<u64>,
    tile_evictions: usize,
    next_dataset_id: u64,
    jobs_completed: usize,
    /// The live observability hub (present when tracing or a status
    /// endpoint is configured): rank 0 feeds it at iteration boundaries.
    hub: Option<Arc<obs::LiveHub>>,
    /// The HTTP status endpoint, kept alive (and serving) for the
    /// engine's lifetime; shut down on drop.
    status: Option<obs::StatusServer>,
}

impl Engine {
    /// Validate the config, spawn the rank pool, and build every rank's
    /// backend. Fails (instead of panicking mid-job) on a non-square grid
    /// or an unconstructible backend.
    pub fn new(cfg: EngineConfig) -> Result<Engine> {
        cfg.validate()?;
        // The hub exists whenever something can feed or read it: a
        // traced run flushes spans into it, a status endpoint serves it.
        let hub = if cfg.trace || cfg.status_port.is_some() {
            Some(Arc::new(obs::LiveHub::new()))
        } else {
            None
        };
        let status = match (cfg.status_port, &hub) {
            (Some(port), Some(hub)) => {
                let server = obs::StatusServer::start(port, Arc::clone(hub))?;
                eprintln!(
                    "drescal: status endpoint on http://{} (/healthz /metrics /progress /trace)",
                    server.addr()
                );
                Some(server)
            }
            _ => None,
        };
        let pool = match &cfg.transport {
            TransportKind::InProcess => {
                PoolImpl::Local(pool::RankPool::spawn(cfg.p, &cfg.backend, cfg.trace, hub.clone())?)
            }
            TransportKind::TcpLeader(cluster_cfg) => {
                if !matches!(cfg.backend, BackendSpec::Native) {
                    bail!(
                        "TCP cluster mode supports only the native backend — each \
                         worker process builds its own"
                    );
                }
                PoolImpl::Cluster(cluster::ClusterPool::new(
                    cfg.p,
                    &cfg.backend,
                    cfg.trace,
                    cluster_cfg.clone(),
                    hub.clone(),
                )?)
            }
        };
        let grid = Grid::new(cfg.p);
        Ok(Engine {
            grid,
            pool,
            cfg,
            datasets: HashMap::new(),
            inline_cache: HashMap::new(),
            inline_lru: Vec::new(),
            resident_lru: Vec::new(),
            tile_evictions: 0,
            next_dataset_id: 0,
            jobs_completed: 0,
            hub,
            status,
        })
    }

    /// The configuration this engine was built from.
    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// The bound address of the live status endpoint, when one is
    /// configured (`EngineConfig::status_port`).
    pub fn status_addr(&self) -> Option<std::net::SocketAddr> {
        self.status.as_ref().map(obs::StatusServer::addr)
    }

    /// Distribute a dataset once: validate the spec on the leader, then
    /// have every rank materialize and cache its resident tile. The
    /// returned handle feeds any number of jobs with no further tiling or
    /// data movement. For [`DatasetSpec::Synthetic`] the tiles are
    /// generated rank-locally — the global tensor never exists, so the
    /// shape is not bounded by leader RAM.
    pub fn load_dataset(&mut self, spec: impl Into<DatasetSpec>) -> Result<DatasetHandle> {
        let spec = spec.into();
        spec.validate()?;
        let mut info = spec.info();
        let inline_key = match &spec {
            DatasetSpec::InMemory(data) => Some(Self::inline_key(data)),
            _ => None,
        };
        let id = self.next_dataset_id;
        let spec = Arc::new(spec);
        info.resident_bytes = self.distribute_tiles(id, &spec, info.n)?;
        self.next_dataset_id += 1;
        let handle = DatasetHandle(id);
        self.datasets.insert(id, DatasetEntry { spec, info, resident: true });
        self.resident_lru.push(id);
        if let Some(key) = inline_key {
            // an explicit load supersedes an *auto*-registration of the
            // same tensor: unload the auto handle (the caller never saw
            // it) so its tiles don't stay resident unreachably; the new
            // handle is caller-owned and never evicted
            if self.inline_lru.contains(&key) {
                if let Some(&old) = self.inline_cache.get(&key) {
                    self.unload_dataset(old)?;
                }
            }
            self.inline_cache.insert(key, handle);
        }
        self.enforce_cache_budget(id)?;
        Ok(handle)
    }

    /// Broadcast a dataset's tiles to the ranks and gather the resident
    /// byte total. On any rank's failure (e.g. a corrupt shard) the
    /// partial load is rolled back on every rank before the typed error
    /// is returned, so no rank keeps an orphan tile.
    fn distribute_tiles(&mut self, id: u64, spec: &Arc<DatasetSpec>, n: usize) -> Result<usize> {
        let outs = self.pool.exchange(&pool::RankJob::LoadDataset {
            id,
            spec: Arc::clone(spec),
            n,
        })?;
        let mut resident = 0usize;
        let mut failure: Option<String> = None;
        for (rank, out) in outs.into_iter().enumerate() {
            let msg = match out {
                pool::RankOut::Loaded { bytes } => {
                    resident += bytes;
                    continue;
                }
                pool::RankOut::JobError(e) => format!("rank {rank}: {e}"),
                pool::RankOut::CommError(e) => {
                    format!("rank {rank}: communication failure: {e}")
                }
                _ => format!("rank {rank}: unexpected reply to dataset load"),
            };
            failure.get_or_insert(msg);
        }
        if let Some(msg) = failure {
            let _ = self.pool.exchange(&pool::RankJob::UnloadDataset { id })?;
            bail!("{msg}");
        }
        Ok(resident)
    }

    /// Make a registered dataset's tiles rank-resident again if the
    /// cache budget evicted them, and mark it most-recently used.
    fn ensure_resident(&mut self, id: u64) -> Result<()> {
        let entry = self
            .datasets
            .get(&id)
            .ok_or_else(|| err!("unknown dataset handle {id}"))?;
        if entry.resident {
            self.touch_resident(id);
            return Ok(());
        }
        let spec = Arc::clone(&entry.spec);
        let n = entry.info.n;
        let resident = self.distribute_tiles(id, &spec, n)?;
        let entry = self.datasets.get_mut(&id).expect("entry existence checked above");
        entry.resident = true;
        entry.info.resident_bytes = resident;
        self.resident_lru.push(id);
        self.enforce_cache_budget(id)
    }

    fn touch_resident(&mut self, id: u64) {
        if let Some(pos) = self.resident_lru.iter().position(|&d| d == id) {
            self.resident_lru.remove(pos);
            self.resident_lru.push(id);
        }
    }

    /// Drop a dataset's rank tiles but keep its registration — the cache
    /// eviction path, vs [`Engine::unload_dataset`] which forgets the
    /// handle entirely. The next job on the handle rebuilds the tiles.
    fn evict_dataset(&mut self, id: u64) -> Result<()> {
        let outs = self.pool.exchange(&pool::RankJob::UnloadDataset { id })?;
        for (rank, out) in outs.into_iter().enumerate() {
            match out {
                pool::RankOut::Unloaded => {}
                _ => bail!("rank {rank}: unexpected reply to dataset eviction"),
            }
        }
        if let Some(entry) = self.datasets.get_mut(&id) {
            entry.resident = false;
            // the tiles are gone from every rank; keep the public
            // dataset_info accounting truthful until a reload remeasures
            entry.info.resident_bytes = 0;
        }
        self.resident_lru.retain(|&d| d != id);
        self.tile_evictions += 1;
        Ok(())
    }

    /// Enforce [`EngineConfig::dataset_cache_bytes`]: evict
    /// least-recently-used datasets (never `protect`, the one just
    /// loaded or used) until the resident total fits. A single dataset
    /// larger than the whole budget stays resident — evicting it would
    /// buy nothing.
    fn enforce_cache_budget(&mut self, protect: u64) -> Result<()> {
        let budget = self.cfg.dataset_cache_bytes;
        if budget == 0 {
            return Ok(());
        }
        while self.resident_bytes() > budget {
            match self.resident_lru.iter().copied().find(|&d| d != protect) {
                Some(victim) => self.evict_dataset(victim)?,
                None => break,
            }
        }
        Ok(())
    }

    fn resident_bytes(&self) -> usize {
        self.datasets
            .values()
            .filter(|e| e.resident)
            .map(|e| e.info.resident_bytes)
            .sum()
    }

    /// Drop a dataset's resident tiles on every rank and forget the
    /// handle. Subsequent jobs on the handle fail with a typed error.
    pub fn unload_dataset(&mut self, handle: DatasetHandle) -> Result<()> {
        if self.datasets.remove(&handle.0).is_none() {
            bail!("unknown dataset handle {} (already unloaded?)", handle.0);
        }
        self.inline_cache.retain(|_, h| *h != handle);
        let cache = &self.inline_cache;
        self.inline_lru.retain(|k| cache.contains_key(k));
        self.resident_lru.retain(|&d| d != handle.0);
        let outs = self.pool.exchange(&pool::RankJob::UnloadDataset { id: handle.0 })?;
        for (rank, out) in outs.into_iter().enumerate() {
            match out {
                pool::RankOut::Unloaded => {}
                _ => bail!("rank {rank}: unexpected reply to dataset unload"),
            }
        }
        Ok(())
    }

    /// Shape metadata of a registered dataset (None after unload).
    pub fn dataset_info(&self, handle: DatasetHandle) -> Option<DatasetInfo> {
        self.datasets.get(&handle.0).map(|e| e.info)
    }

    /// The spec a dataset was registered from (None after unload).
    pub fn dataset_spec(&self, handle: DatasetHandle) -> Option<&DatasetSpec> {
        self.datasets.get(&handle.0).map(|e| &*e.spec)
    }

    fn inline_key(data: &JobData) -> usize {
        match data {
            JobData::Dense(x) => Arc::as_ptr(x) as usize,
            JobData::Sparse(s) => Arc::as_ptr(s) as usize,
        }
    }

    /// Resolve a job's data reference to a registered handle,
    /// auto-registering inline data on first sight (keyed by `Arc`
    /// identity, so resubmitting the same tensor tiles zero times).
    /// Auto-registrations are bounded: beyond [`INLINE_RESIDENT_MAX`]
    /// distinct tensors, the least-recently-used one is unloaded so the
    /// fresh-tensor-per-job pattern cannot grow rank memory without
    /// bound. Explicit `load_dataset` handles are never evicted.
    fn resolve(&mut self, data: DatasetRef) -> Result<DatasetHandle> {
        match data {
            DatasetRef::Handle(h) => {
                if !self.datasets.contains_key(&h.0) {
                    bail!(
                        "unknown dataset handle {} — was it unloaded, or loaded on a \
                         different engine?",
                        h.0
                    );
                }
                Ok(h)
            }
            DatasetRef::Inline(data) => {
                let key = Self::inline_key(&data);
                if let Some(&h) = self.inline_cache.get(&key) {
                    // refresh LRU position, but only for auto-registered
                    // entries — explicit load_dataset handles never enter
                    // the eviction order
                    if let Some(pos) = self.inline_lru.iter().position(|k| *k == key) {
                        self.inline_lru.remove(pos);
                        self.inline_lru.push(key);
                    }
                    return Ok(h);
                }
                let handle = self.load_dataset(DatasetSpec::InMemory(data))?;
                self.inline_lru.push(key);
                while self.inline_lru.len() > INLINE_RESIDENT_MAX {
                    let oldest = self.inline_lru[0];
                    match self.inline_cache.get(&oldest).copied() {
                        // unload_dataset also removes `oldest` from the LRU
                        Some(old_handle) => self.unload_dataset(old_handle)?,
                        None => {
                            self.inline_lru.remove(0);
                        }
                    }
                }
                Ok(handle)
            }
        }
    }

    /// Submit one typed job and gather its unified report.
    pub fn submit(&mut self, job: JobSpec) -> Result<Report> {
        match job {
            JobSpec::Factorize { data, opts, init, model } => {
                self.run_factorize(data, opts, init, model).map(Report::Factorize)
            }
            JobSpec::ModelSelect { data, cfg } => {
                self.run_model_select(data, cfg).map(Report::ModelSelect)
            }
            JobSpec::Simulate(spec) => {
                let rows = match spec.scenario {
                    SimScenario::Dense11Tb => {
                        vec![SimRow::from(&exascale::dense_11tb_run(&spec.machine))]
                    }
                    SimScenario::SparseExabyte => exascale::sparse_exabyte_runs(&spec.machine)
                        .iter()
                        .map(SimRow::from)
                        .collect(),
                };
                self.jobs_completed += 1;
                Ok(Report::Simulate(SimReport {
                    scenario: spec.scenario.name().to_string(),
                    rows,
                }))
            }
        }
    }

    /// Convenience: one seeded-random factorization. Takes a registered
    /// [`DatasetHandle`] or (compat) `&JobData`/`JobData`.
    pub fn factorize(
        &mut self,
        data: impl Into<DatasetRef>,
        opts: &RescalOptions,
        seed: u64,
    ) -> Result<RescalReport> {
        let report = self.submit(JobSpec::Factorize {
            data: data.into(),
            opts: opts.clone(),
            init: DistInit::Random { seed },
            model: self.cfg.model,
        })?;
        match report {
            Report::Factorize(r) => Ok(r),
            _ => Err(err!("factorize job returned a non-factorize report")),
        }
    }

    /// Convenience: one model-selection sweep. Takes a registered
    /// [`DatasetHandle`] or (compat) `&JobData`/`JobData`.
    pub fn model_select(
        &mut self,
        data: impl Into<DatasetRef>,
        cfg: &RescalkConfig,
    ) -> Result<RescalkReport> {
        let report =
            self.submit(JobSpec::ModelSelect { data: data.into(), cfg: cfg.clone() })?;
        match report {
            Report::ModelSelect(r) => Ok(r),
            _ => Err(err!("model-select job returned a non-model-select report")),
        }
    }

    /// Export a training report's factors as a servable
    /// [`FactorModel`](crate::serve::FactorModel) artifact, stamping the
    /// engine's grid size and backend into its provenance. The returned
    /// model is self-contained: persist it with `FactorModel::save` and
    /// serve it from a process that never builds an engine. `Simulate`
    /// reports carry no factors and are a typed error.
    pub fn export_model(&self, report: &Report) -> Result<crate::serve::FactorModel> {
        let mut model = crate::serve::FactorModel::from_report(report)?;
        let prov = model.provenance_mut();
        prov.p = self.cfg.p;
        prov.backend = format!("{:?}", self.cfg.backend);
        Ok(model)
    }

    /// Like [`Engine::export_model`], but also attaches the training
    /// dataset's interned entity/relation name dictionaries when it
    /// carries them (an ingested [`DatasetSpec::File`] corpus does), so
    /// the served model answers queries by name end to end.
    pub fn export_model_for(
        &self,
        report: &Report,
        data: DatasetHandle,
    ) -> Result<crate::serve::FactorModel> {
        let mut model = self.export_model(report)?;
        if let Some(entry) = self.datasets.get(&data.0) {
            if let Some((ents, rels)) = entry.spec.names() {
                model = model
                    .with_entity_names(ents.to_vec())?
                    .with_relation_names(rels.to_vec())?;
            }
        }
        Ok(model)
    }

    /// Convenience: one modeled replay.
    pub fn simulate(&mut self, spec: SimSpec) -> Result<SimReport> {
        let report = self.submit(JobSpec::Simulate(spec))?;
        match report {
            Report::Simulate(r) => Ok(r),
            _ => Err(err!("simulate job returned a non-simulate report")),
        }
    }

    /// Health probe: every rank replies with its worker thread id (rank
    /// order). Thread ids are stable across jobs — the pool never
    /// respawns.
    pub fn ping(&mut self) -> Result<Vec<std::thread::ThreadId>> {
        let outs = self.pool.exchange(&pool::RankJob::Ping)?;
        outs.into_iter()
            .enumerate()
            .map(|(rank, o)| match o {
                pool::RankOut::Ping(id) => Ok(id),
                _ => Err(err!("rank {rank}: unexpected reply to ping")),
            })
            .collect()
    }

    /// Pool health counters.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            ranks: self.pool.p(),
            backend_builds: self.pool.backend_builds(),
            tile_builds: self.pool.tile_builds(),
            datasets_resident: self.datasets.len(),
            tile_evictions: self.tile_evictions,
            resident_bytes: self.resident_bytes(),
            jobs_completed: self.jobs_completed,
        }
    }

    fn run_factorize(
        &mut self,
        data: DatasetRef,
        opts: RescalOptions,
        init: DistInit,
        model: ModelKind,
    ) -> Result<RescalReport> {
        let handle = self.resolve(data)?;
        self.ensure_resident(handle.0)?;
        let n = self.datasets[&handle.0].info.n;
        let k = opts.k;
        if let Some(hub) = &self.hub {
            hub.job_started("factorize", opts.max_iters as u64);
            hub.gauge_set("resident_tile_bytes", self.resident_bytes() as f64);
            hub.gauge_set("workspace_mat_allocs", 0.0);
        }
        let t0 = Instant::now();
        let outs = self
            .pool
            .exchange(&pool::RankJob::Factorize { dataset: handle.0, n, opts, init, model })?;
        let wall_seconds = t0.elapsed().as_secs_f64();
        let mut blocks: Vec<(usize, usize, Mat)> = Vec::with_capacity(outs.len());
        let mut traces: Vec<Trace> = Vec::with_capacity(outs.len());
        let mut timeline: Vec<crate::obs::RankTimeline> = Vec::new();
        let mut workspace = crate::backend::WorkspaceStats::default();
        let mut first = None;
        for (rank, out) in outs.into_iter().enumerate() {
            match out {
                pool::RankOut::Factorize { row, col, result, trace, timeline: tl } => {
                    // only diagonal ranks' row blocks enter the gathered A
                    if row == col {
                        blocks.push((row, col, result.a_row.clone()));
                    }
                    // the mesh gather leaves the full cross-rank timeline on
                    // world rank 0 only; every other rank reports empty
                    if !tl.is_empty() {
                        timeline = tl;
                    }
                    traces.push(trace);
                    workspace = workspace.merged(result.workspace);
                    if first.is_none() {
                        first = Some(result);
                    }
                }
                pool::RankOut::JobError(e) => bail!("rank {rank}: {e}"),
                pool::RankOut::CommError(e) => {
                    bail!("rank {rank}: communication failure: {e}")
                }
                _ => bail!("rank {rank}: unexpected reply to factorize job"),
            }
        }
        let first = first.ok_or_else(|| err!("factorize job returned no rank results"))?;
        let a = gather_a(&self.grid, n, k, &blocks);
        self.jobs_completed += 1;
        let watchdog = self.seal_job(&mut timeline, first.rel_error, &workspace);
        Ok(RescalReport {
            a,
            r: first.r.clone(),
            rel_error: first.rel_error,
            iters_run: first.iters_run,
            traces,
            timeline,
            wall_seconds,
            workspace,
            transport_backend: self.pool.backend_name().to_string(),
            model,
            watchdog,
        })
    }

    /// End-of-job hub bookkeeping: merge the live mirror's orphaned
    /// timelines (pre-crash spans of workers whose pid never reached the
    /// final gather) into the exported timeline, stamp final gauges, and
    /// collect the watchdog warnings for the report.
    fn seal_job(
        &self,
        timeline: &mut Vec<crate::obs::RankTimeline>,
        rel_error: f32,
        workspace: &crate::backend::WorkspaceStats,
    ) -> Vec<crate::obs::WatchdogEvent> {
        let Some(hub) = &self.hub else {
            return Vec::new();
        };
        if !timeline.is_empty() {
            let live: std::collections::BTreeSet<u64> = timeline.iter().map(|t| t.pid).collect();
            timeline.extend(hub.orphan_timelines(&live));
        }
        hub.gauge_set("workspace_mat_allocs", workspace.mat_allocs as f64);
        hub.gauge_set("workspace_mat_reuses", workspace.mat_reuses as f64);
        hub.finish(rel_error)
    }

    fn run_model_select(
        &mut self,
        data: DatasetRef,
        cfg: RescalkConfig,
    ) -> Result<RescalkReport> {
        if cfg.model != ModelKind::Rescal && matches!(cfg.init, InitStrategy::Nndsvd { .. }) {
            bail!(
                "NNDSVD initialization is defined for the Gaussian rescal family only; \
                 use random init with --model {}",
                cfg.model.as_str()
            );
        }
        let model = cfg.model;
        let handle = self.resolve(data)?;
        self.ensure_resident(handle.0)?;
        let n = self.datasets[&handle.0].info.n;
        if let Some(hub) = &self.hub {
            hub.job_started("model_select", 0);
            hub.gauge_set("resident_tile_bytes", self.resident_bytes() as f64);
        }
        let t0 = Instant::now();
        let outs = self
            .pool
            .exchange(&pool::RankJob::ModelSelect { dataset: handle.0, n, cfg })?;
        let wall_seconds = t0.elapsed().as_secs_f64();
        let mut results = Vec::with_capacity(outs.len());
        let mut traces: Vec<Trace> = Vec::with_capacity(outs.len());
        let mut timeline: Vec<crate::obs::RankTimeline> = Vec::new();
        for (rank, out) in outs.into_iter().enumerate() {
            match out {
                pool::RankOut::ModelSelect { row, col, result, trace, timeline: tl } => {
                    results.push((row, col, result));
                    if !tl.is_empty() {
                        timeline = tl;
                    }
                    traces.push(trace);
                }
                pool::RankOut::JobError(e) => bail!("rank {rank}: {e}"),
                pool::RankOut::CommError(e) => {
                    bail!("rank {rank}: communication failure: {e}")
                }
                _ => bail!("rank {rank}: unexpected reply to model-select job"),
            }
        }
        // deterministic collectives should force agreement; verify it for
        // real (in release builds too) instead of trusting a debug_assert
        let k_opts: Vec<usize> = results.iter().map(|(_, _, r)| r.k_opt).collect();
        let k_opt = check_k_agreement(&k_opts)?;
        // only diagonal ranks' row blocks enter the gathered A
        let blocks: Vec<(usize, usize, Mat)> = results
            .iter()
            .filter(|(row, col, _)| row == col)
            .map(|(row, col, r)| (*row, *col, r.a_opt_row.clone()))
            .collect();
        let a = gather_a(&self.grid, n, k_opt, &blocks);
        let workspace = results
            .iter()
            .fold(crate::backend::WorkspaceStats::default(), |acc, (_, _, r)| {
                acc.merged(r.workspace)
            });
        let (_, _, first) = &results[0];
        self.jobs_completed += 1;
        let rel_error = first.scores.last().map(|s| s.rel_error).unwrap_or(f32::NAN);
        let watchdog = self.seal_job(&mut timeline, rel_error, &workspace);
        Ok(RescalkReport {
            scores: first.scores.clone(),
            k_opt,
            a,
            r: first.r_opt.clone(),
            traces,
            timeline,
            wall_seconds,
            workspace,
            transport_backend: self.pool.backend_name().to_string(),
            model,
            watchdog,
        })
    }
}

/// Verify every rank selected the same k; a disagreement means the
/// deterministic-collective contract was violated and the gathered factors
/// would be inconsistent, so it is a hard runtime error, not a debug
/// assertion.
pub fn check_k_agreement(k_opts: &[usize]) -> Result<usize> {
    let k0 = match k_opts.first() {
        Some(&k) => k,
        None => bail!("model-selection job returned no rank results"),
    };
    for (rank, &k) in k_opts.iter().enumerate() {
        if k != k0 {
            bail!(
                "cross-rank model-selection disagreement: rank 0 chose k={k0} \
                 but rank {rank} chose k={k} — rank results are inconsistent"
            );
        }
    }
    Ok(k0)
}

/// Assemble the full A from the diagonal ranks' row blocks.
pub(crate) fn gather_a(
    grid: &Grid,
    n: usize,
    k: usize,
    blocks: &[(usize, usize, Mat)],
) -> Mat {
    let mut a = Mat::zeros(n, k);
    for (row, col, block) in blocks {
        if row == col {
            let (s, _) = grid.chunk(n, *row);
            for i in 0..block.rows() {
                for j in 0..k {
                    a[(s + i, j)] = block[(i, j)];
                }
            }
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn config_validation_rejects_non_square_grids() {
        assert!(EngineConfig::new(4).validate().is_ok());
        assert!(EngineConfig::new(9).validate().is_ok());
        assert!(EngineConfig::new(1).validate().is_ok());
        let e = EngineConfig::new(8).validate().unwrap_err();
        assert!(e.to_string().contains("perfect square"), "{e}");
        assert!(EngineConfig::new(0).validate().is_err());
        assert!(Engine::new(EngineConfig::new(6)).is_err());
    }

    #[test]
    fn k_agreement_check_is_a_real_runtime_error() {
        assert_eq!(check_k_agreement(&[3, 3, 3, 3]).unwrap(), 3);
        assert_eq!(check_k_agreement(&[5]).unwrap(), 5);
        let e = check_k_agreement(&[3, 3, 4, 3]).unwrap_err();
        assert!(e.to_string().contains("disagreement"), "{e}");
        assert!(check_k_agreement(&[]).is_err());
    }

    #[test]
    fn engine_defaults_to_tracing_off() {
        let cfg = EngineConfig::default();
        assert!(!cfg.trace, "tracing must be opt-in");
        let mut engine = Engine::new(cfg).unwrap();
        let planted = synthetic::block_tensor(16, 2, 2, 0.01, 42);
        let data = JobData::dense(planted.x);
        let report = engine.factorize(&data, &RescalOptions::new(2, 20), 1).unwrap();
        for trace in &report.traces {
            assert!(trace.events().is_empty(), "untraced run recorded events");
        }
    }

    #[test]
    fn simulate_runs_on_the_leader() {
        let mut engine = Engine::new(EngineConfig::new(1)).unwrap();
        let report = engine
            .simulate(SimSpec { machine: Machine::cpu_cluster(), scenario: SimScenario::SparseExabyte })
            .unwrap();
        assert_eq!(report.scenario, "sparse_exabyte");
        assert_eq!(report.rows.len(), 5);
        for row in &report.rows {
            assert!(row.comm_fraction() > 0.85);
        }
        assert_eq!(engine.stats().jobs_completed, 1);
    }
}
