//! Unified job reports and their JSON form.
//!
//! Every [`super::Engine`] job returns one [`Report`] variant; all three
//! serialize to JSON through the crate's own [`crate::json::Json`] value
//! (`Report::to_json`) and parse back (`Report::from_json`), so run
//! results can be archived, diffed, or fed to external tooling without
//! any external serialization crate.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::comm::{CommOp, Trace};
use crate::coordinator::{RescalReport, RescalkReport};
use crate::err;
use crate::error::Result;
use crate::json::Json;
use crate::model_selection::KScore;
use crate::rescal::ModelKind;
use crate::simulate::exascale::ExascaleRun;
use crate::tensor::{Mat, Tensor3};

/// The unified result of one engine job.
pub enum Report {
    /// One distributed factorization (Alg 3).
    Factorize(RescalReport),
    /// One model-selection sweep (Alg 1).
    ModelSelect(RescalkReport),
    /// One cluster-scale replay through the calibrated machine model.
    Simulate(SimReport),
}

/// One modeled run row (owned analogue of [`ExascaleRun`], so reports can
/// round-trip through JSON).
#[derive(Clone, Debug, PartialEq)]
pub struct SimRow {
    pub label: String,
    pub n: usize,
    pub m: usize,
    pub p: usize,
    pub density: f64,
    pub iters: usize,
    pub compute_seconds: f64,
    pub comm_seconds: f64,
}

impl SimRow {
    pub fn total(&self) -> f64 {
        self.compute_seconds + self.comm_seconds
    }

    pub fn comm_fraction(&self) -> f64 {
        self.comm_seconds / self.total().max(1e-30)
    }

    /// Logical tensor size in bytes (f32 dense equivalent).
    pub fn logical_bytes(&self) -> f64 {
        self.n as f64 * self.n as f64 * self.m as f64 * 4.0
    }
}

impl From<&ExascaleRun> for SimRow {
    fn from(r: &ExascaleRun) -> Self {
        SimRow {
            label: r.label.to_string(),
            n: r.n,
            m: r.m,
            p: r.p,
            density: r.density,
            iters: r.iters,
            compute_seconds: r.compute_seconds,
            comm_seconds: r.comm_seconds,
        }
    }
}

/// Result of a [`super::JobSpec::Simulate`] job.
#[derive(Clone, Debug, PartialEq)]
pub struct SimReport {
    /// Scenario name (e.g. "dense_11tb").
    pub scenario: String,
    pub rows: Vec<SimRow>,
}

impl Report {
    /// Report kind tag used in the JSON form.
    pub fn kind(&self) -> &'static str {
        match self {
            Report::Factorize(_) => "factorize",
            Report::ModelSelect(_) => "model_select",
            Report::Simulate(_) => "simulate",
        }
    }

    /// Serialize through the crate JSON value.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("kind".to_string(), Json::Str(self.kind().to_string()));
        match self {
            Report::Factorize(r) => {
                obj.insert("rel_error".to_string(), Json::Num(r.rel_error as f64));
                obj.insert("iters_run".to_string(), Json::Num(r.iters_run as f64));
                obj.insert("wall_seconds".to_string(), Json::Num(r.wall_seconds));
                obj.insert("a".to_string(), mat_to_json(&r.a));
                obj.insert("r".to_string(), tensor_to_json(&r.r));
                obj.insert(
                    "telemetry".to_string(),
                    telemetry_to_json(
                        &r.traces,
                        r.workspace,
                        &r.transport_backend,
                        &r.timeline,
                        &r.watchdog,
                    ),
                );
                obj.insert("model".to_string(), Json::Str(r.model.as_str().to_string()));
            }
            Report::ModelSelect(r) => {
                obj.insert("k_opt".to_string(), Json::Num(r.k_opt as f64));
                obj.insert(
                    "scores".to_string(),
                    Json::Arr(r.scores.iter().map(score_to_json).collect()),
                );
                obj.insert("wall_seconds".to_string(), Json::Num(r.wall_seconds));
                obj.insert("a".to_string(), mat_to_json(&r.a));
                obj.insert("r".to_string(), tensor_to_json(&r.r));
                obj.insert(
                    "telemetry".to_string(),
                    telemetry_to_json(
                        &r.traces,
                        r.workspace,
                        &r.transport_backend,
                        &r.timeline,
                        &r.watchdog,
                    ),
                );
                obj.insert("model".to_string(), Json::Str(r.model.as_str().to_string()));
            }
            Report::Simulate(r) => {
                obj.insert("scenario".to_string(), Json::Str(r.scenario.clone()));
                obj.insert(
                    "runs".to_string(),
                    Json::Arr(r.rows.iter().map(sim_row_to_json).collect()),
                );
            }
        }
        Json::Obj(obj)
    }

    /// Parse a report back from its JSON form. Trace timings are restored
    /// as one aggregate event per op category (nanosecond-rounded), which
    /// is exactly what the JSON form carries.
    pub fn from_json(v: &Json) -> Result<Report> {
        let kind = v
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| err!("report missing 'kind'"))?;
        match kind {
            "factorize" => Ok(Report::Factorize(RescalReport {
                a: mat_from_json(v.get("a").ok_or_else(|| err!("missing 'a'"))?)?,
                r: tensor_from_json(v.get("r").ok_or_else(|| err!("missing 'r'"))?)?,
                rel_error: get_f64(v, "rel_error")? as f32,
                iters_run: get_f64(v, "iters_run")? as usize,
                traces: report_traces_from_json(v)?,
                timeline: timeline_from_report_json(v)?,
                wall_seconds: get_f64(v, "wall_seconds")?,
                workspace: workspace_from_json(telemetry_field(v, "workspace")),
                transport_backend: transport_backend_from_json(v),
                model: model_from_json(v)?,
                watchdog: watchdog_from_report_json(v),
            })),
            "model_select" => {
                let scores = v
                    .get("scores")
                    .and_then(|s| s.as_arr())
                    .ok_or_else(|| err!("missing 'scores'"))?
                    .iter()
                    .map(score_from_json)
                    .collect::<Result<Vec<_>>>()?;
                Ok(Report::ModelSelect(RescalkReport {
                    scores,
                    k_opt: get_f64(v, "k_opt")? as usize,
                    a: mat_from_json(v.get("a").ok_or_else(|| err!("missing 'a'"))?)?,
                    r: tensor_from_json(v.get("r").ok_or_else(|| err!("missing 'r'"))?)?,
                    traces: report_traces_from_json(v)?,
                    timeline: timeline_from_report_json(v)?,
                    wall_seconds: get_f64(v, "wall_seconds")?,
                    workspace: workspace_from_json(telemetry_field(v, "workspace")),
                    transport_backend: transport_backend_from_json(v),
                    model: model_from_json(v)?,
                    watchdog: watchdog_from_report_json(v),
                }))
            }
            "simulate" => {
                let scenario = v
                    .get("scenario")
                    .and_then(|s| s.as_str())
                    .ok_or_else(|| err!("missing 'scenario'"))?
                    .to_string();
                let rows = v
                    .get("runs")
                    .and_then(|r| r.as_arr())
                    .ok_or_else(|| err!("missing 'runs'"))?
                    .iter()
                    .map(sim_row_from_json)
                    .collect::<Result<Vec<_>>>()?;
                Ok(Report::Simulate(SimReport { scenario, rows }))
            }
            other => Err(err!("unknown report kind '{other}'")),
        }
    }
}

fn get_f64(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| err!("report missing numeric field '{key}'"))
}

pub(crate) fn mat_to_json(m: &Mat) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("rows".to_string(), Json::Num(m.rows() as f64));
    obj.insert("cols".to_string(), Json::Num(m.cols() as f64));
    obj.insert(
        "data".to_string(),
        Json::Arr(m.as_slice().iter().map(|&x| Json::Num(x as f64)).collect()),
    );
    Json::Obj(obj)
}

pub(crate) fn mat_from_json(v: &Json) -> Result<Mat> {
    let rows = get_f64(v, "rows")? as usize;
    let cols = get_f64(v, "cols")? as usize;
    let data = v
        .get("data")
        .and_then(|d| d.as_arr())
        .ok_or_else(|| err!("matrix missing 'data'"))?
        .iter()
        .map(|x| x.as_f64().map(|f| f as f32).ok_or_else(|| err!("non-numeric matrix entry")))
        .collect::<Result<Vec<f32>>>()?;
    // untrusted-input path: absurd shapes must not overflow the
    // expected-length product (debug panic), and the length mismatch
    // stays a typed error rather than the Mat::from_vec assert
    let expect = rows
        .checked_mul(cols)
        .ok_or_else(|| err!("matrix shape {rows}x{cols} overflows"))?;
    if data.len() != expect {
        return Err(err!("matrix data length {} != {rows}x{cols}", data.len()));
    }
    Ok(Mat::from_vec(rows, cols, data))
}

pub(crate) fn tensor_to_json(t: &Tensor3) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert(
        "slices".to_string(),
        Json::Arr(t.slices().iter().map(mat_to_json).collect()),
    );
    Json::Obj(obj)
}

pub(crate) fn tensor_from_json(v: &Json) -> Result<Tensor3> {
    let slices = v
        .get("slices")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| err!("tensor missing 'slices'"))?
        .iter()
        .map(mat_from_json)
        .collect::<Result<Vec<Mat>>>()?;
    if slices.is_empty() {
        return Err(err!("tensor has no slices"));
    }
    // this function parses untrusted files (model artifacts, archived
    // reports): ragged slices must be a typed error, not the
    // `Tensor3::from_slices` assert
    let shape = slices[0].shape();
    if let Some(t) = slices.iter().position(|s| s.shape() != shape) {
        return Err(err!(
            "tensor slice {t} is {}×{} but slice 0 is {}×{} — all slices must share one shape",
            slices[t].rows(),
            slices[t].cols(),
            shape.0,
            shape.1
        ));
    }
    Ok(Tensor3::from_slices(slices))
}

/// The unified `telemetry` section: per-rank op-aggregate traces, the
/// workspace counters, the transport backend + compute/comm split with
/// real wire traffic, and (when span tracing ran) the cross-rank
/// timeline the Chrome-trace exporter consumes.
fn telemetry_to_json(
    traces: &[Trace],
    workspace: crate::backend::WorkspaceStats,
    backend: &str,
    timeline: &[crate::obs::RankTimeline],
    watchdog: &[crate::obs::WatchdogEvent],
) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("traces".to_string(), traces_to_json(traces));
    obj.insert("workspace".to_string(), workspace_to_json(workspace));
    obj.insert("transport".to_string(), transport_to_json(backend, traces));
    obj.insert("kernel".to_string(), kernel_to_json());
    obj.insert(
        "timeline".to_string(),
        Json::Arr(timeline.iter().map(crate::obs::timeline_to_json).collect()),
    );
    obj.insert(
        "watchdog".to_string(),
        Json::Arr(watchdog.iter().map(crate::obs::WatchdogEvent::to_json).collect()),
    );
    Json::Obj(obj)
}

/// Watchdog warnings from the unified telemetry section; absent-tolerant
/// (pre-live-plane reports carry none) and skips malformed entries
/// rather than failing the whole report parse.
fn watchdog_from_report_json(v: &Json) -> Vec<crate::obs::WatchdogEvent> {
    telemetry_field(v, "watchdog")
        .and_then(Json::as_arr)
        .map(|events| {
            events.iter().filter_map(crate::obs::WatchdogEvent::from_json).collect()
        })
        .unwrap_or_default()
}

/// The kernel-plane context every report carries: which SIMD microkernel
/// dispatch selected on this machine and the blocking in effect (default
/// or a `drescal tune` profile) — so an archived report's timings are
/// attributable to the code path that produced them.
fn kernel_to_json() -> Json {
    let kern = crate::tensor::kernel::dispatch::active();
    let (mc, kc, nc) = crate::tensor::kernel::blocking();
    let mut obj = BTreeMap::new();
    obj.insert("variant".to_string(), Json::Str(kern.name.to_string()));
    obj.insert("isa".to_string(), Json::Str(kern.isa.to_string()));
    obj.insert("mr".to_string(), Json::Num(kern.mr as f64));
    obj.insert("nr".to_string(), Json::Num(kern.nr as f64));
    obj.insert("mc".to_string(), Json::Num(mc as f64));
    obj.insert("kc".to_string(), Json::Num(kc as f64));
    obj.insert("nc".to_string(), Json::Num(nc as f64));
    Json::Obj(obj)
}

/// Look a field up under the unified `telemetry` section, falling back to
/// the top level where archived pre-telemetry-plane reports kept it.
fn telemetry_field<'a>(v: &'a Json, key: &str) -> Option<&'a Json> {
    v.get("telemetry").and_then(|t| t.get(key)).or_else(|| v.get(key))
}

/// Traces from either report layout; a report with neither section (e.g.
/// one archived from an untraced run) parses to no traces rather than
/// erroring, matching the empty-trace-tolerant metric aggregation.
fn report_traces_from_json(v: &Json) -> Result<Vec<Trace>> {
    match telemetry_field(v, "traces") {
        Some(t) => traces_from_json(t),
        None => Ok(Vec::new()),
    }
}

/// The gathered span timeline; absent in archived pre-telemetry-plane
/// reports and in untraced runs, which both parse to empty.
fn timeline_from_report_json(v: &Json) -> Result<Vec<crate::obs::RankTimeline>> {
    match telemetry_field(v, "timeline").and_then(|t| t.as_arr()) {
        Some(arr) => arr.iter().map(crate::obs::timeline_from_json).collect(),
        None => Ok(Vec::new()),
    }
}

/// The report's `transport` section: which backend the collectives ran
/// over, plus the per-rank compute/comm split with real wire traffic.
fn transport_to_json(backend: &str, traces: &[Trace]) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("backend".to_string(), Json::Str(backend.to_string()));
    obj.insert(
        "ranks".to_string(),
        Json::Arr(
            traces
                .iter()
                .map(|t| {
                    let (comp, comm) = t.compute_comm_split();
                    let (bytes, ops) = t.comm_totals();
                    let mut r = BTreeMap::new();
                    r.insert("compute_seconds".to_string(), Json::Num(comp));
                    r.insert("comm_seconds".to_string(), Json::Num(comm));
                    r.insert("comm_bytes".to_string(), Json::Num(bytes as f64));
                    r.insert("comm_ops".to_string(), Json::Num(ops as f64));
                    Json::Obj(r)
                })
                .collect(),
        ),
    );
    Json::Obj(obj)
}

/// Archived pre-model-family reports have no `model` field; those jobs
/// all ran the Gaussian RESCAL rule. A present-but-unknown name is a
/// typed error, not a silent default.
pub(crate) fn model_from_json(v: &Json) -> Result<ModelKind> {
    match v.get("model").and_then(|m| m.as_str()) {
        Some(name) => ModelKind::parse(name),
        None => Ok(ModelKind::Rescal),
    }
}

/// Archived pre-transport-plane reports have no `transport` section;
/// those jobs all ran in-process.
fn transport_backend_from_json(v: &Json) -> String {
    telemetry_field(v, "transport")
        .and_then(|t| t.get("backend"))
        .and_then(|b| b.as_str())
        .unwrap_or("in_process")
        .to_string()
}

/// Workspace counters serialize as a small object; absent in archived
/// pre-kernel-plane reports, so parsing treats a missing field as zeros.
pub(crate) fn workspace_to_json(w: crate::backend::WorkspaceStats) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("mat_allocs".to_string(), Json::Num(w.mat_allocs as f64));
    obj.insert("mat_reuses".to_string(), Json::Num(w.mat_reuses as f64));
    Json::Obj(obj)
}

pub(crate) fn workspace_from_json(v: Option<&Json>) -> crate::backend::WorkspaceStats {
    let mut w = crate::backend::WorkspaceStats::default();
    if let Some(v) = v {
        if let Some(x) = v.get("mat_allocs").and_then(|x| x.as_f64()) {
            w.mat_allocs = x as usize;
        }
        if let Some(x) = v.get("mat_reuses").and_then(|x| x.as_f64()) {
            w.mat_reuses = x as usize;
        }
    }
    w
}

pub(crate) fn score_to_json(s: &KScore) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("k".to_string(), Json::Num(s.k as f64));
    obj.insert("sil_min".to_string(), Json::Num(s.sil_min as f64));
    obj.insert("sil_avg".to_string(), Json::Num(s.sil_avg as f64));
    obj.insert("rel_error".to_string(), Json::Num(s.rel_error as f64));
    Json::Obj(obj)
}

pub(crate) fn score_from_json(v: &Json) -> Result<KScore> {
    Ok(KScore {
        k: get_f64(v, "k")? as usize,
        sil_min: get_f64(v, "sil_min")? as f32,
        sil_avg: get_f64(v, "sil_avg")? as f32,
        rel_error: get_f64(v, "rel_error")? as f32,
    })
}

/// Per-rank traces serialize as the per-op aggregate (seconds + bytes),
/// which is what the scaling figures consume.
pub(crate) fn traces_to_json(traces: &[Trace]) -> Json {
    Json::Arr(
        traces
            .iter()
            .map(|t| {
                let mut ops = BTreeMap::new();
                for &op in CommOp::all() {
                    let secs = t.seconds(op);
                    let bytes = t.bytes(op);
                    if secs > 0.0 || bytes > 0 {
                        let mut entry = BTreeMap::new();
                        entry.insert("seconds".to_string(), Json::Num(secs));
                        entry.insert("bytes".to_string(), Json::Num(bytes as f64));
                        ops.insert(op.name().to_string(), Json::Obj(entry));
                    }
                }
                Json::Obj(ops)
            })
            .collect(),
    )
}

fn op_from_name(name: &str) -> Option<CommOp> {
    CommOp::all().iter().copied().find(|op| op.name() == name)
}

pub(crate) fn traces_from_json(v: &Json) -> Result<Vec<Trace>> {
    v.as_arr()
        .ok_or_else(|| err!("'traces' must be an array"))?
        .iter()
        .map(|t| {
            let obj = t.as_obj().ok_or_else(|| err!("trace must be an object"))?;
            let mut trace = Trace::new();
            for (name, entry) in obj {
                let op = op_from_name(name)
                    .ok_or_else(|| err!("unknown trace op '{name}'"))?;
                let secs = get_f64(entry, "seconds")?;
                let bytes = get_f64(entry, "bytes")? as usize;
                trace.push(op, bytes, Duration::from_secs_f64(secs));
            }
            Ok(trace)
        })
        .collect()
}

fn sim_row_to_json(r: &SimRow) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("label".to_string(), Json::Str(r.label.clone()));
    obj.insert("n".to_string(), Json::Num(r.n as f64));
    obj.insert("m".to_string(), Json::Num(r.m as f64));
    obj.insert("p".to_string(), Json::Num(r.p as f64));
    obj.insert("density".to_string(), Json::Num(r.density));
    obj.insert("iters".to_string(), Json::Num(r.iters as f64));
    obj.insert("compute_seconds".to_string(), Json::Num(r.compute_seconds));
    obj.insert("comm_seconds".to_string(), Json::Num(r.comm_seconds));
    Json::Obj(obj)
}

fn sim_row_from_json(v: &Json) -> Result<SimRow> {
    Ok(SimRow {
        label: v
            .get("label")
            .and_then(|l| l.as_str())
            .ok_or_else(|| err!("run missing 'label'"))?
            .to_string(),
        n: get_f64(v, "n")? as usize,
        m: get_f64(v, "m")? as usize,
        p: get_f64(v, "p")? as usize,
        density: get_f64(v, "density")?,
        iters: get_f64(v, "iters")? as usize,
        compute_seconds: get_f64(v, "compute_seconds")?,
        comm_seconds: get_f64(v, "comm_seconds")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulate_report_json_roundtrip_exact() {
        let report = Report::Simulate(SimReport {
            scenario: "dense_11tb".to_string(),
            rows: vec![SimRow {
                label: "dense 11.5TB".to_string(),
                n: 396_800,
                m: 20,
                p: 4096,
                density: 1.0,
                iters: 200,
                compute_seconds: 5000.25,
                comm_seconds: 1250.5,
            }],
        });
        let json = report.to_json();
        // serialize -> parse is the identity on the Json value
        let reparsed = Json::parse(&json.to_string()).unwrap();
        assert_eq!(reparsed, json);
        // from_json rebuilds the same report
        let back = Report::from_json(&reparsed).unwrap();
        match (report, back) {
            (Report::Simulate(a), Report::Simulate(b)) => assert_eq!(a, b),
            _ => panic!("kind changed in roundtrip"),
        }
    }

    #[test]
    fn sim_row_derived_quantities() {
        let row = SimRow {
            label: "x".into(),
            n: 1000,
            m: 2,
            p: 4,
            density: 1.0,
            iters: 10,
            compute_seconds: 3.0,
            comm_seconds: 1.0,
        };
        assert_eq!(row.total(), 4.0);
        assert!((row.comm_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(row.logical_bytes(), 8e9);
    }

    #[test]
    fn kernel_section_reports_dispatch_and_blocking() {
        let v = kernel_to_json();
        let kern = crate::tensor::kernel::dispatch::active();
        let (mc, kc, nc) = crate::tensor::kernel::blocking();
        assert_eq!(v.get("variant").and_then(Json::as_str), Some(kern.name));
        assert_eq!(v.get("isa").and_then(Json::as_str), Some(kern.isa));
        assert_eq!(v.get("mr").and_then(Json::as_usize), Some(kern.mr));
        assert_eq!(v.get("nr").and_then(Json::as_usize), Some(kern.nr));
        assert_eq!(v.get("mc").and_then(Json::as_usize), Some(mc));
        assert_eq!(v.get("kc").and_then(Json::as_usize), Some(kc));
        assert_eq!(v.get("nc").and_then(Json::as_usize), Some(nc));
    }

    #[test]
    fn ragged_tensor_slices_are_a_typed_error() {
        // untrusted artifact JSON must not reach the Tensor3 assert
        let json = Json::parse(
            r#"{"slices":[{"rows":1,"cols":1,"data":[1]},{"rows":2,"cols":2,"data":[1,2,3,4]}]}"#,
        )
        .unwrap();
        let e = tensor_from_json(&json).unwrap_err();
        assert!(e.to_string().contains("share one shape"), "{e}");
    }

    #[test]
    fn rejects_malformed_reports() {
        assert!(Report::from_json(&Json::parse(r#"{"kind":"nope"}"#).unwrap()).is_err());
        assert!(Report::from_json(&Json::parse(r#"{"no_kind":1}"#).unwrap()).is_err());
        assert!(
            Report::from_json(&Json::parse(r#"{"kind":"factorize"}"#).unwrap()).is_err()
        );
    }
}
