//! # drescal — Distributed non-negative RESCAL with automatic model selection
//!
//! A from-scratch reproduction of **pyDRESCALk** (Bhattarai et al., 2022):
//! non-negative RESCAL factorization of relational tensors
//! `X_t ≈ A R_t Aᵀ` distributed over a 2D virtual processor grid, with
//! automatic selection of the number of latent communities `k` via
//! perturbation resampling, LSA-aligned clustering, and silhouette
//! statistics.
//!
//! The stack has three layers (see DESIGN.md):
//! * L1/L2 (build time): Pallas kernels + JAX segments, AOT-lowered to HLO
//!   text in `artifacts/`.
//! * L3 (this crate): the distributed algorithm, virtual-MPI substrate,
//!   model selection, datasets, CLI, and benchmarks. Compute runs either on
//!   the PJRT runtime (`runtime`/`backend::xla`) or the native fallback.
pub mod backend;
pub mod bench_util;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod linalg;
pub mod model_selection;
pub mod rescal;
pub mod rng;
pub mod simulate;
pub mod runtime;
pub mod tensor;
pub mod testing;
