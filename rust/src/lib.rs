//! # drescal — Distributed non-negative RESCAL with automatic model selection
//!
//! A from-scratch reproduction of **pyDRESCALk** (Bhattarai et al., 2022):
//! non-negative RESCAL factorization of relational tensors
//! `X_t ≈ A R_t Aᵀ` distributed over a 2D virtual processor grid, with
//! automatic selection of the number of latent communities `k` via
//! perturbation resampling, LSA-aligned clustering, and silhouette
//! statistics.
//!
//! ## The job engine
//!
//! All distributed work goes through [`engine::Engine`], built once from a
//! typed [`engine::EngineConfig`] and reused for any number of jobs:
//!
//! * **ingest** — real corpora enter through the storage plane
//!   ([`store`]): `drescal ingest` streams a triple list into
//!   checksummed binary tile shards plus a manifest, with entity and
//!   relation names interned to deterministic ids;
//! * **configure / rendezvous** — [`engine::Engine::new`] validates the
//!   config and builds the rank pool for the configured
//!   [`engine::TransportKind`]: in-process √p×√p rank threads (the
//!   default), or a TCP cluster where construction blocks until the
//!   remote `drescal worker` processes have joined (see
//!   [`engine::cluster`] and [`comm::transport`]); either way each
//!   rank's compute backend is built exactly once;
//! * **load** — [`engine::Engine::load_dataset`] distributes a
//!   [`engine::DatasetSpec`] once; every rank caches its resident tile
//!   (synthetic data is generated rank-locally, and ingested corpora are
//!   read shard-by-shard on the ranks — dense tiles memory-map
//!   zero-copy — so the global tensor never exists on the leader);
//! * **submit** — [`engine::JobSpec::Factorize`] (Alg 3),
//!   [`engine::JobSpec::ModelSelect`] (Alg 1), or
//!   [`engine::JobSpec::Simulate`] (the Fig 13 cluster-scale replay),
//!   each referencing a registered [`engine::DatasetHandle`];
//! * **report** — every job returns a unified [`engine::Report`] that
//!   serializes to JSON;
//! * **export** — [`engine::Engine::export_model`] turns a factorize or
//!   model-select report into a persisted [`serve::FactorModel`]
//!   artifact;
//! * **serve** — a [`serve::QueryEngine`] answers pointwise and batched
//!   top-k link-prediction queries from the reloaded artifact (the read
//!   path that mirrors the engine's write path — see [`serve`]);
//! * **observe** — every plane feeds the *live* telemetry plane
//!   ([`obs`]): a per-rank span [`obs::Recorder`] times each collective,
//!   GEMM, and MU phase (zero overhead and counter-provably zero
//!   allocations when disabled). Remote workers stream incremental span
//!   deltas to the leader at every iteration boundary, so the leader's
//!   [`obs::LiveHub`] is current mid-job and a crashed worker's
//!   pre-crash spans survive into the final artifact. `--status-port`
//!   serves the hub over a dependency-free HTTP/1.1 endpoint
//!   ([`obs::StatusServer`]): `/healthz`, `/metrics` (Prometheus text
//!   from [`obs::MetricsRegistry`]), `/progress` (per-iteration JSON
//!   with [`obs::ProgressEvent`] history and [`obs::Watchdog`] warnings
//!   on stall, NaN/divergence, deadline overrun, and transport
//!   degradation), and `/trace`; `drescal monitor` renders it live.
//!   `--trace-out` exports the whole cluster's wall-clock-anchored
//!   timeline as Chrome trace-event JSON for Perfetto, with
//!   `drescal trace-summary` printing the paper's §6.3-style per-op
//!   breakdown from the same file. The serve path records per-query
//!   latency into log-bucketed [`obs::Histogram`]s (p50/p95/p99).
//!
//! ## The model-family axis
//!
//! The per-relation update math is a [`rescal::model::Model`] trait
//! behind the shared distributed loop ([`rescal::distributed::rescal_rank`]
//! owns the collectives, normalization, and convergence checks; the
//! family supplies one `slice_update`). Three families ship, selected by
//! [`rescal::ModelKind`] (`--model` on the CLI,
//! [`engine::EngineConfig::with_model`] in the API):
//!
//! * `rescal` (default) — the paper's Gaussian rule with dense `k×k`
//!   cores;
//! * `distmult` — diagonal cores persisted as `1×k` vectors; the core
//!   update collapses to `O(k²)` per slice and serving scores without
//!   ever densifying a core;
//! * `logistic` — Bernoulli likelihood whose MU denominators use the
//!   sigmoid reconstruction `σ(A R_t Aᵀ)`; served scores are
//!   probabilities.
//!
//! Reports and exported artifacts are stamped with the family
//! (pre-family artifacts load as `rescal`), and serving under the wrong
//! family is a typed mismatch error
//! ([`serve::FactorModel::ensure_model`]).
//!
//! The persistent pool and resident dataset tiles are what make
//! repeated-job workloads (k sweeps, perturbation ensembles, bench loops)
//! fast: no per-job thread spawn, no backend or XLA executable-cache
//! rebuild, no per-job re-tiling. The typed CLI layer
//! ([`config::RunConfig`]) parses and validates all flags in one place
//! before any engine is built.
//!
//! ## The stack
//!
//! Three layers (see DESIGN.md):
//! * L1/L2 (build time): Pallas kernels + JAX segments, AOT-lowered to HLO
//!   text in `artifacts/`.
//! * L3 (this crate): the distributed algorithm, virtual-MPI substrate
//!   ([`comm`]), the job engine ([`engine`]), model selection, datasets,
//!   CLI, and benchmarks. Compute runs either on the PJRT runtime
//!   ([`runtime`] / [`backend::xla`], `--features pjrt`) or the native
//!   fallback; the default offline build ships a stub runtime so the whole
//!   system works without the XLA bindings.
//!
//! The crate is dependency-free: JSON ([`json`]), error handling
//! ([`error`]), RNG ([`rng`]), and the bench harness ([`bench_util`]) are
//! small internal modules.
pub mod backend;
pub mod bench_util;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod json;
pub mod linalg;
pub mod model_selection;
pub mod obs;
pub mod rescal;
pub mod rng;
pub mod serve;
pub mod simulate;
pub mod store;
pub mod runtime;
pub mod tensor;
pub mod testing;
