//! Seedable PRNG and distributions.
//!
//! The offline crate set has no `rand`, so we carry our own xoshiro256++
//! generator (Blackman & Vigna). pyDRESCALk seeds each MPI rank with a
//! function of its rank (§6.1.3); [`Rng::for_rank`] reproduces that scheme.

/// xoshiro256++ generator: fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// splitmix64, used to expand a 64-bit seed into the full state as the
/// xoshiro authors recommend.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless counter-based hash of one tensor cell: a well-mixed u64 from
/// `(seed, stream, t, i, j)`. This is what makes rank-local dataset
/// generation grid-invariant — any rank can reproduce the randomness of
/// any global cell without owning a shared generator (the per-cell
/// analogue of the [`Rng::for_rank`] per-block scheme).
#[inline]
pub fn hash_cell(seed: u64, stream: u64, t: usize, i: usize, j: usize) -> u64 {
    let mut s = seed
        ^ stream.wrapping_mul(0xD1B5_4A32_D192_ED03)
        ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (i as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ (j as u64).wrapping_mul(0x94D0_49BB_1331_11EB);
    // the multiplies above are linear; splitmix64's three-stage
    // finalizer supplies the avalanche
    splitmix64(&mut s)
}

/// Uniform f32 in [0, 1) derived from [`hash_cell`].
#[inline]
pub fn hash_cell_unit(seed: u64, stream: u64, t: usize, i: usize, j: usize) -> f32 {
    (hash_cell(seed, stream, t, i, j) >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Per-rank generator: `seed` is the experiment seed, `rank` the MPI
    /// rank, `stream` distinguishes uses (perturbation index, init, …).
    pub fn for_rank(seed: u64, rank: usize, stream: u64) -> Self {
        Rng::new(
            seed ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ stream.wrapping_mul(0xD1B5_4A32_D192_ED03),
        )
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform_f32()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // our non-cryptographic uses.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple, branch-light).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std as f32.
    #[inline]
    pub fn normal(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Exponential with scale (mean) `scale`.
    #[inline]
    pub fn exponential(&mut self, scale: f32) -> f32 {
        let u: f64 = 1.0 - self.uniform(); // (0,1]
        (-(u.ln()) as f32) * scale
    }

    /// Fill a slice with U[lo, hi).
    pub fn fill_uniform(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf.iter_mut() {
            *v = self.uniform_range(lo, hi);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn rank_streams_are_distinct() {
        let mut a = Rng::for_rank(42, 0, 0);
        let mut b = Rng::for_rank(42, 1, 0);
        let mut c = Rng::for_rank(42, 0, 1);
        let x = a.next_u64();
        assert_ne!(x, b.next_u64());
        assert_ne!(x, c.next_u64());
    }

    #[test]
    fn hash_cell_is_deterministic_and_mixes() {
        assert_eq!(hash_cell(42, 1, 0, 3, 4), hash_cell(42, 1, 0, 3, 4));
        // neighbouring cells, streams, and seeds all decorrelate
        let base = hash_cell(42, 1, 0, 3, 4);
        assert_ne!(base, hash_cell(42, 1, 0, 3, 5));
        assert_ne!(base, hash_cell(42, 1, 0, 4, 4));
        assert_ne!(base, hash_cell(42, 1, 1, 3, 4));
        assert_ne!(base, hash_cell(42, 2, 0, 3, 4));
        assert_ne!(base, hash_cell(43, 1, 0, 3, 4));
    }

    #[test]
    fn hash_cell_unit_is_uniform_enough() {
        let n = 64usize;
        let mut sum = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let u = hash_cell_unit(7, 3, 0, i, j);
                assert!((0.0..1.0).contains(&u));
                sum += u as f64;
            }
        }
        let mean = sum / (n * n) as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean_matches_scale() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(13);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(17);
        let p = r.permutation(50);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
