//! Native backend: the packed microkernel GEMM from
//! [`crate::tensor::kernel`], written straight into caller-owned
//! (workspace) buffers. Every op rides the runtime-dispatched SIMD
//! microkernel (AVX2/AVX-512/NEON, scalar fallback) and the blocking
//! installed by `drescal tune`; `gram_into` routes its mirrored lower
//! triangle through the same packed path without allocating.

use super::Backend;
use crate::tensor::{kernel, Mat};

/// CPU backend with no external dependencies; handles every shape.
#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn matmul_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        kernel::gemm_nn_into(a, b, out, false);
    }

    fn t_matmul_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        kernel::gemm_tn_into(a, b, out);
    }

    fn matmul_t_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        kernel::gemm_nt_into(a, b, out);
    }

    fn gram_into(&mut self, a: &Mat, out: &mut Mat) {
        kernel::gram_into(a, out);
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::assert_close;

    #[test]
    fn backend_ops_match_mat_ops() {
        let mut rng = Rng::new(90);
        let mut be = NativeBackend::new();
        let a = Mat::random_uniform(12, 5, 0.0, 1.0, &mut rng);
        let b = Mat::random_uniform(5, 7, 0.0, 1.0, &mut rng);
        assert_close(be.matmul(&a, &b).as_slice(), a.matmul(&b).as_slice(), 1e-6);
        let c = Mat::random_uniform(12, 7, 0.0, 1.0, &mut rng);
        assert_close(be.t_matmul(&a, &c).as_slice(), a.t_matmul(&c).as_slice(), 1e-6);
        let d = Mat::random_uniform(7, 5, 0.0, 1.0, &mut rng);
        assert_close(be.matmul_t(&a, &d).as_slice(), a.matmul_t(&d).as_slice(), 1e-6);
        assert_close(be.gram(&a).as_slice(), a.gram().as_slice(), 1e-6);
        assert_eq!(be.name(), "native");
    }

    #[test]
    fn into_ops_overwrite_stale_contents() {
        let mut rng = Rng::new(91);
        let mut be = NativeBackend::new();
        let a = Mat::random_uniform(9, 4, 0.0, 1.0, &mut rng);
        let b = Mat::random_uniform(4, 6, 0.0, 1.0, &mut rng);
        // a reused workspace buffer arrives with stale values; the into
        // contract is overwrite, not accumulate
        let mut out = Mat::full(9, 6, 123.0);
        be.matmul_into(&a, &b, &mut out);
        assert_close(out.as_slice(), a.matmul(&b).as_slice(), 1e-6);
        let mut g = Mat::full(4, 4, -7.0);
        be.gram_into(&a, &mut g);
        assert_close(g.as_slice(), a.gram().as_slice(), 1e-6);
    }

    #[test]
    fn gram_never_clones_and_is_symmetric() {
        let mut rng = Rng::new(92);
        let mut be = NativeBackend::new();
        let a = Mat::random_uniform(40, 8, 0.0, 1.0, &mut rng);
        let g = be.gram(&a);
        // exactly symmetric by construction (upper triangle mirrored)
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(g[(i, j)], g[(j, i)]);
            }
        }
        assert_close(g.as_slice(), a.t_matmul(&a).as_slice(), 1e-4);
    }

    #[test]
    fn spec_builds_native() {
        let spec = super::super::BackendSpec::Native;
        let be = spec.build().unwrap();
        assert_eq!(be.name(), "native");
    }
}
