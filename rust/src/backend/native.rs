//! Native backend: the blocked, thread-parallel GEMM from `tensor::dense`.

use super::Backend;
use crate::tensor::Mat;

/// CPU backend with no external dependencies; handles every shape.
#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn matmul(&mut self, a: &Mat, b: &Mat) -> Mat {
        a.matmul(b)
    }

    fn t_matmul(&mut self, a: &Mat, b: &Mat) -> Mat {
        a.t_matmul(b)
    }

    fn matmul_t(&mut self, a: &Mat, b: &Mat) -> Mat {
        a.matmul_t(b)
    }

    fn gram(&mut self, a: &Mat) -> Mat {
        a.gram()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::assert_close;

    #[test]
    fn backend_ops_match_mat_ops() {
        let mut rng = Rng::new(90);
        let mut be = NativeBackend::new();
        let a = Mat::random_uniform(12, 5, 0.0, 1.0, &mut rng);
        let b = Mat::random_uniform(5, 7, 0.0, 1.0, &mut rng);
        assert_close(be.matmul(&a, &b).as_slice(), a.matmul(&b).as_slice(), 1e-6);
        let c = Mat::random_uniform(12, 7, 0.0, 1.0, &mut rng);
        assert_close(be.t_matmul(&a, &c).as_slice(), a.t_matmul(&c).as_slice(), 1e-6);
        let d = Mat::random_uniform(7, 5, 0.0, 1.0, &mut rng);
        assert_close(be.matmul_t(&a, &d).as_slice(), a.matmul_t(&d).as_slice(), 1e-6);
        assert_close(be.gram(&a).as_slice(), a.gram().as_slice(), 1e-6);
        assert_eq!(be.name(), "native");
    }

    #[test]
    fn spec_builds_native() {
        let spec = super::super::BackendSpec::Native;
        let be = spec.build().unwrap();
        assert_eq!(be.name(), "native");
    }
}
