//! Compute backends.
//!
//! The paper swaps NumPy/OpenBLAS (CPU) for CuPy/cuBLAS (GPU) behind one
//! array API; we do the same behind [`Backend`]: `Native` is the
//! hand-written blocked GEMM in `tensor::dense`, `Xla` executes the
//! AOT-compiled JAX/Pallas artifacts through PJRT (see `runtime`). Each
//! virtual rank owns one backend instance (`&mut self` lets backends keep
//! executable caches and workspaces without locks).

pub mod native;
pub mod xla;

use crate::tensor::Mat;

/// Dense compute interface used by the RESCAL hot path.
///
/// Not `Send`: the PJRT handles in the XLA backend hold raw pointers, so
/// each rank thread builds its own backend via [`BackendSpec::build`].
pub trait Backend {
    /// `A · B`
    fn matmul(&mut self, a: &Mat, b: &Mat) -> Mat;
    /// `Aᵀ · B`
    fn t_matmul(&mut self, a: &Mat, b: &Mat) -> Mat;
    /// `A · Bᵀ`
    fn matmul_t(&mut self, a: &Mat, b: &Mat) -> Mat;
    /// `AᵀA`
    fn gram(&mut self, a: &Mat) -> Mat {
        self.t_matmul(&a.clone(), a)
    }
    /// Fused multiplicative update `target *= num / (deno + eps)`.
    fn mu_update(&mut self, target: &mut Mat, num: &Mat, deno: &Mat, eps: f32) {
        crate::tensor::ops::mu_update(target, num, deno, eps);
    }
    /// Fused R-slice MU step `R_t ∘ AᵀXA / (AᵀA·R_t·AᵀA + ε)` — one L1
    /// Pallas kernel on the XLA backend (two k×k GEMMs + the elementwise
    /// update without leaving the artifact). `None` = not supported for
    /// this shape; caller composes from the generic ops.
    fn r_update_fused(&mut self, _r_t: &Mat, _ata: &Mat, _atxa: &Mat) -> Option<Mat> {
        None
    }
    /// Fused per-slice local segment (Alg 3 lines 7-11 + 15-19): given
    /// `(R_t, AᵀA, AᵀXA, XA, A_row)` returns
    /// `(R_t_new, XART, AR, DenoTerms)` in one artifact execution — the
    /// §Perf fusion that collapses ~9 PJRT calls per slice into one.
    /// `None` = unsupported shape; the caller composes from generic ops.
    fn slice_segment(
        &mut self,
        _r_t: &Mat,
        _ata: &Mat,
        _atxa: &Mat,
        _xa: &Mat,
        _a_row: &Mat,
    ) -> Option<(Mat, Mat, Mat, Mat)> {
        None
    }
    /// Backend display name.
    fn name(&self) -> &'static str;
}

/// How to construct a backend on each rank thread.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum BackendSpec {
    /// Hand-written blocked GEMM (works for every shape).
    #[default]
    Native,
    /// PJRT execution of the AOT artifacts in the given directory, with
    /// native fallback for shapes not in the manifest.
    Xla {
        artifact_dir: String,
    },
}

impl BackendSpec {
    /// Instantiate the backend for one rank.
    pub fn build(&self) -> crate::error::Result<Box<dyn Backend>> {
        match self {
            BackendSpec::Native => Ok(Box::new(native::NativeBackend::new())),
            BackendSpec::Xla { artifact_dir } => {
                Ok(Box::new(xla::XlaBackend::new(artifact_dir)?))
            }
        }
    }
}
