//! Compute backends and the kernel plane's write-into contract.
//!
//! The paper swaps NumPy/OpenBLAS (CPU) for CuPy/cuBLAS (GPU) behind one
//! array API; we do the same behind [`Backend`]: `Native` runs the
//! packed microkernel GEMM in [`crate::tensor::kernel`], `Xla` executes
//! the AOT-compiled JAX/Pallas artifacts through PJRT (see
//! `crate::runtime`). Each virtual rank owns one backend instance
//! (`&mut self` lets backends keep executable caches without locks).
//!
//! # The write-into API and workspace ownership
//!
//! The hot path runs on the `*_into` methods ([`Backend::matmul_into`],
//! [`Backend::t_matmul_into`], [`Backend::matmul_t_into`],
//! [`Backend::gram_into`]): the **caller** owns the output matrix and
//! the backend only fills it. Outputs and every iteration temporary come
//! from the per-rank [`Workspace`] arena — acquired once, reused by
//! every subsequent iteration and job — so a steady-state MU iteration
//! performs **zero matrix-buffer allocations**. (When a single GEMM is
//! large enough to cross the kernel's internal threading threshold, its
//! short-lived scoped workers still allocate their own pack scratch —
//! inherent to spawning; the engine's virtual-rank topology keeps
//! per-rank tiles below that threshold, and the scaling benches pin
//! `DRESCAL_THREADS=1`.) Two layers make the guarantee hold:
//!
//! * the [`Workspace`] owns all `Mat`-level temporaries (`XA`, `AᵀXA`,
//!   `AR`, numerator/denominator blocks, serve batch buffers) and counts
//!   alloc-vs-reuse checkouts ([`WorkspaceStats`]), surfaced in job
//!   reports and `ServeStats` so tests can *prove* the reuse;
//! * the packed kernel owns its A/B pack panels in per-thread scratch
//!   (see [`crate::tensor::kernel`]), sized once per thread.
//!
//! ## Contract
//!
//! `*_into` outputs must already have the product's exact shape (the
//! kernels assert it); contents are overwritten, not accumulated. The
//! allocating methods ([`Backend::matmul`] &c.) remain as thin compat
//! shims — one `Workspace`-free allocation plus the `*_into` call — for
//! cold paths and tests.
//!
//! ## How XLA fused paths coexist with native packing
//!
//! The XLA backend first offers each call to its artifact manifest
//! (static shapes baked by `aot.py`); on a hit the PJRT result is copied
//! into the caller's output buffer, on a miss it falls through to the
//! same native packed kernels. The bigger fused artifacts
//! ([`Backend::r_update_fused`], [`Backend::slice_segment`]) keep their
//! allocating `Option` signatures: they return multiple artifact outputs
//! at once and are XLA-only — the native path composes the same algebra
//! from `*_into` calls on workspace buffers instead.

pub mod native;
pub mod workspace;
pub mod xla;

pub use workspace::{Workspace, WorkspaceStats};

use crate::tensor::Mat;

/// Dense compute interface used by the RESCAL hot path.
///
/// Not `Send`: the PJRT handles in the XLA backend hold raw pointers, so
/// each rank thread builds its own backend via [`BackendSpec::build`].
pub trait Backend {
    /// `out = A · B`. `out` must be `a.rows() × b.cols()`.
    fn matmul_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat);
    /// `out = Aᵀ · B`. `out` must be `a.cols() × b.cols()`.
    fn t_matmul_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat);
    /// `out = A · Bᵀ`. `out` must be `a.rows() × b.rows()`.
    fn matmul_t_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat);
    /// `out = AᵀA` (exactly symmetric). `out` must be
    /// `a.cols() × a.cols()`.
    fn gram_into(&mut self, a: &Mat, out: &mut Mat);

    /// `A · B`, allocating — compat shim over [`Backend::matmul_into`].
    fn matmul(&mut self, a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows(), b.cols());
        self.matmul_into(a, b, &mut out);
        out
    }
    /// `Aᵀ · B`, allocating — compat shim over
    /// [`Backend::t_matmul_into`].
    fn t_matmul(&mut self, a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.cols(), b.cols());
        self.t_matmul_into(a, b, &mut out);
        out
    }
    /// `A · Bᵀ`, allocating — compat shim over
    /// [`Backend::matmul_t_into`].
    fn matmul_t(&mut self, a: &Mat, b: &Mat) -> Mat {
        let mut out = Mat::zeros(a.rows(), b.rows());
        self.matmul_t_into(a, b, &mut out);
        out
    }
    /// `AᵀA`, allocating — compat shim over [`Backend::gram_into`].
    fn gram(&mut self, a: &Mat) -> Mat {
        let mut out = Mat::zeros(a.cols(), a.cols());
        self.gram_into(a, &mut out);
        out
    }

    /// Fused multiplicative update `target *= num / (deno + eps)`.
    fn mu_update(&mut self, target: &mut Mat, num: &Mat, deno: &Mat, eps: f32) {
        crate::tensor::ops::mu_update(target, num, deno, eps);
    }
    /// Fused R-slice MU step `R_t ∘ AᵀXA / (AᵀA·R_t·AᵀA + ε)` — one L1
    /// Pallas kernel on the XLA backend (two k×k GEMMs + the elementwise
    /// update without leaving the artifact). `None` = not supported for
    /// this shape; caller composes from the generic ops.
    fn r_update_fused(&mut self, _r_t: &Mat, _ata: &Mat, _atxa: &Mat) -> Option<Mat> {
        None
    }
    /// Fused per-slice local segment (Alg 3 lines 7-11 + 15-19): given
    /// `(R_t, AᵀA, AᵀXA, XA, A_row)` returns
    /// `(R_t_new, XART, AR, DenoTerms)` in one artifact execution — the
    /// §Perf fusion that collapses ~9 PJRT calls per slice into one.
    /// `None` = unsupported shape; the caller composes from generic ops.
    fn slice_segment(
        &mut self,
        _r_t: &Mat,
        _ata: &Mat,
        _atxa: &Mat,
        _xa: &Mat,
        _a_row: &Mat,
    ) -> Option<(Mat, Mat, Mat, Mat)> {
        None
    }
    /// Backend display name.
    fn name(&self) -> &'static str;
}

/// How to construct a backend on each rank thread.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum BackendSpec {
    /// Hand-written packed microkernel GEMM (works for every shape).
    #[default]
    Native,
    /// PJRT execution of the AOT artifacts in the given directory, with
    /// native fallback for shapes not in the manifest.
    Xla {
        artifact_dir: String,
    },
}

impl BackendSpec {
    /// Instantiate the backend for one rank.
    pub fn build(&self) -> crate::error::Result<Box<dyn Backend>> {
        match self {
            BackendSpec::Native => Ok(Box::new(native::NativeBackend::new())),
            BackendSpec::Xla { artifact_dir } => {
                Ok(Box::new(xla::XlaBackend::new(artifact_dir)?))
            }
        }
    }
}
