//! XLA/PJRT backend: executes the AOT JAX+Pallas artifacts on the hot
//! path, falling back to the native GEMM for shapes outside the manifest.
//!
//! HLO artifacts have static shapes, so `aot.py` bakes the tile-shape set
//! of the configured experiments; anything else (odd tail tiles, tests
//! with random sizes) transparently takes the native path. Per-call hit /
//! fallback counts are kept so tests and benches can assert the artifact
//! path is actually exercised.

use super::{native::NativeBackend, Backend};
use crate::runtime::Runtime;
use crate::tensor::Mat;

/// PJRT-execution backend with native fallback.
pub struct XlaBackend {
    runtime: Runtime,
    native: NativeBackend,
    /// Calls served by PJRT artifacts.
    pub hits: usize,
    /// Calls that fell back to native.
    pub fallbacks: usize,
}

impl XlaBackend {
    /// Load and compile all artifacts in `artifact_dir`.
    pub fn new(artifact_dir: &str) -> crate::error::Result<Self> {
        let runtime = Runtime::load(artifact_dir)?;
        Ok(XlaBackend { runtime, native: NativeBackend::new(), hits: 0, fallbacks: 0 })
    }

    /// Wrap an already-loaded runtime.
    pub fn from_runtime(runtime: Runtime) -> Self {
        XlaBackend { runtime, native: NativeBackend::new(), hits: 0, fallbacks: 0 }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    fn run(&mut self, kind: &str, inputs: &[&Mat]) -> Option<Mat> {
        match self.runtime.execute(kind, inputs) {
            Ok(Some(m)) => {
                self.hits += 1;
                Some(m)
            }
            Ok(None) => {
                self.fallbacks += 1;
                None
            }
            Err(e) => {
                // PJRT failure on a matching shape is a real error: surface
                // loudly rather than silently diverging from the artifacts.
                panic!("PJRT execution failed for {kind}: {e:#}");
            }
        }
    }
}

impl Backend for XlaBackend {
    fn matmul_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        match self.run("matmul", &[a, b]) {
            Some(m) => out.copy_from(&m),
            None => self.native.matmul_into(a, b, out),
        }
    }

    fn t_matmul_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        match self.run("t_matmul", &[a, b]) {
            Some(m) => out.copy_from(&m),
            None => self.native.t_matmul_into(a, b, out),
        }
    }

    fn matmul_t_into(&mut self, a: &Mat, b: &Mat, out: &mut Mat) {
        match self.run("matmul_t", &[a, b]) {
            Some(m) => out.copy_from(&m),
            None => self.native.matmul_t_into(a, b, out),
        }
    }

    fn gram_into(&mut self, a: &Mat, out: &mut Mat) {
        match self.run("gram", &[a]) {
            Some(m) => out.copy_from(&m),
            None => self.native.gram_into(a, out),
        }
    }

    fn r_update_fused(&mut self, r_t: &Mat, ata: &Mat, atxa: &Mat) -> Option<Mat> {
        self.run("r_update", &[r_t, ata, atxa])
    }

    fn slice_segment(
        &mut self,
        r_t: &Mat,
        ata: &Mat,
        atxa: &Mat,
        xa: &Mat,
        a_row: &Mat,
    ) -> Option<(Mat, Mat, Mat, Mat)> {
        match self.runtime.execute_multi("slice_segment", &[r_t, ata, atxa, xa, a_row]) {
            Ok(Some(mut outs)) if outs.len() == 4 => {
                self.hits += 1;
                let deno = outs.pop().unwrap();
                let ar = outs.pop().unwrap();
                let xart = outs.pop().unwrap();
                let r_new = outs.pop().unwrap();
                Some((r_new, xart, ar, deno))
            }
            Ok(Some(_)) => panic!("slice_segment artifact returned wrong arity"),
            Ok(None) => {
                self.fallbacks += 1;
                None
            }
            Err(e) => panic!("PJRT execution failed for slice_segment: {e:#}"),
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
