//! The per-rank workspace arena behind the write-into [`super::Backend`]
//! API.
//!
//! Every iteration temporary of the training hot loop (`XA`, `AᵀXA`,
//! `AR`, the MU numerator/denominator blocks) and the serving scorer's
//! batch buffers are checked out of a [`Workspace`] instead of freshly
//! allocated. A checkout ([`Workspace::acquire`]) hands back a [`Mat`]
//! built on a recycled buffer whenever one with enough capacity has
//! been [`Workspace::release`]d before — so a steady-state iteration
//! (or a repeated job on the engine's persistent rank pool) performs
//! **zero** heap allocations for its matrix temporaries. Checkout
//! contents are **unspecified** (recycled buffers keep their stale
//! values, skipping a redundant memset): every consumer follows the
//! write-into contract and fully overwrites before reading.
//!
//! The arena counts both outcomes ([`WorkspaceStats`]): `mat_allocs` is
//! the number of checkouts that had to allocate a new buffer,
//! `mat_reuses` the number served from the free list. Those counters are
//! the proof mechanism for the zero-allocation guarantee: they surface
//! per job in `Report` (training) and cumulatively in `ServeStats`
//! (serving), and the kernel-plane tests assert `mat_allocs` stops
//! growing after warm-up.
//!
//! Buffer matching is best-fit on capacity, so a workspace shared by
//! mixed shapes (a model-selection sweep over several k, say) keeps the
//! small k×k core buffers from pinning the large n×k panels.

use crate::tensor::Mat;

/// Checkout counters, cumulative over a workspace's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// Checkouts that allocated a fresh buffer (free list empty or all
    /// candidates too small).
    pub mat_allocs: usize,
    /// Checkouts served by recycling a released buffer — no allocation.
    pub mat_reuses: usize,
}

impl WorkspaceStats {
    /// Counter delta since an earlier snapshot of the same workspace.
    pub fn since(self, earlier: WorkspaceStats) -> WorkspaceStats {
        WorkspaceStats {
            mat_allocs: self.mat_allocs - earlier.mat_allocs,
            mat_reuses: self.mat_reuses - earlier.mat_reuses,
        }
    }

    /// Elementwise sum (used to aggregate per-rank deltas into a job
    /// report).
    pub fn merged(self, other: WorkspaceStats) -> WorkspaceStats {
        WorkspaceStats {
            mat_allocs: self.mat_allocs + other.mat_allocs,
            mat_reuses: self.mat_reuses + other.mat_reuses,
        }
    }
}

/// A buffer arena for matrix temporaries: acquire mats (contents
/// unspecified — the write-into contract), release them back when done,
/// and the allocations live on for the next checkout of a compatible
/// shape.
#[derive(Default)]
pub struct Workspace {
    /// Released backing buffers, unordered; checkout scans for the
    /// best (smallest sufficient) capacity.
    free: Vec<Vec<f32>>,
    stats: WorkspaceStats,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Check out a `rows×cols` matrix with **unspecified contents**
    /// (callers fully overwrite it — the write-into contract), recycling
    /// the smallest released buffer whose capacity suffices and
    /// allocating only when none does.
    pub fn acquire(&mut self, rows: usize, cols: usize) -> Mat {
        let need = rows * cols;
        let mut best: Option<(usize, usize)> = None;
        for (i, buf) in self.free.iter().enumerate() {
            let cap = buf.capacity();
            let better = match best {
                None => true,
                Some((_, best_cap)) => cap < best_cap,
            };
            if cap >= need && better {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                self.stats.mat_reuses += 1;
                Mat::from_buffer_raw(rows, cols, self.free.swap_remove(i))
            }
            None => {
                self.stats.mat_allocs += 1;
                Mat::zeros(rows, cols)
            }
        }
    }

    /// Return a matrix's buffer to the arena for future checkouts.
    pub fn release(&mut self, m: Mat) {
        self.free.push(m.into_vec());
    }

    /// Cumulative checkout counters.
    pub fn stats(&self) -> WorkspaceStats {
        self.stats
    }

    /// Number of buffers currently parked in the free list.
    pub fn free_buffers(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_reuses_allocations() {
        let mut ws = Workspace::new();
        let a = ws.acquire(4, 5);
        assert_eq!(a.shape(), (4, 5));
        assert_eq!(ws.stats(), WorkspaceStats { mat_allocs: 1, mat_reuses: 0 });
        ws.release(a);
        // same shape comes back from the free list (contents are
        // unspecified — consumers overwrite before reading)
        let b = ws.acquire(4, 5);
        assert_eq!(ws.stats(), WorkspaceStats { mat_allocs: 1, mat_reuses: 1 });
        assert_eq!(b.shape(), (4, 5));
        // a smaller checkout also reuses
        ws.release(b);
        let c = ws.acquire(2, 3);
        assert_eq!(c.shape(), (2, 3));
        assert_eq!(ws.stats().mat_reuses, 2);
        // a larger one must allocate
        let d = ws.acquire(10, 10);
        assert_eq!(ws.stats().mat_allocs, 2);
        ws.release(c);
        ws.release(d);
        assert_eq!(ws.free_buffers(), 2);
    }

    #[test]
    fn best_fit_prefers_the_smallest_sufficient_buffer() {
        let mut ws = Workspace::new();
        let small = ws.acquire(2, 2);
        let big = ws.acquire(100, 100);
        ws.release(big);
        ws.release(small);
        // a tiny checkout must not consume the 100×100 buffer
        let t = ws.acquire(2, 2);
        ws.release(t);
        let back = ws.acquire(100, 100);
        assert_eq!(
            ws.stats(),
            WorkspaceStats { mat_allocs: 2, mat_reuses: 2 },
            "both checkouts after warm-up must be reuses"
        );
        ws.release(back);
    }

    #[test]
    fn stats_delta_and_merge() {
        let a = WorkspaceStats { mat_allocs: 5, mat_reuses: 9 };
        let b = WorkspaceStats { mat_allocs: 2, mat_reuses: 4 };
        assert_eq!(a.since(b), WorkspaceStats { mat_allocs: 3, mat_reuses: 5 });
        assert_eq!(b.merged(b), WorkspaceStats { mat_allocs: 4, mat_reuses: 8 });
    }
}
