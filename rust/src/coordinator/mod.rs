//! The job coordinator: the paper's L3 system contribution as a library.
//!
//! Takes a relational tensor (dense or CSR), scatters it over the √p×√p
//! virtual grid, spawns one worker thread per rank with its own compute
//! backend, runs distributed RESCAL (Alg 3) or the full RESCALk
//! model-selection sweep (Alg 1), and gathers factors, errors, and per-op
//! timing traces into a single report.

pub mod metrics;

use std::sync::Arc;
use std::time::Instant;

use crate::backend::BackendSpec;
use crate::comm::grid::run_on_grid;
use crate::comm::{Grid, Trace};
use crate::model_selection::{rescalk_rank, KScore, RescalkConfig};
use crate::rescal::distributed::{rescal_rank, DistInit, DistRescalConfig};
use crate::rescal::{LocalTile, RescalOptions};
use crate::tensor::{Csr, Mat, Tensor3};

/// Coordinator-level configuration shared by both job kinds.
#[derive(Clone)]
pub struct JobConfig {
    /// Number of virtual MPI ranks (perfect square).
    pub p: usize,
    /// Compute backend each rank builds.
    pub backend: BackendSpec,
    /// Record per-op timing traces.
    pub trace: bool,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig { p: 4, backend: BackendSpec::Native, trace: true }
    }
}

/// Input tensor, shared read-only across rank threads.
#[derive(Clone)]
pub enum JobData {
    Dense(Arc<Tensor3>),
    Sparse(Arc<Vec<Csr>>),
}

impl JobData {
    pub fn dense(x: Tensor3) -> Self {
        JobData::Dense(Arc::new(x))
    }

    pub fn sparse(x: Vec<Csr>) -> Self {
        JobData::Sparse(Arc::new(x))
    }

    /// Global entity count n.
    pub fn n(&self) -> usize {
        match self {
            JobData::Dense(x) => x.n1(),
            JobData::Sparse(s) => s[0].rows(),
        }
    }

    /// Relation count m.
    pub fn m(&self) -> usize {
        match self {
            JobData::Dense(x) => x.m(),
            JobData::Sparse(s) => s.len(),
        }
    }

    /// Extract rank (row, col)'s tile.
    fn tile(&self, grid: &Grid, row: usize, col: usize) -> LocalTile {
        let n = self.n();
        let (r0, r1) = grid.chunk(n, row);
        let (c0, c1) = grid.chunk(n, col);
        match self {
            JobData::Dense(x) => LocalTile::Dense(x.tile(r0, r1, c0, c1)),
            JobData::Sparse(s) => {
                LocalTile::Sparse(s.iter().map(|m| m.tile(r0, r1, c0, c1)).collect())
            }
        }
    }
}

/// Gathered result of a plain factorization job.
pub struct RescalReport {
    pub a: Mat,
    pub r: Tensor3,
    pub rel_error: f32,
    pub iters_run: usize,
    /// Per-rank traces, rank order.
    pub traces: Vec<Trace>,
    /// Wall-clock of the distributed section.
    pub wall_seconds: f64,
}

/// Gathered result of a model-selection job.
pub struct RescalkReport {
    pub scores: Vec<KScore>,
    pub k_opt: usize,
    /// Robust Ã (n × k_opt).
    pub a: Mat,
    /// Robust core (k_opt × k_opt × m).
    pub r: Tensor3,
    pub traces: Vec<Trace>,
    pub wall_seconds: f64,
}

/// Assemble the full A from the diagonal ranks' row blocks.
fn gather_a(grid: &Grid, n: usize, k: usize, blocks: &[(usize, usize, Mat)]) -> Mat {
    let mut a = Mat::zeros(n, k);
    for (row, col, block) in blocks {
        if row == col {
            let (s, _) = grid.chunk(n, *row);
            for i in 0..block.rows() {
                for j in 0..k {
                    a[(s + i, j)] = block[(i, j)];
                }
            }
        }
    }
    a
}

/// Run one distributed non-negative RESCAL factorization.
pub fn run_rescal(
    data: &JobData,
    job: &JobConfig,
    opts: &RescalOptions,
    seed: u64,
) -> RescalReport {
    let n = data.n();
    let grid = Grid::new(job.p);
    let t0 = Instant::now();
    let results = run_on_grid(job.p, |ctx| {
        let tile = data.tile(&ctx.grid, ctx.row, ctx.col);
        let cfg = DistRescalConfig {
            opts: opts.clone(),
            init: DistInit::Random { seed },
            n,
        };
        let mut backend = job.backend.build().expect("backend build");
        let mut trace = if job.trace { Trace::new() } else { Trace::disabled() };
        let out = rescal_rank(&ctx, &tile, &cfg, backend.as_mut(), &mut trace);
        (ctx.row, ctx.col, out, trace)
    });
    let wall_seconds = t0.elapsed().as_secs_f64();
    let blocks: Vec<(usize, usize, Mat)> =
        results.iter().map(|(r, c, out, _)| (*r, *c, out.a_row.clone())).collect();
    let a = gather_a(&grid, n, opts.k, &blocks);
    let (_, _, out0, _) = &results[0];
    RescalReport {
        a,
        r: out0.r.clone(),
        rel_error: out0.rel_error,
        iters_run: out0.iters_run,
        traces: results.into_iter().map(|(_, _, _, t)| t).collect(),
        wall_seconds,
    }
}

/// Run the full RESCALk model-selection sweep.
pub fn run_rescalk(data: &JobData, job: &JobConfig, cfg: &RescalkConfig) -> RescalkReport {
    let n = data.n();
    let grid = Grid::new(job.p);
    let t0 = Instant::now();
    let results = run_on_grid(job.p, |ctx| {
        let tile = data.tile(&ctx.grid, ctx.row, ctx.col);
        let mut backend = job.backend.build().expect("backend build");
        let mut trace = if job.trace { Trace::new() } else { Trace::disabled() };
        let out = rescalk_rank(&ctx, &tile, n, cfg, backend.as_mut(), &mut trace);
        (ctx.row, ctx.col, out, trace)
    });
    let wall_seconds = t0.elapsed().as_secs_f64();
    let k_opt = results[0].2.k_opt;
    debug_assert!(results.iter().all(|(_, _, o, _)| o.k_opt == k_opt));
    let blocks: Vec<(usize, usize, Mat)> =
        results.iter().map(|(r, c, out, _)| (*r, *c, out.a_opt_row.clone())).collect();
    let a = gather_a(&grid, n, k_opt, &blocks);
    let (_, _, out0, _) = &results[0];
    RescalkReport {
        scores: out0.scores.clone(),
        k_opt,
        a,
        r: out0.r_opt.clone(),
        traces: results.into_iter().map(|(_, _, _, t)| t).collect(),
        wall_seconds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn run_rescal_gathers_consistent_report() {
        let planted = synthetic::block_tensor(24, 2, 3, 0.01, 1200);
        let data = JobData::dense(planted.x.clone());
        let job = JobConfig { p: 4, backend: BackendSpec::Native, trace: true };
        let report = run_rescal(&data, &job, &RescalOptions::new(3, 150), 3);
        assert_eq!(report.a.shape(), (24, 3));
        assert_eq!(report.r.shape(), (3, 3, 2));
        assert!(report.rel_error < 0.1, "err={}", report.rel_error);
        assert_eq!(report.traces.len(), 4);
        assert!(report.wall_seconds > 0.0);
        // gathered A actually reconstructs the tensor
        let direct = planted.x.rel_error(&report.a, &report.r);
        assert!((direct - report.rel_error).abs() < 1e-3);
    }

    #[test]
    fn run_rescalk_selects_k() {
        let planted = synthetic::block_tensor(20, 2, 2, 0.01, 1201);
        let data = JobData::dense(planted.x.clone());
        let job = JobConfig { p: 4, backend: BackendSpec::Native, trace: false };
        let cfg = RescalkConfig {
            k_min: 1,
            k_max: 4,
            perturbations: 5,
            rescal_iters: 500,
            regress_iters: 25,
            seed: 9,
            ..Default::default()
        };
        let report = run_rescalk(&data, &job, &cfg);
        assert_eq!(report.k_opt, 2, "scores {:?}", report.scores);
        assert_eq!(report.a.shape(), (20, 2));
        assert_eq!(report.scores.len(), 4);
    }

    #[test]
    fn sparse_job_data_tiles() {
        let xs = synthetic::sparse_planted(16, 2, 2, 0.2, 1202);
        let data = JobData::sparse(xs);
        assert_eq!(data.n(), 16);
        assert_eq!(data.m(), 2);
        let job = JobConfig { p: 4, backend: BackendSpec::Native, trace: true };
        let report = run_rescal(&data, &job, &RescalOptions::new(2, 30), 5);
        assert_eq!(report.a.shape(), (16, 2));
        assert!(report.rel_error.is_finite());
    }
}
