//! Job-level data types shared with the [`crate::engine`], plus the
//! legacy one-shot entry points.
//!
//! Historically this module *was* the coordinator: `run_rescal` /
//! `run_rescalk` spawned a fresh grid of rank threads and rebuilt every
//! backend per call. That work now lives in the persistent
//! [`crate::engine::Engine`]; this module keeps the input/result types
//! ([`JobData`], [`RescalReport`], [`RescalkReport`], [`JobConfig`]) and
//! thin deprecated shims that delegate to a one-shot engine so old call
//! sites keep working during migration.

pub mod metrics;

use std::sync::Arc;

use crate::backend::{BackendSpec, WorkspaceStats};
use crate::comm::{Grid, Trace};
use crate::engine::{Engine, EngineConfig};
use crate::model_selection::{KScore, RescalkConfig};
use crate::rescal::{LocalTile, ModelKind, RescalOptions};
use crate::tensor::{Csr, Mat, Tensor3};

/// Legacy coordinator-level configuration (superseded by
/// [`EngineConfig`], which it converts into).
#[derive(Clone)]
pub struct JobConfig {
    /// Number of virtual MPI ranks (perfect square).
    pub p: usize,
    /// Compute backend each rank builds.
    pub backend: BackendSpec,
    /// Record per-op timing traces (opt-in: tracing taxes every op).
    pub trace: bool,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig { p: 4, backend: BackendSpec::Native, trace: false }
    }
}

impl From<JobConfig> for EngineConfig {
    fn from(job: JobConfig) -> EngineConfig {
        EngineConfig { p: job.p, backend: job.backend, trace: job.trace, ..Default::default() }
    }
}

/// Input tensor, shared read-only across rank threads.
#[derive(Clone)]
pub enum JobData {
    Dense(Arc<Tensor3>),
    Sparse(Arc<Vec<Csr>>),
}

impl JobData {
    pub fn dense(x: Tensor3) -> Self {
        JobData::Dense(Arc::new(x))
    }

    pub fn sparse(x: Vec<Csr>) -> Self {
        JobData::Sparse(Arc::new(x))
    }

    /// Global entity count n (0 for an empty sparse relation list, which
    /// [`JobData::validate`] rejects before any rank sees it — indexing
    /// here used to panic inside a worker thread and poison the pool).
    pub fn n(&self) -> usize {
        match self {
            JobData::Dense(x) => x.n1(),
            JobData::Sparse(s) => s.first().map_or(0, |c| c.rows()),
        }
    }

    /// Relation count m.
    pub fn m(&self) -> usize {
        match self {
            JobData::Dense(x) => x.m(),
            JobData::Sparse(s) => s.len(),
        }
    }

    /// Shape validation, run at dataset-registration/submit time so bad
    /// inputs surface as typed errors on the leader instead of panics in
    /// rank threads: relation slices must exist, be square, and agree in
    /// shape.
    pub fn validate(&self) -> crate::error::Result<()> {
        match self {
            JobData::Dense(x) => {
                if x.n1() != x.n2() {
                    crate::bail!(
                        "dense job tensor must have square slices, got {}×{}×{}",
                        x.n1(),
                        x.n2(),
                        x.m()
                    );
                }
            }
            JobData::Sparse(s) => {
                let first = match s.first() {
                    Some(f) => f,
                    None => crate::bail!("sparse job data has no relation slices"),
                };
                if first.rows() != first.cols() {
                    crate::bail!(
                        "sparse relation slices must be square, got {}×{}",
                        first.rows(),
                        first.cols()
                    );
                }
                for (t, c) in s.iter().enumerate() {
                    if c.rows() != first.rows() || c.cols() != first.cols() {
                        crate::bail!(
                            "sparse relation slice {t} is {}×{} but slice 0 is {}×{} — \
                             all slices must share one shape",
                            c.rows(),
                            c.cols(),
                            first.rows(),
                            first.cols()
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Extract rank (row, col)'s tile.
    pub(crate) fn tile(&self, grid: &Grid, row: usize, col: usize) -> LocalTile {
        let n = self.n();
        let (r0, r1) = grid.chunk(n, row);
        let (c0, c1) = grid.chunk(n, col);
        match self {
            JobData::Dense(x) => LocalTile::Dense(x.tile(r0, r1, c0, c1)),
            JobData::Sparse(s) => {
                LocalTile::Sparse(s.iter().map(|m| m.tile(r0, r1, c0, c1)).collect())
            }
        }
    }
}

/// Gathered result of a plain factorization job.
pub struct RescalReport {
    pub a: Mat,
    pub r: Tensor3,
    pub rel_error: f32,
    pub iters_run: usize,
    /// Per-rank traces, rank order.
    pub traces: Vec<Trace>,
    /// Cross-rank span timelines gathered to the leader (rank order;
    /// empty when tracing is off). Feeds the Chrome-trace exporter.
    pub timeline: Vec<crate::obs::RankTimeline>,
    /// Wall-clock of the distributed section.
    pub wall_seconds: f64,
    /// Workspace checkout counters summed over ranks (delta for this
    /// job): `mat_allocs == 0` on a warm pool proves the zero-allocation
    /// steady state.
    pub workspace: WorkspaceStats,
    /// Transport backend the job's collectives ran over: `"in_process"`
    /// (thread pool, the default) or `"tcp"` (multi-process cluster).
    pub transport_backend: String,
    /// Model family the factors were trained under; determines the core
    /// slice shape (k×k for `rescal`/`logistic`, 1×k for `distmult`) and
    /// how a served model scores triples.
    pub model: ModelKind,
    /// Typed warnings the convergence watchdog raised during the job
    /// (stall, NaN/divergence, deadline overrun, transport degradation);
    /// empty on clean untraced runs.
    pub watchdog: Vec<crate::obs::WatchdogEvent>,
}

/// Gathered result of a model-selection job.
pub struct RescalkReport {
    pub scores: Vec<KScore>,
    pub k_opt: usize,
    /// Robust Ã (n × k_opt).
    pub a: Mat,
    /// Robust core (k_opt × k_opt × m).
    pub r: Tensor3,
    pub traces: Vec<Trace>,
    /// Cross-rank span timelines gathered to the leader (rank order;
    /// empty when tracing is off).
    pub timeline: Vec<crate::obs::RankTimeline>,
    pub wall_seconds: f64,
    /// Workspace checkout counters summed over ranks (delta for this
    /// job).
    pub workspace: WorkspaceStats,
    /// Transport backend the job's collectives ran over: `"in_process"`
    /// or `"tcp"`.
    pub transport_backend: String,
    /// Model family the sweep ran under (every candidate k uses it).
    pub model: ModelKind,
    /// Typed warnings the convergence watchdog raised during the sweep.
    pub watchdog: Vec<crate::obs::WatchdogEvent>,
}

/// Run one distributed non-negative RESCAL factorization on a one-shot
/// engine (the pool is torn down afterwards — build an [`Engine`] for
/// repeated jobs).
///
/// # Panics
/// On invalid configuration or a dead rank; the engine API returns these
/// as errors instead.
#[deprecated(note = "build an engine::Engine and call factorize: the pool persists across jobs")]
pub fn run_rescal(
    data: &JobData,
    job: &JobConfig,
    opts: &RescalOptions,
    seed: u64,
) -> RescalReport {
    let mut engine =
        Engine::new(EngineConfig::from(job.clone())).expect("engine construction");
    engine.factorize(data, opts, seed).expect("factorize job")
}

/// Run the full RESCALk model-selection sweep on a one-shot engine (see
/// [`run_rescal`] on why the engine API is preferred).
///
/// # Panics
/// On invalid configuration, a dead rank, or cross-rank k_opt
/// disagreement; the engine API returns these as errors instead.
#[deprecated(note = "build an engine::Engine and call model_select: the pool persists across jobs")]
pub fn run_rescalk(data: &JobData, job: &JobConfig, cfg: &RescalkConfig) -> RescalkReport {
    let mut engine =
        Engine::new(EngineConfig::from(job.clone())).expect("engine construction");
    engine.model_select(data, cfg).expect("model-select job")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn job_config_defaults_to_tracing_off() {
        assert!(!JobConfig::default().trace, "tracing must be opt-in");
        let engine_cfg = EngineConfig::from(JobConfig::default());
        assert_eq!(engine_cfg.p, 4);
        assert!(!engine_cfg.trace);
    }

    #[test]
    fn job_data_validation_is_typed_not_panicking() {
        // empty relation list: used to panic via s[0] in n()
        let empty = JobData::sparse(vec![]);
        assert_eq!(empty.n(), 0);
        assert_eq!(empty.m(), 0);
        let e = empty.validate().unwrap_err();
        assert!(e.to_string().contains("no relation slices"), "{e}");
        // non-square slice
        let rect = JobData::sparse(vec![Csr::from_triplets(4, 6, vec![(0, 0, 1.0)])]);
        assert!(rect.validate().unwrap_err().to_string().contains("square"));
        // mismatched slice shapes
        let mixed = JobData::sparse(vec![
            Csr::from_triplets(4, 4, vec![(0, 0, 1.0)]),
            Csr::from_triplets(6, 6, vec![(0, 0, 1.0)]),
        ]);
        assert!(mixed.validate().unwrap_err().to_string().contains("slice 1"));
        // well-formed data passes, dense and sparse
        assert!(JobData::sparse(synthetic::sparse_planted(8, 2, 2, 0.3, 1)).validate().is_ok());
        assert!(JobData::dense(synthetic::block_tensor(8, 2, 2, 0.01, 1).x).validate().is_ok());
    }

    #[test]
    fn sparse_job_data_shapes() {
        let xs = synthetic::sparse_planted(16, 2, 2, 0.2, 1202);
        let data = JobData::sparse(xs);
        assert_eq!(data.n(), 16);
        assert_eq!(data.m(), 2);
        let tile = data.tile(&Grid::new(4), 0, 1);
        assert_eq!(tile.rows(), 8);
        assert_eq!(tile.cols(), 8);
        assert_eq!(tile.m(), 2);
    }

    /// The deprecated shims must behave exactly like a one-shot engine.
    #[test]
    #[allow(deprecated)]
    fn shims_delegate_to_the_engine() {
        let planted = synthetic::block_tensor(24, 2, 3, 0.01, 1200);
        let data = JobData::dense(planted.x.clone());
        let job = JobConfig { p: 4, backend: BackendSpec::Native, trace: true };
        let report = run_rescal(&data, &job, &RescalOptions::new(3, 150), 3);
        assert_eq!(report.a.shape(), (24, 3));
        assert_eq!(report.r.shape(), (3, 3, 2));
        assert!(report.rel_error < 0.1, "err={}", report.rel_error);
        assert_eq!(report.traces.len(), 4);
        assert!(report.wall_seconds > 0.0);
        // gathered A actually reconstructs the tensor
        let direct = planted.x.rel_error(&report.a, &report.r);
        assert!((direct - report.rel_error).abs() < 1e-3);
    }

    #[test]
    #[allow(deprecated)]
    fn rescalk_shim_selects_k() {
        let planted = synthetic::block_tensor(20, 2, 2, 0.01, 1201);
        let data = JobData::dense(planted.x.clone());
        let job = JobConfig { p: 4, backend: BackendSpec::Native, trace: false };
        let cfg = RescalkConfig {
            k_min: 1,
            k_max: 4,
            perturbations: 5,
            rescal_iters: 500,
            regress_iters: 25,
            seed: 9,
            ..Default::default()
        };
        let report = run_rescalk(&data, &job, &cfg);
        assert_eq!(report.k_opt, 2, "scores {:?}", report.scores);
        assert_eq!(report.a.shape(), (20, 2));
        assert_eq!(report.scores.len(), 4);
    }
}
