//! Job-level data types shared with the [`crate::engine`], plus the
//! legacy one-shot entry points.
//!
//! Historically this module *was* the coordinator: `run_rescal` /
//! `run_rescalk` spawned a fresh grid of rank threads and rebuilt every
//! backend per call. That work now lives in the persistent
//! [`crate::engine::Engine`]; this module keeps the input/result types
//! ([`JobData`], [`RescalReport`], [`RescalkReport`], [`JobConfig`]) and
//! thin deprecated shims that delegate to a one-shot engine so old call
//! sites keep working during migration.

pub mod metrics;

use std::sync::Arc;

use crate::backend::BackendSpec;
use crate::comm::{Grid, Trace};
use crate::engine::{Engine, EngineConfig};
use crate::model_selection::{KScore, RescalkConfig};
use crate::rescal::{LocalTile, RescalOptions};
use crate::tensor::{Csr, Mat, Tensor3};

/// Legacy coordinator-level configuration (superseded by
/// [`EngineConfig`], which it converts into).
#[derive(Clone)]
pub struct JobConfig {
    /// Number of virtual MPI ranks (perfect square).
    pub p: usize,
    /// Compute backend each rank builds.
    pub backend: BackendSpec,
    /// Record per-op timing traces (opt-in: tracing taxes every op).
    pub trace: bool,
}

impl Default for JobConfig {
    fn default() -> Self {
        JobConfig { p: 4, backend: BackendSpec::Native, trace: false }
    }
}

impl From<JobConfig> for EngineConfig {
    fn from(job: JobConfig) -> EngineConfig {
        EngineConfig { p: job.p, backend: job.backend, trace: job.trace }
    }
}

/// Input tensor, shared read-only across rank threads.
#[derive(Clone)]
pub enum JobData {
    Dense(Arc<Tensor3>),
    Sparse(Arc<Vec<Csr>>),
}

impl JobData {
    pub fn dense(x: Tensor3) -> Self {
        JobData::Dense(Arc::new(x))
    }

    pub fn sparse(x: Vec<Csr>) -> Self {
        JobData::Sparse(Arc::new(x))
    }

    /// Global entity count n.
    pub fn n(&self) -> usize {
        match self {
            JobData::Dense(x) => x.n1(),
            JobData::Sparse(s) => s[0].rows(),
        }
    }

    /// Relation count m.
    pub fn m(&self) -> usize {
        match self {
            JobData::Dense(x) => x.m(),
            JobData::Sparse(s) => s.len(),
        }
    }

    /// Extract rank (row, col)'s tile.
    pub(crate) fn tile(&self, grid: &Grid, row: usize, col: usize) -> LocalTile {
        let n = self.n();
        let (r0, r1) = grid.chunk(n, row);
        let (c0, c1) = grid.chunk(n, col);
        match self {
            JobData::Dense(x) => LocalTile::Dense(x.tile(r0, r1, c0, c1)),
            JobData::Sparse(s) => {
                LocalTile::Sparse(s.iter().map(|m| m.tile(r0, r1, c0, c1)).collect())
            }
        }
    }
}

/// Gathered result of a plain factorization job.
pub struct RescalReport {
    pub a: Mat,
    pub r: Tensor3,
    pub rel_error: f32,
    pub iters_run: usize,
    /// Per-rank traces, rank order.
    pub traces: Vec<Trace>,
    /// Wall-clock of the distributed section.
    pub wall_seconds: f64,
}

/// Gathered result of a model-selection job.
pub struct RescalkReport {
    pub scores: Vec<KScore>,
    pub k_opt: usize,
    /// Robust Ã (n × k_opt).
    pub a: Mat,
    /// Robust core (k_opt × k_opt × m).
    pub r: Tensor3,
    pub traces: Vec<Trace>,
    pub wall_seconds: f64,
}

/// Run one distributed non-negative RESCAL factorization on a one-shot
/// engine (the pool is torn down afterwards — build an [`Engine`] for
/// repeated jobs).
///
/// # Panics
/// On invalid configuration or a dead rank; the engine API returns these
/// as errors instead.
#[deprecated(note = "build an engine::Engine and call factorize: the pool persists across jobs")]
pub fn run_rescal(
    data: &JobData,
    job: &JobConfig,
    opts: &RescalOptions,
    seed: u64,
) -> RescalReport {
    let mut engine =
        Engine::new(EngineConfig::from(job.clone())).expect("engine construction");
    engine.factorize(data, opts, seed).expect("factorize job")
}

/// Run the full RESCALk model-selection sweep on a one-shot engine (see
/// [`run_rescal`] on why the engine API is preferred).
///
/// # Panics
/// On invalid configuration, a dead rank, or cross-rank k_opt
/// disagreement; the engine API returns these as errors instead.
#[deprecated(note = "build an engine::Engine and call model_select: the pool persists across jobs")]
pub fn run_rescalk(data: &JobData, job: &JobConfig, cfg: &RescalkConfig) -> RescalkReport {
    let mut engine =
        Engine::new(EngineConfig::from(job.clone())).expect("engine construction");
    engine.model_select(data, cfg).expect("model-select job")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn job_config_defaults_to_tracing_off() {
        assert!(!JobConfig::default().trace, "tracing must be opt-in");
        let engine_cfg = EngineConfig::from(JobConfig::default());
        assert_eq!(engine_cfg.p, 4);
        assert!(!engine_cfg.trace);
    }

    #[test]
    fn sparse_job_data_shapes() {
        let xs = synthetic::sparse_planted(16, 2, 2, 0.2, 1202);
        let data = JobData::sparse(xs);
        assert_eq!(data.n(), 16);
        assert_eq!(data.m(), 2);
        let tile = data.tile(&Grid::new(4), 0, 1);
        assert_eq!(tile.rows(), 8);
        assert_eq!(tile.cols(), 8);
        assert_eq!(tile.m(), 2);
    }

    /// The deprecated shims must behave exactly like a one-shot engine.
    #[test]
    #[allow(deprecated)]
    fn shims_delegate_to_the_engine() {
        let planted = synthetic::block_tensor(24, 2, 3, 0.01, 1200);
        let data = JobData::dense(planted.x.clone());
        let job = JobConfig { p: 4, backend: BackendSpec::Native, trace: true };
        let report = run_rescal(&data, &job, &RescalOptions::new(3, 150), 3);
        assert_eq!(report.a.shape(), (24, 3));
        assert_eq!(report.r.shape(), (3, 3, 2));
        assert!(report.rel_error < 0.1, "err={}", report.rel_error);
        assert_eq!(report.traces.len(), 4);
        assert!(report.wall_seconds > 0.0);
        // gathered A actually reconstructs the tensor
        let direct = planted.x.rel_error(&report.a, &report.r);
        assert!((direct - report.rel_error).abs() < 1e-3);
    }

    #[test]
    #[allow(deprecated)]
    fn rescalk_shim_selects_k() {
        let planted = synthetic::block_tensor(20, 2, 2, 0.01, 1201);
        let data = JobData::dense(planted.x.clone());
        let job = JobConfig { p: 4, backend: BackendSpec::Native, trace: false };
        let cfg = RescalkConfig {
            k_min: 1,
            k_max: 4,
            perturbations: 5,
            rescal_iters: 500,
            regress_iters: 25,
            seed: 9,
            ..Default::default()
        };
        let report = run_rescalk(&data, &job, &cfg);
        assert_eq!(report.k_opt, 2, "scores {:?}", report.scores);
        assert_eq!(report.a.shape(), (20, 2));
        assert_eq!(report.scores.len(), 4);
    }
}
