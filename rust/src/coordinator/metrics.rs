//! Metric aggregation: turns per-rank traces into the tables the paper
//! plots — runtime breakdown by operation, compute/communication split,
//! speedup, and GFLOPS.

use crate::comm::{CommOp, Trace};

/// Aggregated metrics over all ranks of one run.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    /// Mean-over-ranks seconds per op category (the paper averages runtimes
    /// across MPI processes, §6.3).
    pub per_op_seconds: Vec<(&'static str, f64)>,
    pub compute_seconds: f64,
    pub comm_seconds: f64,
    pub total_seconds: f64,
}

impl RunMetrics {
    /// Aggregate per-rank traces (mean across ranks, as the paper
    /// reports). A zero-trace run (tracing disabled, or an archived
    /// report without a traces section) aggregates to empty metrics
    /// instead of crashing the coordinator path.
    pub fn from_traces(traces: &[Trace]) -> RunMetrics {
        if traces.is_empty() {
            return RunMetrics {
                per_op_seconds: Vec::new(),
                compute_seconds: 0.0,
                comm_seconds: 0.0,
                total_seconds: 0.0,
            };
        }
        let p = traces.len() as f64;
        let mut per_op_seconds = Vec::new();
        for &op in CommOp::all() {
            let total: f64 = traces.iter().map(|t| t.seconds(op)).sum();
            if total > 0.0 {
                per_op_seconds.push((op.name(), total / p));
            }
        }
        let (mut comp, mut comm) = (0.0, 0.0);
        for t in traces {
            let (c, m) = t.compute_comm_split();
            comp += c;
            comm += m;
        }
        RunMetrics {
            per_op_seconds,
            compute_seconds: comp / p,
            comm_seconds: comm / p,
            total_seconds: (comp + comm) / p,
        }
    }

    /// Fraction of runtime spent communicating.
    pub fn comm_fraction(&self) -> f64 {
        if self.total_seconds == 0.0 {
            0.0
        } else {
            self.comm_seconds / self.total_seconds
        }
    }

    /// Pretty one-run breakdown block (paper-style rows).
    pub fn format_breakdown(&self) -> String {
        let mut out = String::new();
        for (name, secs) in &self.per_op_seconds {
            out.push_str(&format!("  {name:<20} {secs:>10.4} s\n"));
        }
        out.push_str(&format!(
            "  {:<20} {:>10.4} s\n  {:<20} {:>10.4} s  ({:.1}% comm)\n",
            "compute",
            self.compute_seconds,
            "communication",
            self.comm_seconds,
            100.0 * self.comm_fraction()
        ));
        out
    }
}

/// Dense RESCAL FLOP count per MU iteration (paper §5.1.1): the dominant
/// terms are the two tile GEMMs per slice (X_t·A and X_tᵀ·AR, 2·n²·k each)
/// plus the n·k² products.
pub fn rescal_flops_per_iter(n: usize, m: usize, k: usize) -> f64 {
    let n = n as f64;
    let m = m as f64;
    let k = k as f64;
    // X·A and Xᵀ·AR: 2 × (2 n² k) per slice
    let tile_gemms = m * 2.0 * 2.0 * n * n * k;
    // AᵀXA, XART, AR, deno terms: ~6 × (2 n k²) per slice + gram
    let skinny = m * 6.0 * 2.0 * n * k * k + 2.0 * n * k * k;
    // k×k algebra
    let small = m * 4.0 * 2.0 * k * k * k;
    tile_gemms + skinny + small
}

/// Sparse variant: tile GEMMs scale with density δ.
pub fn sparse_rescal_flops_per_iter(n: usize, m: usize, k: usize, density: f64) -> f64 {
    let dense = rescal_flops_per_iter(n, m, k);
    let n = n as f64;
    let m = m as f64;
    let k = k as f64;
    let tile_gemms = m * 2.0 * 2.0 * n * n * k;
    dense - tile_gemms * (1.0 - density)
}

/// GFLOPS from a measured runtime.
pub fn gflops(flops: f64, seconds: f64) -> f64 {
    if seconds <= 0.0 {
        0.0
    } else {
        flops / seconds / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn aggregates_mean_over_ranks() {
        let mut t1 = Trace::new();
        t1.push(CommOp::MatrixMul, 0, Duration::from_millis(100));
        t1.push(CommOp::RowReduce, 0, Duration::from_millis(50));
        let mut t2 = Trace::new();
        t2.push(CommOp::MatrixMul, 0, Duration::from_millis(200));
        let m = RunMetrics::from_traces(&[t1, t2]);
        let mm = m.per_op_seconds.iter().find(|(n, _)| *n == "matrix_mul").unwrap().1;
        assert!((mm - 0.150).abs() < 1e-9);
        assert!((m.comm_seconds - 0.025).abs() < 1e-9);
        assert!(m.comm_fraction() > 0.0 && m.comm_fraction() < 1.0);
    }

    #[test]
    fn flops_scale_quadratically_in_n() {
        let f1 = rescal_flops_per_iter(1000, 10, 8);
        let f2 = rescal_flops_per_iter(2000, 10, 8);
        let ratio = f2 / f1;
        assert!(ratio > 3.5 && ratio < 4.5, "ratio={ratio}");
    }

    #[test]
    fn sparse_flops_below_dense() {
        let d = rescal_flops_per_iter(1000, 5, 8);
        let s = sparse_rescal_flops_per_iter(1000, 5, 8, 1e-3);
        assert!(s < d / 10.0);
        // density 1 == dense
        let s1 = sparse_rescal_flops_per_iter(1000, 5, 8, 1.0);
        assert!((s1 - d).abs() < 1.0);
    }

    #[test]
    fn empty_traces_give_empty_metrics() {
        let m = RunMetrics::from_traces(&[]);
        assert!(m.per_op_seconds.is_empty());
        assert_eq!(m.total_seconds, 0.0);
        assert_eq!(m.comm_fraction(), 0.0);
        assert!(m.format_breakdown().contains("% comm"));
    }

    #[test]
    fn gflops_sane() {
        assert_eq!(gflops(1e9, 1.0), 1.0);
        assert_eq!(gflops(1e9, 0.0), 0.0);
    }

    #[test]
    fn breakdown_formats() {
        let mut t = Trace::new();
        t.push(CommOp::GramMul, 0, Duration::from_millis(10));
        let m = RunMetrics::from_traces(&[t]);
        let s = m.format_breakdown();
        assert!(s.contains("gram_mul"));
        assert!(s.contains("% comm"));
    }
}
