//! Tiny benchmarking harness (criterion is unavailable offline; DESIGN.md
//! §3): warmup + N samples, median/p10/p90, and paper-style table output.

use std::time::Instant;

/// Robust summary of repeated timings, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub samples: usize,
}

/// Time `f` with `warmup` throwaway runs and `samples` measured runs.
pub fn time_fn(warmup: usize, samples: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..samples.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| times[((times.len() - 1) as f64 * q).round() as usize];
    Stats { median: pct(0.5), p10: pct(0.1), p90: pct(0.9), samples: times.len() }
}

/// Measure this host's sustained dense GEMM rate (FLOP/s) for calibrating
/// the cluster replay model.
pub fn calibrate_dense_flops() -> f64 {
    use crate::rng::Rng;
    use crate::tensor::Mat;
    let n = 512;
    let mut rng = Rng::new(1);
    let a = Mat::random_uniform(n, n, 0.0, 1.0, &mut rng);
    let b = Mat::random_uniform(n, n, 0.0, 1.0, &mut rng);
    let stats = time_fn(1, 5, || {
        std::hint::black_box(a.matmul(&b));
    });
    2.0 * (n as f64).powi(3) / stats.median
}

/// Achieved GFLOP/s of an m×k·k×n GEMM (2·m·k·n flops) that took `wall`
/// seconds — the roofline axis of the kernel bench rows.
pub fn gemm_gflops(m: usize, k: usize, n: usize, wall: f64) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64 / wall.max(1e-12) / 1e9
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 300.0 {
        format!("{:.2} s", s)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

/// Format a byte count with an adaptive unit.
pub fn fmt_bytes(b: usize) -> String {
    let b = b as f64;
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.1} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Print a table: header then rows of equal length, space-aligned.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_orders_percentiles() {
        let stats = time_fn(0, 9, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(stats.p10 <= stats.median);
        assert!(stats.median <= stats.p90);
        assert_eq!(stats.samples, 9);
    }

    #[test]
    fn calibration_is_plausible() {
        let flops = calibrate_dense_flops();
        // any machine lands between 100 MFLOP/s and 10 TFLOP/s
        assert!(flops > 1e8 && flops < 1e13, "calibrated {flops}");
    }

    #[test]
    fn gflops_is_2mkn_over_wall() {
        assert!((gemm_gflops(512, 512, 512, 1.0) - 2.0 * 512.0f64.powi(3) / 1e9).abs() < 1e-9);
        // a zero wall clamps instead of dividing by zero
        assert!(gemm_gflops(8, 8, 8, 0.0).is_finite());
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_secs(5e-9).contains("ns"));
        assert!(fmt_secs(5e-5).contains("µs"));
        assert!(fmt_secs(5e-2).contains("ms"));
        assert!(fmt_secs(5.0).contains(" s"));
        assert!(fmt_secs(7200.0).contains(" h"));
    }
}

// ---------------------------------------------------------------------------
// Scaling-run helpers shared by the paper-figure benches
// ---------------------------------------------------------------------------

use crate::coordinator::metrics::RunMetrics;
use crate::data::synthetic::SyntheticSpec;
use crate::engine::{Engine, EngineConfig};
use crate::rescal::RescalOptions;

/// One measured scaling point.
pub struct ScalingPoint {
    pub p: usize,
    pub wall_seconds: f64,
    pub metrics: RunMetrics,
}

/// A traced `p`-rank engine on the native backend (the benches measure
/// the L3 system, not PJRT call overhead — the XLA path is benchmarked
/// separately in microbench_ops). `measure_dense`/`measure_sparse` build
/// one per point because each point uses a different `p`; hold one of
/// these yourself to run repeated jobs at a fixed `p` on one pool.
pub fn bench_engine(p: usize) -> Engine {
    Engine::new(EngineConfig::new(p).with_trace(true)).expect("bench engine")
}

/// Run distributed RESCAL on a planted dense tensor and return wall time +
/// per-op metrics (mean over ranks). `iters` MU iterations, no early stop.
/// The dataset goes through the engine's data plane, so tiles are
/// generated rank-locally — the bench leader never materializes X and the
/// scaling shapes are not bounded by leader RAM.
pub fn measure_dense(n: usize, m: usize, k: usize, p: usize, iters: usize, seed: u64) -> ScalingPoint {
    let mut engine = bench_engine(p);
    let data = engine.load_dataset(SyntheticSpec::dense(n, m, k, seed)).expect("load dataset");
    let report =
        engine.factorize(data, &RescalOptions::new(k, iters), seed).expect("factorize");
    ScalingPoint {
        p,
        wall_seconds: report.wall_seconds,
        metrics: RunMetrics::from_traces(&report.traces),
    }
}

/// Sparse variant at the given density.
pub fn measure_sparse(
    n: usize,
    m: usize,
    k: usize,
    p: usize,
    density: f64,
    iters: usize,
    seed: u64,
) -> ScalingPoint {
    let mut engine = bench_engine(p);
    let data = engine
        .load_dataset(SyntheticSpec::sparse(n, m, k, density, seed))
        .expect("load dataset");
    let report =
        engine.factorize(data, &RescalOptions::new(k, iters), seed).expect("factorize");
    ScalingPoint {
        p,
        wall_seconds: report.wall_seconds,
        metrics: RunMetrics::from_traces(&report.traces),
    }
}

/// Pin the GEMM thread pool to one thread per rank thread — the scaling
/// benches parallelize across virtual ranks, so nested GEMM threading
/// would oversubscribe the host. Must run before the first GEMM.
pub fn pin_single_threaded_gemm() {
    std::env::set_var("DRESCAL_THREADS", "1");
}

// ---------------------------------------------------------------------------
// Serving-throughput helpers (`drescal serve-bench` and the serve section
// of `drescal bench`)
// ---------------------------------------------------------------------------

use crate::error::Result;
use crate::serve::{FactorModel, Query, QueryEngine, ServeStats};

/// One measured serving pass: wall time plus the pass's serve counters
/// (including the cumulative latency percentiles at pass end).
pub struct ServePoint {
    pub wall_seconds: f64,
    pub stats: ServeStats,
}

impl ServePoint {
    /// Queries answered per second over the pass.
    pub fn qps(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            0.0
        } else {
            self.stats.queries as f64 / self.wall_seconds
        }
    }
}

/// The standard serve-bench workload: `total` top-k object completions
/// cycling over all subjects and relations of the model.
fn serve_workload(model: &FactorModel, total: usize, top: usize) -> Vec<Query> {
    let n = model.n();
    let m = model.m();
    (0..total)
        .map(|i| Query::TopObjects { s: i % n, r: (i / n) % m, top })
        .collect()
}

/// Measure batched top-k serving throughput: `total` `(s, r, ?)`
/// completions submitted in micro-batches of `batch`, answer cache
/// disabled so every query is scored. `batch = 1` measures the
/// unbatched (one GEMV per query) path.
pub fn measure_serve_topk(
    model: &FactorModel,
    batch: usize,
    total: usize,
    top: usize,
) -> Result<ServePoint> {
    let mut qe = QueryEngine::with_cache_capacity(model.clone(), 0);
    let queries = serve_workload(model, total, top);
    let t0 = Instant::now();
    for chunk in queries.chunks(batch.max(1)) {
        qe.submit_batch(chunk)?;
    }
    Ok(ServePoint { wall_seconds: t0.elapsed().as_secs_f64(), stats: qe.stats() })
}

/// Measure the cached path: the same workload twice on one engine with
/// an ample LRU. Returns (cold pass, warm pass); the warm pass's
/// counters are the delta, so `warm.stats.scored_candidates == 0`
/// proves the replay never touched the scoring kernels.
pub fn measure_serve_cached_replay(
    model: &FactorModel,
    batch: usize,
    total: usize,
    top: usize,
) -> Result<(ServePoint, ServePoint)> {
    let mut qe = QueryEngine::with_cache_capacity(model.clone(), total.max(1));
    let queries = serve_workload(model, total, top);
    let t0 = Instant::now();
    for chunk in queries.chunks(batch.max(1)) {
        qe.submit_batch(chunk)?;
    }
    let cold = ServePoint { wall_seconds: t0.elapsed().as_secs_f64(), stats: qe.stats() };
    let t1 = Instant::now();
    for chunk in queries.chunks(batch.max(1)) {
        qe.submit_batch(chunk)?;
    }
    let warm = ServePoint {
        wall_seconds: t1.elapsed().as_secs_f64(),
        stats: stats_since(qe.stats(), cold.stats),
    };
    Ok((cold, warm))
}

/// Counter delta between two cumulative [`ServeStats`] snapshots.
fn stats_since(now: ServeStats, earlier: ServeStats) -> ServeStats {
    ServeStats {
        queries: now.queries - earlier.queries,
        cache_hits: now.cache_hits - earlier.cache_hits,
        batches: now.batches - earlier.batches,
        scored_candidates: now.scored_candidates - earlier.scored_candidates,
        ws_allocs: now.ws_allocs - earlier.ws_allocs,
        ws_reuses: now.ws_reuses - earlier.ws_reuses,
        // fixed at engine construction, not a per-pass counter
        projection_bytes_saved: now.projection_bytes_saved,
        // distribution snapshots, not deltas: report the latest
        latency_p50_us: now.latency_p50_us,
        latency_p95_us: now.latency_p95_us,
        latency_p99_us: now.latency_p99_us,
    }
}
