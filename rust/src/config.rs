//! Run configuration: CLI flag parsing (no clap offline) plus optional
//! JSON config files, feeding the coordinator.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::backend::BackendSpec;
use crate::json::Json;

/// Parsed command line: subcommand + `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`: first positional is the subcommand, then
    /// `--key value` (or `--switch` before another flag / end = "true").
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{tok}'"))?
                .to_string();
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            flags.insert(key, value);
        }
        Ok(Args { subcommand, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Merge flags from a JSON config file (CLI flags win).
    pub fn merge_config_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(|e| anyhow!("config JSON: {e}"))?;
        let obj = v.as_obj().ok_or_else(|| anyhow!("config must be a JSON object"))?;
        for (key, val) in obj {
            if self.flags.contains_key(key) {
                continue; // CLI overrides file
            }
            let s = match val {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                other => bail!("config key '{key}' has unsupported type: {other:?}"),
            };
            self.flags.insert(key.clone(), s);
        }
        Ok(())
    }

    /// The backend spec selected by `--backend native|xla` (+
    /// `--artifacts DIR`).
    pub fn backend(&self) -> Result<BackendSpec> {
        match self.get("backend").unwrap_or("native") {
            "native" => Ok(BackendSpec::Native),
            "xla" => Ok(BackendSpec::Xla {
                artifact_dir: self.get("artifacts").unwrap_or("artifacts").to_string(),
            }),
            other => bail!("unknown backend '{other}' (native|xla)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(argv("run --n 64 --k 4 --trace")).unwrap();
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.get_usize("n", 0).unwrap(), 64);
        assert_eq!(a.get_usize("k", 0).unwrap(), 4);
        assert!(a.get_bool("trace"));
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_flags() {
        assert!(Args::parse(argv("run oops")).is_err());
        let a = Args::parse(argv("run --n abc")).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn backend_selection() {
        let a = Args::parse(argv("run")).unwrap();
        assert_eq!(a.backend().unwrap(), BackendSpec::Native);
        let a = Args::parse(argv("run --backend xla --artifacts art")).unwrap();
        assert_eq!(a.backend().unwrap(), BackendSpec::Xla { artifact_dir: "art".into() });
        let a = Args::parse(argv("run --backend cuda")).unwrap();
        assert!(a.backend().is_err());
    }

    #[test]
    fn config_file_merge_cli_wins() {
        let dir = std::env::temp_dir().join(format!("drescal_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"n": 128, "k": 5, "mode": "rescalk"}"#).unwrap();
        let mut a = Args::parse(argv("run --n 64")).unwrap();
        a.merge_config_file(path.to_str().unwrap()).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 64); // CLI wins
        assert_eq!(a.get_usize("k", 0).unwrap(), 5); // file fills
        assert_eq!(a.get("mode"), Some("rescalk"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
