//! Run configuration.
//!
//! Two layers:
//! * [`Args`] — raw CLI flag parsing (no clap offline) plus optional JSON
//!   config-file merge (CLI wins);
//! * [`RunConfig`] — the **typed, validated** layer the binary actually
//!   consumes: every flag is parsed, range-checked (perfect-square grid,
//!   sane k ranges, known backends/datasets), and folded into typed
//!   structs in `RunConfig::from_args`. Nothing outside this module does
//!   stringly flag lookups.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::backend::BackendSpec;
use crate::coordinator::JobData;
use crate::data::synthetic::SyntheticSpec;
use crate::data::{nations, synthetic, trade};
use crate::engine::{ClusterConfig, DatasetSpec, EngineConfig, TransportKind};
use crate::error::{Context as _, Result};
use crate::json::Json;
use crate::model_selection::{InitStrategy, RescalkConfig, SelectionRule};
use crate::rescal::{ModelKind, RescalOptions};
use crate::tensor::DType;
use crate::{bail, err};

/// Parsed command line: subcommand + `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv[1..]`: first positional is the subcommand, then
    /// `--key value` (or `--switch` before another flag / end = "true").
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| err!("expected --flag, got '{tok}'"))?
                .to_string();
            let value = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            flags.insert(key, value);
        }
        Ok(Args { subcommand, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| err!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| err!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| err!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// An optional integer flag: `None` when absent, error when
    /// non-numeric.
    pub fn get_opt_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| err!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Merge flags from a JSON config file (CLI flags win).
    pub fn merge_config_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path}"))?;
        let v = Json::parse(&text).map_err(|e| err!("config JSON: {e}"))?;
        let obj = v.as_obj().ok_or_else(|| err!("config must be a JSON object"))?;
        for (key, val) in obj {
            if self.flags.contains_key(key) {
                continue; // CLI overrides file
            }
            let s = match val {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                other => bail!("config key '{key}' has unsupported type: {other:?}"),
            };
            self.flags.insert(key.clone(), s);
        }
        Ok(())
    }

    /// The backend spec selected by `--backend native|xla` (+
    /// `--artifacts DIR`).
    pub fn backend(&self) -> Result<BackendSpec> {
        match self.get("backend").unwrap_or("native") {
            "native" => Ok(BackendSpec::Native),
            "xla" => Ok(BackendSpec::Xla {
                artifact_dir: self.get("artifacts").unwrap_or("artifacts").to_string(),
            }),
            other => bail!("unknown backend '{other}' (native|xla)"),
        }
    }
}

// ---------------------------------------------------------------------------
// Typed layer
// ---------------------------------------------------------------------------

/// Which dataset a run loads.
#[derive(Clone, Debug, PartialEq)]
pub enum DataSpec {
    /// Planted Gaussian-feature tensor; `density < 1` takes the CSR path.
    Synthetic { n: usize, m: usize, k_true: usize, density: f64 },
    /// Block-community tensor with mild noise.
    Blocks { n: usize, m: usize, k_true: usize },
    /// The 14×14×56 Nations relational tensor.
    Nations,
    /// The trade tensor, zero-padded to 24 entities so 2×2 and 3×3 grids
    /// divide the axis (paper §6.2.2).
    Trade,
    /// An ingested on-disk corpus: `--data file:<manifest.json>` (or the
    /// dataset directory). Ranks read only their own shards.
    File { manifest: String },
}

impl DataSpec {
    /// Ground-truth latent dimension, where the dataset has one.
    pub fn k_true(&self) -> Option<usize> {
        match self {
            DataSpec::Synthetic { k_true, .. } | DataSpec::Blocks { k_true, .. } => {
                Some(*k_true)
            }
            DataSpec::Nations => Some(4),
            DataSpec::Trade => Some(5),
            DataSpec::File { .. } => None,
        }
    }

    /// Materialize the tensor **on the leader** (legacy path — prefer
    /// [`DataSpec::to_dataset_spec`], which keeps synthetic tensors off
    /// the leader and file corpora on their ranks' disks).
    pub fn load(&self, seed: u64) -> Result<JobData> {
        Ok(match self {
            DataSpec::Synthetic { n, m, k_true, density } => {
                if *density < 1.0 {
                    JobData::sparse(synthetic::sparse_planted(*n, *m, *k_true, *density, seed))
                } else {
                    JobData::dense(synthetic::planted_tensor(*n, *m, *k_true, 0.0, seed).x)
                }
            }
            DataSpec::Blocks { n, m, k_true } => {
                JobData::dense(synthetic::block_tensor(*n, *m, *k_true, 0.01, seed).x)
            }
            DataSpec::Nations => JobData::dense(nations::nations_tensor(seed)),
            DataSpec::Trade => JobData::dense(trade::trade_tensor_padded(seed, 24)),
            DataSpec::File { manifest } => {
                crate::store::read_dataset_inline(&crate::store::StoreManifest::load(manifest)?)?
            }
        })
    }

    /// The engine-registrable form of this dataset. Synthetic tensors map
    /// to [`DatasetSpec::Synthetic`] — each rank generates its own tile
    /// from block-keyed RNG streams, so `drescal run --data synthetic`
    /// can use shapes larger than leader RAM. File corpora map to
    /// [`DatasetSpec::File`] — the leader loads only the manifest and
    /// each rank reads its own shards. The real (small) built-in
    /// datasets stay leader-resident.
    pub fn to_dataset_spec(&self, seed: u64) -> Result<DatasetSpec> {
        Ok(match self {
            DataSpec::Synthetic { n, m, k_true, density } => {
                DatasetSpec::Synthetic(if *density < 1.0 {
                    SyntheticSpec::sparse(*n, *m, *k_true, *density, seed)
                } else {
                    SyntheticSpec::dense(*n, *m, *k_true, seed)
                })
            }
            DataSpec::File { manifest } => DatasetSpec::from_manifest_path(manifest)?,
            _ => DatasetSpec::InMemory(self.load(seed)?),
        })
    }
}

/// Which modeled machine the `exascale` replay uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MachineSpec {
    Cpu,
    Gpu,
    /// Calibrate the dense rate on this host first.
    Calibrated,
}

/// `drescal run` — one distributed factorization.
#[derive(Clone, Debug)]
pub struct FactorizeCmd {
    pub data: DataSpec,
    pub engine: EngineConfig,
    pub opts: RescalOptions,
    pub seed: u64,
    /// Also print the unified report as JSON.
    pub json: bool,
    /// `--trace-out FILE`: write the gathered span timeline as Chrome
    /// trace-event JSON (implies `--trace`).
    pub trace_out: Option<String>,
}

/// `drescal model-select` — the full RESCALk sweep.
#[derive(Clone)]
pub struct ModelSelectCmd {
    pub data: DataSpec,
    pub engine: EngineConfig,
    pub sweep: RescalkConfig,
    pub json: bool,
    /// `--trace-out FILE`: write the gathered span timeline as Chrome
    /// trace-event JSON (implies `--trace`).
    pub trace_out: Option<String>,
}

/// `drescal exascale` — the Fig 13 replay.
#[derive(Clone, Copy, Debug)]
pub struct ExascaleCmd {
    pub machine: MachineSpec,
}

/// `drescal train` — lead a multi-process TCP cluster factorization:
/// this process runs rank 0 and coordinates `--workers` remote
/// `drescal worker` processes (so p = workers + 1 must be a perfect
/// square).
#[derive(Clone)]
pub struct TrainCmd {
    pub data: DataSpec,
    /// Engine config with `transport = TcpLeader` already folded in.
    pub engine: EngineConfig,
    pub opts: RescalOptions,
    pub seed: u64,
    pub json: bool,
    /// `--trace-out FILE`: write the gathered cross-process span
    /// timeline as Chrome trace-event JSON (implies `--trace`).
    pub trace_out: Option<String>,
}

/// `drescal worker` — join a leader's cluster and serve rank jobs until
/// it shuts down.
#[derive(Clone, Debug)]
pub struct WorkerCmd {
    /// Leader control address, e.g. `127.0.0.1:47001`.
    pub connect: String,
}

/// `drescal bench` — the fixed-shape perf harness. Runs factorize,
/// model-select, and serving jobs on synthetic datasets and emits a
/// machine-readable `BENCH_rescal.json` so the perf trajectory is
/// tracked in CI (a 1-iteration invocation doubles as a smoke test).
/// When a baseline file exists, per-section deltas are reported and a
/// wall-time regression beyond `--max-regression` is a hard error.
#[derive(Clone, Debug)]
pub struct BenchCmd {
    pub engine: EngineConfig,
    /// MU iterations per factorization (1 = smoke, default 10).
    pub iters: usize,
    /// Output path of the JSON results.
    pub out: String,
    /// Baseline to diff against (defaults to the previous contents of
    /// `out`; missing file = no comparison).
    pub baseline: String,
    /// Fail when any section's wall time exceeds `baseline × this`
    /// (0 = report deltas only, never fail).
    pub max_regression: f64,
    /// Sections whose baseline wall is below this many seconds are
    /// reported but never gated — sub-10ms timings on shared CI runners
    /// swing severalfold without any code change.
    pub gate_floor: f64,
}

/// `drescal export` — train (factorize, or a full model-select sweep
/// with `--sweep`) and persist the factors as a servable
/// [`crate::serve::FactorModel`] JSON artifact.
#[derive(Clone)]
pub struct ExportCmd {
    pub data: DataSpec,
    pub engine: EngineConfig,
    pub opts: RescalOptions,
    /// `Some` = run the RESCALk sweep and export the k_opt model.
    pub sweep: Option<RescalkConfig>,
    pub seed: u64,
    /// Output path of the model artifact.
    pub model: String,
    /// Storage precision of the exported factors: `--dtype f16|bf16`
    /// quantizes A and R (round-to-nearest-even) before serializing.
    pub dtype: DType,
}

/// `drescal query` — load a persisted model and answer one
/// link-prediction query: `--s --o` = pointwise score, `--s` alone =
/// top-k objects `(s,r,?)`, `--o` alone = top-k subjects `(?,r,o)`.
/// Anchors and relation are tokens: integer indices, or names resolved
/// through the model's interned dictionaries.
#[derive(Clone, Debug)]
pub struct QueryCmd {
    /// Model artifact path.
    pub model: String,
    /// Subject anchor: entity index or interned name.
    pub s: Option<String>,
    /// Object anchor: entity index or interned name.
    pub o: Option<String>,
    /// Relation: index or interned name.
    pub r: String,
    /// Completion depth for top-k queries.
    pub top: usize,
    /// `--family`: assert the artifact was trained under this model
    /// family before answering (typed mismatch error otherwise).
    pub family: Option<ModelKind>,
    /// Also print the answer as JSON.
    pub json: bool,
}

/// `drescal ingest` — stream a triple list into binary tile shards plus
/// a manifest (see `crate::store`), ready for `--data file:<manifest>`.
#[derive(Clone, Debug)]
pub struct IngestCmd {
    /// Input triple list: `subject<TAB>relation<TAB>object[<TAB>weight]`.
    pub input: String,
    /// Output dataset directory.
    pub out: String,
    /// Shard grid side length g (g×g shards).
    pub grid: usize,
    /// Store dense (memory-mappable) blocks instead of CSR.
    pub dense: bool,
    /// Element precision of dense shards: `--dtype f16|bf16` halves the
    /// on-disk (and mapped) bytes. Requires `--dense`.
    pub dtype: DType,
    /// Also print the ingest report as JSON.
    pub json: bool,
}

/// `drescal serve-bench` — train a synthetic model, then measure
/// serving throughput: batched vs per-query top-k completion and the
/// cached path.
#[derive(Clone, Debug)]
pub struct ServeBenchCmd {
    pub engine: EngineConfig,
    /// Entities in the synthetic model.
    pub n: usize,
    /// Relations.
    pub m: usize,
    /// Latent dimension.
    pub k: usize,
    /// Training iterations.
    pub iters: usize,
    /// Total top-k queries per measured pass.
    pub queries: usize,
    /// Micro-batch size of the batched pass.
    pub batch: usize,
    /// Completion depth.
    pub top: usize,
    pub seed: u64,
}

/// `drescal artifacts` — inspect the AOT artifact manifest.
#[derive(Clone, Debug)]
pub struct ArtifactsCmd {
    pub dir: String,
}

/// `drescal tune` — time the packed-GEMM MC/KC/NC blocking grid on this
/// machine with the dispatched microkernel and persist the winning
/// parameters to a JSON profile (`KERNEL_tune.json` by default), which
/// every other subcommand auto-loads at startup when its ISA matches.
#[derive(Clone, Debug)]
pub struct TuneCmd {
    /// Output path of the tuning profile.
    pub out: String,
    /// Coarse grid + fewer reps (the CI smoke configuration).
    pub quick: bool,
    /// Also print the profile as JSON.
    pub json: bool,
}

/// `drescal trace-summary <trace.json>` — print the per-op runtime
/// table (paper §6.3 style) aggregated from a Chrome trace-event file
/// written by `--trace-out`.
#[derive(Clone, Debug)]
pub struct TraceSummaryCmd {
    /// The trace file (positional or `--input`).
    pub input: String,
}

/// `drescal monitor <addr>` — poll a running leader's status endpoint
/// (`--status-port`) and render one live row per MU iteration, with a
/// final convergence/watchdog summary when the job completes.
#[derive(Clone, Debug)]
pub struct MonitorCmd {
    /// Leader status address, `host:port` (positional or `--addr`).
    pub addr: String,
    /// Poll interval in milliseconds.
    pub interval_ms: u64,
}

/// One fully-validated CLI invocation.
pub enum Command {
    Run(FactorizeCmd),
    ModelSelect(ModelSelectCmd),
    Exascale(ExascaleCmd),
    Train(TrainCmd),
    Worker(WorkerCmd),
    Artifacts(ArtifactsCmd),
    Bench(BenchCmd),
    Export(ExportCmd),
    Query(QueryCmd),
    ServeBench(ServeBenchCmd),
    Ingest(IngestCmd),
    Tune(TuneCmd),
    TraceSummary(TraceSummaryCmd),
    Monitor(MonitorCmd),
    Help,
}

/// The typed, validated run configuration the binary consumes.
pub struct RunConfig {
    pub command: Command,
}

const RUN_FLAGS: &[&str] = &[
    "config", "data", "n", "m", "k-true", "density", "seed", "p", "backend", "artifacts",
    "trace", "trace-out", "k", "iters", "json", "cache-bytes", "model",
];
const MODEL_SELECT_FLAGS: &[&str] = &[
    "config", "data", "n", "m", "k-true", "density", "seed", "p", "backend", "artifacts",
    "trace", "trace-out", "iters", "json", "k-min", "k-max", "perturbations", "delta",
    "tol", "err-every", "regress-iters", "cache-bytes", "model",
];
const EXASCALE_FLAGS: &[&str] = &["config", "machine"];
const ARTIFACTS_FLAGS: &[&str] = &["config", "artifacts"];
const BENCH_FLAGS: &[&str] = &[
    "config", "p", "backend", "artifacts", "trace", "iters", "out", "baseline",
    "max-regression", "gate-floor", "cache-bytes", "model",
];
// `--model` on export/query is the artifact *path* (predates the model
// families), so those two subcommands spell the family `--family`
const EXPORT_FLAGS: &[&str] = &[
    "config", "data", "n", "m", "k-true", "density", "seed", "p", "backend", "artifacts",
    "trace", "k", "iters", "sweep", "model", "k-min", "k-max", "perturbations", "delta",
    "tol", "err-every", "regress-iters", "cache-bytes", "family", "dtype",
];
const QUERY_FLAGS: &[&str] = &["config", "model", "s", "o", "r", "top", "json", "family"];
const SERVE_BENCH_FLAGS: &[&str] = &[
    "config", "p", "backend", "artifacts", "trace", "n", "m", "k", "iters", "queries",
    "batch", "top", "seed", "cache-bytes", "status-port",
];
const INGEST_FLAGS: &[&str] = &["config", "input", "out", "grid", "dense", "dtype", "json"];
const TUNE_FLAGS: &[&str] = &["config", "out", "quick", "json"];
const TRAIN_FLAGS: &[&str] = &[
    "config", "data", "n", "m", "k-true", "density", "seed", "trace", "trace-out", "k",
    "iters", "json", "workers", "listen", "port-file", "comm-timeout-ms",
    "max-replacements", "model", "status-port",
];
const WORKER_FLAGS: &[&str] = &["config", "connect"];
const TRACE_SUMMARY_FLAGS: &[&str] = &["config", "input"];
const MONITOR_FLAGS: &[&str] = &["config", "addr", "interval-ms"];

impl RunConfig {
    /// Parse + validate a full command line (after the binary name),
    /// merging `--config FILE` first (CLI wins).
    pub fn from_args<I: IntoIterator<Item = String>>(argv: I) -> Result<RunConfig> {
        let mut argv: Vec<String> = argv.into_iter().collect();
        // `trace-summary` takes its trace file as a positional:
        // `drescal trace-summary trace.json` ≡ `--input trace.json`
        if argv.first().map(String::as_str) == Some("trace-summary")
            && argv.get(1).map(|a| !a.starts_with("--")).unwrap_or(false)
        {
            argv.insert(1, "--input".to_string());
        }
        // `monitor` likewise: `drescal monitor 127.0.0.1:8650` ≡ `--addr ...`
        if argv.first().map(String::as_str) == Some("monitor")
            && argv.get(1).map(|a| !a.starts_with("--")).unwrap_or(false)
        {
            argv.insert(1, "--addr".to_string());
        }
        let mut args = Args::parse(argv)?;
        // only flags the user typed are checked against the allowlist; a
        // config file may be shared across subcommands, so its unused
        // keys are silently ignored (as the old CLI did)
        let cli_flags: Vec<String> = args.flags.keys().cloned().collect();
        if let Some(path) = args.get("config").map(|s| s.to_string()) {
            args.merge_config_file(&path)?;
        }
        let command = match args.subcommand.as_str() {
            "run" => {
                check_known_flags(&args.subcommand, &cli_flags, RUN_FLAGS)?;
                let k = args.get_usize("k", 4)?;
                let iters = args.get_usize("iters", 200)?;
                if k == 0 {
                    bail!("--k must be >= 1");
                }
                if iters == 0 {
                    bail!("--iters must be >= 1");
                }
                Command::Run(FactorizeCmd {
                    data: data_spec(&args)?,
                    engine: engine_config(&args)?.with_model(model_kind(&args, "model")?),
                    opts: RescalOptions::new(k, iters),
                    seed: args.get_u64("seed", 42)?,
                    json: args.get_bool("json"),
                    trace_out: args.get("trace-out").map(str::to_string),
                })
            }
            "model-select" => {
                check_known_flags(&args.subcommand, &cli_flags, MODEL_SELECT_FLAGS)?;
                Command::ModelSelect(ModelSelectCmd {
                    data: data_spec(&args)?,
                    engine: engine_config(&args)?.with_model(model_kind(&args, "model")?),
                    sweep: sweep_config(&args, "model")?,
                    json: args.get_bool("json"),
                    trace_out: args.get("trace-out").map(str::to_string),
                })
            }
            "exascale" => {
                check_known_flags(&args.subcommand, &cli_flags, EXASCALE_FLAGS)?;
                let machine = match args.get("machine").unwrap_or("cpu") {
                    "cpu" => MachineSpec::Cpu,
                    "gpu" => MachineSpec::Gpu,
                    "calibrated" => MachineSpec::Calibrated,
                    other => bail!("unknown --machine '{other}' (cpu|gpu|calibrated)"),
                };
                Command::Exascale(ExascaleCmd { machine })
            }
            "artifacts" => {
                check_known_flags(&args.subcommand, &cli_flags, ARTIFACTS_FLAGS)?;
                Command::Artifacts(ArtifactsCmd {
                    dir: args.get("artifacts").unwrap_or("artifacts").to_string(),
                })
            }
            "bench" => {
                check_known_flags(&args.subcommand, &cli_flags, BENCH_FLAGS)?;
                let iters = args.get_usize("iters", 10)?;
                if iters == 0 {
                    bail!("--iters must be >= 1");
                }
                let out = args.get("out").unwrap_or("BENCH_rescal.json").to_string();
                let max_regression = args.get_f64("max-regression", 0.0)?;
                if max_regression < 0.0 {
                    bail!("--max-regression must be >= 0 (0 = report only)");
                }
                let gate_floor = args.get_f64("gate-floor", 0.01)?;
                if gate_floor < 0.0 {
                    bail!("--gate-floor must be >= 0 seconds");
                }
                Command::Bench(BenchCmd {
                    engine: engine_config(&args)?.with_model(model_kind(&args, "model")?),
                    iters,
                    // default baseline: the previous run's output
                    baseline: args.get("baseline").unwrap_or(&out).to_string(),
                    out,
                    max_regression,
                    gate_floor,
                })
            }
            "export" => {
                check_known_flags(&args.subcommand, &cli_flags, EXPORT_FLAGS)?;
                let k = args.get_usize("k", 4)?;
                let iters = args.get_usize("iters", 200)?;
                if k == 0 {
                    bail!("--k must be >= 1");
                }
                if iters == 0 {
                    bail!("--iters must be >= 1");
                }
                let sweep = if args.get_bool("sweep") {
                    Some(sweep_config(&args, "family")?)
                } else {
                    None
                };
                Command::Export(ExportCmd {
                    data: data_spec(&args)?,
                    engine: engine_config(&args)?.with_model(model_kind(&args, "family")?),
                    opts: RescalOptions::new(k, iters),
                    sweep,
                    seed: args.get_u64("seed", 42)?,
                    model: args.get("model").unwrap_or("model.json").to_string(),
                    dtype: dtype_flag(&args)?,
                })
            }
            "query" => {
                check_known_flags(&args.subcommand, &cli_flags, QUERY_FLAGS)?;
                let s = args.get("s").map(str::to_string);
                let o = args.get("o").map(str::to_string);
                if s.is_none() && o.is_none() {
                    bail!(
                        "query needs --s and/or --o: --s --o = score, --s = top-k \
                         objects (s,r,?), --o = top-k subjects (?,r,o); anchors and \
                         --r take indices or interned names"
                    );
                }
                let top = args.get_usize("top", 5)?;
                if top == 0 {
                    bail!("--top must be >= 1");
                }
                Command::Query(QueryCmd {
                    model: args.get("model").unwrap_or("model.json").to_string(),
                    s,
                    o,
                    r: args.get("r").unwrap_or("0").to_string(),
                    top,
                    family: args.get("family").map(ModelKind::parse).transpose()?,
                    json: args.get_bool("json"),
                })
            }
            "ingest" => {
                check_known_flags(&args.subcommand, &cli_flags, INGEST_FLAGS)?;
                let input = args
                    .get("input")
                    .ok_or_else(|| {
                        err!(
                            "ingest needs --input FILE (one triple per line: \
                             subject<TAB>relation<TAB>object[<TAB>weight])"
                        )
                    })?
                    .to_string();
                let grid = args.get_usize("grid", 1)?;
                if grid == 0 {
                    bail!("--grid must be >= 1");
                }
                let dtype = dtype_flag(&args)?;
                let dense = args.get_bool("dense");
                if dtype.is_half() && !dense {
                    bail!("--dtype {} requires --dense (sparse shards stay f32)", dtype.as_str());
                }
                Command::Ingest(IngestCmd {
                    input,
                    out: args.get("out").unwrap_or("corpus").to_string(),
                    grid,
                    dense,
                    dtype,
                    json: args.get_bool("json"),
                })
            }
            "tune" => {
                check_known_flags(&args.subcommand, &cli_flags, TUNE_FLAGS)?;
                Command::Tune(TuneCmd {
                    out: args
                        .get("out")
                        .unwrap_or(crate::tensor::kernel::tune::PROFILE_FILE)
                        .to_string(),
                    quick: args.get_bool("quick"),
                    json: args.get_bool("json"),
                })
            }
            "serve-bench" => {
                check_known_flags(&args.subcommand, &cli_flags, SERVE_BENCH_FLAGS)?;
                let n = args.get_usize("n", 512)?;
                let m = args.get_usize("m", 2)?;
                let k = args.get_usize("k", 8)?;
                let iters = args.get_usize("iters", 30)?;
                let queries = args.get_usize("queries", 2048)?;
                let batch = args.get_usize("batch", 64)?;
                let top = args.get_usize("top", 10)?;
                let sizes = [n, m, k, iters, queries, batch, top];
                if sizes.contains(&0) {
                    bail!(
                        "serve-bench sizes (--n --m --k --iters --queries --batch \
                         --top) must all be >= 1"
                    );
                }
                Command::ServeBench(ServeBenchCmd {
                    engine: engine_config(&args)?,
                    n,
                    m,
                    k,
                    iters,
                    queries,
                    batch,
                    top,
                    seed: args.get_u64("seed", 42)?,
                })
            }
            "train" => {
                check_known_flags(&args.subcommand, &cli_flags, TRAIN_FLAGS)?;
                let workers = args.get_usize("workers", 3)?;
                let p = workers + 1;
                let q = (p as f64).sqrt().round() as usize;
                if q * q != p {
                    bail!(
                        "--workers {workers} gives p = {p} ranks (workers + leader), \
                         which must be a perfect square — try --workers 3, 8, or 15"
                    );
                }
                let k = args.get_usize("k", 4)?;
                let iters = args.get_usize("iters", 200)?;
                if k == 0 {
                    bail!("--k must be >= 1");
                }
                if iters == 0 {
                    bail!("--iters must be >= 1");
                }
                let timeout_ms = args.get_u64("comm-timeout-ms", 10_000)?;
                if timeout_ms == 0 {
                    bail!("--comm-timeout-ms must be >= 1");
                }
                let cluster = ClusterConfig {
                    listen: args.get("listen").unwrap_or("127.0.0.1:0").to_string(),
                    timeout_ms,
                    max_replacements: args.get_u64("max-replacements", 1)? as u32,
                    port_file: args.get("port-file").map(PathBuf::from),
                };
                let status_port = status_port_flag(&args)?;
                let engine = EngineConfig {
                    p,
                    backend: BackendSpec::Native,
                    // --trace-out needs span recording on every rank, and
                    // --status-port needs the per-iteration telemetry flush
                    trace: args.get_bool("trace")
                        || args.get("trace-out").is_some()
                        || status_port.is_some(),
                    transport: TransportKind::TcpLeader(cluster),
                    model: model_kind(&args, "model")?,
                    status_port,
                    ..Default::default()
                };
                Command::Train(TrainCmd {
                    data: data_spec(&args)?,
                    engine,
                    opts: RescalOptions::new(k, iters),
                    seed: args.get_u64("seed", 42)?,
                    json: args.get_bool("json"),
                    trace_out: args.get("trace-out").map(str::to_string),
                })
            }
            "worker" => {
                check_known_flags(&args.subcommand, &cli_flags, WORKER_FLAGS)?;
                let connect = args
                    .get("connect")
                    .ok_or_else(|| err!("worker needs --connect <leader addr>"))?
                    .to_string();
                Command::Worker(WorkerCmd { connect })
            }
            "trace-summary" => {
                check_known_flags(&args.subcommand, &cli_flags, TRACE_SUMMARY_FLAGS)?;
                let input = args
                    .get("input")
                    .ok_or_else(|| {
                        err!("trace-summary needs a trace file: drescal trace-summary trace.json")
                    })?
                    .to_string();
                Command::TraceSummary(TraceSummaryCmd { input })
            }
            "monitor" => {
                check_known_flags(&args.subcommand, &cli_flags, MONITOR_FLAGS)?;
                let addr = args
                    .get("addr")
                    .ok_or_else(|| {
                        err!("monitor needs a status address: drescal monitor 127.0.0.1:8650")
                    })?
                    .to_string();
                let interval_ms = args.get_u64("interval-ms", 250)?;
                if interval_ms == 0 {
                    bail!("--interval-ms must be >= 1");
                }
                Command::Monitor(MonitorCmd { addr, interval_ms })
            }
            "help" | "--help" | "-h" => Command::Help,
            other => bail!("unknown subcommand '{other}' — try `drescal help`"),
        };
        Ok(RunConfig { command })
    }
}

fn check_known_flags(subcommand: &str, cli_flags: &[String], allowed: &[&str]) -> Result<()> {
    for key in cli_flags {
        if !allowed.contains(&key.as_str()) {
            bail!("unknown flag --{key} for subcommand '{subcommand}'");
        }
    }
    Ok(())
}

/// `--dtype f32|f16|bf16` (default f32), shared by `ingest` and
/// `export`.
fn dtype_flag(args: &Args) -> Result<DType> {
    match args.get("dtype") {
        None => Ok(DType::F32),
        Some(s) => DType::parse(s).ok_or_else(|| err!("unknown --dtype '{s}' (f32|f16|bf16)")),
    }
}

/// Typed engine configuration: grid size (perfect-square-checked), backend
/// spec, opt-in tracing (`--trace`, implied by `--trace-out` and
/// `--status-port` — the live endpoint needs spans to serve).
fn engine_config(args: &Args) -> Result<EngineConfig> {
    let status_port = status_port_flag(args)?;
    let cfg = EngineConfig {
        p: args.get_usize("p", 4)?,
        backend: args.backend()?,
        trace: args.get_bool("trace")
            || args.get("trace-out").is_some()
            || status_port.is_some(),
        // resident-tile memory budget; 0 (the default) = unbounded
        dataset_cache_bytes: args.get_usize("cache-bytes", 0)?,
        transport: TransportKind::InProcess,
        status_port,
        ..Default::default()
    };
    cfg.validate().context("--p")?;
    Ok(cfg)
}

/// `--status-port N` (0 = ephemeral; absent = no status endpoint).
fn status_port_flag(args: &Args) -> Result<Option<u16>> {
    match args.get("status-port") {
        None => Ok(None),
        Some(s) => s
            .parse::<u16>()
            .map(Some)
            .map_err(|_| err!("--status-port expects a port 0-65535 (0 = ephemeral), got '{s}'")),
    }
}

/// The model family under `--model` (or `--family` on subcommands where
/// `--model` is the artifact path); absent = the paper's Gaussian RESCAL.
fn model_kind(args: &Args, key: &str) -> Result<ModelKind> {
    match args.get(key) {
        Some(name) => ModelKind::parse(name),
        None => Ok(ModelKind::Rescal),
    }
}

fn data_spec(args: &Args) -> Result<DataSpec> {
    let n = args.get_usize("n", 64)?;
    let m = args.get_usize("m", 4)?;
    let k_true = args.get_usize("k-true", 4)?;
    if n == 0 || m == 0 || k_true == 0 {
        bail!("--n, --m, and --k-true must all be >= 1");
    }
    Ok(match args.get("data").unwrap_or("synthetic") {
        "synthetic" => {
            let density = args.get_f64("density", 1.0)?;
            if density <= 0.0 || density > 1.0 {
                bail!("--density must be in (0, 1], got {density}");
            }
            DataSpec::Synthetic { n, m, k_true, density }
        }
        "blocks" => DataSpec::Blocks { n, m, k_true },
        "nations" => DataSpec::Nations,
        "trade" => DataSpec::Trade,
        file if file.starts_with("file:") => {
            let manifest = file["file:".len()..].to_string();
            if manifest.is_empty() {
                bail!("--data file: needs a path: --data file:corpus/manifest.json");
            }
            DataSpec::File { manifest }
        }
        other => bail!(
            "unknown --data '{other}' (synthetic|blocks|nations|trade|file:<manifest>)"
        ),
    })
}

/// `model_key` names the family flag: `model-select` spells it
/// `--model`, `export --sweep` spells it `--family` (its `--model` is
/// the output artifact path).
fn sweep_config(args: &Args, model_key: &str) -> Result<RescalkConfig> {
    let k_min = args.get_usize("k-min", 2)?;
    let k_max = args.get_usize("k-max", 8)?;
    if k_min < 1 {
        bail!("--k-min must be >= 1");
    }
    if k_min > k_max {
        bail!("bad k range: --k-min {k_min} > --k-max {k_max}");
    }
    let perturbations = args.get_usize("perturbations", 10)?;
    if perturbations == 0 {
        bail!("--perturbations must be >= 1");
    }
    let delta = args.get_f64("delta", 0.02)?;
    if !(0.0..1.0).contains(&delta) {
        bail!("--delta must be in [0, 1), got {delta}");
    }
    let tol = args.get_f64("tol", 0.0)?;
    if tol < 0.0 {
        bail!("--tol must be >= 0, got {tol}");
    }
    Ok(RescalkConfig {
        k_min,
        k_max,
        perturbations,
        delta: delta as f32,
        rescal_iters: args.get_usize("iters", 200)?,
        tol: tol as f32,
        err_every: args.get_usize("err-every", 25)?,
        regress_iters: args.get_usize("regress-iters", 30)?,
        seed: args.get_u64("seed", 42)?,
        rule: SelectionRule::default(),
        init: InitStrategy::Random,
        model: model_kind(args, model_key)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = Args::parse(argv("run --n 64 --k 4 --trace")).unwrap();
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.get_usize("n", 0).unwrap(), 64);
        assert_eq!(a.get_usize("k", 0).unwrap(), 4);
        assert!(a.get_bool("trace"));
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_bad_flags() {
        assert!(Args::parse(argv("run oops")).is_err());
        let a = Args::parse(argv("run --n abc")).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn backend_selection() {
        let a = Args::parse(argv("run")).unwrap();
        assert_eq!(a.backend().unwrap(), BackendSpec::Native);
        let a = Args::parse(argv("run --backend xla --artifacts art")).unwrap();
        assert_eq!(a.backend().unwrap(), BackendSpec::Xla { artifact_dir: "art".into() });
        let a = Args::parse(argv("run --backend cuda")).unwrap();
        assert!(a.backend().is_err());
    }

    #[test]
    fn config_file_merge_cli_wins() {
        let dir = std::env::temp_dir().join(format!("drescal_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"n": 128, "k": 5, "mode": "rescalk"}"#).unwrap();
        let mut a = Args::parse(argv("run --n 64")).unwrap();
        a.merge_config_file(path.to_str().unwrap()).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 64); // CLI wins
        assert_eq!(a.get_usize("k", 0).unwrap(), 5); // file fills
        assert_eq!(a.get("mode"), Some("rescalk"));
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- typed layer ----

    #[test]
    fn run_defaults_are_typed() {
        let cfg = RunConfig::from_args(argv("run")).unwrap();
        match cfg.command {
            Command::Run(cmd) => {
                assert_eq!(
                    cmd.data,
                    DataSpec::Synthetic { n: 64, m: 4, k_true: 4, density: 1.0 }
                );
                assert_eq!(cmd.engine.p, 4);
                assert_eq!(cmd.engine.backend, BackendSpec::Native);
                assert!(!cmd.engine.trace, "tracing must be opt-in");
                assert_eq!(cmd.opts.k, 4);
                assert_eq!(cmd.opts.max_iters, 200);
                assert_eq!(cmd.seed, 42);
                assert!(!cmd.json);
            }
            _ => panic!("expected run command"),
        }
    }

    #[test]
    fn trace_is_opt_in() {
        let cfg = RunConfig::from_args(argv("run --trace")).unwrap();
        match cfg.command {
            Command::Run(cmd) => assert!(cmd.engine.trace),
            _ => panic!("expected run command"),
        }
    }

    #[test]
    fn non_square_grid_rejected() {
        let e = RunConfig::from_args(argv("run --p 8")).unwrap_err();
        assert!(e.to_string().contains("perfect square"), "{e}");
        let e = RunConfig::from_args(argv("model-select --p 6")).unwrap_err();
        assert!(e.to_string().contains("perfect square"), "{e}");
    }

    #[test]
    fn bad_k_range_rejected() {
        let e = RunConfig::from_args(argv("model-select --k-min 5 --k-max 3")).unwrap_err();
        assert!(e.to_string().contains("bad k range"), "{e}");
        let e = RunConfig::from_args(argv("model-select --k-min 0")).unwrap_err();
        assert!(e.to_string().contains("--k-min"), "{e}");
        assert!(RunConfig::from_args(argv("run --k 0")).is_err());
    }

    #[test]
    fn unknown_backend_rejected() {
        let e = RunConfig::from_args(argv("run --backend cuda")).unwrap_err();
        assert!(e.to_string().contains("unknown backend"), "{e}");
    }

    #[test]
    fn unknown_data_and_machine_rejected() {
        assert!(RunConfig::from_args(argv("run --data mystery")).is_err());
        assert!(RunConfig::from_args(argv("exascale --machine quantum")).is_err());
    }

    #[test]
    fn unknown_flags_rejected_per_subcommand() {
        let e = RunConfig::from_args(argv("run --k-min 2")).unwrap_err();
        assert!(e.to_string().contains("unknown flag --k-min"), "{e}");
        let e = RunConfig::from_args(argv("exascale --k 4")).unwrap_err();
        assert!(e.to_string().contains("unknown flag --k"), "{e}");
    }

    #[test]
    fn density_validation() {
        assert!(RunConfig::from_args(argv("run --density 0.5")).is_ok());
        assert!(RunConfig::from_args(argv("run --density 0")).is_err());
        assert!(RunConfig::from_args(argv("run --density 1.5")).is_err());
    }

    #[test]
    fn config_file_feeds_typed_layer() {
        let dir =
            std::env::temp_dir().join(format!("drescal_rcfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"data": "blocks", "n": 24, "k": 3, "p": 9}"#).unwrap();
        let cfg = RunConfig::from_args(argv(&format!(
            "run --config {} --n 32",
            path.to_str().unwrap()
        )))
        .unwrap();
        match cfg.command {
            Command::Run(cmd) => {
                // CLI wins over file; file fills the rest
                assert_eq!(cmd.data, DataSpec::Blocks { n: 32, m: 4, k_true: 4 });
                assert_eq!(cmd.opts.k, 3);
                assert_eq!(cmd.engine.p, 9);
            }
            _ => panic!("expected run command"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_file_keys_for_other_subcommands_are_ignored() {
        let dir =
            std::env::temp_dir().join(format!("drescal_shared_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        // "k" belongs to `run`, "k-min" to `model-select`; one shared file
        // must work with both subcommands
        std::fs::write(&path, r#"{"k": 3, "k-min": 2, "p": 4}"#).unwrap();
        let p = path.to_str().unwrap();
        assert!(RunConfig::from_args(argv(&format!("run --config {p}"))).is_ok());
        assert!(RunConfig::from_args(argv(&format!("model-select --config {p}"))).is_ok());
        // but a typed unknown flag is still rejected
        assert!(RunConfig::from_args(argv(&format!("run --config {p} --k-min 2"))).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_subcommand_is_typed() {
        let cfg = RunConfig::from_args(argv("bench")).unwrap();
        match cfg.command {
            Command::Bench(cmd) => {
                assert_eq!(cmd.iters, 10);
                assert_eq!(cmd.out, "BENCH_rescal.json");
                assert_eq!(cmd.engine.p, 4);
            }
            _ => panic!("expected bench command"),
        }
        let cfg = RunConfig::from_args(argv("bench --iters 1 --out x.json --p 1")).unwrap();
        match cfg.command {
            Command::Bench(cmd) => {
                assert_eq!(cmd.iters, 1);
                assert_eq!(cmd.out, "x.json");
                assert_eq!(cmd.engine.p, 1);
            }
            _ => panic!("expected bench command"),
        }
        assert!(RunConfig::from_args(argv("bench --iters 0")).is_err());
        assert!(RunConfig::from_args(argv("bench --k 4")).is_err());
    }

    #[test]
    fn bench_baseline_defaults_to_out_path() {
        let cfg = RunConfig::from_args(argv("bench --out here.json")).unwrap();
        match cfg.command {
            Command::Bench(cmd) => {
                assert_eq!(cmd.baseline, "here.json");
                assert_eq!(cmd.max_regression, 0.0, "regression gate is opt-in");
                assert_eq!(cmd.gate_floor, 0.01, "10ms noise floor by default");
            }
            _ => panic!("expected bench command"),
        }
        let cfg = RunConfig::from_args(argv(
            "bench --baseline old.json --max-regression 2",
        ))
        .unwrap();
        match cfg.command {
            Command::Bench(cmd) => {
                assert_eq!(cmd.baseline, "old.json");
                assert_eq!(cmd.out, "BENCH_rescal.json");
                assert_eq!(cmd.max_regression, 2.0);
            }
            _ => panic!("expected bench command"),
        }
        assert!(RunConfig::from_args(argv("bench --max-regression -1")).is_err());
        assert!(RunConfig::from_args(argv("bench --gate-floor -0.5")).is_err());
    }

    #[test]
    fn export_subcommand_is_typed() {
        let cfg = RunConfig::from_args(argv("export --n 32 --k 3")).unwrap();
        match cfg.command {
            Command::Export(cmd) => {
                assert_eq!(cmd.opts.k, 3);
                assert!(cmd.sweep.is_none());
                assert_eq!(cmd.model, "model.json");
            }
            _ => panic!("expected export command"),
        }
        let cfg = RunConfig::from_args(argv(
            "export --sweep --k-min 2 --k-max 4 --model m.json",
        ))
        .unwrap();
        match cfg.command {
            Command::Export(cmd) => {
                let sweep = cmd.sweep.expect("--sweep selects model-select export");
                assert_eq!((sweep.k_min, sweep.k_max), (2, 4));
                assert_eq!(cmd.model, "m.json");
            }
            _ => panic!("expected export command"),
        }
        assert!(RunConfig::from_args(argv("export --k 0")).is_err());
    }

    #[test]
    fn query_subcommand_validation() {
        // no anchors at all is rejected
        let e = RunConfig::from_args(argv("query --model m.json")).unwrap_err();
        assert!(e.to_string().contains("--s and/or --o"), "{e}");
        let cfg = RunConfig::from_args(argv("query --model m.json --s 3 --r 1")).unwrap();
        match cfg.command {
            Command::Query(cmd) => {
                assert_eq!(cmd.s.as_deref(), Some("3"));
                assert_eq!(cmd.o, None);
                assert_eq!((cmd.r.as_str(), cmd.top), ("1", 5));
            }
            _ => panic!("expected query command"),
        }
        let cfg = RunConfig::from_args(argv("query --s 1 --o 2")).unwrap();
        match cfg.command {
            Command::Query(cmd) => {
                assert_eq!((cmd.s.as_deref(), cmd.o.as_deref()), (Some("1"), Some("2")));
                assert_eq!(cmd.model, "model.json");
            }
            _ => panic!("expected query command"),
        }
        // name anchors pass the typed layer; the model resolves them
        let cfg =
            RunConfig::from_args(argv("query --s alice --r knows --top 3")).unwrap();
        match cfg.command {
            Command::Query(cmd) => {
                assert_eq!(cmd.s.as_deref(), Some("alice"));
                assert_eq!(cmd.r, "knows");
            }
            _ => panic!("expected query command"),
        }
        assert!(RunConfig::from_args(argv("query --s 1 --top 0")).is_err());
        assert!(RunConfig::from_args(argv("query --s 1 --k 4")).is_err());
    }

    #[test]
    fn ingest_subcommand_is_typed() {
        let e = RunConfig::from_args(argv("ingest")).unwrap_err();
        assert!(e.to_string().contains("--input"), "{e}");
        let cfg = RunConfig::from_args(argv("ingest --input kg.tsv")).unwrap();
        match cfg.command {
            Command::Ingest(cmd) => {
                assert_eq!(cmd.input, "kg.tsv");
                assert_eq!(cmd.out, "corpus");
                assert_eq!(cmd.grid, 1);
                assert!(!cmd.dense);
            }
            _ => panic!("expected ingest command"),
        }
        let cfg = RunConfig::from_args(argv(
            "ingest --input kg.tsv --out data --grid 2 --dense",
        ))
        .unwrap();
        match cfg.command {
            Command::Ingest(cmd) => {
                assert_eq!((cmd.out.as_str(), cmd.grid, cmd.dense), ("data", 2, true));
            }
            _ => panic!("expected ingest command"),
        }
        assert!(RunConfig::from_args(argv("ingest --input k.tsv --grid 0")).is_err());
        assert!(RunConfig::from_args(argv("ingest --input k.tsv --k 4")).is_err());
    }

    #[test]
    fn dtype_flags_are_typed_and_validated() {
        // ingest: defaults to f32, accepts half only with --dense
        let cfg = RunConfig::from_args(argv("ingest --input kg.tsv")).unwrap();
        match cfg.command {
            Command::Ingest(cmd) => assert_eq!(cmd.dtype, DType::F32),
            _ => panic!("expected ingest command"),
        }
        let cfg =
            RunConfig::from_args(argv("ingest --input kg.tsv --dense --dtype bf16")).unwrap();
        match cfg.command {
            Command::Ingest(cmd) => assert_eq!(cmd.dtype, DType::Bf16),
            _ => panic!("expected ingest command"),
        }
        let e = RunConfig::from_args(argv("ingest --input kg.tsv --dtype f16")).unwrap_err();
        assert!(e.to_string().contains("--dense"), "{e}");
        let e = RunConfig::from_args(argv("ingest --input kg.tsv --dense --dtype f64"))
            .unwrap_err();
        assert!(e.to_string().contains("--dtype"), "{e}");
        // export: half artifacts need no --dense (the factors are dense
        // by construction)
        let cfg = RunConfig::from_args(argv("export --dtype f16")).unwrap();
        match cfg.command {
            Command::Export(cmd) => assert_eq!(cmd.dtype, DType::F16),
            _ => panic!("expected export command"),
        }
        assert!(RunConfig::from_args(argv("export --dtype f64")).is_err());
        // other subcommands don't take --dtype
        assert!(RunConfig::from_args(argv("run --dtype f16")).is_err());
    }

    #[test]
    fn tune_subcommand_is_typed() {
        let cfg = RunConfig::from_args(argv("tune")).unwrap();
        match cfg.command {
            Command::Tune(cmd) => {
                assert_eq!(cmd.out, crate::tensor::kernel::tune::PROFILE_FILE);
                assert!(!cmd.quick);
                assert!(!cmd.json);
            }
            _ => panic!("expected tune command"),
        }
        let cfg = RunConfig::from_args(argv("tune --quick --out prof.json --json")).unwrap();
        match cfg.command {
            Command::Tune(cmd) => {
                assert_eq!(cmd.out, "prof.json");
                assert!(cmd.quick);
                assert!(cmd.json);
            }
            _ => panic!("expected tune command"),
        }
        assert!(RunConfig::from_args(argv("tune --iters 3")).is_err());
    }

    #[test]
    fn file_data_spec_parses() {
        let cfg = RunConfig::from_args(argv("run --data file:corpus/manifest.json")).unwrap();
        match cfg.command {
            Command::Run(cmd) => {
                assert_eq!(
                    cmd.data,
                    DataSpec::File { manifest: "corpus/manifest.json".to_string() }
                );
                assert_eq!(cmd.data.k_true(), None);
            }
            _ => panic!("expected run command"),
        }
        let e = RunConfig::from_args(argv("run --data file:")).unwrap_err();
        assert!(e.to_string().contains("file:"), "{e}");
        // a missing manifest surfaces when the spec is materialized
        let spec = DataSpec::File { manifest: "/nonexistent/manifest.json".into() };
        assert!(spec.to_dataset_spec(1).is_err());
        assert!(spec.load(1).is_err());
    }

    #[test]
    fn cache_budget_flag_feeds_engine_config() {
        let cfg = RunConfig::from_args(argv("run --cache-bytes 1048576")).unwrap();
        match cfg.command {
            Command::Run(cmd) => assert_eq!(cmd.engine.dataset_cache_bytes, 1 << 20),
            _ => panic!("expected run command"),
        }
        let cfg = RunConfig::from_args(argv("run")).unwrap();
        match cfg.command {
            Command::Run(cmd) => {
                assert_eq!(cmd.engine.dataset_cache_bytes, 0, "budget is opt-in");
            }
            _ => panic!("expected run command"),
        }
        assert!(RunConfig::from_args(argv("run --cache-bytes lots")).is_err());
        assert!(RunConfig::from_args(argv("exascale --cache-bytes 1")).is_err());
    }

    #[test]
    fn serve_bench_defaults() {
        let cfg = RunConfig::from_args(argv("serve-bench")).unwrap();
        match cfg.command {
            Command::ServeBench(cmd) => {
                assert_eq!((cmd.n, cmd.m, cmd.k), (512, 2, 8));
                assert_eq!((cmd.queries, cmd.batch, cmd.top), (2048, 64, 10));
                assert_eq!(cmd.engine.p, 4);
            }
            _ => panic!("expected serve-bench command"),
        }
        assert!(RunConfig::from_args(argv("serve-bench --batch 0")).is_err());
    }

    #[test]
    fn synthetic_data_maps_to_rank_local_generation() {
        let spec = DataSpec::Synthetic { n: 32, m: 2, k_true: 3, density: 1.0 }
            .to_dataset_spec(7)
            .unwrap();
        match spec {
            DatasetSpec::Synthetic(s) => {
                assert_eq!((s.n, s.m, s.k, s.seed), (32, 2, 3, 7));
                assert!(!s.is_sparse());
            }
            _ => panic!("dense synthetic must generate rank-locally"),
        }
        let spec = DataSpec::Synthetic { n: 32, m: 2, k_true: 3, density: 0.1 }
            .to_dataset_spec(7)
            .unwrap();
        match spec {
            DatasetSpec::Synthetic(s) => assert!(s.is_sparse()),
            _ => panic!("sparse synthetic must generate rank-locally"),
        }
        // real datasets stay leader-resident
        assert!(matches!(
            DataSpec::Nations.to_dataset_spec(1).unwrap(),
            DatasetSpec::InMemory(_)
        ));
    }

    #[test]
    fn train_and_worker_subcommands_are_typed() {
        let cfg = RunConfig::from_args(argv(
            "train --workers 3 --listen 127.0.0.1:0 --k 3 --port-file leader.addr",
        ))
        .unwrap();
        match cfg.command {
            Command::Train(cmd) => {
                assert_eq!(cmd.engine.p, 4, "p = workers + leader");
                match &cmd.engine.transport {
                    TransportKind::TcpLeader(c) => {
                        assert_eq!(c.listen, "127.0.0.1:0");
                        assert_eq!(c.timeout_ms, 10_000);
                        assert_eq!(c.max_replacements, 1);
                        assert_eq!(c.port_file.as_deref(), Some(std::path::Path::new("leader.addr")));
                    }
                    _ => panic!("train must select the TCP transport"),
                }
                assert_eq!(cmd.opts.k, 3);
            }
            _ => panic!("expected train command"),
        }
        // workers + leader must form a square grid
        let e = RunConfig::from_args(argv("train --workers 2")).unwrap_err();
        assert!(e.to_string().contains("perfect square"), "{e}");
        assert!(RunConfig::from_args(argv("train --comm-timeout-ms 0")).is_err());
        // worker needs a leader address
        let e = RunConfig::from_args(argv("worker")).unwrap_err();
        assert!(e.to_string().contains("--connect"), "{e}");
        let cfg = RunConfig::from_args(argv("worker --connect 127.0.0.1:9000")).unwrap();
        match cfg.command {
            Command::Worker(cmd) => assert_eq!(cmd.connect, "127.0.0.1:9000"),
            _ => panic!("expected worker command"),
        }
        // everything else on the worker command line is rejected
        assert!(RunConfig::from_args(argv("worker --connect x --k 4")).is_err());
    }

    #[test]
    fn model_family_flag_is_typed() {
        // absent = the paper's Gaussian rule, on every family-aware command
        let cfg = RunConfig::from_args(argv("run")).unwrap();
        match cfg.command {
            Command::Run(cmd) => assert_eq!(cmd.engine.model, ModelKind::Rescal),
            _ => panic!("expected run command"),
        }
        let cfg = RunConfig::from_args(argv("run --model distmult")).unwrap();
        match cfg.command {
            Command::Run(cmd) => assert_eq!(cmd.engine.model, ModelKind::DistMult),
            _ => panic!("expected run command"),
        }
        let cfg = RunConfig::from_args(argv("train --model logistic")).unwrap();
        match cfg.command {
            Command::Train(cmd) => assert_eq!(cmd.engine.model, ModelKind::Logistic),
            _ => panic!("expected train command"),
        }
        let cfg = RunConfig::from_args(argv("model-select --model distmult")).unwrap();
        match cfg.command {
            Command::ModelSelect(cmd) => {
                assert_eq!(cmd.sweep.model, ModelKind::DistMult);
                assert_eq!(cmd.engine.model, ModelKind::DistMult);
            }
            _ => panic!("expected model-select command"),
        }
        let cfg = RunConfig::from_args(argv("bench --model logistic")).unwrap();
        match cfg.command {
            Command::Bench(cmd) => assert_eq!(cmd.engine.model, ModelKind::Logistic),
            _ => panic!("expected bench command"),
        }
        let e = RunConfig::from_args(argv("run --model tucker")).unwrap_err();
        assert!(e.to_string().contains("unknown model family"), "{e}");
    }

    #[test]
    fn export_and_query_spell_the_family_flag_family() {
        // `--model` on export/query is the artifact path, so the family
        // rides `--family` there
        let cfg = RunConfig::from_args(argv(
            "export --family distmult --model out.json --sweep",
        ))
        .unwrap();
        match cfg.command {
            Command::Export(cmd) => {
                assert_eq!(cmd.engine.model, ModelKind::DistMult);
                assert_eq!(cmd.sweep.unwrap().model, ModelKind::DistMult);
                assert_eq!(cmd.model, "out.json");
            }
            _ => panic!("expected export command"),
        }
        let cfg =
            RunConfig::from_args(argv("query --s 1 --r 0 --family logistic")).unwrap();
        match cfg.command {
            Command::Query(cmd) => assert_eq!(cmd.family, Some(ModelKind::Logistic)),
            _ => panic!("expected query command"),
        }
        let cfg = RunConfig::from_args(argv("query --s 1 --r 0")).unwrap();
        match cfg.command {
            Command::Query(cmd) => assert_eq!(cmd.family, None, "assertion is opt-in"),
            _ => panic!("expected query command"),
        }
        assert!(RunConfig::from_args(argv("query --s 1 --family tucker")).is_err());
        // and `--model` as a family spelling stays rejected there
        let e = RunConfig::from_args(argv("export --model-family x")).unwrap_err();
        assert!(e.to_string().contains("unknown flag"), "{e}");
    }

    #[test]
    fn trace_out_implies_tracing() {
        let cfg = RunConfig::from_args(argv("run --trace-out t.json")).unwrap();
        match cfg.command {
            Command::Run(cmd) => {
                assert!(cmd.engine.trace, "--trace-out must enable span recording");
                assert_eq!(cmd.trace_out.as_deref(), Some("t.json"));
            }
            _ => panic!("expected run command"),
        }
        let cfg = RunConfig::from_args(argv("train --trace-out t.json")).unwrap();
        match cfg.command {
            Command::Train(cmd) => {
                assert!(cmd.engine.trace);
                assert_eq!(cmd.trace_out.as_deref(), Some("t.json"));
            }
            _ => panic!("expected train command"),
        }
        let cfg = RunConfig::from_args(argv("model-select --trace-out t.json")).unwrap();
        match cfg.command {
            Command::ModelSelect(cmd) => assert!(cmd.engine.trace),
            _ => panic!("expected model-select command"),
        }
        // without the flag nothing changes
        let cfg = RunConfig::from_args(argv("run")).unwrap();
        match cfg.command {
            Command::Run(cmd) => assert_eq!(cmd.trace_out, None),
            _ => panic!("expected run command"),
        }
        assert!(RunConfig::from_args(argv("exascale --trace-out t.json")).is_err());
    }

    #[test]
    fn trace_summary_takes_a_positional_path() {
        let cfg = RunConfig::from_args(argv("trace-summary trace.json")).unwrap();
        match cfg.command {
            Command::TraceSummary(cmd) => assert_eq!(cmd.input, "trace.json"),
            _ => panic!("expected trace-summary command"),
        }
        let cfg = RunConfig::from_args(argv("trace-summary --input t.json")).unwrap();
        match cfg.command {
            Command::TraceSummary(cmd) => assert_eq!(cmd.input, "t.json"),
            _ => panic!("expected trace-summary command"),
        }
        let e = RunConfig::from_args(argv("trace-summary")).unwrap_err();
        assert!(e.to_string().contains("trace file"), "{e}");
    }

    #[test]
    fn monitor_takes_a_positional_addr() {
        let cfg = RunConfig::from_args(argv("monitor 127.0.0.1:8650")).unwrap();
        match cfg.command {
            Command::Monitor(cmd) => {
                assert_eq!(cmd.addr, "127.0.0.1:8650");
                assert_eq!(cmd.interval_ms, 250);
            }
            _ => panic!("expected monitor command"),
        }
        let cfg =
            RunConfig::from_args(argv("monitor --addr 127.0.0.1:1 --interval-ms 50")).unwrap();
        match cfg.command {
            Command::Monitor(cmd) => assert_eq!(cmd.interval_ms, 50),
            _ => panic!("expected monitor command"),
        }
        let e = RunConfig::from_args(argv("monitor")).unwrap_err();
        assert!(e.to_string().contains("status address"), "{e}");
    }

    #[test]
    fn status_port_implies_tracing_and_validates() {
        let cfg = RunConfig::from_args(argv("train --status-port 0")).unwrap();
        match cfg.command {
            Command::Train(cmd) => {
                assert!(cmd.engine.trace, "--status-port must imply tracing");
                assert_eq!(cmd.engine.status_port, Some(0));
            }
            _ => panic!("expected train command"),
        }
        let cfg = RunConfig::from_args(argv("serve-bench --status-port 18650")).unwrap();
        match cfg.command {
            Command::ServeBench(cmd) => assert_eq!(cmd.engine.status_port, Some(18650)),
            _ => panic!("expected serve-bench command"),
        }
        let e = RunConfig::from_args(argv("train --status-port notaport")).unwrap_err();
        assert!(e.to_string().contains("status-port"), "{e}");
        // run/bench do not accept it (leader endpoint is transport-level)
        assert!(RunConfig::from_args(argv("bench --status-port 1")).is_err());
    }

    #[test]
    fn data_spec_ground_truth() {
        assert_eq!(DataSpec::Nations.k_true(), Some(4));
        assert_eq!(DataSpec::Trade.k_true(), Some(5));
        assert_eq!(
            DataSpec::Blocks { n: 24, m: 2, k_true: 3 }.k_true(),
            Some(3)
        );
    }
}
