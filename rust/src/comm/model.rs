//! α-β (latency-bandwidth) network cost model.
//!
//! The paper's communication complexity (§5.1.2) counts collectives over
//! √p ranks with the standard `O(log p)` tree/butterfly factors from Chan
//! et al. [55]. This model turns those counts into seconds so the scaling
//! figures can be replayed at cluster scale (1024 ranks, §6.3) from a
//! single-node calibration — the substitution documented in DESIGN.md §3.

/// Cluster link parameters.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Per-byte transfer time, seconds (1 / bandwidth).
    pub beta: f64,
}

impl NetworkModel {
    /// Grizzly-like Intel OmniPath fat-tree, *effective per-rank*: the
    /// paper runs ~20-25 MPI ranks per node (§6.5), all sharing one NIC,
    /// so each rank sees ≈1/20 of the 12.5 GB/s link during the
    /// per-subcommunicator collectives. α also includes the MPI software
    /// stack (mpi4py) overhead.
    pub fn omnipath() -> Self {
        NetworkModel { alpha: 2.0e-6, beta: 20.0 / 12.5e9 }
    }

    /// Kodiak-like InfiniBand with CUDA-aware MPI: 4 GPUs share a node's
    /// NIC and every message stages through PCIe + host buffers (the paper
    /// blames exactly this path, §6.3.3), so effective per-rank bandwidth
    /// is far below the link rate and latency is ~10 µs.
    pub fn infiniband_gpu() -> Self {
        NetworkModel { alpha: 1.0e-5, beta: 2.5e-9 }
    }

    /// All_reduce of `bytes` over `p` ranks: recursive doubling/halving,
    /// `2·log2(p)` message rounds, each round moving the full payload
    /// (ring-style long-message term omitted; the paper's bound is the
    /// log-p form).
    pub fn all_reduce(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let lg = (p as f64).log2().ceil();
        lg * (self.alpha + self.beta * bytes as f64) * 2.0
    }

    /// Broadcast of `bytes` over `p` ranks: binomial tree, log2(p) rounds.
    pub fn broadcast(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let lg = (p as f64).log2().ceil();
        lg * (self.alpha + self.beta * bytes as f64)
    }

    /// All_gather of `bytes` (per-rank contribution) over `p` ranks: ring,
    /// p−1 rounds each moving one contribution.
    pub fn all_gather(&self, p: usize, bytes: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        (p - 1) as f64 * (self.alpha + self.beta * bytes as f64)
    }
}

/// Machine compute model: sustained GEMM rate in FLOP/s, used together
/// with [`NetworkModel`] to replay the paper's large-scale runs.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    /// Sustained dense FLOP/s per rank.
    pub flops: f64,
    /// Sustained sparse (CSR SpMM) FLOP/s per rank — bandwidth-bound, so
    /// much lower than the dense rate.
    pub sparse_flops: f64,
}

impl ComputeModel {
    /// Broadwell-era 18-core node running one MPI rank per core, OpenBLAS:
    /// ≈ 30 GFLOP/s effective per rank at the paper's tile sizes. The CSR
    /// SpMM rate is higher than a naive gather estimate because the k-wide
    /// output rows stream (≈4 GFLOP/s), but stays an order below dense.
    pub fn grizzly_cpu_rank() -> Self {
        ComputeModel { flops: 30.0e9, sparse_flops: 4.0e9 }
    }

    /// P100 GPU rank: the paper reports ≥10× CPU; 9.3 TFLOP/s peak f32,
    /// ≈ 3 TFLOP/s sustained for these GEMM shapes.
    pub fn kodiak_p100_rank() -> Self {
        ComputeModel { flops: 3.0e12, sparse_flops: 40.0e9 }
    }

    /// Seconds to execute `flop` dense floating point operations.
    pub fn dense_seconds(&self, flop: f64) -> f64 {
        flop / self.flops
    }

    /// Seconds for sparse operations.
    pub fn sparse_seconds(&self, flop: f64) -> f64 {
        flop / self.sparse_flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_costs_nothing() {
        let m = NetworkModel::omnipath();
        assert_eq!(m.all_reduce(1, 1024), 0.0);
        assert_eq!(m.broadcast(1, 1024), 0.0);
        assert_eq!(m.all_gather(1, 1024), 0.0);
    }

    #[test]
    fn all_reduce_scales_log_p() {
        let m = NetworkModel::omnipath();
        let t4 = m.all_reduce(4, 1 << 20);
        let t16 = m.all_reduce(16, 1 << 20);
        // log2(16)/log2(4) = 2
        assert!((t16 / t4 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_messages_cost_more() {
        let m = NetworkModel::omnipath();
        assert!(m.all_reduce(8, 1 << 24) > m.all_reduce(8, 1 << 10));
        assert!(m.broadcast(8, 1 << 24) > m.broadcast(8, 1 << 10));
    }

    #[test]
    fn latency_dominates_small_messages() {
        let m = NetworkModel::omnipath();
        let t = m.broadcast(1024, 8);
        // ~10 rounds of ~alpha each
        assert!(t > 9.0 * m.alpha && t < 12.0 * (m.alpha + 1e-7));
    }

    #[test]
    fn gpu_rank_is_much_faster_dense() {
        let cpu = ComputeModel::grizzly_cpu_rank();
        let gpu = ComputeModel::kodiak_p100_rank();
        let flop = 1e12;
        assert!(cpu.dense_seconds(flop) / gpu.dense_seconds(flop) >= 10.0);
    }

    #[test]
    fn sparse_rate_below_dense() {
        let cpu = ComputeModel::grizzly_cpu_rank();
        assert!(cpu.sparse_flops < cpu.flops);
    }
}
