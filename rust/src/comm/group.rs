//! A communicator group: the member set of one collective scope
//! (a grid row, a grid column, or the world).
//!
//! `Group` is a thin cloneable handle over a [`Transport`] backend.
//! The default backend is [`transport::inprocess::InProcess`] —
//! per-member shared slots plus a reusable barrier (write-own → barrier
//! → read-all → barrier), the shared-memory analogue of
//! allgather-then-local-reduce. The TCP backend
//! ([`transport::tcp::TcpGroup`]) carries the same collectives between
//! OS processes; both reduce in member order, so results are
//! bit-identical across backends. Message counts and volumes match the
//! MPI collectives the paper uses, and per-op timings are recorded in
//! the caller's [`super::Trace`].
//!
//! Collectives are fallible: a dead or timed-out peer surfaces as a
//! typed [`CommError`] that rank code propagates up to the job layer
//! (in-process groups only fail on length mismatches).

use std::sync::{Arc, Mutex};

use super::transport::{self, CommResult, Transport, WireStats};

pub use super::transport::inprocess::GroupShared;

/// One member's handle on a group.
#[derive(Clone)]
pub struct Group {
    transport: Arc<Mutex<dyn Transport>>,
    /// This member's index within the group (0..size).
    pub rank: usize,
    size: usize,
}

impl Group {
    /// Wrap a transport backend.
    pub fn from_transport(t: impl Transport + 'static) -> Self {
        let rank = t.rank();
        let size = t.size();
        Group { transport: Arc::new(Mutex::new(t)), rank, size }
    }

    /// Attach to an existing in-process shared group (legacy
    /// constructor).
    pub fn new(shared: Arc<GroupShared>, rank: usize) -> Self {
        Group::from_transport(transport::inprocess::InProcess::new(shared, rank))
    }

    /// Group size.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Backend name ("in_process" / "tcp") for reports.
    pub fn backend(&self) -> &'static str {
        self.transport.lock().unwrap().backend()
    }

    /// Cumulative wire traffic moved by this member (used to charge
    /// real per-op byte counts in traces).
    pub fn wire_stats(&self) -> WireStats {
        self.transport.lock().unwrap().wire_stats()
    }

    /// Create the full set of member handles for a fresh in-process
    /// group.
    pub fn create(size: usize) -> Vec<Group> {
        transport::inprocess::InProcess::create(size)
            .into_iter()
            .map(Group::from_transport)
            .collect()
    }

    /// Barrier over the group.
    pub fn barrier(&self) -> CommResult<()> {
        self.transport.lock().unwrap().barrier()
    }

    /// Elementwise-sum all_reduce: on return every member's `data` holds
    /// the sum of all members' inputs, folded in member order so the
    /// result is bit-identical on every member (and across backends).
    pub fn all_reduce_sum(&self, data: &mut [f32]) -> CommResult<()> {
        self.transport.lock().unwrap().all_reduce_sum(data)
    }

    /// Elementwise max all_reduce.
    pub fn all_reduce_max(&self, data: &mut [f32]) -> CommResult<()> {
        self.transport.lock().unwrap().all_reduce_max(data)
    }

    /// Broadcast from `root` (group-local index): on return every member's
    /// `data` equals the root's input.
    pub fn broadcast(&self, root: usize, data: &mut [f32]) -> CommResult<()> {
        self.transport.lock().unwrap().broadcast(root, data)
    }

    /// All-gather: every member contributes `data`; returns the
    /// concatenation ordered by group rank.
    pub fn all_gather(&self, data: &[f32]) -> CommResult<Vec<f32>> {
        self.transport.lock().unwrap().all_gather(data)
    }

    /// Point-to-point send to group member `peer`.
    pub fn send(&self, peer: usize, data: &[f32]) -> CommResult<()> {
        self.transport.lock().unwrap().send(peer, data)
    }

    /// Point-to-point receive from group member `peer`.
    pub fn recv(&self, peer: usize) -> CommResult<Vec<f32>> {
        self.transport.lock().unwrap().recv(peer)
    }

    /// Gather byte payloads to group member 0 (collective; the
    /// telemetry gather — see [`Transport::gather_bytes_to_root`]).
    /// Member 0 receives every member's payload in member order,
    /// everyone else gets `None`.
    pub fn gather_bytes_to_root(&self, data: &[u8]) -> CommResult<Option<Vec<Vec<u8>>>> {
        self.transport.lock().unwrap().gather_bytes_to_root(data)
    }

    /// Gather scalar f64 values (for timing/metric aggregation).
    pub fn all_gather_f64(&self, v: f64) -> CommResult<Vec<f64>> {
        let gathered = self.all_gather(&[(v as f32)])?;
        // f32 precision is fine for metric aggregation, but keep f64 shape
        Ok(gathered.into_iter().map(|x| x as f64).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_group<T: Send>(size: usize, f: impl Fn(Group) -> T + Sync) -> Vec<T> {
        let groups = Group::create(size);
        std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|g| s.spawn(|| f(g)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn all_reduce_sums() {
        let results = run_group(4, |g| {
            let mut data = vec![g.rank as f32, 1.0];
            g.all_reduce_sum(&mut data).unwrap();
            data
        });
        for r in results {
            assert_eq!(r, vec![6.0, 4.0]); // 0+1+2+3, 1*4
        }
    }

    #[test]
    fn all_reduce_max_works() {
        let results = run_group(3, |g| {
            let mut data = vec![g.rank as f32 * 10.0, -(g.rank as f32)];
            g.all_reduce_max(&mut data).unwrap();
            data
        });
        for r in results {
            assert_eq!(r, vec![20.0, 0.0]);
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..3 {
            let results = run_group(3, move |g| {
                let mut data = vec![if g.rank == root { 42.0 } else { 0.0 }];
                g.broadcast(root, &mut data).unwrap();
                data[0]
            });
            assert_eq!(results, vec![42.0; 3]);
        }
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let results = run_group(4, |g| g.all_gather(&[g.rank as f32]).unwrap());
        for r in results {
            assert_eq!(r, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn repeated_collectives_reuse_group() {
        let results = run_group(4, |g| {
            let mut total = 0.0;
            for iter in 0..50 {
                let mut data = vec![(g.rank + iter) as f32];
                g.all_reduce_sum(&mut data).unwrap();
                total += data[0];
            }
            total
        });
        let want: f32 = (0..50).map(|i| (0 + 1 + 2 + 3 + 4 * i) as f32).sum();
        for r in results {
            assert_eq!(r, want);
        }
    }

    #[test]
    fn singleton_group_is_identity() {
        let mut g = Group::create(1);
        let g = g.remove(0);
        let mut data = vec![5.0];
        g.all_reduce_sum(&mut data).unwrap();
        assert_eq!(data, vec![5.0]);
        g.broadcast(0, &mut data).unwrap();
        assert_eq!(g.all_gather(&data).unwrap(), vec![5.0]);
    }

    #[test]
    fn mixed_sequence_no_deadlock() {
        // interleave different collectives; all members follow the same
        // program order so reusable barriers stay aligned
        let results = run_group(4, |g| {
            let mut x = vec![1.0f32];
            g.all_reduce_sum(&mut x).unwrap();
            let mut y = vec![g.rank as f32];
            g.broadcast(2, &mut y).unwrap();
            let z = g.all_gather(&[x[0], y[0]]).unwrap();
            z.iter().sum::<f32>()
        });
        // x=4, y=2 for all, gather = [4,2]*4 -> 24
        for r in results {
            assert_eq!(r, 24.0);
        }
    }

    #[test]
    fn point_to_point_lanes() {
        let results = run_group(2, |g| {
            if g.rank == 0 {
                g.send(1, &[3.0, 4.0]).unwrap();
                g.recv(1).unwrap()
            } else {
                let got = g.recv(0).unwrap();
                g.send(0, &[got[0] + got[1]]).unwrap();
                got
            }
        });
        assert_eq!(results[0], vec![7.0]);
        assert_eq!(results[1], vec![3.0, 4.0]);
    }

    #[test]
    fn gather_bytes_to_root_ragged_and_bit_exact() {
        // ragged payloads, including bytes that alias NaN f32 patterns —
        // the bitcast default impl must return them bit-exact
        let results = run_group(3, |g| {
            let payload: Vec<u8> =
                (0..(2 * g.rank + 1)).map(|i| 0xF8u8.wrapping_add(i as u8)).collect();
            g.gather_bytes_to_root(&payload).unwrap()
        });
        let root = results[0].as_ref().expect("member 0 gets the payloads");
        assert!(results[1].is_none() && results[2].is_none());
        assert_eq!(root.len(), 3);
        for (rank, got) in root.iter().enumerate() {
            let want: Vec<u8> =
                (0..(2 * rank + 1)).map(|i| 0xF8u8.wrapping_add(i as u8)).collect();
            assert_eq!(got, &want, "rank {rank} payload corrupted");
        }
    }

    #[test]
    fn wire_stats_accumulate() {
        let results = run_group(2, |g| {
            let mut v = vec![1.0f32; 8];
            g.all_reduce_sum(&mut v).unwrap();
            g.wire_stats()
        });
        for s in results {
            assert_eq!(s.ops, 1);
            assert!(s.bytes > 0);
        }
    }
}
