//! A communicator group: the member set of one collective scope
//! (a grid row, a grid column, or the world).
//!
//! Collectives are implemented over per-member shared slots plus a
//! reusable barrier: write-own → barrier → read-all → barrier. This is the
//! shared-memory analogue of allgather-then-local-reduce; message counts
//! and volumes match the MPI collectives the paper uses, and per-op
//! timings are recorded in the caller's [`super::Trace`].

use std::sync::{Arc, Barrier, RwLock};

/// State shared by all members of a group.
pub struct GroupShared {
    slots: Vec<RwLock<Vec<f32>>>,
    barrier: Barrier,
}

impl GroupShared {
    pub fn new(size: usize) -> Arc<Self> {
        Arc::new(GroupShared {
            slots: (0..size).map(|_| RwLock::new(Vec::new())).collect(),
            barrier: Barrier::new(size),
        })
    }
}

/// One member's handle on a group.
#[derive(Clone)]
pub struct Group {
    shared: Arc<GroupShared>,
    /// This member's index within the group (0..size).
    pub rank: usize,
}

impl Group {
    pub fn new(shared: Arc<GroupShared>, rank: usize) -> Self {
        Group { shared, rank }
    }

    /// Group size.
    pub fn size(&self) -> usize {
        self.shared.slots.len()
    }

    /// Create the full set of member handles for a fresh group.
    pub fn create(size: usize) -> Vec<Group> {
        let shared = GroupShared::new(size);
        (0..size).map(|r| Group::new(shared.clone(), r)).collect()
    }

    /// Barrier over the group.
    pub fn barrier(&self) {
        self.shared.barrier.wait();
    }

    /// Elementwise-sum all_reduce: on return every member's `data` holds
    /// the sum of all members' inputs.
    pub fn all_reduce_sum(&self, data: &mut [f32]) {
        if self.size() == 1 {
            return;
        }
        {
            let mut slot = self.shared.slots[self.rank].write().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        self.barrier();
        // Sum in fixed slot order (including our own slot) so every member
        // computes the bit-identical result — MPI all_reduce gives the same
        // guarantee, and Algorithm 3 relies on it to keep the replicated
        // factors consistent across a row.
        data.iter_mut().for_each(|d| *d = 0.0);
        for slot in self.shared.slots.iter() {
            let other = slot.read().unwrap();
            assert_eq!(other.len(), data.len(), "all_reduce length mismatch");
            for (d, &o) in data.iter_mut().zip(other.iter()) {
                *d += o;
            }
        }
        // second barrier: nobody may overwrite a slot before all have read
        self.barrier();
    }

    /// Elementwise max all_reduce.
    pub fn all_reduce_max(&self, data: &mut [f32]) {
        if self.size() == 1 {
            return;
        }
        {
            let mut slot = self.shared.slots[self.rank].write().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        self.barrier();
        data.iter_mut().for_each(|d| *d = f32::NEG_INFINITY);
        for slot in self.shared.slots.iter() {
            let other = slot.read().unwrap();
            for (d, &o) in data.iter_mut().zip(other.iter()) {
                if o > *d {
                    *d = o;
                }
            }
        }
        self.barrier();
    }

    /// Broadcast from `root` (group-local index): on return every member's
    /// `data` equals the root's input.
    pub fn broadcast(&self, root: usize, data: &mut [f32]) {
        if self.size() == 1 {
            return;
        }
        if self.rank == root {
            let mut slot = self.shared.slots[root].write().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        self.barrier();
        if self.rank != root {
            let slot = self.shared.slots[root].read().unwrap();
            assert_eq!(slot.len(), data.len(), "broadcast length mismatch");
            data.copy_from_slice(&slot);
        }
        self.barrier();
    }

    /// All-gather: every member contributes `data`; returns the
    /// concatenation ordered by group rank.
    pub fn all_gather(&self, data: &[f32]) -> Vec<f32> {
        if self.size() == 1 {
            return data.to_vec();
        }
        {
            let mut slot = self.shared.slots[self.rank].write().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        self.barrier();
        let mut out = Vec::new();
        for slot in self.shared.slots.iter() {
            out.extend_from_slice(&slot.read().unwrap());
        }
        self.barrier();
        out
    }

    /// Gather scalar f64 values (for timing/metric aggregation).
    pub fn all_gather_f64(&self, v: f64) -> Vec<f64> {
        let gathered = self.all_gather(&[(v as f32)]);
        // f32 precision is fine for metric aggregation, but keep f64 shape
        gathered.into_iter().map(|x| x as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_group<T: Send>(size: usize, f: impl Fn(Group) -> T + Sync) -> Vec<T> {
        let groups = Group::create(size);
        std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|g| s.spawn(|| f(g)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn all_reduce_sums() {
        let results = run_group(4, |g| {
            let mut data = vec![g.rank as f32, 1.0];
            g.all_reduce_sum(&mut data);
            data
        });
        for r in results {
            assert_eq!(r, vec![6.0, 4.0]); // 0+1+2+3, 1*4
        }
    }

    #[test]
    fn all_reduce_max_works() {
        let results = run_group(3, |g| {
            let mut data = vec![g.rank as f32 * 10.0, -(g.rank as f32)];
            g.all_reduce_max(&mut data);
            data
        });
        for r in results {
            assert_eq!(r, vec![20.0, 0.0]);
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..3 {
            let results = run_group(3, move |g| {
                let mut data = vec![if g.rank == root { 42.0 } else { 0.0 }];
                g.broadcast(root, &mut data);
                data[0]
            });
            assert_eq!(results, vec![42.0; 3]);
        }
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let results = run_group(4, |g| g.all_gather(&[g.rank as f32]));
        for r in results {
            assert_eq!(r, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn repeated_collectives_reuse_group() {
        let results = run_group(4, |g| {
            let mut total = 0.0;
            for iter in 0..50 {
                let mut data = vec![(g.rank + iter) as f32];
                g.all_reduce_sum(&mut data);
                total += data[0];
            }
            total
        });
        let want: f32 = (0..50).map(|i| (0 + 1 + 2 + 3 + 4 * i) as f32).sum();
        for r in results {
            assert_eq!(r, want);
        }
    }

    #[test]
    fn singleton_group_is_identity() {
        let mut g = Group::create(1);
        let g = g.remove(0);
        let mut data = vec![5.0];
        g.all_reduce_sum(&mut data);
        assert_eq!(data, vec![5.0]);
        g.broadcast(0, &mut data);
        assert_eq!(g.all_gather(&data), vec![5.0]);
    }

    #[test]
    fn mixed_sequence_no_deadlock() {
        // interleave different collectives; all members follow the same
        // program order so reusable barriers stay aligned
        let results = run_group(4, |g| {
            let mut x = vec![1.0f32];
            g.all_reduce_sum(&mut x);
            let mut y = vec![g.rank as f32];
            g.broadcast(2, &mut y);
            let z = g.all_gather(&[x[0], y[0]]);
            z.iter().sum::<f32>()
        });
        // x=4, y=2 for all, gather = [4,2]*4 -> 24
        for r in results {
            assert_eq!(r, 24.0);
        }
    }
}
