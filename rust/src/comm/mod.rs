//! Virtual MPI: in-process message passing over a 2D processor grid.
//!
//! pyDRESCALk runs on MPI with a √p×√p virtual grid and only three
//! collectives: `all_reduce`, `all_gather`, and `broadcast`, always over
//! row or column sub-communicators (paper §3.2, §4.1). This module
//! reproduces that topology with one OS thread per rank and shared-memory
//! collectives, so the whole distributed algorithm runs unchanged inside a
//! single process.
//!
//! Substitution note (DESIGN.md §3): communication *pattern and volume*
//! are identical to the MPI original; wall-clock extrapolation to cluster
//! scale uses the α-β [`model::NetworkModel`], calibrated exactly like the
//! paper's §5 complexity analysis.
//!
//! Since the transport plane landed, the same collectives also run
//! between real OS processes: [`transport::Transport`] abstracts the
//! backend, with [`transport::inprocess`] (the default described above)
//! and [`transport::tcp`] (framed messages over a leader-rendezvoused
//! socket mesh) producing bit-identical results.

pub mod grid;
pub mod group;
pub mod model;
pub mod trace;
pub mod transport;

pub use grid::{Grid, RankCtx};
pub use group::Group;
pub use model::NetworkModel;
pub use trace::{CommOp, Trace};
pub use transport::{CommError, CommResult, Transport, WireStats};
