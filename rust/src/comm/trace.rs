//! Per-rank operation timing trace.
//!
//! The paper's scaling figures break runtime into named operations:
//! `gram_mul`, `matrix_mul`, `matrix_mul_sparse`, `row_reduce`,
//! `column_reduce`, `row_broadcast`, `column_broadcast` (§6.3). Each rank
//! records (op, bytes, duration) tuples; the coordinator aggregates them
//! into exactly those breakdown rows.
//!
//! An enabled trace also feeds the telemetry plane: every recorded op
//! lands as a timestamped span in an embedded [`crate::obs::Recorder`]
//! (category `"comm"` or `"compute"`, labeled with the op name and the
//! current MU iteration), and the distributed loop brackets each
//! iteration segment with `"phase"` spans via
//! [`Trace::phase_start`]/[`Trace::phase_end`]. The ring snapshot
//! ([`Trace::timeline_snapshot`]) is what rank 0 gathers from the whole
//! cluster and `--trace-out` exports as a Chrome trace.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{self, LiveHub, Recorder};

/// Operation categories matching the paper's breakdown plots.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CommOp {
    GramMul,
    MatrixMul,
    MatrixMulSparse,
    RowReduce,
    ColumnReduce,
    RowBroadcast,
    ColumnBroadcast,
    AllGather,
    Clustering,
    Silhouette,
    Other,
}

impl CommOp {
    pub fn name(&self) -> &'static str {
        match self {
            CommOp::GramMul => "gram_mul",
            CommOp::MatrixMul => "matrix_mul",
            CommOp::MatrixMulSparse => "matrix_mul_sparse",
            CommOp::RowReduce => "row_reduce",
            CommOp::ColumnReduce => "column_reduce",
            CommOp::RowBroadcast => "row_broadcast",
            CommOp::ColumnBroadcast => "column_broadcast",
            CommOp::AllGather => "all_gather",
            CommOp::Clustering => "clustering",
            CommOp::Silhouette => "silhouette",
            CommOp::Other => "other",
        }
    }

    /// True for communication (vs compute) categories.
    pub fn is_comm(&self) -> bool {
        matches!(
            self,
            CommOp::RowReduce
                | CommOp::ColumnReduce
                | CommOp::RowBroadcast
                | CommOp::ColumnBroadcast
                | CommOp::AllGather
        )
    }

    /// All categories, in display order.
    pub fn all() -> &'static [CommOp] {
        &[
            CommOp::GramMul,
            CommOp::MatrixMul,
            CommOp::MatrixMulSparse,
            CommOp::RowReduce,
            CommOp::ColumnReduce,
            CommOp::RowBroadcast,
            CommOp::ColumnBroadcast,
            CommOp::AllGather,
            CommOp::Clustering,
            CommOp::Silhouette,
            CommOp::Other,
        ]
    }
}

/// One recorded event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub op: CommOp,
    pub bytes: usize,
    pub duration: Duration,
}

/// Per-rank trace. Not thread-safe by design: one per rank thread.
#[derive(Default, Clone, Debug)]
pub struct Trace {
    events: Vec<TraceEvent>,
    enabled: bool,
    recorder: Recorder,
    /// Rank 0 on the leader carries the live hub; everyone else `None`.
    hub: Option<Arc<LiveHub>>,
    /// How many recorder spans have already been flushed to the leader.
    flush_cursor: u64,
}

impl Trace {
    pub fn new() -> Self {
        Trace {
            events: Vec::new(),
            enabled: true,
            recorder: Recorder::new(),
            hub: None,
            flush_cursor: 0,
        }
    }

    /// A trace that drops all events (hot-path zero overhead mode).
    pub fn disabled() -> Self {
        Trace {
            events: Vec::new(),
            enabled: false,
            recorder: Recorder::disabled(),
            hub: None,
            flush_cursor: 0,
        }
    }

    /// Attach the leader's live hub: [`Trace::iteration_boundary`] on
    /// this trace will feed gathered span deltas and progress events
    /// into it. Only rank 0 of the leader process gets one.
    pub fn set_hub(&mut self, hub: Arc<LiveHub>) {
        self.hub = Some(hub);
    }

    /// Charge a span to the embedded timeline recorder.
    #[inline]
    fn timeline_push(&mut self, op: CommOp, bytes: u64, t0: Instant, dur: Duration) {
        self.recorder.end_at(
            if op.is_comm() { "comm" } else { "compute" },
            op.name(),
            t0,
            dur,
            bytes,
        );
    }

    /// Time `f`, charging it to `op` with the given payload size.
    #[inline]
    pub fn record<T>(&mut self, op: CommOp, bytes: usize, f: impl FnOnce() -> T) -> T {
        if !self.enabled {
            return f();
        }
        let t0 = Instant::now();
        let out = f();
        let dur = t0.elapsed();
        self.timeline_push(op, bytes as u64, t0, dur);
        self.events.push(TraceEvent { op, bytes, duration: dur });
        out
    }

    /// Time a fallible collective on `group`, charging `op` with the
    /// *real* wire traffic the transport moved (payload + frame headers
    /// for TCP, slot traffic for in-process) instead of a caller-claimed
    /// byte count. The event is recorded even when the collective fails,
    /// so timed-out ops still show up in the breakdown.
    #[inline]
    pub fn record_comm<T>(
        &mut self,
        op: CommOp,
        group: &crate::comm::Group,
        f: impl FnOnce() -> crate::comm::CommResult<T>,
    ) -> crate::comm::CommResult<T> {
        if !self.enabled {
            return f();
        }
        let w0 = group.wire_stats();
        let t0 = Instant::now();
        let out = f();
        let dur = t0.elapsed();
        let wire = group.wire_stats().since(w0);
        self.timeline_push(op, wire.bytes, t0, dur);
        self.events.push(TraceEvent { op, bytes: wire.bytes as usize, duration: dur });
        out
    }

    /// Set the MU iteration charged to subsequent timeline spans
    /// ([`crate::obs::NO_ITER`] outside the loop).
    #[inline]
    pub fn set_iter(&mut self, iter: u32) {
        self.recorder.set_iter(iter);
    }

    /// Open a `"phase"` span (pack/gemm/reduce/mu_update/normalize in
    /// the distributed loop). Returns `None` when tracing is off; close
    /// with [`Trace::phase_end`]. A token API instead of a closure
    /// because the phase body needs `&mut self` for its nested op spans.
    #[inline]
    pub fn phase_start(&self) -> Option<Instant> {
        self.recorder.begin()
    }

    /// Close a phase span opened with [`Trace::phase_start`].
    #[inline]
    pub fn phase_end(&mut self, label: &'static str, t0: Option<Instant>) {
        self.recorder.end("phase", label, t0, 0);
    }

    /// Whether the embedded timeline recorder is collecting spans.
    pub fn timeline_enabled(&self) -> bool {
        self.recorder.enabled()
    }

    /// Snapshot the timeline ring for the cross-process gather.
    pub fn timeline_snapshot(&self, rank: usize) -> obs::RankTimeline {
        self.recorder.snapshot(rank)
    }

    /// Streaming telemetry flush at an MU iteration boundary. Every rank
    /// ships the spans recorded since its last flush to member 0 of
    /// `world` (one `KIND_TELEMETRY` frame per rank on the TCP backend);
    /// on the leader the gathered deltas land in the live hub together
    /// with one structured progress event, so `/progress` and `/trace`
    /// are current mid-job and a crashed worker's pre-flush spans
    /// survive into the final artifact.
    ///
    /// This is a collective: every member of `world` must call it at the
    /// same iteration (the trace flag rides the cluster welcome, so the
    /// cadence is uniform across ranks). No-op when the recorder is off.
    pub fn iteration_boundary(
        &mut self,
        world: &crate::comm::Group,
        iter: u32,
        rel_error: f32,
        err_fresh: bool,
    ) -> crate::comm::CommResult<()> {
        if !self.recorder.enabled() {
            return Ok(());
        }
        let delta = self.recorder.snapshot_since(world.rank, self.flush_cursor);
        self.flush_cursor = self.recorder.total_pushed();
        let payload = obs::timeline_to_bytes(&delta);
        let gathered = world.gather_bytes_to_root(&payload)?;
        if let (Some(payloads), Some(hub)) = (gathered, self.hub.as_ref()) {
            let mut rank0_delta = obs::RankTimeline::default();
            for (rank, bytes) in payloads.iter().enumerate() {
                let t = obs::timeline_from_bytes(rank, bytes).map_err(|e| {
                    crate::comm::CommError::Protocol {
                        reason: format!("telemetry flush decode (rank {rank}): {e}"),
                    }
                })?;
                if rank == 0 {
                    rank0_delta = t.clone();
                }
                hub.absorb(t);
            }
            let wire_bytes = self.comm_totals().0 as u64;
            hub.on_iteration(iter, rel_error, err_fresh, wire_bytes, &rank0_delta);
        }
        Ok(())
    }

    /// Record an event with a known duration (used when replaying modeled
    /// timings).
    pub fn push(&mut self, op: CommOp, bytes: usize, duration: Duration) {
        if self.enabled {
            self.events.push(TraceEvent { op, bytes, duration });
        }
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Total seconds charged to `op`.
    pub fn seconds(&self, op: CommOp) -> f64 {
        self.events
            .iter()
            .filter(|e| e.op == op)
            .map(|e| e.duration.as_secs_f64())
            .sum()
    }

    /// Total seconds across all events.
    pub fn total_seconds(&self) -> f64 {
        self.events.iter().map(|e| e.duration.as_secs_f64()).sum()
    }

    /// Total bytes charged to `op`.
    pub fn bytes(&self, op: CommOp) -> usize {
        self.events.iter().filter(|e| e.op == op).map(|e| e.bytes).sum()
    }

    /// (compute seconds, communication seconds).
    pub fn compute_comm_split(&self) -> (f64, f64) {
        let mut comp = 0.0;
        let mut comm = 0.0;
        for e in &self.events {
            if e.op.is_comm() {
                comm += e.duration.as_secs_f64();
            } else {
                comp += e.duration.as_secs_f64();
            }
        }
        (comp, comm)
    }

    /// (total wire bytes, event count) over communication categories —
    /// the per-rank row of the report's `transport` section.
    pub fn comm_totals(&self) -> (usize, usize) {
        let mut bytes = 0;
        let mut ops = 0;
        for e in &self.events {
            if e.op.is_comm() {
                bytes += e.bytes;
                ops += 1;
            }
        }
        (bytes, ops)
    }

    /// Merge another trace into this one (coordinator-side aggregation).
    pub fn merge(&mut self, other: &Trace) {
        self.events.extend(other.events.iter().cloned());
    }

    /// Breakdown rows `(op name, seconds, bytes)` over all categories with
    /// at least one event, in display order.
    pub fn breakdown(&self) -> Vec<(&'static str, f64, usize)> {
        CommOp::all()
            .iter()
            .filter(|&&op| self.events.iter().any(|e| e.op == op))
            .map(|&op| (op.name(), self.seconds(op), self.bytes(op)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_charges_op() {
        let mut t = Trace::new();
        let v = t.record(CommOp::GramMul, 128, || 2 + 2);
        assert_eq!(v, 4);
        assert_eq!(t.events().len(), 1);
        assert_eq!(t.bytes(CommOp::GramMul), 128);
        assert!(t.seconds(CommOp::GramMul) >= 0.0);
    }

    #[test]
    fn disabled_trace_drops_events() {
        let mut t = Trace::disabled();
        t.record(CommOp::MatrixMul, 64, || ());
        assert!(t.events().is_empty());
    }

    #[test]
    fn compute_comm_split_classifies() {
        let mut t = Trace::new();
        t.push(CommOp::MatrixMul, 0, Duration::from_millis(30));
        t.push(CommOp::RowReduce, 0, Duration::from_millis(20));
        t.push(CommOp::ColumnBroadcast, 0, Duration::from_millis(10));
        let (comp, comm) = t.compute_comm_split();
        assert!((comp - 0.030).abs() < 1e-9);
        assert!((comm - 0.030).abs() < 1e-9);
    }

    #[test]
    fn merge_and_breakdown() {
        let mut a = Trace::new();
        a.push(CommOp::GramMul, 10, Duration::from_millis(5));
        let mut b = Trace::new();
        b.push(CommOp::GramMul, 20, Duration::from_millis(5));
        b.push(CommOp::RowReduce, 30, Duration::from_millis(1));
        a.merge(&b);
        let rows = a.breakdown();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "gram_mul");
        assert_eq!(rows[0].2, 30);
        assert_eq!(rows[1].0, "row_reduce");
    }
}
