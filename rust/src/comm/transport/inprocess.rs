//! Shared-memory transport: per-member slots plus a reusable barrier.
//!
//! This is the original virtual-MPI substrate — write-own → barrier →
//! read-all → barrier — now behind the [`Transport`] trait. Collectives
//! fold contributions in fixed slot order (including the member's own
//! slot), the property Algorithm 3 relies on to keep replicated factors
//! bit-identical across a row, and the contract the TCP backend must
//! match.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Barrier, Mutex, RwLock};

use super::{CommError, CommResult, Transport, WireStats};

/// State shared by all members of an in-process group.
pub struct GroupShared {
    slots: Vec<RwLock<Vec<f32>>>,
    barrier: Barrier,
}

impl GroupShared {
    pub fn new(size: usize) -> Arc<Self> {
        Arc::new(GroupShared {
            slots: (0..size).map(|_| RwLock::new(Vec::new())).collect(),
            barrier: Barrier::new(size),
        })
    }

    pub fn size(&self) -> usize {
        self.slots.len()
    }
}

/// One member's shared-memory transport handle.
pub struct InProcess {
    shared: Arc<GroupShared>,
    rank: usize,
    /// Point-to-point lanes: `tx[j]` sends to member j, `rx[j]` receives
    /// from member j (None for self).
    tx: Vec<Option<Sender<Vec<f32>>>>,
    rx: Vec<Option<Mutex<Receiver<Vec<f32>>>>>,
    stats: WireStats,
}

impl InProcess {
    /// Create the full set of member transports for a fresh group.
    pub fn create(size: usize) -> Vec<InProcess> {
        let shared = GroupShared::new(size);
        // one mpsc lane per ordered pair (i -> j)
        let mut txs: Vec<Vec<Option<Sender<Vec<f32>>>>> =
            (0..size).map(|_| (0..size).map(|_| None).collect()).collect();
        let mut rxs: Vec<Vec<Option<Mutex<Receiver<Vec<f32>>>>>> =
            (0..size).map(|_| (0..size).map(|_| None).collect()).collect();
        for i in 0..size {
            for j in 0..size {
                if i == j {
                    continue;
                }
                let (tx, rx) = std::sync::mpsc::channel();
                txs[i][j] = Some(tx);
                rxs[j][i] = Some(Mutex::new(rx));
            }
        }
        txs.into_iter()
            .zip(rxs)
            .enumerate()
            .map(|(rank, (tx, rx))| InProcess {
                shared: shared.clone(),
                rank,
                tx,
                rx,
                stats: WireStats::default(),
            })
            .collect()
    }

    /// Attach a member handle to an existing shared group (legacy
    /// constructor; no point-to-point lanes).
    pub fn new(shared: Arc<GroupShared>, rank: usize) -> Self {
        let size = shared.size();
        InProcess {
            shared,
            rank,
            tx: (0..size).map(|_| None).collect(),
            rx: (0..size).map(|_| None).collect(),
            stats: WireStats::default(),
        }
    }

    fn wait(&self) {
        self.shared.barrier.wait();
    }

    /// Charge one completed op moving `payload` f32s out and
    /// `(size-1) * payload` f32s in — the volume that actually crosses
    /// the shared slots (zero for singleton groups).
    fn charge(&mut self, payload: usize) {
        if self.shared.size() > 1 {
            self.stats.bytes += (payload * 4 * self.shared.size()) as u64;
        }
        self.stats.ops += 1;
    }
}

impl Transport for InProcess {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.shared.size()
    }

    fn backend(&self) -> &'static str {
        "in_process"
    }

    fn barrier(&mut self) -> CommResult<()> {
        if self.size() > 1 {
            self.wait();
        }
        self.stats.ops += 1;
        Ok(())
    }

    fn all_reduce_sum(&mut self, data: &mut [f32]) -> CommResult<()> {
        if self.size() == 1 {
            self.charge(0);
            return Ok(());
        }
        {
            let mut slot = self.shared.slots[self.rank].write().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        self.wait();
        // Sum in fixed slot order (including our own slot) so every member
        // computes the bit-identical result — MPI all_reduce gives the same
        // guarantee, and Algorithm 3 relies on it to keep the replicated
        // factors consistent across a row.
        data.iter_mut().for_each(|d| *d = 0.0);
        let mut mismatch = None;
        for (peer, slot) in self.shared.slots.iter().enumerate() {
            let other = slot.read().unwrap();
            if other.len() != data.len() {
                mismatch = Some((peer, other.len()));
                continue;
            }
            for (d, &o) in data.iter_mut().zip(other.iter()) {
                *d += o;
            }
        }
        // second barrier: nobody may overwrite a slot before all have read
        self.wait();
        if let Some((peer, len)) = mismatch {
            return Err(CommError::Protocol {
                reason: format!(
                    "all_reduce length mismatch: peer {peer} contributed {len} elements, \
                     expected {}",
                    data.len()
                ),
            });
        }
        self.charge(data.len());
        Ok(())
    }

    fn all_reduce_max(&mut self, data: &mut [f32]) -> CommResult<()> {
        if self.size() == 1 {
            self.charge(0);
            return Ok(());
        }
        {
            let mut slot = self.shared.slots[self.rank].write().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        self.wait();
        data.iter_mut().for_each(|d| *d = f32::NEG_INFINITY);
        for slot in self.shared.slots.iter() {
            let other = slot.read().unwrap();
            for (d, &o) in data.iter_mut().zip(other.iter()) {
                if o > *d {
                    *d = o;
                }
            }
        }
        self.wait();
        self.charge(data.len());
        Ok(())
    }

    fn broadcast(&mut self, root: usize, data: &mut [f32]) -> CommResult<()> {
        if self.size() == 1 {
            self.charge(0);
            return Ok(());
        }
        if self.rank == root {
            let mut slot = self.shared.slots[root].write().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        self.wait();
        let mut mismatch = None;
        if self.rank != root {
            let slot = self.shared.slots[root].read().unwrap();
            if slot.len() == data.len() {
                data.copy_from_slice(&slot);
            } else {
                mismatch = Some(slot.len());
            }
        }
        self.wait();
        if let Some(len) = mismatch {
            return Err(CommError::Protocol {
                reason: format!(
                    "broadcast length mismatch: root {root} sent {len} elements, expected {}",
                    data.len()
                ),
            });
        }
        // root sends one copy, others receive one copy
        if self.size() > 1 {
            self.stats.bytes += (data.len() * 4) as u64;
        }
        self.stats.ops += 1;
        Ok(())
    }

    fn all_gather(&mut self, data: &[f32]) -> CommResult<Vec<f32>> {
        if self.size() == 1 {
            self.charge(0);
            return Ok(data.to_vec());
        }
        {
            let mut slot = self.shared.slots[self.rank].write().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        self.wait();
        let mut out = Vec::new();
        for slot in self.shared.slots.iter() {
            out.extend_from_slice(&slot.read().unwrap());
        }
        self.wait();
        self.charge(data.len());
        Ok(out)
    }

    fn send(&mut self, peer: usize, data: &[f32]) -> CommResult<()> {
        let lane = self.tx.get(peer).and_then(|t| t.as_ref()).ok_or_else(|| {
            CommError::Protocol { reason: format!("no point-to-point lane to peer {peer}") }
        })?;
        lane.send(data.to_vec()).map_err(|_| CommError::PeerDisconnected { peer })?;
        self.stats.bytes += (data.len() * 4) as u64;
        self.stats.ops += 1;
        Ok(())
    }

    fn recv(&mut self, peer: usize) -> CommResult<Vec<f32>> {
        let lane = self.rx.get(peer).and_then(|r| r.as_ref()).ok_or_else(|| {
            CommError::Protocol { reason: format!("no point-to-point lane from peer {peer}") }
        })?;
        let data = lane
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| CommError::PeerDisconnected { peer })?;
        self.stats.bytes += (data.len() * 4) as u64;
        self.stats.ops += 1;
        Ok(data)
    }

    fn wire_stats(&self) -> WireStats {
        self.stats
    }
}
