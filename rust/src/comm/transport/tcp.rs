//! TCP transport: length-prefixed frames over a full peer mesh.
//!
//! ## Wire format
//!
//! Every message is one frame:
//!
//! ```text
//! magic   u32 LE   0x44525450 ("DRTP")
//! kind    u8       1 = hello (mesh handshake), 2 = data,
//!                  3 = telemetry (span-buffer gather to member 0)
//! group   u32 LE   communicator scope id (world = 0, rows, cols)
//! seq     u64 LE   per-group collective sequence number
//! len     u32 LE   payload length in bytes
//! payload [u8; len]
//! ```
//!
//! The `group`/`seq` pair is verified on every receive: because all
//! ranks execute collectives in the same program order, a mismatch
//! means a desynchronized or corrupted stream and surfaces as a typed
//! [`CommError::Protocol`] instead of silently folding wrong data.
//!
//! ## Mesh and collectives
//!
//! [`TcpMesh::establish`] builds one socket per peer pair (rank `i`
//! dials every `j < i` and accepts every `j > i`; each connection opens
//! with a hello frame carrying `{version, epoch, rank}` so mismatched
//! builds or stale epochs fail fast with [`CommError::Handshake`]).
//! Row, column, and world [`TcpGroup`]s share the one mesh — legal
//! because a rank thread runs its collectives strictly in program
//! order, so a socket never carries two scopes' traffic at once.
//!
//! Collectives move data around a **ring**: `all_gather` rotates blocks
//! `size-1` steps, and `all_reduce` is that ring all-gather followed by
//! a *local fold in group-member order 0..size* — the same order the
//! in-process slots use, which is what makes TCP runs bit-identical to
//! in-process runs (a classic reduce-scatter ring would change the f32
//! summation order). Deadlock freedom with blocking sockets comes from
//! one rule: group member 0 receives before it sends, everyone else
//! sends before receiving, which breaks the ring's wait cycle no matter
//! how large the payload.
//!
//! All socket operations carry read/write deadlines with bounded retry;
//! a dead peer surfaces as [`CommError::PeerDisconnected`] (EOF/reset)
//! or [`CommError::Timeout`], never a panic or a hang.

use std::io::{Read, Write};
use std::net::{IpAddr, SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::{CommError, CommResult, Transport, WireStats};
use crate::comm::grid::{Grid, RankCtx};
use crate::comm::Group;

/// Transport wire-protocol version; bumped on incompatible frame or
/// rendezvous changes. Mismatches fail the handshake.
pub const TRANSPORT_VERSION: u32 = 1;

const MAGIC: u32 = 0x4452_5450; // "DRTP"
const KIND_HELLO: u8 = 1;
const KIND_DATA: u8 = 2;
const KIND_TELEMETRY: u8 = 3;
const HEADER_LEN: usize = 4 + 1 + 4 + 8 + 4;

/// Socket deadlines and retry budget for one mesh.
#[derive(Clone, Copy, Debug)]
pub struct TcpConfig {
    /// Per-read/-write deadline. One collective step waits at most
    /// `timeout * (retries + 1)` before surfacing [`CommError::Timeout`].
    pub timeout: Duration,
    /// Bounded retries after a timed-out partial read/write.
    pub retries: u32,
}

impl Default for TcpConfig {
    fn default() -> Self {
        TcpConfig { timeout: Duration::from_secs(10), retries: 2 }
    }
}

/// A bound, not-yet-connected mesh endpoint. Created *before* addresses
/// are exchanged so every peer's dial is guaranteed a listener.
pub struct MeshListener {
    listener: TcpListener,
    /// The bound address (ephemeral port resolved).
    pub addr: SocketAddr,
}

impl MeshListener {
    /// Bind an ephemeral port on `ip`.
    pub fn bind(ip: IpAddr) -> CommResult<Self> {
        let listener = TcpListener::bind((ip, 0)).map_err(|e| CommError::Io {
            op: "bind mesh listener",
            detail: e.to_string(),
        })?;
        let addr = listener.local_addr().map_err(|e| CommError::Io {
            op: "resolve mesh listener addr",
            detail: e.to_string(),
        })?;
        Ok(MeshListener { listener, addr })
    }
}

/// The fully-connected socket mesh of one process (one rank), shared by
/// all of that rank's communicator scopes.
pub struct TcpMesh {
    rank: usize,
    size: usize,
    cfg: TcpConfig,
    conns: Vec<Option<TcpStream>>,
}

impl TcpMesh {
    /// This rank's world index.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// World size.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Connect the mesh: dial every lower rank, accept every higher
    /// rank, and validate a hello handshake (version + epoch + peer
    /// identity) on each connection. `addrs[j]` must be rank j's
    /// [`MeshListener`] address; `epoch` increments on every rendezvous
    /// so survivors of a crash can't cross-connect with a stale mesh.
    pub fn establish(
        rank: usize,
        size: usize,
        epoch: u64,
        listener: MeshListener,
        addrs: &[SocketAddr],
        cfg: TcpConfig,
    ) -> CommResult<TcpMesh> {
        if addrs.len() != size {
            return Err(CommError::Protocol {
                reason: format!("mesh wants {size} addresses, got {}", addrs.len()),
            });
        }
        let mut conns: Vec<Option<TcpStream>> = (0..size).map(|_| None).collect();
        // dial lower ranks
        for (peer, addr) in addrs.iter().enumerate().take(rank) {
            let stream = dial(*addr, peer, cfg)?;
            send_hello(&stream, epoch, rank, peer, cfg)?;
            let from = recv_hello(&stream, epoch, peer, cfg)?;
            if from != peer {
                return Err(CommError::Handshake {
                    reason: format!("dialed rank {peer} but peer identified as {from}"),
                });
            }
            conns[peer] = Some(stream);
        }
        // accept higher ranks (any arrival order; identified by hello)
        let expected = size - rank - 1;
        let mut accepted = 0;
        listener.listener.set_nonblocking(true).map_err(|e| CommError::Io {
            op: "mesh accept",
            detail: e.to_string(),
        })?;
        let deadline = Instant::now() + cfg.timeout.mul_f64((cfg.retries + 1) as f64);
        while accepted < expected {
            match listener.listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false).map_err(|e| CommError::Io {
                        op: "mesh accept",
                        detail: e.to_string(),
                    })?;
                    configure(&stream, cfg)?;
                    let from = recv_hello(&stream, epoch, usize::MAX, cfg)?;
                    if from <= rank || from >= size {
                        return Err(CommError::Handshake {
                            reason: format!("unexpected hello from rank {from} (we are {rank})"),
                        });
                    }
                    send_hello(&stream, epoch, rank, from, cfg)?;
                    if conns[from].is_some() {
                        return Err(CommError::Handshake {
                            reason: format!("rank {from} connected twice"),
                        });
                    }
                    conns[from] = Some(stream);
                    accepted += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(CommError::Timeout { op: "mesh accept", peer: usize::MAX });
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => {
                    return Err(CommError::Io { op: "mesh accept", detail: e.to_string() })
                }
            }
        }
        Ok(TcpMesh { rank, size, cfg, conns })
    }

    fn conn(&mut self, peer: usize) -> CommResult<&mut TcpStream> {
        self.conns
            .get_mut(peer)
            .and_then(|c| c.as_mut())
            .ok_or(CommError::PeerDisconnected { peer })
    }

    /// Send one data frame to world rank `peer`; returns wire bytes.
    fn send_frame(
        &mut self,
        peer: usize,
        group: u32,
        seq: u64,
        payload: &[u8],
    ) -> CommResult<usize> {
        self.send_frame_kind(peer, KIND_DATA, group, seq, payload)
    }

    /// Send one frame of the given kind to world rank `peer`; returns
    /// wire bytes.
    fn send_frame_kind(
        &mut self,
        peer: usize,
        frame_kind: u8,
        group: u32,
        seq: u64,
        payload: &[u8],
    ) -> CommResult<usize> {
        let cfg = self.cfg;
        let stream = self.conn(peer)?;
        let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.push(frame_kind);
        buf.extend_from_slice(&group.to_le_bytes());
        buf.extend_from_slice(&seq.to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(payload);
        write_all_retry(stream, &buf, "collective send", peer, cfg)?;
        Ok(buf.len())
    }

    /// Receive one data frame from world rank `peer`, verifying frame
    /// alignment against the expected group/sequence; returns
    /// (payload, wire bytes).
    fn recv_frame(&mut self, peer: usize, group: u32, seq: u64) -> CommResult<(Vec<u8>, usize)> {
        self.recv_frame_kind(peer, KIND_DATA, group, seq)
    }

    /// Receive one frame of the given kind from world rank `peer`,
    /// verifying kind and frame alignment; returns (payload, wire
    /// bytes). A kind mismatch is a protocol error — the program order
    /// of collectives fixes which kind arrives when.
    fn recv_frame_kind(
        &mut self,
        peer: usize,
        frame_kind: u8,
        group: u32,
        seq: u64,
    ) -> CommResult<(Vec<u8>, usize)> {
        let cfg = self.cfg;
        let stream = self.conn(peer)?;
        let mut header = [0u8; HEADER_LEN];
        read_exact_retry(stream, &mut header, "collective recv", peer, cfg)?;
        let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
        let kind = header[4];
        let got_group = u32::from_le_bytes(header[5..9].try_into().unwrap());
        let got_seq = u64::from_le_bytes(header[9..17].try_into().unwrap());
        let len = u32::from_le_bytes(header[17..21].try_into().unwrap()) as usize;
        if magic != MAGIC || kind != frame_kind {
            return Err(CommError::Protocol {
                reason: format!(
                    "bad frame from rank {peer}: magic={magic:#x} kind={kind} \
                     (expected kind {frame_kind})"
                ),
            });
        }
        if got_group != group || got_seq != seq {
            return Err(CommError::Protocol {
                reason: format!(
                    "collective misalignment with rank {peer}: got group {got_group} seq \
                     {got_seq}, expected group {group} seq {seq}"
                ),
            });
        }
        let mut payload = vec![0u8; len];
        read_exact_retry(stream, &mut payload, "collective recv", peer, cfg)?;
        Ok((payload, HEADER_LEN + len))
    }
}

/// One member's handle on a communicator scope over a shared
/// [`TcpMesh`]. `members` lists the scope's world ranks in group order;
/// the member-order fold over that list is what keeps results
/// bit-identical to the in-process backend.
pub struct TcpGroup {
    mesh: Arc<Mutex<TcpMesh>>,
    members: Vec<usize>,
    my: usize,
    group_id: u32,
    seq: u64,
    stats: WireStats,
}

impl TcpGroup {
    /// Build a scope over `members` (world ranks, group order). The
    /// calling rank must be a member; every member must construct the
    /// scope with the same `members` and `group_id`.
    pub fn new(
        mesh: Arc<Mutex<TcpMesh>>,
        members: Vec<usize>,
        group_id: u32,
    ) -> CommResult<TcpGroup> {
        let world_rank = mesh.lock().unwrap().rank;
        let my = members.iter().position(|&m| m == world_rank).ok_or_else(|| {
            CommError::Protocol {
                reason: format!("rank {world_rank} is not a member of group {group_id}"),
            }
        })?;
        Ok(TcpGroup { mesh, members, my, group_id, seq: 0, stats: WireStats::default() })
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    fn peer_at(&self, offset: usize) -> usize {
        let n = self.members.len();
        self.members[(self.my + offset) % n]
    }

    fn send_f32(&mut self, world_peer: usize, seq: u64, data: &[f32]) -> CommResult<()> {
        let payload = f32s_to_bytes(data);
        let bytes =
            self.mesh.lock().unwrap().send_frame(world_peer, self.group_id, seq, &payload)?;
        self.stats.bytes += bytes as u64;
        Ok(())
    }

    fn recv_f32(&mut self, world_peer: usize, seq: u64) -> CommResult<Vec<f32>> {
        let (payload, bytes) =
            self.mesh.lock().unwrap().recv_frame(world_peer, self.group_id, seq)?;
        self.stats.bytes += bytes as u64;
        bytes_to_f32s(&payload, world_peer)
    }

    /// Ring all-gather: after `size-1` rotation steps every member holds
    /// every block, indexed by origin member. Member 0 receives before
    /// sending (everyone else sends first), which breaks the ring's
    /// blocking-write cycle for arbitrarily large payloads.
    fn ring_gather_blocks(&mut self, data: &[f32]) -> CommResult<Vec<Vec<f32>>> {
        let n = self.members.len();
        let seq = self.next_seq();
        let mut blocks: Vec<Vec<f32>> = vec![Vec::new(); n];
        blocks[self.my] = data.to_vec();
        let mut carry = data.to_vec();
        for step in 1..n {
            let next = self.peer_at(1);
            let prev = self.peer_at(n - 1);
            let received = if self.my == 0 {
                let r = self.recv_f32(prev, seq)?;
                self.send_f32(next, seq, &carry)?;
                r
            } else {
                self.send_f32(next, seq, &carry)?;
                self.recv_f32(prev, seq)?
            };
            let origin = (self.my + n - step) % n;
            blocks[origin] = received.clone();
            carry = received;
        }
        Ok(blocks)
    }
}

impl Transport for TcpGroup {
    fn rank(&self) -> usize {
        self.my
    }

    fn size(&self) -> usize {
        self.members.len()
    }

    fn backend(&self) -> &'static str {
        "tcp"
    }

    fn barrier(&mut self) -> CommResult<()> {
        if self.size() > 1 {
            // an empty-payload ring all-gather: leaving it requires a
            // frame originating at every other member, i.e. everyone
            // has entered
            self.ring_gather_blocks(&[])?;
        }
        self.stats.ops += 1;
        Ok(())
    }

    fn all_reduce_sum(&mut self, data: &mut [f32]) -> CommResult<()> {
        if self.size() > 1 {
            let blocks = self.ring_gather_blocks(data)?;
            for (member, b) in blocks.iter().enumerate() {
                if b.len() != data.len() {
                    return Err(CommError::Protocol {
                        reason: format!(
                            "all_reduce length mismatch: member {member} contributed {} \
                             elements, expected {}",
                            b.len(),
                            data.len()
                        ),
                    });
                }
            }
            // fold in member order 0..size — bit-identical to the
            // in-process slot loop
            data.iter_mut().for_each(|d| *d = 0.0);
            for b in &blocks {
                for (d, &o) in data.iter_mut().zip(b.iter()) {
                    *d += o;
                }
            }
        }
        self.stats.ops += 1;
        Ok(())
    }

    fn all_reduce_max(&mut self, data: &mut [f32]) -> CommResult<()> {
        if self.size() > 1 {
            let blocks = self.ring_gather_blocks(data)?;
            data.iter_mut().for_each(|d| *d = f32::NEG_INFINITY);
            for b in &blocks {
                for (d, &o) in data.iter_mut().zip(b.iter()) {
                    if o > *d {
                        *d = o;
                    }
                }
            }
        }
        self.stats.ops += 1;
        Ok(())
    }

    fn broadcast(&mut self, root: usize, data: &mut [f32]) -> CommResult<()> {
        let n = self.size();
        if n > 1 {
            if root >= n {
                return Err(CommError::Protocol {
                    reason: format!("broadcast root {root} out of range (size {n})"),
                });
            }
            let seq = self.next_seq();
            // forward chain in ring order starting at the root
            let pos = (self.my + n - root) % n;
            if pos == 0 {
                self.send_f32(self.peer_at(1), seq, data)?;
            } else {
                let prev = self.peer_at(n - 1);
                let received = self.recv_f32(prev, seq)?;
                if received.len() != data.len() {
                    return Err(CommError::Protocol {
                        reason: format!(
                            "broadcast length mismatch: root {root} sent {} elements, \
                             expected {}",
                            received.len(),
                            data.len()
                        ),
                    });
                }
                data.copy_from_slice(&received);
                if pos < n - 1 {
                    self.send_f32(self.peer_at(1), seq, data)?;
                }
            }
        }
        self.stats.ops += 1;
        Ok(())
    }

    fn all_gather(&mut self, data: &[f32]) -> CommResult<Vec<f32>> {
        let out = if self.size() > 1 {
            let blocks = self.ring_gather_blocks(data)?;
            let mut out = Vec::with_capacity(blocks.iter().map(|b| b.len()).sum());
            for b in blocks {
                out.extend_from_slice(&b);
            }
            out
        } else {
            data.to_vec()
        };
        self.stats.ops += 1;
        Ok(out)
    }

    fn send(&mut self, peer: usize, data: &[f32]) -> CommResult<()> {
        let world = *self.members.get(peer).ok_or_else(|| CommError::Protocol {
            reason: format!("send peer {peer} out of range (size {})", self.size()),
        })?;
        let seq = self.next_seq();
        self.send_f32(world, seq, data)?;
        self.stats.ops += 1;
        Ok(())
    }

    fn recv(&mut self, peer: usize) -> CommResult<Vec<f32>> {
        let world = *self.members.get(peer).ok_or_else(|| CommError::Protocol {
            reason: format!("recv peer {peer} out of range (size {})", self.size()),
        })?;
        let seq = self.next_seq();
        let out = self.recv_f32(world, seq)?;
        self.stats.ops += 1;
        Ok(out)
    }

    /// True gather via dedicated telemetry frames: members 1..n each
    /// send one frame to member 0, received in member order — no
    /// all-to-all ring, no f32 bitcasting. Every member advances the
    /// group sequence, so the frames stay aligned with the collective
    /// program order.
    fn gather_bytes_to_root(&mut self, data: &[u8]) -> CommResult<Option<Vec<Vec<u8>>>> {
        let n = self.members.len();
        let seq = self.next_seq();
        let out = if self.my == 0 {
            let mut out = Vec::with_capacity(n);
            out.push(data.to_vec());
            for m in 1..n {
                let world = self.members[m];
                let (payload, bytes) = self
                    .mesh
                    .lock()
                    .unwrap()
                    .recv_frame_kind(world, KIND_TELEMETRY, self.group_id, seq)?;
                self.stats.bytes += bytes as u64;
                out.push(payload);
            }
            Some(out)
        } else {
            let root = self.members[0];
            let bytes = self
                .mesh
                .lock()
                .unwrap()
                .send_frame_kind(root, KIND_TELEMETRY, self.group_id, seq, data)?;
            self.stats.bytes += bytes as u64;
            None
        };
        self.stats.ops += 1;
        Ok(out)
    }

    fn wire_stats(&self) -> WireStats {
        self.stats
    }
}

/// Build one rank's full [`RankCtx`] (world + row + column scopes) over
/// a connected mesh. Group ids are derived from the grid topology, so
/// every rank numbers the scopes identically: world = 0, row `i` =
/// `1 + i`, column `j` = `1 + q + j`.
pub fn rank_ctx_from_mesh(mesh: TcpMesh, grid: Grid) -> CommResult<RankCtx> {
    let rank = mesh.rank();
    if mesh.size() != grid.p() {
        return Err(CommError::Protocol {
            reason: format!("mesh size {} does not match grid p {}", mesh.size(), grid.p()),
        });
    }
    let q = grid.q;
    let row = grid.row_of(rank);
    let col = grid.col_of(rank);
    let mesh = Arc::new(Mutex::new(mesh));
    let world_members: Vec<usize> = (0..grid.p()).collect();
    let row_members: Vec<usize> = (0..q).map(|c| grid.rank_at(row, c)).collect();
    let col_members: Vec<usize> = (0..q).map(|r| grid.rank_at(r, col)).collect();
    let world = Group::from_transport(TcpGroup::new(mesh.clone(), world_members, 0)?);
    let row_comm =
        Group::from_transport(TcpGroup::new(mesh.clone(), row_members, 1 + row as u32)?);
    let col_comm =
        Group::from_transport(TcpGroup::new(mesh, col_members, 1 + q as u32 + col as u32)?);
    Ok(RankCtx { grid, rank, row, col, row_comm, col_comm, world })
}

/// Test/bench harness: bind `size` listeners on localhost and establish
/// all meshes concurrently. Returns the meshes in rank order.
pub fn loopback_meshes(size: usize, cfg: TcpConfig) -> CommResult<Vec<TcpMesh>> {
    let ip: IpAddr = "127.0.0.1".parse().expect("loopback ip");
    let mut listeners = Vec::with_capacity(size);
    for _ in 0..size {
        listeners.push(MeshListener::bind(ip)?);
    }
    let addrs: Vec<SocketAddr> = listeners.iter().map(|l| l.addr).collect();
    let metas: Vec<(usize, MeshListener)> = listeners.into_iter().enumerate().collect();
    let meshes = std::thread::scope(|s| {
        let handles: Vec<_> = metas
            .into_iter()
            .map(|(rank, listener)| {
                let addrs = addrs.clone();
                s.spawn(move || TcpMesh::establish(rank, size, 0, listener, &addrs, cfg))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("mesh thread panicked"))
            .collect::<CommResult<Vec<_>>>()
    })?;
    Ok(meshes)
}

fn f32s_to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(payload: &[u8], peer: usize) -> CommResult<Vec<f32>> {
    if payload.len() % 4 != 0 {
        return Err(CommError::Protocol {
            reason: format!("payload from rank {peer} is {} bytes, not a multiple of 4", payload.len()),
        });
    }
    Ok(payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn configure(stream: &TcpStream, cfg: TcpConfig) -> CommResult<()> {
    let apply = || -> std::io::Result<()> {
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(cfg.timeout))?;
        stream.set_write_timeout(Some(cfg.timeout))?;
        Ok(())
    };
    apply().map_err(|e| CommError::Io { op: "configure socket", detail: e.to_string() })
}

/// Dial a peer's listener with bounded retry (its listener is bound
/// before addresses are exchanged, but the connect can still race the
/// OS accept queue under load).
fn dial(addr: SocketAddr, peer: usize, cfg: TcpConfig) -> CommResult<TcpStream> {
    let deadline = Instant::now() + cfg.timeout.mul_f64((cfg.retries + 1) as f64);
    loop {
        match TcpStream::connect_timeout(&addr, cfg.timeout) {
            Ok(stream) => {
                configure(&stream, cfg)?;
                return Ok(stream);
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    return if e.kind() == std::io::ErrorKind::TimedOut {
                        Err(CommError::Timeout { op: "mesh dial", peer })
                    } else {
                        Err(CommError::Io { op: "mesh dial", detail: e.to_string() })
                    };
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn send_hello(
    stream: &TcpStream,
    epoch: u64,
    from: usize,
    peer: usize,
    cfg: TcpConfig,
) -> CommResult<()> {
    let mut buf = Vec::with_capacity(HEADER_LEN + 16);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.push(KIND_HELLO);
    buf.extend_from_slice(&0u32.to_le_bytes()); // group (unused in hello)
    buf.extend_from_slice(&0u64.to_le_bytes()); // seq (unused in hello)
    buf.extend_from_slice(&16u32.to_le_bytes());
    buf.extend_from_slice(&TRANSPORT_VERSION.to_le_bytes());
    buf.extend_from_slice(&epoch.to_le_bytes());
    buf.extend_from_slice(&(from as u32).to_le_bytes());
    let mut s = stream;
    write_all_retry(&mut s, &buf, "mesh hello", peer, cfg)
}

/// Read and validate a hello; returns the peer's claimed rank.
fn recv_hello(
    stream: &TcpStream,
    epoch: u64,
    peer: usize,
    cfg: TcpConfig,
) -> CommResult<usize> {
    let mut buf = [0u8; HEADER_LEN + 16];
    let mut s = stream;
    read_exact_retry(&mut s, &mut buf, "mesh hello", peer, cfg)?;
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    let kind = buf[4];
    let len = u32::from_le_bytes(buf[17..21].try_into().unwrap());
    if magic != MAGIC {
        return Err(CommError::Handshake {
            reason: format!("bad magic {magic:#x} (not a drescal transport peer?)"),
        });
    }
    if kind != KIND_HELLO || len != 16 {
        return Err(CommError::Handshake {
            reason: format!("expected hello frame, got kind {kind} len {len}"),
        });
    }
    let version = u32::from_le_bytes(buf[21..25].try_into().unwrap());
    let got_epoch = u64::from_le_bytes(buf[25..33].try_into().unwrap());
    let from = u32::from_le_bytes(buf[33..37].try_into().unwrap()) as usize;
    if version != TRANSPORT_VERSION {
        return Err(CommError::Handshake {
            reason: format!(
                "transport version mismatch: peer speaks v{version}, we speak \
                 v{TRANSPORT_VERSION}"
            ),
        });
    }
    if got_epoch != epoch {
        return Err(CommError::Handshake {
            reason: format!("stale mesh epoch: peer is at {got_epoch}, we are at {epoch}"),
        });
    }
    Ok(from)
}

fn map_io(e: std::io::Error, op: &'static str, peer: usize) -> CommError {
    use std::io::ErrorKind::*;
    match e.kind() {
        WouldBlock | TimedOut => CommError::Timeout { op, peer },
        UnexpectedEof | ConnectionReset | ConnectionAborted | BrokenPipe | NotConnected => {
            CommError::PeerDisconnected { peer }
        }
        _ => CommError::Io { op, detail: e.to_string() },
    }
}

fn write_all_retry(
    stream: &mut (impl Write + ?Sized),
    buf: &[u8],
    op: &'static str,
    peer: usize,
    cfg: TcpConfig,
) -> CommResult<()> {
    let mut off = 0;
    let mut timeouts = 0;
    while off < buf.len() {
        match stream.write(&buf[off..]) {
            Ok(0) => return Err(CommError::PeerDisconnected { peer }),
            Ok(k) => off += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                timeouts += 1;
                if timeouts > cfg.retries {
                    return Err(CommError::Timeout { op, peer });
                }
            }
            Err(e) => return Err(map_io(e, op, peer)),
        }
    }
    Ok(())
}

fn read_exact_retry(
    stream: &mut (impl Read + ?Sized),
    buf: &mut [u8],
    op: &'static str,
    peer: usize,
    cfg: TcpConfig,
) -> CommResult<()> {
    let mut off = 0;
    let mut timeouts = 0;
    while off < buf.len() {
        match stream.read(&mut buf[off..]) {
            Ok(0) => return Err(CommError::PeerDisconnected { peer }),
            Ok(k) => off += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                timeouts += 1;
                if timeouts > cfg.retries {
                    return Err(CommError::Timeout { op, peer });
                }
            }
            Err(e) => return Err(map_io(e, op, peer)),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> TcpConfig {
        TcpConfig { timeout: Duration::from_secs(5), retries: 1 }
    }

    /// Run `f` on every member of a `size`-rank loopback mesh, each on
    /// its own thread, with a world-scope TcpGroup.
    fn run_world<T: Send>(size: usize, f: impl Fn(TcpGroup) -> T + Sync) -> Vec<T> {
        let meshes = loopback_meshes(size, quick_cfg()).expect("loopback mesh");
        std::thread::scope(|s| {
            let handles: Vec<_> = meshes
                .into_iter()
                .map(|mesh| {
                    let members: Vec<usize> = (0..size).collect();
                    let g = TcpGroup::new(Arc::new(Mutex::new(mesh)), members, 0)
                        .expect("world group");
                    s.spawn(|| f(g))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn ring_all_reduce_sums_in_member_order() {
        let results = run_world(3, |mut g| {
            let mut v = vec![g.rank() as f32, 1.0];
            g.all_reduce_sum(&mut v).unwrap();
            v
        });
        for r in results {
            assert_eq!(r, vec![3.0, 3.0]);
        }
    }

    #[test]
    fn broadcast_and_gather_over_ring() {
        let results = run_world(4, |mut g| {
            let mut v = vec![if g.rank() == 2 { 7.5 } else { 0.0 }];
            g.broadcast(2, &mut v).unwrap();
            let gathered = g.all_gather(&[g.rank() as f32]).unwrap();
            (v[0], gathered)
        });
        for (b, gathered) in results {
            assert_eq!(b, 7.5);
            assert_eq!(gathered, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn barrier_and_empty_payloads() {
        let results = run_world(3, |mut g| {
            g.barrier().unwrap();
            let gathered = g.all_gather(&[]).unwrap();
            let mut nothing: [f32; 0] = [];
            g.all_reduce_sum(&mut nothing).unwrap();
            g.barrier().unwrap();
            gathered.len()
        });
        assert_eq!(results, vec![0, 0, 0]);
    }

    #[test]
    fn point_to_point_roundtrip() {
        let results = run_world(2, |mut g| {
            if g.rank() == 0 {
                g.send(1, &[1.0, 2.0]).unwrap();
                g.recv(1).unwrap()
            } else {
                let got = g.recv(0).unwrap();
                g.send(0, &[got[0] * 10.0, got[1] * 10.0]).unwrap();
                got
            }
        });
        assert_eq!(results[0], vec![10.0, 20.0]);
        assert_eq!(results[1], vec![1.0, 2.0]);
    }

    #[test]
    fn wire_stats_count_real_bytes() {
        let results = run_world(2, |mut g| {
            let mut v = vec![1.0f32; 8];
            g.all_reduce_sum(&mut v).unwrap();
            g.wire_stats()
        });
        for s in results {
            // one ring step each way: 2 frames * (21B header + 32B payload)
            assert_eq!(s.bytes, 2 * (HEADER_LEN as u64 + 32));
            assert_eq!(s.ops, 1);
        }
    }

    #[test]
    fn telemetry_gather_ships_bytes_to_member_zero() {
        let results = run_world(3, |mut g| {
            let rank = g.rank();
            let payload: Vec<u8> = (0..(10 * rank + 1)).map(|i| (rank * 100 + i) as u8).collect();
            let before = g.wire_stats();
            let out = g.gather_bytes_to_root(&payload).unwrap();
            // a collective is still legal on the same group afterwards —
            // the telemetry frame advanced the shared sequence everywhere
            let mut v = vec![1.0f32];
            g.all_reduce_sum(&mut v).unwrap();
            (out, g.wire_stats().since(before), v[0])
        });
        let root = results[0].0.as_ref().expect("member 0 gets payloads");
        assert!(results[1].0.is_none() && results[2].0.is_none());
        assert_eq!(root.len(), 3);
        for (rank, got) in root.iter().enumerate() {
            let want: Vec<u8> =
                (0..(10 * rank + 1)).map(|i| (rank * 100 + i) as u8).collect();
            assert_eq!(got, &want, "rank {rank} payload corrupted");
        }
        for (rank, (_, wire, sum)) in results.iter().enumerate() {
            assert_eq!(*sum, 3.0, "collective after gather desynced on rank {rank}");
            assert_eq!(wire.ops, 2);
            assert!(wire.bytes > 0, "gather moved no wire bytes on rank {rank}");
        }
        // senders are charged at least their one telemetry frame
        assert!(results[1].1.bytes >= (HEADER_LEN + 11) as u64);
    }

    #[test]
    fn dead_peer_is_a_typed_error() {
        let results = run_world(2, |mut g| {
            if g.rank() == 1 {
                // die without participating: drop the mesh
                return Ok(());
            }
            let mut v = vec![1.0f32; 4];
            g.all_reduce_sum(&mut v)
        });
        assert!(results[1].is_ok());
        match &results[0] {
            Err(CommError::PeerDisconnected { .. }) | Err(CommError::Timeout { .. }) => {}
            other => panic!("expected disconnect/timeout, got {other:?}"),
        }
    }

    #[test]
    fn version_mismatch_fails_handshake() {
        // hand-roll a hello with the wrong version against a real listener
        let ip: IpAddr = "127.0.0.1".parse().unwrap();
        let listener = MeshListener::bind(ip).unwrap();
        let addr = listener.addr;
        let t = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            configure(&stream, quick_cfg()).unwrap();
            let mut buf = Vec::new();
            buf.extend_from_slice(&MAGIC.to_le_bytes());
            buf.push(KIND_HELLO);
            buf.extend_from_slice(&0u32.to_le_bytes());
            buf.extend_from_slice(&0u64.to_le_bytes());
            buf.extend_from_slice(&16u32.to_le_bytes());
            buf.extend_from_slice(&999u32.to_le_bytes()); // bogus version
            buf.extend_from_slice(&0u64.to_le_bytes());
            buf.extend_from_slice(&1u32.to_le_bytes());
            let mut s = &stream;
            write_all_retry(&mut s, &buf, "test hello", 1, quick_cfg()).unwrap();
            // keep the socket open until the other side has judged us
            std::thread::sleep(Duration::from_millis(200));
        });
        let err = TcpMesh::establish(0, 2, 0, listener, &[addr, addr], quick_cfg())
            .err()
            .expect("establish must fail");
        match err {
            CommError::Handshake { reason } => assert!(reason.contains("version")),
            other => panic!("expected handshake error, got {other:?}"),
        }
        t.join().unwrap();
    }
}
