//! The transport plane: pluggable collective/point-to-point backends.
//!
//! A [`Transport`] owns one communicator scope (a grid row, a grid
//! column, or the world) for one member and implements the collectives
//! the paper uses — `all_reduce`, `all_gather`, `broadcast`, barrier —
//! plus point-to-point send/recv. Two backends exist:
//!
//! * [`inprocess::InProcess`] — today's shared-memory slots (one OS
//!   thread per rank inside a single process). The default, and the
//!   reference for bit-identical results.
//! * [`tcp::TcpGroup`] — length-prefixed frames over std TCP between
//!   real OS processes, built on a full peer mesh established by a
//!   leader-coordinated rendezvous (see [`crate::engine::cluster`]).
//!
//! **Bit-identity contract**: both backends reduce contributions in
//! group-member order `0..size`, so a TCP run produces byte-identical
//! factors to an in-process run of the same job. The TCP backend moves
//! data with a ring all-gather and then folds locally in member order —
//! ring data movement, deterministic reduction order.
//!
//! All operations return typed [`CommError`]s instead of panicking:
//! a dead peer surfaces as `PeerDisconnected`/`Timeout` on the survivors
//! and is rolled back as a job error, never a poisoned rank thread.

pub mod inprocess;
pub mod tcp;

use std::fmt;

/// Typed communication failure. Carried through the rank code as
/// `Result<_, CommError>` and converted to a job error at the pool
/// boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CommError {
    /// A read or write did not complete within the transport deadline.
    Timeout { op: &'static str, peer: usize },
    /// The peer's connection closed mid-collective (process death).
    PeerDisconnected { peer: usize },
    /// Version/magic mismatch while establishing a connection.
    Handshake { reason: String },
    /// Frames arrived but did not line up with the collective program
    /// order (group/sequence/length mismatch) — a logic error or a
    /// corrupted stream.
    Protocol { reason: String },
    /// Any other socket-level failure.
    Io { op: &'static str, detail: String },
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::Timeout { op, peer } => {
                write!(f, "comm timeout: {op} with peer {peer} exceeded the transport deadline")
            }
            CommError::PeerDisconnected { peer } => {
                write!(f, "peer {peer} disconnected mid-collective")
            }
            CommError::Handshake { reason } => write!(f, "transport handshake failed: {reason}"),
            CommError::Protocol { reason } => write!(f, "transport protocol error: {reason}"),
            CommError::Io { op, detail } => write!(f, "transport i/o error during {op}: {detail}"),
        }
    }
}

impl std::error::Error for CommError {}

impl From<CommError> for crate::error::Error {
    fn from(e: CommError) -> Self {
        crate::error::Error::msg(e)
    }
}

/// Result alias for transport operations.
pub type CommResult<T> = Result<T, CommError>;

/// Cumulative wire-traffic counters for one transport handle: bytes and
/// operation counts actually moved (payload + frame headers for TCP,
/// bytes through the shared slots for in-process). Callers snapshot
/// before/after a collective to charge *real* per-op volumes in the
/// trace instead of caller-claimed estimates.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// Bytes sent + received by this member.
    pub bytes: u64,
    /// Collective / point-to-point operations completed.
    pub ops: u64,
}

impl WireStats {
    /// Traffic since an earlier snapshot.
    pub fn since(&self, earlier: WireStats) -> WireStats {
        WireStats {
            bytes: self.bytes.saturating_sub(earlier.bytes),
            ops: self.ops.saturating_sub(earlier.ops),
        }
    }
}

/// One member's handle on a communicator scope. Implementations must
/// guarantee the member-order reduction contract documented on the
/// module: `all_reduce_*` folds contributions in group index order
/// `0..size` so every backend produces bit-identical results.
pub trait Transport: Send {
    /// This member's index within the group (0..size).
    fn rank(&self) -> usize;
    /// Number of members.
    fn size(&self) -> usize;
    /// Backend name for reports ("in_process" / "tcp").
    fn backend(&self) -> &'static str;

    /// Synchronize all members.
    fn barrier(&mut self) -> CommResult<()>;
    /// Elementwise sum; on return every member holds the identical sum.
    fn all_reduce_sum(&mut self, data: &mut [f32]) -> CommResult<()>;
    /// Elementwise max.
    fn all_reduce_max(&mut self, data: &mut [f32]) -> CommResult<()>;
    /// Replicate `root`'s buffer to all members.
    fn broadcast(&mut self, root: usize, data: &mut [f32]) -> CommResult<()>;
    /// Concatenate all members' buffers in member order.
    fn all_gather(&mut self, data: &[f32]) -> CommResult<Vec<f32>>;

    /// Point-to-point send to group member `peer`.
    fn send(&mut self, peer: usize, data: &[f32]) -> CommResult<()>;
    /// Point-to-point receive from group member `peer`.
    fn recv(&mut self, peer: usize) -> CommResult<Vec<f32>>;

    /// Gather arbitrary byte payloads to group member 0 — the telemetry
    /// gather that ships remote span buffers to the leader at job end.
    /// Collective: every member calls it; member 0 receives
    /// `Some(payloads)` ordered by member index (its own at `[0]`),
    /// everyone else `None`.
    ///
    /// The default implementation rides [`Transport::all_gather`]:
    /// payloads are padded to the longest and bitcast into f32 words.
    /// `all_gather` is copy-only (no arithmetic), so arbitrary bit
    /// patterns — including ones that alias NaN — survive the trip
    /// intact. The TCP backend overrides this with a true gather
    /// (dedicated telemetry frames to member 0 only) so span shipment
    /// doesn't cost a full all-to-all.
    fn gather_bytes_to_root(&mut self, data: &[u8]) -> CommResult<Option<Vec<Vec<u8>>>> {
        let size = self.size();
        let lens = self.all_gather(&[f32::from_bits(data.len() as u32)])?;
        let lens: Vec<usize> = lens.iter().map(|f| f.to_bits() as usize).collect();
        if lens.len() != size {
            return Err(CommError::Protocol {
                reason: format!("byte gather saw {} length slots for {size} members", lens.len()),
            });
        }
        let max_len = lens.iter().copied().max().unwrap_or(0);
        // uniform across members (everyone holds the same `lens`), so
        // skipping the payload round is still collective-consistent
        if max_len == 0 {
            return Ok(if self.rank() == 0 { Some(vec![Vec::new(); size]) } else { None });
        }
        let words = max_len.div_ceil(4);
        let mut packed = vec![0f32; words];
        for (i, chunk) in data.chunks(4).enumerate() {
            let mut b = [0u8; 4];
            b[..chunk.len()].copy_from_slice(chunk);
            packed[i] = f32::from_bits(u32::from_le_bytes(b));
        }
        let gathered = self.all_gather(&packed)?;
        if self.rank() != 0 {
            return Ok(None);
        }
        let mut out = Vec::with_capacity(size);
        for (m, &len) in lens.iter().enumerate() {
            let mut bytes = Vec::with_capacity(words * 4);
            for w in &gathered[m * words..(m + 1) * words] {
                bytes.extend_from_slice(&w.to_bits().to_le_bytes());
            }
            bytes.truncate(len);
            out.push(bytes);
        }
        Ok(Some(out))
    }

    /// Cumulative wire traffic for this member.
    fn wire_stats(&self) -> WireStats;
}
