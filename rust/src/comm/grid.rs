//! The √p × √p virtual processor grid (paper Figure 3).
//!
//! Rank `r` sits at grid position `(row, col) = (r / q, r % q)` with
//! `q = √p`. Each rank belongs to three groups: its row sub-communicator,
//! its column sub-communicator, and the world. Diagonal ranks (`row ==
//! col`) hold `A^(i) = (A^(j))ᵀ` and act as broadcast roots (Alg 3 lines
//! 13/23).

use super::group::Group;

/// Immutable description of the grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid {
    /// side length q = √p
    pub q: usize,
}

impl Grid {
    /// Build a grid for `p` ranks; `p` must be a perfect square (the paper
    /// requires p_r = p_c, §6.1.3).
    pub fn new(p: usize) -> Self {
        let q = (p as f64).sqrt().round() as usize;
        assert_eq!(q * q, p, "grid size {p} is not a perfect square");
        assert!(q >= 1);
        Grid { q }
    }

    pub fn p(&self) -> usize {
        self.q * self.q
    }

    #[inline]
    pub fn row_of(&self, rank: usize) -> usize {
        rank / self.q
    }

    #[inline]
    pub fn col_of(&self, rank: usize) -> usize {
        rank % self.q
    }

    #[inline]
    pub fn rank_at(&self, row: usize, col: usize) -> usize {
        row * self.q + col
    }

    /// Partition `n` into `q` contiguous chunks; returns (start, end) of
    /// chunk `i`. Sizes differ by at most one (block distribution).
    pub fn chunk(&self, n: usize, i: usize) -> (usize, usize) {
        let base = n / self.q;
        let rem = n % self.q;
        let start = i * base + i.min(rem);
        let len = base + usize::from(i < rem);
        (start, start + len)
    }
}

/// Everything one virtual rank needs: its grid coordinates and its three
/// communicator handles.
pub struct RankCtx {
    pub grid: Grid,
    pub rank: usize,
    pub row: usize,
    pub col: usize,
    /// Sub-communicator over the ranks sharing this rank's grid **row**
    /// (its index within the group is this rank's `col`).
    pub row_comm: Group,
    /// Sub-communicator over the ranks sharing this rank's grid **column**
    /// (its index within the group is this rank's `row`).
    pub col_comm: Group,
    /// All ranks.
    pub world: Group,
}

impl RankCtx {
    /// True on the grid diagonal.
    pub fn is_diagonal(&self) -> bool {
        self.row == self.col
    }

    /// Create contexts for all p ranks of a fresh grid.
    pub fn create_all(p: usize) -> Vec<RankCtx> {
        let grid = Grid::new(p);
        let q = grid.q;
        let world = Group::create(p);
        // row i's group members are ranks (i*q)..(i*q+q); member index = col
        let mut row_groups: Vec<Vec<Group>> = (0..q).map(|_| Group::create(q)).collect();
        let mut col_groups: Vec<Vec<Group>> = (0..q).map(|_| Group::create(q)).collect();
        let mut out = Vec::with_capacity(p);
        // build in reverse so we can pop() per-rank handles in O(1)
        let mut world = world;
        for rank in (0..p).rev() {
            let row = grid.row_of(rank);
            let col = grid.col_of(rank);
            out.push(RankCtx {
                grid,
                rank,
                row,
                col,
                row_comm: row_groups[row].pop().expect("row group handle"),
                col_comm: col_groups[col].pop().expect("col group handle"),
                world: world.pop().expect("world handle"),
            });
        }
        out.reverse();
        out
    }
}

/// Run `f` on every rank of a p-rank grid, each on its own OS thread, and
/// return the per-rank results in rank order. This is the harness all
/// distributed entry points build on.
pub fn run_on_grid<T: Send>(p: usize, f: impl Fn(RankCtx) -> T + Sync) -> Vec<T> {
    let ctxs = RankCtx::create_all(p);
    std::thread::scope(|s| {
        let handles: Vec<_> = ctxs.into_iter().map(|ctx| s.spawn(|| f(ctx))).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_coordinates() {
        let g = Grid::new(9);
        assert_eq!(g.q, 3);
        assert_eq!(g.row_of(5), 1);
        assert_eq!(g.col_of(5), 2);
        assert_eq!(g.rank_at(1, 2), 5);
    }

    #[test]
    #[should_panic]
    fn non_square_rejected() {
        Grid::new(8);
    }

    #[test]
    fn chunks_partition() {
        let g = Grid::new(9);
        // n = 10 over q = 3 -> sizes 4,3,3
        let chunks: Vec<_> = (0..3).map(|i| g.chunk(10, i)).collect();
        assert_eq!(chunks, vec![(0, 4), (4, 7), (7, 10)]);
    }

    #[test]
    fn chunks_cover_exactly() {
        for p in [1usize, 4, 9, 16] {
            let g = Grid::new(p);
            for n in [1usize, 5, 16, 33, 100] {
                let mut covered = 0;
                for i in 0..g.q {
                    let (s, e) = g.chunk(n, i);
                    assert_eq!(s, covered);
                    covered = e;
                }
                assert_eq!(covered, n);
            }
        }
    }

    #[test]
    fn rank_ctx_topology() {
        let ctxs = RankCtx::create_all(4);
        for (i, c) in ctxs.iter().enumerate() {
            assert_eq!(c.rank, i);
            assert_eq!(c.row, i / 2);
            assert_eq!(c.col, i % 2);
            assert_eq!(c.row_comm.rank, c.col);
            assert_eq!(c.col_comm.rank, c.row);
            assert_eq!(c.row_comm.size(), 2);
            assert_eq!(c.col_comm.size(), 2);
            assert_eq!(c.world.size(), 4);
        }
        assert!(ctxs[0].is_diagonal());
        assert!(!ctxs[1].is_diagonal());
        assert!(ctxs[3].is_diagonal());
    }

    #[test]
    fn row_reduce_stays_in_row() {
        // each rank contributes its row id; a row all_reduce must yield
        // row * q (sum over the row), NOT involving other rows
        let results = run_on_grid(9, |ctx| {
            let mut v = vec![ctx.row as f32];
            ctx.row_comm.all_reduce_sum(&mut v).unwrap();
            v[0]
        });
        for (rank, r) in results.iter().enumerate() {
            let row = rank / 3;
            assert_eq!(*r, (row * 3) as f32);
        }
    }

    #[test]
    fn col_reduce_stays_in_col() {
        let results = run_on_grid(9, |ctx| {
            let mut v = vec![ctx.col as f32];
            ctx.col_comm.all_reduce_sum(&mut v).unwrap();
            v[0]
        });
        for (rank, r) in results.iter().enumerate() {
            let col = rank % 3;
            assert_eq!(*r, (col * 3) as f32);
        }
    }

    #[test]
    fn diagonal_broadcast_along_column() {
        // diagonal rank of column j is at row j; broadcast its value down
        let results = run_on_grid(9, |ctx| {
            let mut v = vec![if ctx.is_diagonal() { (ctx.col * 100) as f32 } else { 0.0 }];
            // within col_comm the member index equals the grid row, and the
            // diagonal of column `col` sits at row == col
            ctx.col_comm.broadcast(ctx.col, &mut v).unwrap();
            v[0]
        });
        for (rank, r) in results.iter().enumerate() {
            let col = rank % 3;
            assert_eq!(*r, (col * 100) as f32);
        }
    }

    #[test]
    fn world_gather_orders_by_rank() {
        let results = run_on_grid(4, |ctx| ctx.world.all_gather(&[ctx.rank as f32]).unwrap());
        for r in results {
            assert_eq!(r, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn single_rank_grid() {
        let results = run_on_grid(1, |ctx| {
            let mut v = vec![3.0f32];
            ctx.row_comm.all_reduce_sum(&mut v).unwrap();
            ctx.col_comm.all_reduce_sum(&mut v).unwrap();
            v[0]
        });
        assert_eq!(results, vec![3.0]);
    }
}
