//! NNDSVD initialization (Boutsidis & Gallopoulos; Atif et al. variant the
//! paper cites as [56]).
//!
//! The paper's custom initialization (§6.1.3): NNDSVD-decompose the
//! concatenated unfoldings of X along axes 1 and 2 to obtain A, then run R
//! updates to get the matching core. This module supplies the NNDSVD of a
//! non-negative matrix; the unfolding concatenation + R bootstrap live in
//! `rescal::init`.

use super::svd::jacobi_svd;
use crate::tensor::Mat;

/// Split a vector into its positive and negative parts.
fn pos_neg(v: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let pos = v.iter().map(|&x| x.max(0.0)).collect();
    let neg = v.iter().map(|&x| (-x).max(0.0)).collect();
    (pos, neg)
}

fn norm(v: &[f32]) -> f32 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// NNDSVD: non-negative n×k initialization from the leading k singular
/// triplets of `x` (n×m, non-negative). Columns are the dominant
/// non-negative parts of the singular vectors, scaled by √σ.
///
/// Zero entries are flipped to a small positive floor (`eps_fill`) so MU
/// iterations cannot zero-lock — the "NNDSVDa"-style variant.
pub fn nndsvd_init(x: &Mat, k: usize, eps_fill: f32) -> Mat {
    let (n, _m) = x.shape();
    assert!(k >= 1, "k must be >= 1");
    let svd = jacobi_svd(x);
    let r = svd.s.len();
    let mut a = Mat::zeros(n, k);
    let mean = x.sum() / (x.rows() * x.cols()) as f32;
    for j in 0..k {
        if j == 0 && r > 0 {
            // leading singular vector of a non-negative matrix is
            // non-negative up to sign (Perron–Frobenius)
            let u0 = svd.u.col(0);
            let sign = if u0.iter().sum::<f32>() >= 0.0 { 1.0 } else { -1.0 };
            let s0 = svd.s[0].max(0.0).sqrt();
            let col: Vec<f32> = u0.iter().map(|&v| (sign * v).max(0.0) * s0).collect();
            a.set_col(0, &col);
        } else if j < r {
            let uj = svd.u.col(j);
            let vj = svd.v.col(j);
            let (up, un) = pos_neg(&uj);
            let (vp, vn) = pos_neg(&vj);
            let (upn, unn) = (norm(&up), norm(&un));
            let (vpn, vnn) = (norm(&vp), norm(&vn));
            let termp = upn * vpn;
            let termn = unn * vnn;
            let sj = svd.s[j].max(0.0).sqrt();
            let col: Vec<f32> = if termp >= termn {
                let scale = if upn > 0.0 { sj * (termp.sqrt() / upn) } else { 0.0 };
                up.iter().map(|&v| v * scale).collect()
            } else {
                let scale = if unn > 0.0 { sj * (termn.sqrt() / unn) } else { 0.0 };
                un.iter().map(|&v| v * scale).collect()
            };
            a.set_col(j, &col);
        } else {
            // k exceeds available rank: fill with the matrix mean
            let col = vec![mean.max(eps_fill); n];
            a.set_col(j, &col);
        }
    }
    // flip zeros to a small positive floor
    crate::tensor::ops::clamp_min(&mut a, eps_fill.max(mean.abs() * 1e-4));
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::ops::is_nonnegative;

    #[test]
    fn output_nonnegative_and_shaped() {
        let mut rng = Rng::new(80);
        let x = Mat::random_uniform(20, 15, 0.0, 1.0, &mut rng);
        let a = nndsvd_init(&x, 4, 1e-6);
        assert_eq!(a.shape(), (20, 4));
        assert!(is_nonnegative(&a));
        assert!(a.as_slice().iter().all(|&v| v > 0.0), "strictly positive fill");
    }

    #[test]
    fn k_beyond_rank_is_filled() {
        // rank-1 matrix but k = 3
        let x = Mat::from_fn(6, 6, |i, j| ((i + 1) * (j + 1)) as f32);
        let a = nndsvd_init(&x, 3, 1e-6);
        assert_eq!(a.shape(), (6, 3));
        assert!(is_nonnegative(&a));
    }

    #[test]
    fn leading_column_tracks_dominant_structure() {
        // block matrix: first 5 rows heavy, last 5 light -> leading NNDSVD
        // column should weight the heavy block more
        let x = Mat::from_fn(10, 10, |i, _| if i < 5 { 10.0 } else { 0.1 });
        let a = nndsvd_init(&x, 2, 1e-6);
        let c0 = a.col(0);
        let heavy: f32 = c0[..5].iter().sum();
        let light: f32 = c0[5..].iter().sum();
        assert!(heavy > 10.0 * light, "heavy={heavy}, light={light}");
    }

    #[test]
    fn better_than_random_start_for_mu() {
        // NNDSVD first column explains the rank-1 part: relative error of
        // rank-1 reconstruction from the init should beat a random column.
        let mut rng = Rng::new(81);
        let u: Vec<f32> = (0..12).map(|_| rng.uniform_f32() + 0.1).collect();
        let x = Mat::from_fn(12, 12, |i, j| u[i] * u[j]);
        let a = nndsvd_init(&x, 1, 1e-6);
        let c0 = Mat::from_vec(12, 1, a.col(0));
        let rec = c0.matmul(&c0.transpose());
        let mut diff = x.clone();
        diff.sub_assign(&rec);
        let rel = diff.norm_fro() / x.norm_fro();
        assert!(rel < 0.05, "rel={rel}");
    }
}
