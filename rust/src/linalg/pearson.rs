//! Pearson correlation — the paper's correctness metric for comparing
//! recovered latent features against ground truth (§6.2.1, Fig 5d).

use crate::tensor::Mat;

/// Pearson correlation coefficient of two equal-length vectors.
pub fn pearson(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    assert!(n > 0.0);
    let mx = x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let my = y.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        let dx = a as f64 - mx;
        let dy = b as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    (sxy / (sxx.sqrt() * syy.sqrt())) as f32
}

/// Column-by-column Pearson correlation matrix between two n×k matrices:
/// `out[(i, j)] = pearson(X[:, i], Y[:, j])` — Fig 5d's correlation matrix.
pub fn pearson_matrix(x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.rows(), y.rows());
    Mat::from_fn(x.cols(), y.cols(), |i, j| pearson(&x.col(i), &y.col(j)))
}

/// Mean of the best-match correlations: aligns columns of `found` to
/// `truth` greedily via the correlation matrix and averages |r| over the
/// matches. Used to score feature recovery as in §6.2.1.
pub fn best_match_correlation(truth: &Mat, found: &Mat) -> f32 {
    let corr = pearson_matrix(truth, found);
    let aligned = crate::linalg::lsa::lsa_max(&Mat::from_fn(corr.rows(), corr.cols(), |i, j| {
        corr[(i, j)].abs()
    }));
    let total: f32 = aligned.iter().enumerate().map(|(i, &j)| corr[(i, j)].abs()).sum();
    total / corr.rows() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn perfect_correlation() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn perfect_anticorrelation() {
        let x = [1.0, 2.0, 3.0];
        let y = [3.0, 2.0, 1.0];
        assert!((pearson(&x, &y) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn constant_vector_gives_zero() {
        let x = [1.0, 1.0, 1.0];
        let y = [1.0, 2.0, 3.0];
        assert_eq!(pearson(&x, &y), 0.0);
    }

    #[test]
    fn shift_and_scale_invariant() {
        let mut rng = Rng::new(60);
        let x: Vec<f32> = (0..50).map(|_| rng.uniform_f32()).collect();
        let y: Vec<f32> = x.iter().map(|&v| 3.0 * v + 7.0).collect();
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn independent_near_zero() {
        let mut rng = Rng::new(61);
        let x: Vec<f32> = (0..20_000).map(|_| rng.uniform_f32()).collect();
        let y: Vec<f32> = (0..20_000).map(|_| rng.uniform_f32()).collect();
        assert!(pearson(&x, &y).abs() < 0.03);
    }

    #[test]
    fn pearson_matrix_diag_of_self() {
        let mut rng = Rng::new(62);
        let a = Mat::random_uniform(30, 4, 0.0, 1.0, &mut rng);
        let c = pearson_matrix(&a, &a);
        for i in 0..4 {
            assert!((c[(i, i)] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn best_match_recovers_permuted_features() {
        let mut rng = Rng::new(63);
        let truth = Mat::random_uniform(40, 5, 0.0, 1.0, &mut rng);
        // found = truth with columns permuted and rescaled
        let perm = rng.permutation(5);
        let mut found = Mat::zeros(40, 5);
        for (i, &j) in perm.iter().enumerate() {
            let mut col = truth.col(i);
            col.iter_mut().for_each(|v| *v *= 2.5);
            found.set_col(j, &col);
        }
        let score = best_match_correlation(&truth, &found);
        assert!(score > 0.999, "score={score}");
    }
}
