//! Numerical building blocks: linear sum assignment, medians, Pearson
//! correlation, one-sided Jacobi SVD, and NNDSVD initialization.

pub mod lsa;
pub mod median;
pub mod nndsvd;
pub mod pearson;
pub mod svd;

pub use lsa::{lsa_max, lsa_min};
pub use median::{column_median, median_of};
pub use nndsvd::nndsvd_init;
pub use pearson::{pearson, pearson_matrix};
pub use svd::jacobi_svd;
