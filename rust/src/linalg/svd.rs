//! One-sided Jacobi SVD.
//!
//! Needed only at initialization time (NNDSVD, §3.4 of the paper), never on
//! the MU hot path, so a simple robust O(n·k²)-per-sweep Jacobi scheme is
//! plenty: it orthogonalizes the columns of A in place; singular values are
//! the resulting column norms, U the normalized columns, V the accumulated
//! rotations.

use crate::tensor::Mat;

/// Result of a thin SVD: `a ≈ u · diag(s) · vᵀ` with `u` m×r, `s` r, `v` n×r.
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub v: Mat,
}

/// One-sided Jacobi SVD of an m×n matrix (m ≥ n recommended; for m < n the
/// transpose is decomposed and factors swapped).
pub fn jacobi_svd(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        let t = jacobi_svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    let mut u = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 60;
    let tol = 1e-10f64;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2x2 gram entries
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for i in 0..m {
                    let up = u[(i, p)] as f64;
                    let uq = u[(i, q)] as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= tol * (app * aqq).sqrt() {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing apq
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[(i, p)] as f64;
                    let uq = u[(i, q)] as f64;
                    u[(i, p)] = (c * up - s * uq) as f32;
                    u[(i, q)] = (s * up + c * uq) as f32;
                }
                for i in 0..n {
                    let vp = v[(i, p)] as f64;
                    let vq = v[(i, q)] as f64;
                    v[(i, p)] = (c * vp - s * vq) as f32;
                    v[(i, q)] = (s * vp + c * vq) as f32;
                }
            }
        }
        if off < tol {
            break;
        }
    }
    // singular values = column norms of u; normalize u
    let mut s: Vec<f32> = Vec::with_capacity(n);
    for j in 0..n {
        let norm = (0..m).map(|i| (u[(i, j)] as f64).powi(2)).sum::<f64>().sqrt();
        s.push(norm as f32);
        if norm > 0.0 {
            for i in 0..m {
                u[(i, j)] = (u[(i, j)] as f64 / norm) as f32;
            }
        }
    }
    // sort by descending singular value
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| s[j].partial_cmp(&s[i]).unwrap());
    let us = Mat::from_fn(m, n, |i, j| u[(i, order[j])]);
    let vs = Mat::from_fn(n, n, |i, j| v[(i, order[j])]);
    let ss: Vec<f32> = order.iter().map(|&i| s[i]).collect();
    Svd { u: us, s: ss, v: vs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::{assert_close, property};

    fn reconstruct(svd: &Svd) -> Mat {
        let (m, r) = svd.u.shape();
        let n = svd.v.rows();
        let mut out = Mat::zeros(m, n);
        for j in 0..r {
            let sj = svd.s[j];
            for i in 0..m {
                let uij = svd.u[(i, j)] * sj;
                for l in 0..n {
                    out[(i, l)] += uij * svd.v[(l, j)];
                }
            }
        }
        out
    }

    #[test]
    fn reconstructs_random_matrices() {
        property(10, |rng| {
            let m = 3 + rng.below(12);
            let n = 2 + rng.below(m.min(8));
            let a = Mat::random_uniform(m, n, -1.0, 1.0, rng);
            let svd = jacobi_svd(&a);
            let rec = reconstruct(&svd);
            assert_close(rec.as_slice(), a.as_slice(), 1e-3);
        });
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        let mut rng = Rng::new(70);
        let a = Mat::random_uniform(20, 6, -1.0, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Rng::new(71);
        let a = Mat::random_uniform(25, 5, -1.0, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let g = svd.u.gram();
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g[(i, j)] - want).abs() < 1e-3, "g[{i},{j}]={}", g[(i, j)]);
            }
        }
    }

    #[test]
    fn v_columns_orthonormal() {
        let mut rng = Rng::new(72);
        let a = Mat::random_uniform(25, 5, -1.0, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let g = svd.v.gram();
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g[(i, j)] - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn wide_matrix_via_transpose() {
        let mut rng = Rng::new(73);
        let a = Mat::random_uniform(4, 9, -1.0, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let rec = reconstruct(&svd);
        assert_close(rec.as_slice(), a.as_slice(), 1e-3);
    }

    #[test]
    fn rank_one_matrix() {
        // a = x yᵀ has one nonzero singular value = |x||y|
        let x = [1.0f32, 2.0, 2.0];
        let y = [3.0f32, 4.0];
        let a = Mat::from_fn(3, 2, |i, j| x[i] * y[j]);
        let svd = jacobi_svd(&a);
        assert!((svd.s[0] - 15.0).abs() < 1e-3, "s0={}", svd.s[0]);
        assert!(svd.s[1].abs() < 1e-3);
    }
}
