//! Medians.
//!
//! The robust solution Ã for each k is the elementwise median over the r
//! aligned perturbation solutions (paper §2.3 step 3, Alg 5 line 11). The
//! median is local to each rank's row block, so no communication is needed.

use crate::tensor::Mat;

/// Median of a slice (destructive on a copy; averages the two middle
/// elements for even lengths).
pub fn median_of(xs: &[f32]) -> f32 {
    assert!(!xs.is_empty(), "median of empty slice");
    let mut v = xs.to_vec();
    let n = v.len();
    v.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Elementwise median across a stack of equally-shaped matrices.
pub fn matrix_median(stack: &[Mat]) -> Mat {
    assert!(!stack.is_empty());
    let (rows, cols) = stack[0].shape();
    assert!(stack.iter().all(|m| m.shape() == (rows, cols)));
    let mut out = Mat::zeros(rows, cols);
    let mut buf = vec![0f32; stack.len()];
    for i in 0..rows {
        for j in 0..cols {
            for (q, m) in stack.iter().enumerate() {
                buf[q] = m[(i, j)];
            }
            out[(i, j)] = median_of(&buf);
        }
    }
    out
}

/// Median across the third axis of an n×k×r stack given as r matrices —
/// alias of [`matrix_median`] matching the paper's `median(A')` notation.
pub fn column_median(perturbations: &[Mat]) -> Mat {
    matrix_median(perturbations)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::property;

    #[test]
    fn median_odd() {
        assert_eq!(median_of(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn median_even() {
        assert_eq!(median_of(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn median_single() {
        assert_eq!(median_of(&[7.0]), 7.0);
    }

    #[test]
    fn median_is_order_invariant() {
        property(20, |rng| {
            let n = 1 + rng.below(20);
            let xs: Vec<f32> = (0..n).map(|_| rng.uniform_f32()).collect();
            let m1 = median_of(&xs);
            let mut shuffled = xs.clone();
            rng.shuffle(&mut shuffled);
            assert_eq!(m1, median_of(&shuffled));
        });
    }

    #[test]
    fn median_bounded_by_extremes() {
        property(20, |rng| {
            let n = 1 + rng.below(15);
            let xs: Vec<f32> = (0..n).map(|_| rng.uniform_range(-5.0, 5.0)).collect();
            let m = median_of(&xs);
            let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(m >= lo && m <= hi);
        });
    }

    #[test]
    fn matrix_median_elementwise() {
        let a = Mat::from_vec(1, 2, vec![1.0, 10.0]);
        let b = Mat::from_vec(1, 2, vec![2.0, 30.0]);
        let c = Mat::from_vec(1, 2, vec![3.0, 20.0]);
        let m = matrix_median(&[a, b, c]);
        assert_eq!(m.as_slice(), &[2.0, 20.0]);
    }

    #[test]
    fn matrix_median_robust_to_outlier() {
        let mut rng = Rng::new(50);
        let base = Mat::random_uniform(4, 3, 0.0, 1.0, &mut rng);
        let mut outlier = base.clone();
        outlier.scale(100.0);
        // 4 copies of base + 1 outlier -> median == base
        let stack = vec![base.clone(), base.clone(), base.clone(), base.clone(), outlier];
        let m = matrix_median(&stack);
        crate::testing::assert_close(m.as_slice(), base.as_slice(), 1e-6);
    }
}
