//! Linear sum assignment (the Hungarian method, O(k³)).
//!
//! Algorithm 5 permutes the columns of each perturbation's A factor so the
//! latent communities align across perturbations; the permutation is the
//! assignment maximizing total cosine similarity to the current medoids
//! (paper §4.3 uses `LSA(G_q)` with an O(k³) bound, citing Burkard et al.).
//!
//! Implementation: the classic shortest-augmenting-path / potentials form
//! (Jonker–Volgenant style), solving the *minimization* problem; the
//! maximization entry point negates the cost matrix.

use crate::tensor::Mat;

/// Minimum-cost assignment of rows to columns of a square cost matrix.
/// Returns `perm` with `perm[row] = col`.
pub fn lsa_min(cost: &Mat) -> Vec<usize> {
    let n = cost.rows();
    assert_eq!(n, cost.cols(), "LSA needs a square cost matrix");
    if n == 0 {
        return Vec::new();
    }
    // Potentials + augmenting path over columns (1-indexed sentinel at 0).
    let inf = f64::INFINITY;
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    // p[j] = row assigned to column j (0 = none); j in 1..=n
    let mut p = vec![0usize; n + 1];
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[(i0 - 1, j - 1)] as f64 - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // augment along the path
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut perm = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            perm[p[j] - 1] = j - 1;
        }
    }
    perm
}

/// Maximum-total-similarity assignment: `perm[row] = col` maximizing
/// `Σ sim[(row, perm[row])]`.
pub fn lsa_max(sim: &Mat) -> Vec<usize> {
    let neg = Mat::from_fn(sim.rows(), sim.cols(), |i, j| -sim[(i, j)]);
    lsa_min(&neg)
}

/// Total cost of an assignment.
pub fn assignment_cost(cost: &Mat, perm: &[usize]) -> f64 {
    perm.iter().enumerate().map(|(i, &j)| cost[(i, j)] as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testing::property;

    /// Brute-force optimal assignment by permutation enumeration.
    fn brute_min(cost: &Mat) -> f64 {
        let n = cost.rows();
        let mut idx: Vec<usize> = (0..n).collect();
        let mut best = f64::INFINITY;
        permute(&mut idx, 0, &mut |p| {
            let c = assignment_cost(cost, p);
            if c < best {
                best = c;
            }
        });
        best
    }

    fn permute(xs: &mut Vec<usize>, i: usize, f: &mut impl FnMut(&[usize])) {
        if i == xs.len() {
            f(xs);
            return;
        }
        for j in i..xs.len() {
            xs.swap(i, j);
            permute(xs, i + 1, f);
            xs.swap(i, j);
        }
    }

    #[test]
    fn identity_cost_picks_diagonal() {
        // cost 0 on diagonal, 1 off-diagonal -> identity permutation
        let c = Mat::from_fn(4, 4, |i, j| if i == j { 0.0 } else { 1.0 });
        assert_eq!(lsa_min(&c), vec![0, 1, 2, 3]);
    }

    #[test]
    fn known_small_case() {
        // classic 3x3 example; optimal = 5 (0->1, 1->0, 2->2)
        let c = Mat::from_vec(3, 3, vec![4.0, 1.0, 3.0, 2.0, 0.0, 5.0, 3.0, 2.0, 2.0]);
        let p = lsa_min(&c);
        assert_eq!(assignment_cost(&c, &p), 5.0);
    }

    #[test]
    fn matches_bruteforce_random() {
        property(30, |rng| {
            let n = 2 + rng.below(5);
            let c = Mat::random_uniform(n, n, 0.0, 10.0, rng);
            let p = lsa_min(&c);
            // p must be a permutation
            let mut seen = vec![false; n];
            for &j in &p {
                assert!(!seen[j]);
                seen[j] = true;
            }
            let got = assignment_cost(&c, &p);
            let want = brute_min(&c);
            assert!((got - want).abs() < 1e-6, "got {got}, want {want}");
        });
    }

    #[test]
    fn max_is_min_of_negation() {
        let mut rng = Rng::new(44);
        let s = Mat::random_uniform(6, 6, -1.0, 1.0, &mut rng);
        let p = lsa_max(&s);
        let total: f64 = p.iter().enumerate().map(|(i, &j)| s[(i, j)] as f64).sum();
        // compare against brute force maximum
        let neg = Mat::from_fn(6, 6, |i, j| -s[(i, j)]);
        let want = -brute_min(&neg);
        assert!((total - want).abs() < 1e-6);
    }

    #[test]
    fn permutation_similarity_recovers_permutation() {
        // sim = permutation matrix -> lsa_max must recover it exactly
        let mut rng = Rng::new(45);
        for _ in 0..10 {
            let n = 3 + rng.below(6);
            let perm = rng.permutation(n);
            let s = Mat::from_fn(n, n, |i, j| if perm[i] == j { 1.0 } else { 0.0 });
            assert_eq!(lsa_max(&s), perm);
        }
    }

    #[test]
    fn single_element() {
        let c = Mat::from_vec(1, 1, vec![3.0]);
        assert_eq!(lsa_min(&c), vec![0]);
    }
}
