//! Automatic model selection (RESCALk): perturbation resampling, custom
//! clustering with LSA column alignment, silhouette statistics, and the
//! k-selection driver (paper Algorithms 1, 4, 5, 6 + §2.3).

pub mod clustering;
pub mod perturb;
pub mod regress;
pub mod rescalk;
pub mod selection;
pub mod silhouette;

pub use clustering::{custom_cluster_rank, ClusterOutput};
pub use perturb::perturb_tile;
pub use regress::regress_r_rank;
pub use rescalk::{nndsvd_factors, rescalk_rank, InitStrategy, KScore, RescalkConfig, RescalkResult};
pub use selection::KScoreRow;
pub use selection::{select_k, SelectionRule};
pub use silhouette::{silhouette_rank, Silhouettes};
