//! Robust core regression: recompute R on the **unperturbed** tensor with
//! the median Ã held fixed (paper §2.3 step 3 / Algorithm 1 line 9 —
//! "performing RESCAL updates for R").
//!
//! This reuses exactly the R-update path of Algorithm 3: per slice,
//! `XA` (row all_reduce), `AᵀXA` (column all_reduce), then multiplicative
//! updates of the replicated R with fixed AᵀA.

use crate::backend::Backend;
use crate::comm::grid::RankCtx;
use crate::comm::{CommOp, CommResult, Trace};
use crate::rescal::distmm::{broadcast_mat, dist_mm};
use crate::rescal::model::sigmoid;
use crate::rescal::{LocalTile, ModelKind};
use crate::tensor::ops::{mu_update, MU_EPS};
use crate::tensor::{Mat, Tensor3};

/// Given this rank's median row block `a_row` (replicated across its grid
/// row), derive `a_col` by diagonal broadcast and run `iters` R-update
/// sweeps of the given model family on the unperturbed tile. Returns the
/// replicated core R (k×k slices, or 1×k for the diagonal family).
#[allow(clippy::too_many_arguments)]
pub fn regress_r_rank(
    ctx: &RankCtx,
    tile: &LocalTile,
    a_row: &Mat,
    iters: usize,
    model: ModelKind,
    backend: &mut dyn Backend,
    trace: &mut Trace,
) -> CommResult<(Tensor3, Mat)> {
    let k = a_row.cols();
    let m = tile.m();
    // a_col from the diagonal of this rank's grid column (its width is the
    // tile's column count)
    let mut a_col = if ctx.is_diagonal() {
        a_row.clone()
    } else {
        Mat::zeros(tile.cols(), k)
    };
    broadcast_mat(&ctx.col_comm, ctx.col, &mut a_col, CommOp::ColumnBroadcast, trace)?;

    // replicated AᵀA
    let ata_partial = trace.record(CommOp::GramMul, 0, || backend.gram(&a_col));
    let ata = dist_mm(&ctx.row_comm, ata_partial, CommOp::RowReduce, trace)?;

    let core_rows = model.core_rows(k);
    let mut r =
        Tensor3::from_slices((0..m).map(|_| Mat::full(core_rows, k, 0.5)).collect());
    for t in 0..m {
        let xa_partial = tile.xa(t, &a_col, backend, trace);
        let xa = dist_mm(&ctx.row_comm, xa_partial, CommOp::RowReduce, trace)?;
        let atxa_partial = trace.record(CommOp::MatrixMul, 0, || backend.t_matmul(a_row, &xa));
        let atxa = dist_mm(&ctx.col_comm, atxa_partial, CommOp::ColumnReduce, trace)?;
        match model {
            ModelKind::Rescal => {
                for _ in 0..iters {
                    let rata =
                        trace.record(CommOp::MatrixMul, 0, || backend.matmul(r.slice(t), &ata));
                    let deno = trace.record(CommOp::MatrixMul, 0, || backend.matmul(&ata, &rata));
                    mu_update(r.slice_mut(t), &atxa, &deno, MU_EPS);
                }
            }
            ModelKind::DistMult => {
                // diagonal core: numerator diag(AᵀXA) is fixed across
                // sweeps; denominator d·(G∘G) refreshes per sweep
                let mut num_d = Mat::zeros(1, k);
                for j in 0..k {
                    num_d[(0, j)] = atxa[(j, j)];
                }
                let mut gg = ata.clone();
                gg.hadamard_assign(&ata);
                for _ in 0..iters {
                    let deno =
                        trace.record(CommOp::MatrixMul, 0, || backend.matmul(r.slice(t), &gg));
                    mu_update(r.slice_mut(t), &num_d, &deno, MU_EPS);
                }
            }
            ModelKind::Logistic => {
                // the denominator Aᵀσ(AR_tAᵀ)A depends on R_t, so each
                // sweep rebuilds the local sigmoid reconstruction tile and
                // reduces S·A / AᵀSA like the training loop does
                for _ in 0..iters {
                    let ar =
                        trace.record(CommOp::MatrixMul, 0, || backend.matmul(a_row, r.slice(t)));
                    let mut s =
                        trace.record(CommOp::MatrixMul, 0, || backend.matmul_t(&ar, &a_col));
                    for v in s.as_mut_slice() {
                        *v = sigmoid(*v);
                    }
                    let sa_partial =
                        trace.record(CommOp::MatrixMul, 0, || backend.matmul(&s, &a_col));
                    let sa = dist_mm(&ctx.row_comm, sa_partial, CommOp::RowReduce, trace)?;
                    let atsa_partial =
                        trace.record(CommOp::MatrixMul, 0, || backend.t_matmul(a_row, &sa));
                    let atsa = dist_mm(&ctx.col_comm, atsa_partial, CommOp::ColumnReduce, trace)?;
                    mu_update(r.slice_mut(t), &atxa, &atsa, MU_EPS);
                }
            }
        }
    }
    Ok((r, a_col))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::comm::grid::run_on_grid;
    use crate::data::synthetic;

    /// With A fixed at the truth, R regression must reconstruct X well.
    #[test]
    fn recovers_core_given_true_a() {
        let planted = synthetic::block_tensor(16, 3, 2, 0.001, 600);
        let x = planted.x.clone();
        let a_true = planted.a_true.clone();
        let n = 16;
        let results = run_on_grid(4, |ctx| {
            let (r0, r1) = ctx.grid.chunk(n, ctx.row);
            let (c0, c1) = ctx.grid.chunk(n, ctx.col);
            let tile = LocalTile::Dense(x.tile(r0, r1, c0, c1));
            let a_row = Mat::from_fn(r1 - r0, 2, |i, j| a_true[(r0 + i, j)]);
            let mut backend = NativeBackend::new();
            let mut trace = Trace::new();
            let (r, _a_col) = regress_r_rank(
                &ctx, &tile, &a_row, 60, ModelKind::Rescal, &mut backend, &mut trace,
            )
            .unwrap();
            r
        });
        // all ranks agree on the replicated R
        for w in results.windows(2) {
            for t in 0..3 {
                crate::testing::assert_close(
                    w[0].slice(t).as_slice(),
                    w[1].slice(t).as_slice(),
                    1e-5,
                );
            }
        }
        // and the reconstruction from (A_true, R) is accurate
        let err = x.rel_error(&a_true, &results[0]);
        assert!(err < 0.05, "rel_error={err}");
    }
}
