//! Algorithm 4: distributed resampling.
//!
//! Each rank perturbs its own tile elementwise by U[1−δ, 1+δ] with a seed
//! that is a function of (experiment seed, rank, perturbation index) — the
//! paper's per-rank unique-seed scheme (§6.1.3). The ensemble mean is the
//! original tensor; no communication is involved. For sparse tiles only
//! stored nonzeros are perturbed, preserving the pattern.

use crate::rescal::LocalTile;
use crate::rng::Rng;

/// Perturbation-index RNG stream id (keeps factor-init and noise streams
/// separate).
const PERTURB_STREAM: u64 = 0x7e27;

/// Perturb a rank's tile for perturbation `q`.
pub fn perturb_tile(tile: &LocalTile, delta: f32, seed: u64, rank: usize, q: usize) -> LocalTile {
    let mut rng = Rng::for_rank(seed ^ PERTURB_STREAM, rank, q as u64);
    tile.perturb(delta, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor3;

    fn dense_tile(seed: u64) -> LocalTile {
        let mut rng = Rng::new(seed);
        LocalTile::Dense(Tensor3::random_uniform(8, 8, 2, 0.5, 1.0, &mut rng))
    }

    fn as_dense(t: &LocalTile) -> &Tensor3 {
        match t {
            LocalTile::Dense(x) => x,
            _ => panic!("dense expected"),
        }
    }

    #[test]
    fn ensemble_mean_approaches_original() {
        let tile = dense_tile(300);
        let x = as_dense(&tile);
        let r = 400;
        let mut acc = Tensor3::zeros(8, 8, 2);
        for q in 0..r {
            let p = perturb_tile(&tile, 0.03, 99, 0, q);
            let px = as_dense(&p);
            for t in 0..2 {
                acc.slice_mut(t).add_assign(px.slice(t));
            }
        }
        for t in 0..2 {
            for (sum, orig) in acc.slice(t).as_slice().iter().zip(x.slice(t).as_slice()) {
                let mean = sum / r as f32;
                assert!((mean / orig - 1.0).abs() < 0.01, "mean {mean} vs {orig}");
            }
        }
    }

    #[test]
    fn different_q_different_noise() {
        let tile = dense_tile(301);
        let p0 = perturb_tile(&tile, 0.03, 7, 0, 0);
        let p1 = perturb_tile(&tile, 0.03, 7, 0, 1);
        assert_ne!(as_dense(&p0).slice(0), as_dense(&p1).slice(0));
    }

    #[test]
    fn different_rank_different_noise() {
        let tile = dense_tile(302);
        let p0 = perturb_tile(&tile, 0.03, 7, 0, 0);
        let p1 = perturb_tile(&tile, 0.03, 7, 1, 0);
        assert_ne!(as_dense(&p0).slice(0), as_dense(&p1).slice(0));
    }

    #[test]
    fn deterministic_replay() {
        let tile = dense_tile(303);
        let p0 = perturb_tile(&tile, 0.02, 11, 3, 5);
        let p1 = perturb_tile(&tile, 0.02, 11, 3, 5);
        assert_eq!(as_dense(&p0).slice(1), as_dense(&p1).slice(1));
    }
}
