//! Algorithm 1: the RESCALk driver — RESCAL with automatic model
//! selection, executed per rank inside the 2D grid.
//!
//! For each k in `[k_min, k_max]`: perturb the tile r times (Alg 4),
//! factorize each perturbation (Alg 3), align the r solutions (Alg 5),
//! score cluster stability (Alg 6), regress the robust core on the
//! unperturbed tensor, and evaluate the reconstruction error. The scores
//! feed [`super::selection::select_k`].

use crate::backend::{Backend, Workspace, WorkspaceStats};
use crate::comm::grid::RankCtx;
use crate::comm::{CommResult, Trace};
use crate::rescal::distributed::{rescal_rank, DistInit, DistRescalConfig};
use crate::rescal::{LocalTile, ModelKind, RescalOptions};
use crate::tensor::{Mat, Tensor3};

use super::clustering::custom_cluster_rank;
use super::perturb::perturb_tile;
use super::regress::regress_r_rank;
use super::selection::{select_k, KScoreRow, SelectionRule};
use super::silhouette::silhouette_rank;

/// Re-export under the paper's name.
pub type KScore = KScoreRow;

/// How each perturbation's factorization is initialized (paper §6.1.3
/// offers exactly these two options).
#[derive(Clone)]
pub enum InitStrategy {
    /// Fresh random factors per (k, q) — the default.
    Random,
    /// NNDSVD factors per k (computed once by the coordinator from the
    /// unperturbed tensor, paper §3.4: "custom NNDSVD-based initialization
    /// leads to a faster convergence"), jittered per perturbation by
    /// `U[1±jitter]` so the ensemble still probes solution stability.
    /// The map holds the full-height factors per k.
    Nndsvd {
        factors: std::sync::Arc<std::collections::BTreeMap<usize, (Mat, Tensor3)>>,
        jitter: f32,
    },
}

/// RESCALk sweep configuration.
#[derive(Clone)]
pub struct RescalkConfig {
    /// Inclusive k range to explore.
    pub k_min: usize,
    pub k_max: usize,
    /// Number of perturbations r.
    pub perturbations: usize,
    /// Perturbation noise δ (paper: 0.005–0.03).
    pub delta: f32,
    /// MU iterations per factorization.
    pub rescal_iters: usize,
    /// Early-stop tolerance on the relative error (0 = run all
    /// iterations). Converged runs stop early, which both saves time and
    /// stabilizes the perturbation ensemble at k ≥ k_true.
    pub tol: f32,
    /// How often (iterations) to evaluate the error when `tol > 0`.
    pub err_every: usize,
    /// R-regression sweeps for the robust core.
    pub regress_iters: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Selection rule for k_opt.
    pub rule: SelectionRule,
    /// Factor initialization strategy.
    pub init: InitStrategy,
    /// Model family every factorization (and the core regression) runs
    /// under. NNDSVD initialization is Gaussian-only; the engine rejects
    /// the combination before any rank sees it.
    pub model: ModelKind,
}

impl Default for RescalkConfig {
    fn default() -> Self {
        RescalkConfig {
            k_min: 2,
            k_max: 8,
            perturbations: 10,
            delta: 0.02,
            rescal_iters: 200,
            tol: 0.0,
            err_every: 25,
            regress_iters: 30,
            seed: 42,
            rule: SelectionRule::default(),
            init: InitStrategy::Random,
            model: ModelKind::Rescal,
        }
    }
}

/// Precompute NNDSVD factors for every k in the sweep from the full
/// (unperturbed) tensor — done once by the coordinator/leader before the
/// grid spawns. Substitution note (DESIGN.md §3): the paper computes this
/// through pyDNMFk's distributed SVD; here the leader holds the tensor
/// anyway, so a central NNDSVD is faithful and simpler.
pub fn nndsvd_factors(
    x: &Tensor3,
    k_min: usize,
    k_max: usize,
) -> std::sync::Arc<std::collections::BTreeMap<usize, (Mat, Tensor3)>> {
    let mut map = std::collections::BTreeMap::new();
    let mut rng = crate::rng::Rng::new(0);
    for k in k_min..=k_max {
        let (a, r) = crate::rescal::Init::Nndsvd.materialize(x, k, &mut rng);
        map.insert(k, (a, r));
    }
    std::sync::Arc::new(map)
}

/// Per-rank result of the sweep.
pub struct RescalkResult {
    /// One score row per explored k.
    pub scores: Vec<KScore>,
    /// Selected k (identical on all ranks).
    pub k_opt: usize,
    /// Robust Ã row block for k_opt.
    pub a_opt_row: Mat,
    /// Robust core for k_opt (replicated).
    pub r_opt: Tensor3,
    /// Workspace checkout counters across the whole sweep (delta): a
    /// warm rank re-running the same sweep reports zero allocs.
    pub workspace: WorkspaceStats,
}

/// Run the full model-selection sweep on this rank's tile. `n` is the
/// global entity count.
pub fn rescalk_rank(
    ctx: &RankCtx,
    tile: &LocalTile,
    n: usize,
    cfg: &RescalkConfig,
    backend: &mut dyn Backend,
    ws: &mut Workspace,
    trace: &mut Trace,
) -> CommResult<RescalkResult> {
    assert!(cfg.k_min >= 1 && cfg.k_min <= cfg.k_max);
    assert!(cfg.perturbations >= 1);
    let ws_before = ws.stats();
    let mut scores = Vec::new();
    let mut per_k: Vec<(Mat, Tensor3)> = Vec::new();
    for k in cfg.k_min..=cfg.k_max {
        // ---- r perturbed factorizations (Alg 1 lines 2-5) ----
        let mut stack: Vec<Mat> = Vec::with_capacity(cfg.perturbations);
        for q in 0..cfg.perturbations {
            let perturbed = perturb_tile(tile, cfg.delta, cfg.seed, ctx.rank, q);
            // same init on every rank for a given (seed, k, q)
            let init = match &cfg.init {
                InitStrategy::Random => DistInit::Random {
                    seed: cfg
                        .seed
                        .wrapping_add((k as u64) << 32)
                        .wrapping_add(q as u64 + 1),
                },
                InitStrategy::Nndsvd { factors, jitter } => {
                    let (a0, r0) = factors
                        .get(&k)
                        .expect("NNDSVD factors missing for explored k");
                    // identical jitter stream on every rank
                    let mut jrng =
                        crate::rng::Rng::for_rank(cfg.seed ^ 0x4e4e_d5fd, k, q as u64);
                    let mut a = a0.clone();
                    for v in a.as_mut_slice() {
                        *v *= jrng.uniform_range(1.0 - jitter, 1.0 + jitter);
                    }
                    let mut r = r0.clone();
                    for t in 0..r.m() {
                        for v in r.slice_mut(t).as_mut_slice() {
                            *v *= jrng.uniform_range(1.0 - jitter, 1.0 + jitter);
                        }
                    }
                    DistInit::Given(std::sync::Arc::new(a), std::sync::Arc::new(r))
                }
            };
            let dist_cfg = DistRescalConfig {
                opts: RescalOptions::new(k, cfg.rescal_iters)
                    .with_tol(cfg.tol, if cfg.tol > 0.0 { cfg.err_every.max(1) } else { 0 }),
                init,
                n,
                model: cfg.model,
            };
            let out = rescal_rank(ctx, &perturbed, &dist_cfg, backend, ws, trace)?;
            stack.push(out.a_row);
        }
        // ---- align solutions (Alg 1 line 6, Alg 5) ----
        let clustered = custom_cluster_rank(&ctx.col_comm, &stack, 100, trace)?;
        // ---- cluster stability (line 8, Alg 6) ----
        let sil = silhouette_rank(&ctx.col_comm, &clustered.aligned, trace)?;
        // ---- robust core + reconstruction error (lines 7, 9, 10) ----
        let (r_reg, a_col) = regress_r_rank(
            ctx, tile, &clustered.median, cfg.regress_iters, cfg.model, backend, trace,
        )?;
        let rel_error = rel_error_rank(
            ctx, tile, &clustered.median, &a_col, &r_reg, cfg.model, backend, trace,
        )?;
        scores.push(KScore { k, sil_min: sil.min, sil_avg: sil.avg, rel_error });
        per_k.push((clustered.median, r_reg));
    }
    let k_opt = select_k(&scores, cfg.rule).expect("non-empty sweep");
    let idx = k_opt - cfg.k_min;
    let (a_opt_row, r_opt) = per_k.swap_remove(idx);
    Ok(RescalkResult { scores, k_opt, a_opt_row, r_opt, workspace: ws.stats().since(ws_before) })
}

/// Distributed relative reconstruction error for explicit factors,
/// against the model family's reconstruction.
#[allow(clippy::too_many_arguments)]
fn rel_error_rank(
    ctx: &RankCtx,
    tile: &LocalTile,
    a_row: &Mat,
    a_col: &Mat,
    r: &Tensor3,
    model: ModelKind,
    backend: &mut dyn Backend,
    trace: &mut Trace,
) -> CommResult<f32> {
    let mut local = 0.0f64;
    for t in 0..tile.m() {
        local += model.slice_residual_sq(tile, t, a_row, r.slice(t), a_col, backend, trace);
    }
    let mut buf = vec![local as f32, tile.norm_sq() as f32];
    ctx.world.all_reduce_sum(&mut buf)?;
    Ok(((buf[0] as f64).max(0.0).sqrt() / (buf[1] as f64).max(1e-300).sqrt()) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::comm::grid::run_on_grid;
    use crate::data::synthetic;

    /// The flagship correctness property (paper §6.2.1): RESCALk recovers
    /// the planted k on block-structured data.
    #[test]
    fn recovers_planted_k() {
        let planted = synthetic::block_tensor(24, 3, 3, 0.01, 700);
        let x = planted.x.clone();
        let cfg = RescalkConfig {
            k_min: 2,
            k_max: 5,
            perturbations: 6,
            delta: 0.02,
            rescal_iters: 150,
            tol: 0.0,
            err_every: 25,
            regress_iters: 30,
            seed: 1,
            rule: SelectionRule::default(),
            init: InitStrategy::Random,
            model: ModelKind::Rescal,
        };
        let results = run_on_grid(4, |ctx| {
            let (r0, r1) = ctx.grid.chunk(24, ctx.row);
            let (c0, c1) = ctx.grid.chunk(24, ctx.col);
            let tile = LocalTile::Dense(x.tile(r0, r1, c0, c1));
            let mut backend = NativeBackend::new();
            let mut ws = Workspace::new();
            let mut trace = Trace::disabled();
            rescalk_rank(&ctx, &tile, 24, &cfg, &mut backend, &mut ws, &mut trace)
                .expect("in-process rescalk_rank")
        });
        for res in &results {
            assert_eq!(res.k_opt, 3, "scores: {:?}", res.scores);
            // silhouette at k_true should be high, error low
            let at_true = res.scores.iter().find(|s| s.k == 3).unwrap();
            assert!(at_true.sil_min > 0.75, "sil={}", at_true.sil_min);
            assert!(at_true.rel_error < 0.12, "err={}", at_true.rel_error);
        }
        // ranks agree
        assert_eq!(results[0].k_opt, results[3].k_opt);
    }

    #[test]
    fn error_decreases_with_k_and_silhouette_drops_past_truth() {
        let planted = synthetic::block_tensor(20, 2, 2, 0.01, 701);
        let x = planted.x.clone();
        let cfg = RescalkConfig {
            k_min: 1,
            k_max: 4,
            perturbations: 5,
            delta: 0.02,
            rescal_iters: 120,
            tol: 0.0,
            err_every: 25,
            regress_iters: 25,
            seed: 2,
            rule: SelectionRule::default(),
            init: InitStrategy::Random,
            model: ModelKind::Rescal,
        };
        let results = run_on_grid(1, |ctx| {
            let tile = LocalTile::Dense(x.clone());
            let mut backend = NativeBackend::new();
            let mut ws = Workspace::new();
            let mut trace = Trace::disabled();
            rescalk_rank(&ctx, &tile, 20, &cfg, &mut backend, &mut ws, &mut trace)
                .expect("in-process rescalk_rank")
        });
        let scores = &results[0].scores;
        // error at k>=2 well below error at k=1
        let e1 = scores.iter().find(|s| s.k == 1).unwrap().rel_error;
        let e2 = scores.iter().find(|s| s.k == 2).unwrap().rel_error;
        assert!(e2 < e1 * 0.7, "e1={e1}, e2={e2}");
        // silhouette at k=2 (truth) above k=4 (overfit)
        let s2 = scores.iter().find(|s| s.k == 2).unwrap().sil_min;
        let s4 = scores.iter().find(|s| s.k == 4).unwrap().sil_min;
        assert!(s2 > s4, "s2={s2}, s4={s4}");
    }
}
