//! The k_opt decision rule (paper §2.3 step 6, §6.2.1).
//!
//! "k_opt is determined as the maximum number of stable clusters
//! corresponding to a good accuracy of the reconstruction": high minimum
//! silhouette, low relative error, and the largest separation between the
//! silhouette and error series (the criterion of Vangara et al. [63]).

/// Scores for one explored k.
#[derive(Clone, Debug)]
pub struct KScoreRow {
    pub k: usize,
    pub sil_min: f32,
    pub sil_avg: f32,
    pub rel_error: f32,
}

/// Selection rule variants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SelectionRule {
    /// Largest k whose minimum silhouette stays above the threshold
    /// (default 0.75) — the shape of Fig 5: silhouettes ≈ 1 up to k_true,
    /// then collapse.
    StableThreshold { threshold: f32 },
    /// Maximize separability `sil_min − rel_error` (the [63] criterion),
    /// breaking ties toward larger k.
    MaxSeparation,
    /// Among stable k (sil_min ≥ threshold), pick the largest k whose
    /// reconstruction error still improves by at least `min_gain`
    /// (relative) over the previous stable k — the error-elbow reading of
    /// the paper's "maximum number of stable clusters corresponding to a
    /// good accuracy of the reconstruction". Used when an NNDSVD-seeded
    /// ensemble keeps every k stable, so the error curve must decide.
    StableElbow { threshold: f32, min_gain: f32 },
}

impl Default for SelectionRule {
    fn default() -> Self {
        SelectionRule::StableThreshold { threshold: 0.75 }
    }
}

/// Pick k_opt from the explored scores. Returns `None` for an empty sweep.
pub fn select_k(scores: &[KScoreRow], rule: SelectionRule) -> Option<usize> {
    if scores.is_empty() {
        return None;
    }
    match rule {
        SelectionRule::StableThreshold { threshold } => {
            // largest stable k; fall back to max separation when nothing
            // clears the bar (very noisy data)
            scores
                .iter()
                .filter(|s| s.sil_min >= threshold)
                .map(|s| s.k)
                .max()
                .or_else(|| select_k(scores, SelectionRule::MaxSeparation))
        }
        SelectionRule::MaxSeparation => {
            let best = scores
                .iter()
                .max_by(|a, b| {
                    let sa = a.sil_min - a.rel_error;
                    let sb = b.sil_min - b.rel_error;
                    sa.partial_cmp(&sb).unwrap().then(a.k.cmp(&b.k))
                })
                .unwrap();
            Some(best.k)
        }
        SelectionRule::StableElbow { threshold, min_gain } => {
            let stable: Vec<&KScoreRow> =
                scores.iter().filter(|s| s.sil_min >= threshold).collect();
            if stable.is_empty() {
                return select_k(scores, SelectionRule::MaxSeparation);
            }
            // walk the stable ks in order; keep advancing while the error
            // improves by at least min_gain relative to the previous one
            let mut best = stable[0];
            for s in &stable[1..] {
                if s.rel_error <= best.rel_error * (1.0 - min_gain) {
                    best = s;
                }
            }
            Some(best.k)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(k: usize, sil: f32, err: f32) -> KScoreRow {
        KScoreRow { k, sil_min: sil, sil_avg: sil, rel_error: err }
    }

    #[test]
    fn picks_largest_stable_k() {
        // classic Fig-5 shape: stable through k=7, collapse after
        let scores = vec![
            row(5, 0.99, 0.25),
            row(6, 0.97, 0.12),
            row(7, 0.95, 0.02),
            row(8, 0.30, 0.02),
            row(9, 0.10, 0.015),
        ];
        assert_eq!(select_k(&scores, SelectionRule::default()), Some(7));
    }

    #[test]
    fn falls_back_when_nothing_stable() {
        let scores = vec![row(2, 0.5, 0.4), row(3, 0.6, 0.2), row(4, 0.4, 0.19)];
        // fallback = max separation: k=3 (0.6-0.2=0.4 beats 0.1 and 0.21)
        assert_eq!(
            select_k(&scores, SelectionRule::StableThreshold { threshold: 0.9 }),
            Some(3)
        );
    }

    #[test]
    fn max_separation_rule() {
        let scores = vec![row(2, 0.9, 0.5), row(3, 0.95, 0.05), row(4, 0.2, 0.04)];
        assert_eq!(select_k(&scores, SelectionRule::MaxSeparation), Some(3));
    }

    #[test]
    fn empty_is_none() {
        assert_eq!(select_k(&[], SelectionRule::default()), None);
    }

    #[test]
    fn stable_elbow_finds_error_plateau() {
        // NNDSVD-style sweep: everything stable, error elbows at k=5
        let scores = vec![
            row(2, 1.0, 0.34),
            row(3, 1.0, 0.20),
            row(4, 1.0, 0.15),
            row(5, 1.0, 0.056),
            row(6, 0.99, 0.055),
            row(7, 0.99, 0.054),
        ];
        let rule = SelectionRule::StableElbow { threshold: 0.8, min_gain: 0.10 };
        assert_eq!(select_k(&scores, rule), Some(5));
    }

    #[test]
    fn stable_elbow_ignores_unstable_k() {
        let scores = vec![row(2, 1.0, 0.3), row(3, 0.2, 0.05), row(4, 1.0, 0.28)];
        let rule = SelectionRule::StableElbow { threshold: 0.8, min_gain: 0.10 };
        // k=3 is unstable; k=4's error is within 10% of k=2's -> k=2
        assert_eq!(select_k(&scores, rule), Some(2));
    }

    #[test]
    fn ties_break_to_larger_k() {
        let scores = vec![row(2, 0.9, 0.1), row(3, 0.9, 0.1)];
        assert_eq!(select_k(&scores, SelectionRule::MaxSeparation), Some(3));
    }
}
