//! Algorithm 5: distributed custom clustering with equal cluster size.
//!
//! Aligns the columns of the r perturbation solutions `A^q` so that column
//! c of every solution describes the same latent community. Each iteration
//! computes the medoid-to-solution similarity `G` (local partial `MᵀA_q`
//! per row block, summed over the column sub-communicator), solves a
//! linear sum assignment per perturbation to find the best column
//! permutation, permutes, and refreshes the medoid with the elementwise
//! median. Converges when every assignment is the identity.

use crate::comm::{CommOp, CommResult, Group, Trace};
use crate::linalg::lsa::lsa_max;
use crate::linalg::median::matrix_median;
use crate::tensor::Mat;

/// Output of clustering one rank's row-block stack.
pub struct ClusterOutput {
    /// Aligned per-perturbation row blocks (columns permuted).
    pub aligned: Vec<Mat>,
    /// Elementwise median of the aligned stack — the robust Ã row block.
    pub median: Mat,
    /// Column permutation applied to each perturbation
    /// (`perm[q][c]` = source column of solution q that became column c).
    pub perms: Vec<Vec<usize>>,
    /// Clustering iterations executed.
    pub iters: usize,
}

/// Run distributed custom clustering over this rank's stack of r row
/// blocks (each `n_local × k`). `comm` must contain exactly one rank per
/// row block (the column sub-communicator in the 2D grid, or the world
/// group of a dedicated 1D grid).
pub fn custom_cluster_rank(
    comm: &Group,
    stack: &[Mat],
    max_iters: usize,
    trace: &mut Trace,
) -> CommResult<ClusterOutput> {
    let r = stack.len();
    assert!(r >= 1, "need at least one perturbation");
    let (n_local, k) = stack[0].shape();
    assert!(stack.iter().all(|m| m.shape() == (n_local, k)), "ragged stack");

    let mut aligned: Vec<Mat> = stack.to_vec();
    // line 1: medoid initialized from the first perturbation
    let mut medoid = aligned[0].clone();
    let mut perms: Vec<Vec<usize>> = vec![(0..k).collect(); r];
    let mut iters = 0;
    for _ in 0..max_iters {
        iters += 1;
        // lines 3-5: partial similarity D_q = Mᵀ A_q per row block;
        // flattened into one buffer so a single all_reduce covers all q
        // (one collective per iteration, as in the paper).
        let mut g_buf = vec![0f32; k * k * r];
        for (q, a_q) in aligned.iter().enumerate() {
            let d = trace.record(CommOp::Clustering, 0, || medoid.t_matmul(a_q));
            g_buf[q * k * k..(q + 1) * k * k].copy_from_slice(d.as_slice());
        }
        // line 6: total similarity G via all_reduce
        trace.record_comm(CommOp::ColumnReduce, comm, || comm.all_reduce_sum(&mut g_buf))?;
        // lines 7-10: LSA per perturbation, permute columns
        let mut all_identity = true;
        for q in 0..r {
            let g_q = Mat::from_vec(k, k, g_buf[q * k * k..(q + 1) * k * k].to_vec());
            let porder = lsa_max(&g_q); // porder[medoid col] = solution col
            if porder.iter().enumerate().any(|(i, &j)| i != j) {
                all_identity = false;
                let src = aligned[q].clone();
                for (dst_col, &src_col) in porder.iter().enumerate() {
                    let col = src.col(src_col);
                    aligned[q].set_col(dst_col, &col);
                }
                // compose permutations for reporting
                let prev = perms[q].clone();
                for (dst_col, &src_col) in porder.iter().enumerate() {
                    perms[q][dst_col] = prev[src_col];
                }
            }
        }
        // lines 11-12: medoid = elementwise median of the aligned stack
        medoid = trace.record(CommOp::Clustering, 0, || matrix_median(&aligned));
        if all_identity {
            break;
        }
    }
    Ok(ClusterOutput { aligned, median: medoid, perms, iters })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::grid::run_on_grid;
    use crate::rng::Rng;
    use crate::testing::assert_close;

    /// Build r shuffled/noisy copies of a ground-truth A, shard them into
    /// row blocks, cluster distributedly, and check the alignment.
    #[test]
    fn aligns_permuted_solutions_distributed() {
        let n = 24;
        let k = 4;
        let r = 6;
        let mut rng = Rng::new(400);
        let truth = Mat::random_uniform(n, k, 0.1, 1.0, &mut rng);
        // per-perturbation column permutations + small noise
        let perms: Vec<Vec<usize>> = (0..r).map(|_| rng.permutation(k)).collect();
        let solutions: Vec<Mat> = (0..r)
            .map(|q| {
                let mut m = Mat::zeros(n, k);
                for c in 0..k {
                    // solution column perms[q][c] holds truth column c
                    let mut col = truth.col(c);
                    for v in col.iter_mut() {
                        *v *= 1.0 + 0.02 * (rng.uniform_f32() - 0.5);
                    }
                    m.set_col(perms[q][c], &col);
                }
                m
            })
            .collect();
        let p = 4; // 2x2 grid; col comm spans both row blocks
        let results = run_on_grid(p, |ctx| {
            let (s, e) = ctx.grid.chunk(n, ctx.row);
            let stack: Vec<Mat> = solutions
                .iter()
                .map(|m| Mat::from_fn(e - s, k, |i, j| m[(s + i, j)]))
                .collect();
            let mut trace = Trace::new();
            let out = custom_cluster_rank(&ctx.col_comm, &stack, 50, &mut trace).unwrap();
            (ctx.row, ctx.col, out)
        });
        // after alignment all perturbations should agree elementwise
        for (row, _col, out) in &results {
            let first = &out.aligned[0];
            for q in 1..r {
                assert_close(out.aligned[q].as_slice(), first.as_slice(), 0.05);
            }
            // median close to the truth block (up to a global column perm
            // fixed by perturbation 0's layout)
            let grid = crate::comm::Grid::new(p);
            let (s, e) = grid.chunk(n, *row);
            // aligned columns follow solutions[0]'s ordering
            for c in 0..k {
                let truth_col_idx =
                    (0..k).find(|&tc| perms[0][tc] == c).expect("perm inverse");
                let want: Vec<f32> = (s..e).map(|i| truth[(i, truth_col_idx)]).collect();
                let got = out.median.col(c);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 0.05, "median col {c}: {g} vs {w}");
                }
            }
        }
        // all grid columns must agree (replicated computation)
        let m00 = &results[0].2.median;
        let m01 = &results[1].2.median;
        assert_close(m00.as_slice(), m01.as_slice(), 1e-6);
    }

    #[test]
    fn identity_when_already_aligned() {
        let mut rng = Rng::new(401);
        let a = Mat::random_uniform(10, 3, 0.1, 1.0, &mut rng);
        let stack = vec![a.clone(), a.clone(), a.clone()];
        let groups = Group::create(1);
        let mut trace = Trace::new();
        let out = custom_cluster_rank(&groups[0], &stack, 20, &mut trace).unwrap();
        assert_eq!(out.iters, 1); // converges immediately
        for p in &out.perms {
            assert_eq!(*p, vec![0, 1, 2]);
        }
        assert_close(out.median.as_slice(), a.as_slice(), 1e-6);
    }

    #[test]
    fn single_perturbation_is_its_own_median() {
        let mut rng = Rng::new(402);
        let a = Mat::random_uniform(8, 2, 0.1, 1.0, &mut rng);
        let groups = Group::create(1);
        let mut trace = Trace::new();
        let out = custom_cluster_rank(&groups[0], &[a.clone()], 20, &mut trace).unwrap();
        assert_close(out.median.as_slice(), a.as_slice(), 1e-6);
    }
}
